"""Quickstart: MULTI-BULYAN in 60 seconds.

1. aggregate a stack of gradients containing byzantine rows;
2. run one byzantine-robust distributed train step on a small LM.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import aggregate, apply_attack, theory
from repro.data import lm_batches
from repro.dist import init_train_state, make_train_step, split_workers
from repro import models as MD
from repro.optim import sgd, constant


def part1_gar():
    print("=== 1. the GAR itself ===")
    n, f, d = 15, 3, 1000
    rng = np.random.default_rng(0)
    g_true = np.ones(d, np.float32)                      # the true gradient
    correct = g_true + 0.1 * rng.normal(size=(n - f, d)).astype(np.float32)
    stack = apply_attack(jnp.asarray(correct), f, "inf",
                         jax.random.key(0))              # f byzantine rows
    for rule in ("average", "median", "multi_krum", "multi_bulyan"):
        agg = aggregate(stack, f, rule)
        cos = theory.cone_cosine(agg, jnp.asarray(g_true))
        print(f"  {rule:13s} cos(angle to true gradient) = {cos:+.3f}")
    print(f"  theory: multi-bulyan slowdown vs averaging = "
          f"{theory.multi_bulyan_slowdown(n, f):.2f} "
          f"(Thm 2(iii) — and it is byzantine-proof)")


def part2_training():
    print("=== 2. robust distributed training ===")
    cfg = ArchConfig(name="quickstart", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab_size=128)
    rcfg = RobustConfig(n_workers=11, f=2, gar="multi_bulyan")
    key = jax.random.key(0)
    params = MD.init_model(key, cfg)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)   # the named TrainerState pytree
    step = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.05),
                                   chunk_q=16, attack="inf"))
    data = lm_batches(cfg.vocab_size, 22, 16)
    for i in range(8):
        batch = split_workers(next(data), rcfg.n_workers)
        params, state, m = step(params, state, batch, jax.random.fold_in(key, i))
        print(f"  step {i}: loss={float(m['loss']):.4f}  "
              f"(2 byzantine workers sending 1e30s — training unharmed)")


if __name__ == "__main__":
    part1_gar()
    part2_training()
