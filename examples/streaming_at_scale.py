"""Streaming Multi-Bulyan: exact Algorithm-1 robustness at 100B+ scale.

The paper's GAR needs all n worker gradients at once — impossible at
jamba-398B scale (DESIGN.md §5).  This example demonstrates, on a small
model where both paths fit, that the streaming-global trainer (two manual
backward passes, per-block plan application) produces bit-close updates to
the stacked reference — the property that lets the dry-run lower
jamba-1.5-large-398b×train_4k on 512 chips.

Run:  PYTHONPATH=src python examples/streaming_at_scale.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig, RobustConfig, SSMConfig, HybridConfig
from repro.data import lm_batches
from repro.dist import init_train_state, make_train_step, split_workers
from repro.dist.streaming import make_streaming_train_step
from repro import models as MD
from repro.optim import sgd, constant


def main():
    # a miniature jamba: hybrid attn/mamba with MoE every other layer
    cfg = ArchConfig(
        name="mini-jamba", family="hybrid", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, every=2,
                      capacity_factor=8.0),
        ssm=SSMConfig(dt_rank=8),
        hybrid=HybridConfig(period=2, attn_index=1))
    rcfg = RobustConfig(n_workers=11, f=2, gar="multi_bulyan")
    key = jax.random.key(0)
    params = MD.init_model(key, cfg)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)
    batch = split_workers(next(lm_batches(cfg.vocab_size, 22, 32)), 11)

    stacked = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.05),
                                      chunk_q=16, attack="sign_flip"))
    stream = jax.jit(make_streaming_train_step(
        cfg, rcfg, opt, constant(0.05), scope="global", chunk_q=16,
        attack="sign_flip"))

    p1, _, m1 = stacked(params, state, batch, key)
    p2, _, m2 = stream(params, state, batch, key)
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                     b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print(f"[stream] loss stacked={float(m1['loss']):.4f} "
          f"streaming={float(m2['loss']):.4f}")
    print(f"[stream] max |param diff| stacked vs streaming-global: {diff:.2e}")
    print("[stream] peak gradient memory: n·d (stacked) vs n·d/n_groups "
          "(streaming) — the 398B enabler, see DESIGN.md §5 and "
          "EXPERIMENTS.md §Dry-run.")
    # Tolerance: the selection PLAN is identical between the two trainers
    # (same (n, n) distances up to fp noise, same extraction winners); the
    # residual is bf16 backward noise — the per-block backward and the full
    # backward are different XLA programs, and on this 4-layer MoE/mamba
    # hybrid their gradients differ by ~1e-3 on the embedding table.  The
    # 2-layer property test in tests/test_trainer.py holds 5e-5.
    assert diff < 2e-3, diff


if __name__ == "__main__":
    main()
