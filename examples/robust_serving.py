"""Batched serving example: prefill a request batch, decode continuations.

Demonstrates the serving path the decode shapes lower (KV caches, sliding
window for long contexts), on a reduced architecture of your choice.

Run:  PYTHONPATH=src python examples/robust_serving.py --arch chatglm3-6b \\
          --batch 8 --prompt-len 48 --new-tokens 24 --window 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.dist.serving import generate
from repro import models as MD


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="chatglm3-6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--window", type=int, default=0,
                    help=">0 = sliding-window ring cache (long_500k path)")
    ap.add_argument("--sample", default="greedy",
                    choices=("greedy", "categorical"))
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.key(0)
    params = MD.init_model(key, cfg)
    print(f"[serve] {cfg.name}: "
          f"{sum(x.size for x in jax.tree.leaves(params)):,} params, "
          f"batch={args.batch}, window={args.window or 'full cache'}")

    extra = {}
    if cfg.is_encdec:
        extra["frames"] = jax.random.normal(
            key, (args.batch, cfg.n_frames, cfg.d_model), dtype=jnp.bfloat16)
    if cfg.n_patches:
        extra["prefix_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model), dtype=jnp.bfloat16)
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size,
        dtype=jnp.int32)

    t0 = time.time()
    out = generate(params, cfg, prompts, args.new_tokens,
                   window=args.window, chunk_q=min(args.prompt_len, 512),
                   sample=args.sample,
                   key=None if args.sample == "greedy" else key,
                   extra_batch=extra or None)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"[serve] {args.batch}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s incl. compile)")
    for i in range(min(3, args.batch)):
        print(f"[serve] seq {i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
