"""End-to-end byzantine-robust training campaign (deliverable b).

Runs an attack-schedule *campaign* through the ``repro.sim`` engine: a
clean warmup phase, then the selected attack switches on mid-run, with
plan-level telemetry showing which workers the rule selects and rejects
and how far the aggregate strays from the honest mean.

Presets:
  smoke  ~1.5M params,  20+20 steps  (~2 min CPU)     [default]
  10m    ~11M params,  100+100 steps (~1 h CPU)
  100m   ~124M params, 150+150 steps (target-hardware scale; use a TPU)

Run:  PYTHONPATH=src python examples/byzantine_training.py --preset smoke \\
          --attack little_is_enough:z=4.0 --gar multi_bulyan --compare-average
"""
import argparse

import numpy as np

from repro.configs.base import ArchConfig
from repro.sim import (AttackPhase, AttackSchedule, DataConfig, Scenario,
                       report, run_campaign)

PRESETS = {
    "smoke": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                  d_ff=512, vocab_size=512, seq=64, steps=20),
    "10m": dict(n_layers=4, d_model=320, n_heads=8, n_kv_heads=4,
                d_ff=1280, vocab_size=2048, seq=128, steps=100),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=8192, seq=256, steps=150),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=PRESETS, default="smoke")
    ap.add_argument("--gar", default="multi_bulyan")
    ap.add_argument("--attack", default="little_is_enough:z=4.0",
                    help="attack spec for the second phase "
                         "(adaptive_lie / adaptive_mimic also work)")
    ap.add_argument("--workers", type=int, default=11)
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--trainer", default="stacked",
                    choices=("stacked", "stream_block", "stream_global"))
    ap.add_argument("--transform", action="append", default=[],
                    help="e.g. worker_momentum:beta=0.9 (repeatable)")
    ap.add_argument("--noniid-alpha", type=float, default=0.0)
    ap.add_argument("--report", default=None, help="JSON campaign report")
    ap.add_argument("--compare-average", action="store_true",
                    help="also run the campaign with plain averaging")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ArchConfig(name=f"byz-{args.preset}", family="dense",
                     n_layers=p["n_layers"], d_model=p["d_model"],
                     n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
                     d_ff=p["d_ff"], vocab_size=p["vocab_size"])
    schedule = AttackSchedule((
        AttackPhase(steps=p["steps"], attack="none"),
        AttackPhase(steps=p["steps"], attack=args.attack),
    ))
    runs = [args.gar] + (["average"] if args.compare_average else [])
    for gar in runs:
        sc = Scenario(
            name=f"byz-{args.preset}-{gar}", schedule=schedule,
            n_workers=args.workers, f=args.f, gar=gar,
            transforms=tuple(args.transform), trainer=args.trainer,
            arch=cfg, data=DataConfig(noniid_alpha=args.noniid_alpha),
            per_worker_batch=args.per_worker_batch, seq=p["seq"],
            lr=args.lr)
        print(f"[byz] gar={gar} schedule={schedule.describe()} "
              f"n={args.workers} f={args.f} trainer={args.trainer}")
        result = run_campaign(sc, verbose=True)
        post = result.summary["phases"][-1]
        sel = np.asarray(post["selection_mean"])
        print(f"[byz]   under {post['attack']}: loss "
              f"{post['loss_first']:.4f} -> {post['loss_last']:.4f}, "
              f"honest_dev mean {post['honest_dev_mean']:.3f}, byzantine "
              f"selection mass {post['byz_mass_mean']:.4f}")
        print(f"[byz]   mean selection  byz={np.round(sel[:args.f], 3)} "
              f"honest={np.round(sel[args.f:], 3)}")
        print(f"[byz]   final suspicion {np.round(post['suspicion_last'], 2)}")
        if args.report:
            stem, dot, ext = args.report.rpartition(".")
            path = f"{stem}.{gar}.{ext}" if dot else f"{args.report}.{gar}"
            print(f"[byz]   report -> {report.write_json(path, result)}")


if __name__ == "__main__":
    main()
