"""End-to-end byzantine-robust training driver (deliverable b).

Trains a decoder-only LM with n workers of which f behave arbitrarily
(selectable attack), comparing a robust GAR against plain averaging.

Presets:
  smoke  ~1.5M params,  40 steps  (~1 min CPU)     [default]
  10m    ~11M params,  200 steps  (~40 min CPU)
  100m   ~124M params, 300 steps  (target-hardware scale; runs on CPU but
                                   budget hours — use a TPU slice)

Run:  PYTHONPATH=src python examples/byzantine_training.py --preset smoke \\
          --attack little_is_enough --gar multi_bulyan
"""
import argparse
import time

import jax

from repro.configs.base import ArchConfig, RobustConfig
from repro.data import lm_batches
from repro.dist import make_train_step, split_workers
from repro import models as MD
from repro.optim import sgd, warmup_cosine

PRESETS = {
    "smoke": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                  d_ff=512, vocab_size=512, seq=64, steps=40),
    "10m": dict(n_layers=4, d_model=320, n_heads=8, n_kv_heads=4,
                d_ff=1280, vocab_size=2048, seq=128, steps=200),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=8192, seq=256, steps=300),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=PRESETS, default="smoke")
    ap.add_argument("--gar", default="multi_bulyan")
    ap.add_argument("--attack", default="little_is_enough")
    ap.add_argument("--workers", type=int, default=11)
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--compare-average", action="store_true",
                    help="also train with plain averaging under the attack")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ArchConfig(name=f"byz-{args.preset}", family="dense",
                     n_layers=p["n_layers"], d_model=p["d_model"],
                     n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
                     d_ff=p["d_ff"], vocab_size=p["vocab_size"])
    key = jax.random.key(0)
    runs = [args.gar] + (["average"] if args.compare_average else [])
    for gar in runs:
        rcfg = RobustConfig(n_workers=args.workers, f=args.f, gar=gar)
        params = MD.init_model(key, cfg)
        n_par = sum(x.size for x in jax.tree.leaves(params))
        opt = sgd(momentum=0.9)
        state = opt.init(params)
        lr_fn = warmup_cosine(args.lr, warmup=p["steps"] // 10,
                              total_steps=p["steps"])
        step = jax.jit(make_train_step(cfg, rcfg, opt, lr_fn,
                                       chunk_q=min(p["seq"], 512),
                                       attack=args.attack))
        data = lm_batches(cfg.vocab_size,
                          args.workers * args.per_worker_batch, p["seq"])
        print(f"[byz] gar={gar} params={n_par/1e6:.1f}M attack={args.attack} "
              f"n={args.workers} f={args.f}")
        t0 = time.time()
        for i in range(p["steps"]):
            batch = split_workers(next(data), args.workers)
            params, state, m = step(params, state, batch,
                                    jax.random.fold_in(key, i))
            if i % max(p["steps"] // 10, 1) == 0 or i == p["steps"] - 1:
                print(f"[byz]   step {i:4d} loss {float(m['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)


if __name__ == "__main__":
    main()
