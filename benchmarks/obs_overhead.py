"""Observability overhead: the instrumented step vs the uninstrumented one.

The DESIGN.md §14 budget: with ``ObsConfig(enabled=True)`` the in-graph
registry (counters + gauges + histogram + span ring, all pure ``jnp``
updates fused into the step) must cost **< 3 %** per step on every step
type — stacked, streaming and async.  Disabled obs is not measured
against a budget because it is *proven bitwise identical* to the
uninstrumented step (tests/test_obs.py); this benchmark prices the
enabled path.

Protocol per step type: build the step with ``obs=None`` and with an
enabled config, jit both, one warm-up call each (compile excluded), then
interleaved timed reps with the 2-of-7 median-outlier drop the agg_time
benchmark uses.  The model is deliberately mid-sized: against a toy
model the fixed registry cost would dominate and the percentage would be
meaningless for any real step.

Persists ``BENCH_obs.json`` (schema ``bench.obs.v1``:
``step_type -> {us_base, us_obs, overhead_frac}``) for
``benchmarks/validate_bench.py``'s < 3 % gate.  ``--smoke`` exists for a
quick local sanity run but its numbers must not be gated: smoke-sized
steps carry ±5 % per-rep noise, larger than the budget being enforced —
CI validates the committed full-run JSON.

CSV: name,us_per_call,derived (value column = instrumented step µs).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np
import jax

from repro.configs.base import ArchConfig, RobustConfig
from repro.data import lm_batches
from repro.dist import init_train_state, make_train_step, split_workers
from repro.dist.streaming import make_streaming_train_step
from repro import models as MD
from repro import obs as OBS
from repro.optim import constant, sgd
from repro.serve.service import make_async_train_step, with_buffer
from repro.core import api
from repro.serve.service import AsyncAggService

OBS_JSON = "BENCH_obs.json"
SCHEMA = "bench.obs.v1"

N, F, TAU = 11, 2, 1
ARCH = ArchConfig(name="obs-bench", family="dense", n_layers=2,
                  d_model=256, n_heads=4, n_kv_heads=4, d_ff=1024,
                  vocab_size=512)
SEQ, PWB, REPS = 64, 2, 7

SMOKE_ARCH = ArchConfig(name="obs-bench-smoke", family="dense", n_layers=1,
                        d_model=128, n_heads=2, n_kv_heads=2, d_ff=512,
                        vocab_size=256)
SMOKE_SEQ, SMOKE_REPS = 32, 5


def _timed_pair(fn_base, fn_obs, args_base, args_obs, reps: int
                ) -> Dict[str, float]:
    """Interleaved A/B timing (median-outlier drop) of the two variants.

    Interleaving instead of back-to-back blocks keeps slow drift (thermal,
    scheduler) from landing entirely on one variant — at a < 3 % budget
    the measurement method matters more than the thing measured.
    """
    jax.block_until_ready(fn_base(*args_base)[0])   # compile + warm
    jax.block_until_ready(fn_obs(*args_obs)[0])
    base, obs = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_base(*args_base)[0])
        base.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_obs(*args_obs)[0])
        obs.append(time.perf_counter() - t0)

    def keep(ts):
        ts = np.asarray(ts)
        med = np.median(ts)
        drop = min(2, len(ts) - 1)
        return ts[np.argsort(np.abs(ts - med))][: len(ts) - drop]

    us_base = float(keep(base).mean() * 1e6)
    us_obs = float(keep(obs).mean() * 1e6)
    return {"us_base": us_base, "us_obs": us_obs,
            "overhead_frac": us_obs / us_base - 1.0}


def run(csv_rows: List[str], *, smoke: bool = False,
        json_path: str = OBS_JSON) -> Dict[str, Dict[str, float]]:
    arch = SMOKE_ARCH if smoke else ARCH
    seq = SMOKE_SEQ if smoke else SEQ
    reps = SMOKE_REPS if smoke else REPS
    rcfg = RobustConfig(n_workers=N, f=F, gar="multi_bulyan")
    key = jax.random.key(0)
    params = MD.init_model(key, arch)
    opt = sgd(momentum=0.9)
    lr_fn = constant(0.05)
    chunk_q = min(seq, 512)
    batch = split_workers(next(lm_batches(arch.vocab_size, N * PWB, seq,
                                          seed=3)), N)
    on = OBS.ObsConfig(enabled=True)

    results: Dict[str, Dict[str, float]] = {}

    def add(name: str, cell: Dict[str, float]) -> None:
        results[name] = cell
        csv_rows.append(
            f"obs/{name},{cell['us_obs']:.1f},"
            f"overhead_frac={cell['overhead_frac']:.4f}")

    # stacked ---------------------------------------------------------
    state = init_train_state(opt, params, n_workers=N)
    mk = lambda obs: jax.jit(make_train_step(           # noqa: E731
        arch, rcfg, opt, lr_fn, chunk_q=chunk_q, obs=obs))
    args = (params, state, batch, key)
    add("stacked", _timed_pair(mk(None), mk(on), args, args, reps))

    # streaming (global scope) ---------------------------------------
    mk = lambda obs: jax.jit(make_streaming_train_step(  # noqa: E731
        arch, rcfg, opt, lr_fn, scope="global", chunk_q=chunk_q, obs=obs))
    add("streaming", _timed_pair(mk(None), mk(on), args, args, reps))

    # async (bounded staleness) --------------------------------------
    svc = AsyncAggService(
        backend=api.AggregatorBackend.for_config(rcfg), tau=TAU)
    astate = with_buffer(state, svc, params, N)
    mk = lambda obs: jax.jit(make_async_train_step(      # noqa: E731
        arch, rcfg, opt, lr_fn, tau=TAU, chunk_q=chunk_q, obs=obs))
    import jax.numpy as jnp
    fresh = jnp.ones((N,), bool)
    aargs = (params, astate, batch, key, fresh)
    add("async", _timed_pair(mk(None), mk(on), aargs, aargs, reps))

    meta = {"arch": arch.name, "n": N, "f": F, "tau": TAU, "seq": seq,
            "per_worker_batch": PWB, "reps": reps,
            "d_model": arch.d_model, "n_layers": arch.n_layers}
    with open(json_path, "w") as fh:
        json.dump({"schema": SCHEMA, "meta": meta, "results": results},
                  fh, indent=2, sort_keys=True)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=OBS_JSON)
    args = ap.parse_args()
    rows: List[str] = []
    run(rows, smoke=args.smoke, json_path=args.json)
    print("name,us_per_call,derived")
    print("\n".join(rows))
