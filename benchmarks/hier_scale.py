"""Hierarchical aggregation at large n — the repro.hier scaling story.

The flat plan phase is O(n²) in the worker count (the (n, n) distance
matrix + the θ-round selection loop): at n in the thousands it is
infeasible on this container — the selection loop alone unrolls thousands
of top-k rounds into one XLA program.  The grouped scheme
(``repro.hier.hier_aggregate_tree``) does O(n·g) work in ceil(n/g)
independent (≤g, ≤g) problems plus one (n/g, n/g) outer problem, so the
same rule completes at n = 2048 and beyond.

Grid (CPU-sized; the paper's federated fan-in motivates n ≥ 1000):

* hier cells — explicit (n, g) pairs: g=16 at n=256 exercises a robust
  outer level (f_inner=3, f_outer=1), g=64 scales n=256 → 2048 with the
  group size (and the per-group problem) fixed — the O(n·g) claim is the
  near-linear growth of us_per_call down that column;
* flat cells — timed up to ``FLAT_MAX_N``; above it the cell is written
  as ``{"skipped": reason}`` — the O(n²·θ) selection unroll blows the
  benchmark budget (the validator requires flat to be skipped or ≥ 5×
  slower than hier wherever n ≥ 1024).

Every hier cell also records the two-hop wire bytes
(``repro.comm.hier_wire_stats``, fp32 accounting): level 0 is n rows,
level 1 only ceil(n/g) — the server fan-in reduction rides along for free.

Persists ``BENCH_hier.json`` (schema ``hier.v1``); CSV rows
``hier_scale/<row>/n=<n>/g=<g>/d=<d>,us,...``.
"""
from __future__ import annotations

import functools
import json
import time
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import api
from repro.hier import GroupConfig, hier_aggregate_tree

BENCH_JSON = "BENCH_hier.json"
SCHEMA = "hier.v1"

# explicit (n, g) hier cells — see module docstring for why this shape
HIER_CELLS = ((256, 16), (256, 64), (1024, 64), (2048, 64))
D = 32_768
F = 7
FLAT_MAX_N = 256          # flat timing budget: n > this is written skipped
FLAT_NS = (256, 1024, 2048)

SMOKE_HIER_CELLS = ((64, 16),)
SMOKE_D = 1024
SMOKE_F = 3
SMOKE_FLAT_MAX_N = 64
SMOKE_FLAT_NS = (64,)


def _timed(fn, *args, reps: int = 3, drop: int = 1) -> Tuple[float, float]:
    jax.block_until_ready(fn(*args))  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    med = np.median(times)
    keep = times[np.argsort(np.abs(times - med))][: reps - drop]
    return float(keep.mean()), float(keep.std())


def _bytes_per_level(n: int, g: int, d: int) -> List[int]:
    from repro.comm import hier_wire_stats
    like = {"w": jnp.zeros((d,), jnp.float32)}
    return [ws.total_bytes
            for ws in hier_wire_stats("fp32", like, n=n, g=g)]


def write_json(results: Dict[str, Dict[str, object]],
               path: str = BENCH_JSON) -> None:
    payload = {
        "schema": SCHEMA,
        "rule": "multi_bulyan",
        "notes": "row -> 'n=<n>,g=<g>,d=<d>' -> {us_per_call, n_groups, "
                 "f_inner, f_outer, bytes_per_level} | {skipped}; g=0 is "
                 "the flat path",
        "results": results,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)


def run(csv_rows: List[str], *, smoke: bool = False,
        json_path: str = BENCH_JSON) -> Dict[str, Dict[str, object]]:
    rng = np.random.default_rng(0)
    cells = SMOKE_HIER_CELLS if smoke else HIER_CELLS
    d = SMOKE_D if smoke else D
    f = SMOKE_F if smoke else F
    flat_max = SMOKE_FLAT_MAX_N if smoke else FLAT_MAX_N
    flat_ns = SMOKE_FLAT_NS if smoke else FLAT_NS
    results: Dict[str, Dict[str, object]] = {
        "multi_bulyan[hier]": {}, "multi_bulyan[flat]": {}}

    for n, g in cells:
        G = jnp.asarray(rng.uniform(size=(n, d)).astype(np.float32))
        cfg = GroupConfig(g=g, rule="multi_bulyan")
        budget = cfg.budget(n, f)
        fn = jax.jit(lambda x, _f=f, _cfg=cfg:
                     hier_aggregate_tree(x, _f, _cfg)[0])
        mean, std = _timed(fn, G)
        cell = {"us_per_call": mean * 1e6, "n_groups": budget.n_groups,
                "f_inner": budget.f_inner, "f_outer": budget.f_outer,
                "bytes_per_level": _bytes_per_level(n, g, d)}
        results["multi_bulyan[hier]"][f"n={n},g={g},d={d}"] = cell
        csv_rows.append(
            f"hier_scale/multi_bulyan[hier]/n={n}/g={g}/d={d},"
            f"{mean*1e6:.1f},groups={budget.n_groups}:f_inner="
            f"{budget.f_inner}:f_outer={budget.f_outer}:std_us={std*1e6:.1f}")

    for n in flat_ns:
        key = f"n={n},g=0,d={d}"
        if n > flat_max:
            reason = (f"flat multi_bulyan at n={n} is infeasible in the "
                      f"benchmark budget: the (n,n) distance matrix + "
                      f"O(n^2·θ) selection unroll (θ≈{n - 2 * f - 2} "
                      f"top-k rounds over {n} rows) dwarf the grouped "
                      f"path; see the n={flat_max} flat/hier ratio")
            results["multi_bulyan[flat]"][key] = {"skipped": reason}
            csv_rows.append(
                f"hier_scale/multi_bulyan[flat]/n={n}/g=0/d={d},0.0,skipped")
            continue
        G = jnp.asarray(rng.uniform(size=(n, d)).astype(np.float32))
        fn = jax.jit(functools.partial(
            api.aggregate_tree, f=f, name="multi_bulyan"))
        mean, std = _timed(fn, G)
        results["multi_bulyan[flat]"][key] = {
            "us_per_call": mean * 1e6, "n_groups": 1, "f_inner": f,
            "f_outer": 0,
            "bytes_per_level": [_bytes_per_level(n, n, d)[0]]}
        csv_rows.append(
            f"hier_scale/multi_bulyan[flat]/n={n}/g=0/d={d},"
            f"{mean*1e6:.1f},std_us={std*1e6:.1f}")

    # derived: flat/hier ratio at the largest common n + the O(n·g) column
    hier_cells = results["multi_bulyan[hier]"]
    flat_cells = results["multi_bulyan[flat]"]
    common = []
    for (n, g) in cells:
        fc = flat_cells.get(f"n={n},g=0,d={d}")
        if fc and "us_per_call" in fc:
            common.append(n)
    if common:
        n0 = max(common)
        g0 = max(g for (n, g) in cells if n == n0)
        ratio = (flat_cells[f"n={n0},g=0,d={d}"]["us_per_call"]
                 / max(hier_cells[f"n={n0},g={g0},d={d}"]["us_per_call"],
                       1e-9))
        csv_rows.append(f"hier_scale/flat_over_hier/n={n0},{ratio:.2f},"
                        "largest_common_n")
    write_json(results, json_path)
    return results


if __name__ == "__main__":
    import sys
    rows: List[str] = []
    run(rows, smoke="--smoke" in sys.argv)
    print("\n".join(rows))
