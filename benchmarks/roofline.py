"""§Roofline: derive compute / memory / collective terms per (arch × shape).

Inputs: results/dryrun_single_pod.json produced by launch/dryrun.py — which
records, per combination, the trip-count-corrected per-device dot FLOPs and
collective bytes (launch/hlo_analysis.py) plus memory_analysis sizes.

Terms (TPU v5e):
  compute    = FLOPs_global / (chips · 197e12)   [bf16 peak/chip]
  memory     = HBM_bytes_global / (chips · 819e9)
  collective = coll_bytes_global / (chips · 50e9) [per-link ICI]

With SPMD, per-device quantities × chips = global, so each term reduces to
per-device value / per-chip rate.  HBM traffic is not recoverable from HLO
text, so the memory term uses an explicit analytic traffic model (documented
inline, deliberately first-order):

  train:   4·params·4B (fwd read, remat re-read, bwd grad write+read)
           + opt-state r/w + grad-stack r/w ×3 + boundaries ×4
  prefill: params read + KV write + boundary-free activations (2 passes)
  decode:  params read + full cache read + cache slot write   (per token)

MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (prefill) /
2·N_active·batch (decode); attention FLOPs excluded by convention (they are
included in the HLO count — the ratio column surfaces exactly this).
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_single_pod.json")


def _tokens(shape: str, row: Dict) -> int:
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    return seq * batch


def model_flops(row: Dict) -> float:
    n_act = row["active_params"]
    toks = _tokens(row["shape"], row)
    mult = 6.0 if row["shape"] == "train_4k" else 2.0
    if row.get("trainer") == "stream_global":
        # two streamed backwards (each fwd-recompute 2 + bwd 4) on top of
        # one boundary forward: 2 + 2·(2+4) = 14 ·N·D vs the standard 6
        mult = 14.0
    return mult * n_act * toks


def memory_bytes_per_dev(row: Dict, chips: int) -> float:
    p4 = row["params"] * 4.0
    shape = row["shape"]
    if shape == "train_4k":
        grad_stack = 16 * row["params"] * 4.0 * 3.0 / 1  # n workers r/w x3
        traffic = 4 * p4 + 2 * p4 + grad_stack
    elif shape == "prefill_32k":
        traffic = p4 + 2 * row.get("output_size_in_bytes", 0) * chips
    else:
        # decode: params + cache read (arguments minus params ≈ cache)
        cache = max(row.get("argument_size_in_bytes", 0) * chips - p4, 0)
        traffic = p4 + cache
    return traffic / chips


def derive(row: Dict) -> Dict:
    chips = row["devices"]
    corrected = row.get("corrected", {})
    flops_dev = corrected.get("flops", 0.0)
    coll_dev = corrected.get("coll.total", 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = memory_bytes_per_dev(row, chips) / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(row)
    hlo_global = flops_dev * chips
    ratio = mf / hlo_global if hlo_global else float("nan")
    advice = {
        "compute": "raise arithmetic intensity: larger per-step tokens or "
                   "reduce recompute (remat policy)",
        "memory": "cut parameter/grad traffic: lower-precision stacks, "
                  "fuse GAR passes, shard activations",
        "collective": "reshape collectives: reduce-scatter instead of "
                      "all-gather, overlap with compute, relayout the "
                      "grad stack",
    }[dominant]
    return {
        "arch": row["arch"], "shape": row["shape"], "mesh": row["mesh"],
        "trainer": row.get("trainer", ""),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": ratio, "advice": advice,
    }


def run(csv_rows: List[str], path: Optional[str] = None) -> List[Dict]:
    path = path or RESULTS
    if not os.path.exists(path):
        csv_rows.append("roofline/skipped,0,no dryrun json (run "
                        "repro.launch.dryrun --all --json first)")
        return []
    with open(path) as fh:
        rows = json.load(fh)
    # keep the latest entry per (arch, shape, mesh)
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    out = []
    for r in seen.values():
        d = derive(r)
        out.append(d)
        csv_rows.append(
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']},"
            f"{max(d['t_compute_s'], d['t_memory_s'], d['t_collective_s'])*1e6:.1f},"
            f"compute={d['t_compute_s']*1e3:.2f}ms_memory={d['t_memory_s']*1e3:.2f}ms_"
            f"coll={d['t_collective_s']*1e3:.2f}ms_dom={d['dominant']}_"
            f"useful={d['useful_ratio']:.2f}")
    return out


def markdown(path: Optional[str] = None) -> str:
    rows: List[str] = []
    derived = run(rows, path)
    derived.sort(key=lambda d: (d["arch"], d["shape"]))
    lines = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
             "collective (ms) | dominant | MODEL/HLO | fix |",
             "|---|---|---|---|---|---|---|---|---|"]
    for d in derived:
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{d['t_compute_s']*1e3:.2f} | {d['t_memory_s']*1e3:.2f} | "
            f"{d['t_collective_s']*1e3:.2f} | **{d['dominant']}** | "
            f"{d['useful_ratio']:.2f} | {d['advice']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--markdown":
        print(markdown())
    else:
        rows: List[str] = []
        run(rows)
        print("\n".join(rows))
