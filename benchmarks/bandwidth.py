"""Wire-format bandwidth sweep: bytes/step and round time per codec × (n, d).

At d ≈ 10⁹ the paper's O(d) local cost leaves gradient *transport* as the
bottleneck; this section measures what each ``repro.comm`` codec buys on
the wire and what it costs in compute.  Per (codec × (n, d)) cell:

* ``wire_bytes`` / ``bytes_per_worker`` — exact byte accounting from the
  codec's ``leaf_wire_bytes`` (what ``WireStats`` reports in campaigns);
* ``us_per_call``  — wall time of the full jitted round
  encode → wire → multi-Bulyan aggregate on the encoded stack (paper §V-A
  timing protocol: warm-up, 7 runs, drop the 2 farthest from the median);
* ``ratio_vs_fp32`` — the wire compression factor.

Persists ``BENCH_comm.json`` (schema ``comm.v1``, gated by
``benchmarks/validate_bench.py``):

    {"schema": "comm.v1",
     "results": {codec: {"n=<n>,d=<d>": {"wire_bytes": ..,
                                         "bytes_per_worker": ..,
                                         "us_per_call": ..,
                                         "ratio_vs_fp32": ..}}}}

The validator additionally asserts the acceptance ordering: wire bytes
strictly fp32 > bf16 > qsgd int8 on every shared (n, d) point.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.comm import codecs as CC
from repro.core import api

CODEC_SPECS = ("fp32", "bf16", "qsgd:bits=8", "signsgd", "topk:frac=0.01")
NS = (11, 23)
DS = (262_144, 1_048_576)
SMOKE_NS = (7, 11)
SMOKE_DS = (4_096, 16_384)
BENCH_JSON = "BENCH_comm.json"


def _f_for(n: int) -> int:
    return max(1, (n - 3) // 4)          # the paper's f = floor((n-3)/4)


def _timed(fn, *args, reps: int = 7, drop: int = 2) -> Tuple[float, float]:
    out = fn(*args)
    jax.block_until_ready(out)           # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    med = np.median(times)
    keep = times[np.argsort(np.abs(times - med))][: reps - drop]
    return float(keep.mean()), float(keep.std())


def _round_fn(codec: CC.Codec, f: int):
    """The full wire round: encode -> EncodedGrads -> multi-Bulyan."""

    @jax.jit
    def round_(G, key):
        enc, _ = codec.encode(G, key=key)
        return api.aggregate_tree(enc, f, "multi_bulyan")

    return round_


def write_json(results: Dict[str, Dict[str, Dict[str, float]]],
               path: str = BENCH_JSON) -> None:
    payload = {"schema": "comm.v1", "results": results}
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)


def run(csv_rows: List[str], *, smoke: bool = False,
        json_path: str = BENCH_JSON) -> Dict[str, Dict[str, Dict[str, float]]]:
    rng = np.random.default_rng(0)
    key = jax.random.key(0)
    ns, ds = (SMOKE_NS, SMOKE_DS) if smoke else (NS, DS)
    reps, drop = (3, 1) if smoke else (7, 2)
    results: Dict[str, Dict[str, Dict[str, float]]] = \
        {spec: {} for spec in CODEC_SPECS}
    for d in ds:
        for n in ns:
            G = jnp.asarray(rng.uniform(size=(n, d)).astype(np.float32))
            f = _f_for(n)
            cell_key = f"n={n},d={d}"
            fp32_bytes = 4 * n * d
            for spec in CODEC_SPECS:
                codec = CC.get_codec(spec)
                enc, _ = codec.encode(G, key=key)
                mean, std = _timed(_round_fn(codec, f), G, key,
                                   reps=reps, drop=drop)
                cell = {
                    "wire_bytes": enc.wire_bytes,
                    "bytes_per_worker": enc.bytes_per_worker,
                    "us_per_call": mean * 1e6,
                    "ratio_vs_fp32": round(fp32_bytes / enc.wire_bytes, 4),
                }
                results[spec][cell_key] = cell
                csv_rows.append(
                    f"bandwidth/{spec}/n={n}/d={d},{mean*1e6:.1f},"
                    f"bytes_per_worker={enc.bytes_per_worker}"
                    f"_ratio={cell['ratio_vs_fp32']:.2f}"
                    f"_std_us={std*1e6:.1f}")
    # derived: the acceptance ordering on every point (also CI-gated by
    # validate_bench's comm.v1 check)
    for d in ds:
        for n in ns:
            ckey = f"n={n},d={d}"
            o = [results[s][ckey]["wire_bytes"]
                 for s in ("fp32", "bf16", "qsgd:bits=8")]
            csv_rows.append(
                f"bandwidth/order_fp32_bf16_int8/{ckey},"
                f"{int(o[0] > o[1] > o[2])},strict_ordering_required")
    write_json(results, json_path)
    return results


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (same codecs, small shapes)")
    ap.add_argument("--json", default=BENCH_JSON)
    args = ap.parse_args(argv)
    rows: List[str] = []
    run(rows, smoke=args.smoke, json_path=args.json)
    print("name,us_per_call,derived")
    print("\n".join(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
