"""Fig 3 reproduction: max top-1 accuracy per GAR and per-worker batch size.

Paper setup (§V-A): n=11 workers, f=2, NO attack; GARs averaging / MEDIAN /
MULTI-KRUM / MULTI-BULYAN; the effect under test is the *slowdown*: rules
that aggregate more gradients per step (averaging > multi-krum ≳
multi-bulyan > median) reach higher accuracy in a fixed step budget, and
larger per-worker batches compensate.

Fashion-MNIST is not available in this container; the task is a separable
Gaussian-mixture classification problem (data/synthetic.py) with a small
MLP — same qualitative mechanics (visible accuracy ceiling within a small
step budget, variance-limited early training).

Runs on the plan/apply ``Aggregator`` API (``core.api``) — the aggregator
and its capability flags are resolved once per rule, each step computes
only the statistics the rule's ``plan`` needs and applies the plan
per leaf (the legacy ``tree_aggregate`` shim is no longer involved).

Persists ``BENCH_accuracy.json`` (schema ``accuracy.v1``, gated by
``benchmarks/validate_bench.py``):

    {"schema": "accuracy.v1",
     "results": {rule: {"b=<batch>": {"acc_mean": .., "acc_std": ..}}}}

CSV: name,us_per_call,derived  (us_per_call column reused for accuracy %).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import api
from repro.data import classification_batches
from repro.optim import sgd

N, F = 11, 2
D_IN, N_CLASSES, HIDDEN = 32, 10, 64
STEPS, EVAL_EVERY = 400, 25
BATCHES = (5, 20, 50)
RULES = ("average", "median", "multi_krum", "multi_bulyan")
SEEDS = (1, 2, 3)   # paper uses seeds 1..5
SMOKE_STEPS = 60
SMOKE_BATCHES = (5,)
SMOKE_SEEDS = (1, 2)
BENCH_JSON = "BENCH_accuracy.json"


def _init(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (D_IN, HIDDEN)) / np.sqrt(D_IN),
        "b1": jnp.zeros((HIDDEN,)),
        "w2": jax.random.normal(k2, (HIDDEN, N_CLASSES)) / np.sqrt(HIDDEN),
        "b2": jnp.zeros((N_CLASSES,)),
    }


def _logits(p, x):
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, x, y):
    lg = _logits(p, x)
    return jnp.mean(jax.nn.logsumexp(lg, -1) -
                    jnp.take_along_axis(lg, y[:, None], -1)[:, 0])


def _accuracy(p, x, y) -> float:
    return float(jnp.mean(jnp.argmax(_logits(p, x), -1) == y))


def train_once(rule: str, batch: int, seed: int, steps: int = STEPS) -> float:
    key = jax.random.key(seed)
    params = _init(key)
    opt = sgd(momentum=0.9)   # paper: SGD, momentum 0.9
    state = opt.init(params)
    data = classification_batches(D_IN, N_CLASSES, N * batch, seed=seed,
                                  noise=1.5)
    xt, yt = next(classification_batches(D_IN, N_CLASSES, 2000,
                                         seed=seed + 999, noise=1.5))

    # plan/apply: resolve the rule once; the step computes exactly the
    # statistics its capability flags ask for (average pays no distance
    # pass) and applies the static-shape plan per leaf
    agg = api.get_aggregator(rule)
    agg.validate(N, F)

    @jax.jit
    def step(params, state, x, y):
        def worker_grad(xw, yw):
            return jax.grad(_loss)(params, xw, yw)
        xs = x.reshape(N, batch, D_IN)
        ys = y.reshape(N, batch)
        grads = jax.vmap(worker_grad)(xs, ys)
        stats = api.compute_stats(grads, F, needs_dists=agg.needs_dists)
        out = agg.apply(agg.plan(stats), grads)
        return opt.update(out, state, params, 0.05)

    best = 0.0
    for i in range(steps):
        x, y = next(data)
        params, state = step(params, state, x, y)
        if (i + 1) % EVAL_EVERY == 0 or i == steps - 1:
            best = max(best, _accuracy(params, xt, yt))
    return best


def write_json(results: Dict[str, Dict[int, Dict[str, float]]],
               protocol: Dict, path: str = BENCH_JSON) -> None:
    payload = {
        "schema": "accuracy.v1",
        "protocol": protocol,
        "results": {
            rule: {f"b={b}": cell for b, cell in grid.items()}
            for rule, grid in results.items()
        },
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)


def run(csv_rows: List[str], *, smoke: bool = False,
        json_path: str = BENCH_JSON) -> Dict[str, Dict[int, float]]:
    batches = SMOKE_BATCHES if smoke else BATCHES
    seeds = SMOKE_SEEDS if smoke else SEEDS
    steps = SMOKE_STEPS if smoke else STEPS
    out: Dict[str, Dict[int, float]] = {}
    cells: Dict[str, Dict[int, Dict[str, float]]] = {}
    for rule in RULES:
        out[rule] = {}
        cells[rule] = {}
        for b in batches:
            accs = [train_once(rule, b, s, steps) for s in seeds]
            mean, std = float(np.mean(accs)), float(np.std(accs))
            out[rule][b] = mean
            cells[rule][b] = {"acc_mean": round(mean, 6),
                              "acc_std": round(std, 6)}
            csv_rows.append(f"accuracy/{rule}/b={b},{mean*100:.2f},"
                            f"std={std*100:.2f}")
    # derived orderings (the paper's Fig 3 story)
    b = batches[0]  # most variance-limited point
    csv_rows.append(
        f"accuracy/order_check/b={b},"
        f"{(out['multi_bulyan'][b] >= out['median'][b] - 0.02)*1:.0f},"
        "multibulyan_not_worse_than_median")
    csv_rows.append(
        f"accuracy/avg_vs_mk/b={b},"
        f"{(out['average'][b] >= out['multi_krum'][b] - 0.03)*1:.0f},"
        "averaging_upper_bounds_mk")
    write_json(cells, {"n_workers": N, "f": F, "steps": steps,
                       "seeds": list(seeds), "smoke": smoke,
                       "task": "gaussian-mixture MLP (Fig 3 stand-in)"},
               json_path)
    return out


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)
    print("\n".join(rows))
