"""Benchmark harness — one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (value column semantics noted
per section).  Sections:

* agg_time    — Fig 2: aggregation wall-time vs (n, d), O(d)/O(n²) scaling,
                XLA vs Pallas vs fused apply substrates; persists the perf
                trajectory to BENCH_agg_time.json
* accuracy    — Fig 3: max top-1 accuracy per GAR × per-worker batch size;
                persists BENCH_accuracy.json
* resilience  — rule × attack campaign sweep through the sim engine
                (post-switch honest-mean deviation, byzantine selection
                mass); persists BENCH_resilience.json
* bandwidth   — wire bytes/step + round time per codec × (n, d) through
                repro.comm; persists BENCH_comm.json
* hier        — hierarchical vs flat aggregation at large n (repro.hier):
                O(n·g) grouped selection where the flat O(n²) path is
                infeasible; persists BENCH_hier.json
* serving     — closed-loop async vs sync robust serving throughput
                (repro.serve): QPS × staleness bound × f with the stale
                accounting replayed through the real gradient buffer;
                persists BENCH_serving.json
* obs         — observability overhead: instrumented vs uninstrumented
                step (stacked/streaming/async), must stay < 3 %;
                persists BENCH_obs.json (full grid only — smoke-sized
                steps are too noisy for a 3 % differential budget)
* roofline    — §Roofline terms from the dry-run artifacts (if present)

Env: BENCH_SECTIONS=agg_time,accuracy,... to select a subset (unknown
section names are an error — a typo must not silently skip a section).
``--smoke`` shrinks every section to a CI-sized grid (all four JSONs are
still written so the trajectory checks have something to validate).
A section that cannot run (roofline without the dry-run artifact) prints
an explicit skip reason; ``--strict`` turns any such skip into a non-zero
exit.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List

KNOWN_SECTIONS = ("agg_time", "accuracy", "resilience", "bandwidth",
                  "hier", "serving", "obs", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grids for every selected section")
    ap.add_argument("--strict", action="store_true",
                    help="fail (exit 1) when any selected section skips "
                         "instead of running")
    ap.add_argument("--bench-json", default=None,
                    help="agg_time JSON output path (default "
                         "BENCH_agg_time.json in the cwd)")
    ap.add_argument("--resilience-json", default="BENCH_resilience.json",
                    help="resilience sweep JSON output path")
    ap.add_argument("--comm-json", default="BENCH_comm.json",
                    help="bandwidth sweep JSON output path")
    ap.add_argument("--accuracy-json", default="BENCH_accuracy.json",
                    help="accuracy JSON output path")
    ap.add_argument("--hier-json", default="BENCH_hier.json",
                    help="hierarchical scaling JSON output path")
    ap.add_argument("--serving-json", default="BENCH_serving.json",
                    help="closed-loop serving JSON output path")
    ap.add_argument("--obs-json", default="BENCH_obs.json",
                    help="observability overhead JSON output path")
    args = ap.parse_args()

    # obs is full-grid-only by default: a 3 % differential budget cannot
    # be measured on smoke-sized steps (per-step noise is itself ±5 %),
    # so CI gates the committed full-run BENCH_obs.json instead
    default_sections = "agg_time,accuracy,resilience,bandwidth,hier,serving" \
        if args.smoke else \
        "agg_time,accuracy,resilience,bandwidth,hier,serving,obs,roofline"
    sections = os.environ.get("BENCH_SECTIONS", default_sections).split(",")
    unknown = [s for s in sections if s not in KNOWN_SECTIONS]
    if unknown:
        print(f"unknown BENCH_SECTIONS entries {unknown}; "
              f"known: {list(KNOWN_SECTIONS)}", file=sys.stderr)
        sys.exit(2)
    rows: List[str] = []
    skipped: List[str] = []
    t0 = time.time()
    if "agg_time" in sections:
        from benchmarks import agg_time
        kw = {} if args.bench_json is None else {"json_path": args.bench_json}
        agg_time.run(rows, smoke=args.smoke, **kw)
        print(f"# agg_time done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "accuracy" in sections:
        from benchmarks import accuracy
        accuracy.run(rows, smoke=args.smoke, json_path=args.accuracy_json)
        print(f"# accuracy done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "resilience" in sections:
        from benchmarks import resilience
        resilience.run(rows, smoke=args.smoke,
                       json_path=args.resilience_json)
        print(f"# resilience done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "bandwidth" in sections:
        from benchmarks import bandwidth
        bandwidth.run(rows, smoke=args.smoke, json_path=args.comm_json)
        print(f"# bandwidth done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "hier" in sections:
        from benchmarks import hier_scale
        hier_scale.run(rows, smoke=args.smoke, json_path=args.hier_json)
        print(f"# hier done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "serving" in sections:
        from benchmarks import serving
        serving.run(rows, smoke=args.smoke, json_path=args.serving_json)
        print(f"# serving done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "obs" in sections:
        from benchmarks import obs_overhead
        obs_overhead.run(rows, smoke=args.smoke, json_path=args.obs_json)
        print(f"# obs done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "roofline" in sections:
        from benchmarks import roofline
        derived = roofline.run(rows)
        if not derived:
            reason = ("roofline: SKIPPED — results/dryrun_single_pod.json "
                      "absent; generate it with `python -m "
                      "repro.launch.dryrun --all --json` first")
            print(f"# {reason}", file=sys.stderr)
            skipped.append(reason)
        else:
            print(f"# roofline done ({time.time()-t0:.0f}s)",
                  file=sys.stderr)
    print("name,us_per_call,derived")
    print("\n".join(rows))
    if skipped and args.strict:
        print(f"--strict: {len(skipped)} section(s) skipped:",
              file=sys.stderr)
        for reason in skipped:
            print(f"  {reason}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
