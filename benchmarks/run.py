"""Benchmark harness — one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (value column semantics noted
per section).  Sections:

* agg_time    — Fig 2: aggregation wall-time vs (n, d), O(d)/O(n²) scaling
* accuracy    — Fig 3: max top-1 accuracy per GAR × per-worker batch size
* resilience  — Lemma 1 cone bound, Def-2 leeway scaling, Thm 1/2 slowdown
* roofline    — §Roofline terms from the dry-run artifacts (if present)

Env: BENCH_SECTIONS=agg_time,accuracy,... to select a subset.
"""
from __future__ import annotations

import os
import sys
import time
from typing import List


def main() -> None:
    sections = os.environ.get(
        "BENCH_SECTIONS", "agg_time,accuracy,resilience,roofline").split(",")
    rows: List[str] = []
    t0 = time.time()
    if "agg_time" in sections:
        from benchmarks import agg_time
        agg_time.run(rows)
        print(f"# agg_time done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "accuracy" in sections:
        from benchmarks import accuracy
        accuracy.run(rows)
        print(f"# accuracy done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "resilience" in sections:
        from benchmarks import resilience
        resilience.run(rows)
        print(f"# resilience done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "roofline" in sections:
        from benchmarks import roofline
        roofline.run(rows)
        print(f"# roofline done ({time.time()-t0:.0f}s)", file=sys.stderr)
    print("name,us_per_call,derived")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
