"""Benchmark harness — one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (value column semantics noted
per section).  Sections:

* agg_time    — Fig 2: aggregation wall-time vs (n, d), O(d)/O(n²) scaling,
                XLA vs Pallas vs fused apply substrates; persists the perf
                trajectory to BENCH_agg_time.json
* accuracy    — Fig 3: max top-1 accuracy per GAR × per-worker batch size
* resilience  — rule × attack campaign sweep through the sim engine
                (post-switch honest-mean deviation, byzantine selection
                mass); persists BENCH_resilience.json
* roofline    — §Roofline terms from the dry-run artifacts (if present)

Env: BENCH_SECTIONS=agg_time,accuracy,... to select a subset.
``--smoke`` shrinks agg_time to a single CI-sized grid point and the
resilience sweep to a 2-rule × 1-attack campaign grid (both JSONs are
still written so the trajectory checks have something to validate).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (agg_time only unless BENCH_SECTIONS "
                         "says otherwise)")
    ap.add_argument("--bench-json", default=None,
                    help="agg_time JSON output path (default "
                         "BENCH_agg_time.json in the cwd)")
    ap.add_argument("--resilience-json", default="BENCH_resilience.json",
                    help="resilience sweep JSON output path")
    args = ap.parse_args()

    default_sections = "agg_time,resilience" if args.smoke else \
        "agg_time,accuracy,resilience,roofline"
    sections = os.environ.get("BENCH_SECTIONS", default_sections).split(",")
    rows: List[str] = []
    t0 = time.time()
    if "agg_time" in sections:
        from benchmarks import agg_time
        kw = {} if args.bench_json is None else {"json_path": args.bench_json}
        agg_time.run(rows, smoke=args.smoke, **kw)
        print(f"# agg_time done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "accuracy" in sections:
        from benchmarks import accuracy
        accuracy.run(rows)
        print(f"# accuracy done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "resilience" in sections:
        from benchmarks import resilience
        resilience.run(rows, smoke=args.smoke,
                       json_path=args.resilience_json)
        print(f"# resilience done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if "roofline" in sections:
        from benchmarks import roofline
        roofline.run(rows)
        print(f"# roofline done ({time.time()-t0:.0f}s)", file=sys.stderr)
    print("name,us_per_call,derived")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
