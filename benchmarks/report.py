"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from results/*.json."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.roofline import derive  # noqa: E402

RES = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    path = os.path.join(RES, name)
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        rows = json.load(fh)
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    return list(seen.values())


def dryrun_table(rows):
    lines = [
        "| arch | shape | mesh | mode | lower (s) | compile (s) | "
        "args GB/dev | temp GB/dev | HLO GFLOPs/dev | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        c = r.get("corrected", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['trainer']} | "
            f"{r['lower_s']} | {r['compile_s']} | "
            f"{r.get('argument_size_in_bytes', 0)/1e9:.2f} | "
            f"{r.get('temp_size_in_bytes', 0)/1e9:.2f} | "
            f"{c.get('flops', 0)/1e9:.0f} | "
            f"{c.get('coll.total', 0)/1e9:.2f} |")
    return "\n".join(lines)


def roofline_table(rows):
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | MODEL_FLOPS | MODEL/HLO | what would move it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        d = derive(r)
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['t_compute_s']*1e3:.2f} | "
            f"{d['t_memory_s']*1e3:.2f} | {d['t_collective_s']*1e3:.2f} | "
            f"**{d['dominant']}** | {d['model_flops']:.2e} | "
            f"{d['useful_ratio']:.2f} | {d['advice']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    single = _load("dryrun_single_pod.json")
    multi = _load("dryrun_multi_pod.json")
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Single-pod (16×16 = 256 chips)\n")
        print(dryrun_table(single))
        print("\n### Multi-pod (2×16×16 = 512 chips)\n")
        print(dryrun_table(multi))
    if which in ("all", "roofline"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table(single))
