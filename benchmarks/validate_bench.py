"""Validate a BENCH_agg_time.json trajectory file (CI gate).

Usage: python -m benchmarks.validate_bench [BENCH_agg_time.json]

Fails (exit 1) when the file is missing, is not JSON, deviates from the
``rule -> 'n=<n>,d=<d>' -> us_per_call`` schema, or lacks the three apply
substrate rows (multi_bulyan[xla|pallas|fused]) the perf trajectory exists
to track.
"""
from __future__ import annotations

import json
import math
import re
import sys

REQUIRED_ROWS = ("multi_bulyan[xla]", "multi_bulyan[pallas]",
                 "multi_bulyan[fused]")
_KEY_RE = re.compile(r"^n=\d+,d=\d+$")


def _fail(msg: str) -> "list[str]":
    return [msg]


def check(path: str) -> "list[str]":
    """Return a list of problems (empty = valid)."""
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except FileNotFoundError:
        return _fail(f"{path}: missing — run `python -m benchmarks.run`")
    except json.JSONDecodeError as e:
        return _fail(f"{path}: not valid JSON ({e})")
    problems = []
    if not isinstance(payload, dict) or "results" not in payload:
        return _fail(f"{path}: top level must be an object with 'results'")
    if "schema" not in payload:
        problems.append("missing 'schema' field")
    results = payload["results"]
    if not isinstance(results, dict) or not results:
        return _fail(f"{path}: 'results' must be a non-empty object")
    for rule, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"rule {rule!r}: empty or non-object grid")
            continue
        for key, us in grid.items():
            if not _KEY_RE.match(key):
                problems.append(f"rule {rule!r}: bad grid key {key!r} "
                                "(want 'n=<n>,d=<d>')")
            if not isinstance(us, (int, float)) or not math.isfinite(us) \
                    or us <= 0:
                problems.append(f"rule {rule!r} [{key}]: us_per_call must be "
                                f"a positive finite number, got {us!r}")
    for row in REQUIRED_ROWS:
        if row not in results:
            problems.append(f"missing required substrate row {row!r}")
    return problems


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_agg_time.json"
    problems = check(path)
    if problems:
        for p in problems:
            print(f"BENCH check FAILED: {p}", file=sys.stderr)
        sys.exit(1)
    with open(path) as fh:
        n_rows = len(json.load(fh)["results"])
    print(f"{path}: OK ({n_rows} rules)")


if __name__ == "__main__":
    main()
