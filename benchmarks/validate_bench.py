"""Validate benchmark trajectory JSON files (CI gate).

Usage: python -m benchmarks.validate_bench [FILE ...]

Defaults to ``BENCH_agg_time.json``.  Four schemas are known, dispatched on
the payload's ``schema`` field:

* agg_time (``rule -> 'n=<n>,d=<d>' -> us_per_call``) — must contain the
  four apply substrate rows (multi_bulyan[xla|pallas|fused|sharded]) the
  perf trajectory exists to track, each at the full n ∈ {11, 15} ×
  d ∈ {4096, 1e5, 1e6} substrate grid; the fused row must be *monotone*:
  us_per_call/d non-increasing along d past 1e5 for every n (no deep-grid
  cliff) and within 1.1× the XLA row at the deepest point (n=15, d=1e6);
* resilience (``sim.resilience.v1``) — rule × attack campaign cells from
  ``benchmarks/resilience.py``, each with finite honest-mean deviation,
  byzantine selection mass in [0, 1] and a finite final loss;
* comm (``comm.v1``) — codec × (n, d) wire cells from
  ``benchmarks/bandwidth.py``: positive byte counts and round times, and
  the acceptance ordering wire_bytes fp32 > bf16 > qsgd int8 *strict* on
  every (n, d) point the three rows share;
* accuracy (``accuracy.v1``) — rule × per-worker-batch cells from
  ``benchmarks/accuracy.py``, accuracies in [0, 1];
* hier (``hier.v1``) — hierarchical vs flat scaling cells from
  ``benchmarks/hier_scale.py``: wherever n ≥ 1024 the flat path must be
  skipped-as-infeasible or ≥ 5× slower than the grouped path, and the
  grouped column must grow subquadratically in n (the O(n·g) vs O(n²)
  ordering gate);
* serving (``serving.v2``) — closed-loop async vs sync robust serving
  cells from ``benchmarks/serving.py``: both mode rows present with
  positive finite qps/round_us, per-cell p50/p95/p99 round latency in
  non-decreasing order, and async QPS *strictly above* sync on every
  shared (τ ≥ 1, f > 0) cell — the bounded-staleness buffer must
  actually buy throughput where the byzantine contract is live;
* obs (``bench.obs.v1``) — observability overhead cells from
  ``benchmarks/obs_overhead.py``: every instrumented step type
  (stacked/streaming/async) within the < 3 % per-step overhead budget
  of its uninstrumented baseline;
* analysis (``analysis.v1``) — the static-contract report from
  ``repro.launch.analyze``: zero committed lint violations, every
  sharding contract proven, two-level kernel estimates present at the
  committed grid points, the d=1e6 fused_select launch tiling under a
  budget-fitting multi-window macro block, the traffic-linearity
  diagnosis holding (the deep-grid cliff stays closed), and the
  predicted fused-vs-XLA crossover calibrated against the dispatch
  table (one-sided where the table is censored — no measured loss).

Fails (exit 1) when a file is missing, is not JSON, or deviates from its
schema.
"""
from __future__ import annotations

import json
import math
import re
import sys

REQUIRED_ROWS = ("multi_bulyan[xla]", "multi_bulyan[pallas]",
                 "multi_bulyan[fused]", "multi_bulyan[sharded]")
#: the substrate (n, d) grid every REQUIRED_ROWS row must cover
#: (benchmarks/agg_time.py PATH_NS × PATH_DS)
REQUIRED_CELLS = tuple(f"n={n},d={d}" for n in (11, 15)
                       for d in (4096, 100_000, 1_000_000))
#: d past which the fused row's us_per_call/d must be non-increasing —
#: the two-level kernel's residency claim (below it, fixed plan/launch
#: costs still amortise, so per-coordinate cost legitimately falls)
MONOTONE_MIN_D = 100_000
#: fused must stay within this factor of the XLA substrate at the
#: deepest committed point (n=15, d=1e6) — the cliff-is-closed headline
FUSED_VS_XLA_MAX = 1.1
_KEY_RE = re.compile(r"^n=\d+,d=\d+$")
_BATCH_RE = re.compile(r"^b=\d+$")

AGG_TIME_SCHEMA = "rule -> 'n=<n>,d=<d>' -> us_per_call"
RESILIENCE_SCHEMA = "sim.resilience.v1"
RESILIENCE_FIELDS = ("honest_dev_mean", "honest_dev_max", "byz_mass_mean",
                     "final_loss", "loss_delta_post")
COMM_SCHEMA = "comm.v1"
COMM_FIELDS = ("wire_bytes", "bytes_per_worker", "us_per_call",
               "ratio_vs_fp32")
COMM_ORDER = ("fp32", "bf16", "qsgd:bits=8")   # strictly decreasing bytes
ACCURACY_SCHEMA = "accuracy.v1"
ACCURACY_FIELDS = ("acc_mean", "acc_std")
ANALYSIS_SCHEMA = "analysis.v1"
ANALYSIS_SECTIONS = ("lint", "contracts", "analysis")
ANALYSIS_KERNELS = ("fused_select", "pairwise_stats", "dequant_stats")
HIER_SCHEMA = "hier.v1"
HIER_FIELDS = ("us_per_call", "n_groups", "f_inner", "f_outer",
               "bytes_per_level")
HIER_ROWS = ("multi_bulyan[hier]", "multi_bulyan[flat]")
HIER_FLAT_FACTOR = 5.0          # flat must be >= this × hier at n >= 1024
HIER_BIG_N = 1024
_HIER_KEY_RE = re.compile(r"^n=(\d+),g=(\d+),d=(\d+)$")
SERVING_SCHEMA = "serving.v2"
SERVING_FIELDS = ("qps", "round_us", "round_us_p50", "round_us_p95",
                  "round_us_p99", "agg_us", "stale_rounds",
                  "reused_rounds", "f_defended_mean", "admitted_frac")
SERVING_ROWS = ("multi_bulyan[sync]", "multi_bulyan[async]")
_SERVING_KEY_RE = re.compile(r"^tau=(\d+),f=(\d+)$")
OBS_SCHEMA = "bench.obs.v1"
OBS_FIELDS = ("us_base", "us_obs", "overhead_frac")
OBS_STEPS = ("stacked", "streaming", "async")
OBS_MAX_OVERHEAD = 0.03


def _fail(msg: str) -> "list[str]":
    return [msg]


def _check_agg_time(path: str, results: dict) -> "list[str]":
    problems = []
    for rule, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"rule {rule!r}: empty or non-object grid")
            continue
        for key, us in grid.items():
            if not _KEY_RE.match(key):
                problems.append(f"rule {rule!r}: bad grid key {key!r} "
                                "(want 'n=<n>,d=<d>')")
            if not isinstance(us, (int, float)) or not math.isfinite(us) \
                    or us <= 0:
                problems.append(f"rule {rule!r} [{key}]: us_per_call must be "
                                f"a positive finite number, got {us!r}")
    # the grid-coverage and residency gates apply to full-grid payloads
    # only: a CI smoke run rewrites this file with a single shallow cell
    # (benchmarks/agg_time.py SMOKE_*), where a depth gate is vacuous —
    # same split as BENCH_obs.json.  Any fused cell at d >=
    # MONOTONE_MIN_D marks the payload full-grid.
    fused_cells = _cells_by_n(results.get("multi_bulyan[fused]", {}))
    full_grid = any(d >= MONOTONE_MIN_D
                    for pts in fused_cells.values() for d, _ in pts)
    for row in REQUIRED_ROWS:
        if row not in results:
            problems.append(f"missing required substrate row {row!r}")
            continue
        missing = [c for c in REQUIRED_CELLS if c not in results[row]]
        if missing and full_grid:
            problems.append(f"substrate row {row!r}: missing grid "
                            f"cell(s) {missing}")
    if full_grid:
        problems += _check_fused_monotone(results)
    return problems


def _cells_by_n(grid: dict) -> "dict[int, list[tuple[int, float]]]":
    by_n: dict = {}
    for key, us in grid.items():
        if not (_KEY_RE.match(key) and isinstance(us, (int, float))):
            continue
        kv = dict(p.split("=") for p in key.split(","))
        by_n.setdefault(int(kv["n"]), []).append((int(kv["d"]), us))
    return by_n


def _check_fused_monotone(results: dict) -> "list[str]":
    """The two-level residency gates on the measured fused row.

    * us_per_call/d non-increasing along d past ``MONOTONE_MIN_D`` for
      every n — per-coordinate cost must not degrade with depth (the
      single-level kernel failed exactly this: 0.79 us/coord at d=1e5
      vs 3.0 at d=1e6);
    * fused within ``FUSED_VS_XLA_MAX`` × the XLA substrate at the
      deepest point, n=15, d=1e6 — the fused path may never again be
      the reason to route deep applies to XLA.
    """
    problems = []
    fused = results.get("multi_bulyan[fused]", {})
    for n, pts in sorted(_cells_by_n(fused).items()):
        pts.sort()
        deep = [(d, us) for d, us in pts if d >= MONOTONE_MIN_D]
        for (d1, us1), (d2, us2) in zip(deep, deep[1:]):
            if us2 / d2 > us1 / d1:
                problems.append(
                    f"multi_bulyan[fused] n={n}: us_per_call/d grows from "
                    f"{us1 / d1:.3f} (d={d1}) to {us2 / d2:.3f} (d={d2}) "
                    "— the fused apply path is not monotone in d")
    xla = results.get("multi_bulyan[xla]", {})
    deepest = "n=15,d=1000000"
    f_us, x_us = fused.get(deepest), xla.get(deepest)
    if isinstance(f_us, (int, float)) and isinstance(x_us, (int, float)) \
            and x_us > 0 and f_us > FUSED_VS_XLA_MAX * x_us:
        problems.append(
            f"multi_bulyan[fused] [{deepest}]: {f_us:.0f} us > "
            f"{FUSED_VS_XLA_MAX}x the XLA substrate ({x_us:.0f} us) — "
            "the deep-grid cliff is back")
    return problems


def _check_resilience(path: str, results: dict) -> "list[str]":
    problems = []
    for rule, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"rule {rule!r}: empty or non-object attack grid")
            continue
        for attack, cell in grid.items():
            if not isinstance(cell, dict):
                problems.append(f"{rule}/{attack}: cell must be an object")
                continue
            missing = [f for f in RESILIENCE_FIELDS if f not in cell]
            if missing:
                problems.append(f"{rule}/{attack}: missing {missing}")
            for f in RESILIENCE_FIELDS:
                v = cell.get(f)
                if v is None:
                    continue
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    problems.append(f"{rule}/{attack}: {f} must be finite, "
                                    f"got {v!r}")
            bm = cell.get("byz_mass_mean")
            if isinstance(bm, (int, float)) and not 0.0 <= bm <= 1.0:
                problems.append(f"{rule}/{attack}: byz_mass_mean {bm} "
                                "outside [0, 1]")
            hd = cell.get("honest_dev_mean")
            if isinstance(hd, (int, float)) and hd < 0.0:
                problems.append(f"{rule}/{attack}: negative honest_dev_mean")
    return problems


def _check_comm(path: str, results: dict) -> "list[str]":
    problems = []
    for codec, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"codec {codec!r}: empty or non-object grid")
            continue
        for ckey, cell in grid.items():
            if not _KEY_RE.match(ckey):
                problems.append(f"codec {codec!r}: bad grid key {ckey!r} "
                                "(want 'n=<n>,d=<d>')")
            if not isinstance(cell, dict):
                problems.append(f"{codec}/{ckey}: cell must be an object")
                continue
            missing = [f for f in COMM_FIELDS if f not in cell]
            if missing:
                problems.append(f"{codec}/{ckey}: missing {missing}")
            for f in COMM_FIELDS:
                v = cell.get(f)
                if v is None:
                    continue
                if not isinstance(v, (int, float)) or not math.isfinite(v) \
                        or v <= 0:
                    problems.append(f"{codec}/{ckey}: {f} must be a "
                                    f"positive finite number, got {v!r}")
    missing_rows = [c for c in COMM_ORDER if c not in results]
    if missing_rows:
        problems.append(f"missing required codec row(s) {missing_rows} "
                        f"(the fp32 > bf16 > int8 ordering gate needs them)")
        return problems
    shared = set.intersection(*(set(results[c]) for c in COMM_ORDER))
    if len(shared) < 2:
        problems.append(
            f"need >= 2 shared (n, d) points across {COMM_ORDER}, "
            f"got {sorted(shared)}")
    for ckey in sorted(shared):
        sizes = [results[c][ckey].get("wire_bytes", 0) for c in COMM_ORDER]
        if not (isinstance(sizes[0], (int, float))
                and sizes[0] > sizes[1] > sizes[2] > 0):
            problems.append(
                f"[{ckey}]: wire_bytes not strictly ordered "
                f"fp32 > bf16 > qsgd int8: {dict(zip(COMM_ORDER, sizes))}")
    return problems


def _check_accuracy(path: str, results: dict) -> "list[str]":
    problems = []
    for rule, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"rule {rule!r}: empty or non-object grid")
            continue
        for bkey, cell in grid.items():
            if not _BATCH_RE.match(bkey):
                problems.append(f"rule {rule!r}: bad grid key {bkey!r} "
                                "(want 'b=<batch>')")
            if not isinstance(cell, dict):
                problems.append(f"{rule}/{bkey}: cell must be an object")
                continue
            missing = [f for f in ACCURACY_FIELDS if f not in cell]
            if missing:
                problems.append(f"{rule}/{bkey}: missing {missing}")
            acc = cell.get("acc_mean")
            if acc is not None and (not isinstance(acc, (int, float))
                                    or not 0.0 <= acc <= 1.0):
                problems.append(f"{rule}/{bkey}: acc_mean {acc!r} "
                                "outside [0, 1]")
            std = cell.get("acc_std")
            if std is not None and (not isinstance(std, (int, float))
                                    or std < 0.0 or not math.isfinite(std)):
                problems.append(f"{rule}/{bkey}: bad acc_std {std!r}")
    for rule in ("average", "multi_bulyan"):
        if rule not in results:
            problems.append(f"missing required rule row {rule!r}")
    return problems


def _check_hier(path: str, results: dict) -> "list[str]":
    problems = []
    for row in HIER_ROWS:
        if row not in results:
            problems.append(f"missing required hier row {row!r}")
    cells: dict = {}            # (row, n, g, d) -> cell
    for row, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"row {row!r}: empty or non-object grid")
            continue
        for key, cell in grid.items():
            m = _HIER_KEY_RE.match(key)
            if not m:
                problems.append(f"row {row!r}: bad grid key {key!r} "
                                "(want 'n=<n>,g=<g>,d=<d>')")
                continue
            if not isinstance(cell, dict):
                problems.append(f"{row}/{key}: cell must be an object")
                continue
            cells[(row,) + tuple(int(x) for x in m.groups())] = cell
            if "skipped" in cell:
                if not isinstance(cell["skipped"], str) or not cell["skipped"]:
                    problems.append(f"{row}/{key}: 'skipped' must carry a "
                                    "non-empty reason string")
                continue
            missing = [f for f in HIER_FIELDS if f not in cell]
            if missing:
                problems.append(f"{row}/{key}: missing {missing}")
            us = cell.get("us_per_call")
            if not isinstance(us, (int, float)) or not math.isfinite(us) \
                    or us <= 0:
                problems.append(f"{row}/{key}: us_per_call must be a "
                                f"positive finite number, got {us!r}")
            bpl = cell.get("bytes_per_level")
            if not (isinstance(bpl, list) and bpl
                    and all(isinstance(b, int) and b > 0 for b in bpl)):
                problems.append(f"{row}/{key}: bytes_per_level must be a "
                                f"non-empty list of positive ints, got {bpl!r}")
    hier = {(n, g, d): c for (row, n, g, d), c in cells.items()
            if row == "multi_bulyan[hier]" and "us_per_call" in c}
    flat = {(n, d): c for (row, n, g, d), c in cells.items()
            if row == "multi_bulyan[flat]"}
    if not hier:
        problems.append("no completed multi_bulyan[hier] cells")
        return problems
    # the scaling claim: at n >= 1024 the grouped path completes while the
    # flat path is skipped-as-infeasible or >= 5x slower
    for (n, g, d), hc in sorted(hier.items()):
        if n < HIER_BIG_N:
            continue
        fc = flat.get((n, d))
        if fc is None or "skipped" in fc:
            continue
        ratio = fc["us_per_call"] / max(hc["us_per_call"], 1e-9)
        if ratio < HIER_FLAT_FACTOR:
            problems.append(
                f"n={n},d={d}: flat path only {ratio:.1f}x the grouped "
                f"path (< {HIER_FLAT_FACTOR}x) and not skipped — the "
                "O(n·g) vs O(n²) claim does not hold")
    # O(n·g) ordering: with g and d fixed, grouped time must grow
    # subquadratically in n wherever the grid reaches n >= 1024
    by_gd: dict = {}
    for (n, g, d), hc in hier.items():
        by_gd.setdefault((g, d), []).append((n, hc["us_per_call"]))
    for (g, d), pts in sorted(by_gd.items()):
        pts.sort()
        for (n1, t1), (n2, t2) in zip(pts, pts[1:]):
            if n2 < HIER_BIG_N:
                continue
            quad = (n2 / n1) ** 2
            if t2 / max(t1, 1e-9) >= quad:
                problems.append(
                    f"g={g},d={d}: grouped time grows >= quadratically "
                    f"from n={n1} to n={n2} "
                    f"({t1:.0f} -> {t2:.0f} us, quadratic x{quad:.1f})")
    return problems


def _check_serving(path: str, results: dict) -> "list[str]":
    problems = []
    for row in SERVING_ROWS:
        if row not in results:
            problems.append(f"missing required serving row {row!r}")
    cells: dict = {}            # (row, tau, f) -> cell
    for row, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"row {row!r}: empty or non-object grid")
            continue
        for key, cell in grid.items():
            m = _SERVING_KEY_RE.match(key)
            if not m:
                problems.append(f"row {row!r}: bad grid key {key!r} "
                                "(want 'tau=<t>,f=<f>')")
                continue
            if not isinstance(cell, dict):
                problems.append(f"{row}/{key}: cell must be an object")
                continue
            cells[(row,) + tuple(int(x) for x in m.groups())] = cell
            missing = [f for f in SERVING_FIELDS if f not in cell]
            if missing:
                problems.append(f"{row}/{key}: missing {missing}")
            for f in ("qps", "round_us", "round_us_p50", "round_us_p95",
                      "round_us_p99"):
                v = cell.get(f)
                if not isinstance(v, (int, float)) or not math.isfinite(v) \
                        or v <= 0:
                    problems.append(f"{row}/{key}: {f} must be a positive "
                                    f"finite number, got {v!r}")
            ps = [cell.get(f) for f in ("round_us_p50", "round_us_p95",
                                        "round_us_p99")]
            if all(isinstance(p, (int, float)) for p in ps) and \
                    not ps[0] <= ps[1] <= ps[2]:
                problems.append(
                    f"{row}/{key}: percentiles not non-decreasing "
                    f"(p50={ps[0]!r}, p95={ps[1]!r}, p99={ps[2]!r})")
            af = cell.get("admitted_frac")
            if isinstance(af, (int, float)) and not 0.0 <= af <= 1.0:
                problems.append(f"{row}/{key}: admitted_frac {af} "
                                "outside [0, 1]")
    # the throughput claim: async strictly beats sync wherever the
    # byzantine contract is live and staleness is actually tolerated
    sync = {(t, f): c for (row, t, f), c in cells.items()
            if row == SERVING_ROWS[0]}
    asyn = {(t, f): c for (row, t, f), c in cells.items()
            if row == SERVING_ROWS[1]}
    live = [(t, f) for (t, f) in sorted(set(sync) & set(asyn))
            if t >= 1 and f > 0]
    if not live:
        problems.append("no shared (tau >= 1, f > 0) cell — the "
                        "async-beats-sync ordering gate has nothing to "
                        "check")
    for (t, f) in live:
        sq, aq = sync[(t, f)].get("qps"), asyn[(t, f)].get("qps")
        if not (isinstance(sq, (int, float)) and isinstance(aq, (int, float))
                and aq > sq):
            problems.append(
                f"tau={t},f={f}: async qps ({aq!r}) not strictly above "
                f"sync qps ({sq!r}) — the bounded-staleness buffer bought "
                "no throughput")
    return problems


def _check_obs(path: str, results: dict) -> "list[str]":
    """The observability overhead gate: < 3 % on every step type."""
    problems = []
    for step in OBS_STEPS:
        if step not in results:
            problems.append(f"missing required obs step row {step!r}")
    for step, cell in results.items():
        if not isinstance(cell, dict):
            problems.append(f"{step}: cell must be an object")
            continue
        missing = [f for f in OBS_FIELDS if f not in cell]
        if missing:
            problems.append(f"{step}: missing {missing}")
        for f in ("us_base", "us_obs"):
            v = cell.get(f)
            if not isinstance(v, (int, float)) or not math.isfinite(v) \
                    or v <= 0:
                problems.append(f"{step}: {f} must be a positive finite "
                                f"number, got {v!r}")
        frac = cell.get("overhead_frac")
        if not isinstance(frac, (int, float)) or not math.isfinite(frac):
            problems.append(f"{step}: overhead_frac must be finite, "
                            f"got {frac!r}")
        elif frac >= OBS_MAX_OVERHEAD:
            problems.append(
                f"{step}: obs overhead {frac * 100:.2f}% >= "
                f"{OBS_MAX_OVERHEAD * 100:.0f}% budget "
                f"(us_base={cell.get('us_base')!r}, "
                f"us_obs={cell.get('us_obs')!r}) — the in-graph registry "
                "must stay effectively free")
    return problems


def _check_analysis(path: str, results: dict) -> "list[str]":
    """The static-contract report: ships only when everything is proven."""
    problems = []
    missing = [s for s in ANALYSIS_SECTIONS if s not in results]
    if missing:
        return _fail(f"{path}: missing section(s) {missing}")
    for v in results["lint"].get("violations", [{"rule": "?"}]):
        problems.append(f"lint violation committed: {v.get('rule')} "
                        f"{v.get('path')}:{v.get('line')}: {v.get('msg')}")
    contracts = results["contracts"]
    if not contracts:
        problems.append("no contracts audited")
    for name, cell in contracts.items():
        if cell.get("status") != "proven":
            problems.append(f"contract {name}: status "
                            f"{cell.get('status')!r}, want 'proven' "
                            f"({'; '.join(cell.get('violations', []))})")
    analysis = results["analysis"]
    for kernel in ANALYSIS_KERNELS:
        grid = analysis.get("kernels", {}).get(kernel)
        if not grid:
            problems.append(f"missing kernel estimates for {kernel!r}")
            continue
        for key, est in grid.items():
            if not _KEY_RE.match(key):
                problems.append(f"{kernel}: bad grid key {key!r}")
            for f in ("d_tile", "macro_tile", "windows", "grid_steps",
                      "vmem_bytes", "hbm_read_bytes"):
                v = est.get(f)
                if not isinstance(v, int) or v <= 0:
                    problems.append(f"{kernel}/{key}: {f} must be a "
                                    f"positive int, got {v!r}")
    traffic = analysis.get("traffic_linearity", {})
    if not traffic.get("holds"):
        problems.append("vmem traffic-linearity diagnosis does not hold: "
                        f"{traffic.get('detail')!r}")
    d1e6 = analysis.get("kernels", {}).get("fused_select", {}) \
        .get("n=15,d=1000000")
    if not (d1e6 and d1e6.get("over_budget")
            and not d1e6.get("tile_over_budget")
            and d1e6.get("macro_tile", 0) > d1e6.get("d_tile", 0)):
        problems.append("fused_select n=15,d=1e6 must tile (over_budget), "
                        "fit per macro step, and run a multi-window macro "
                        "block — the two-level residency claim fails")
    for key, x in analysis.get("crossover", {}).items():
        if not x.get("calibrated"):
            problems.append(
                f"crossover {key}: predicted {x.get('predicted_numel')!r} "
                f"vs measured {x.get('measured_numel')!r} "
                f"(ratio {x.get('ratio')!r}, censored={x.get('censored')!r})"
                " — static model uncalibrated against the dispatch table")
    return problems


def check(path: str) -> "list[str]":
    """Return a list of problems (empty = valid)."""
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except FileNotFoundError:
        return _fail(f"{path}: missing — run `python -m benchmarks.run`")
    except json.JSONDecodeError as e:
        return _fail(f"{path}: not valid JSON ({e})")
    if not isinstance(payload, dict) or "results" not in payload:
        return _fail(f"{path}: top level must be an object with 'results'")
    problems = []
    if "schema" not in payload:
        problems.append(f"{path}: missing 'schema' field")
    results = payload["results"]
    if not isinstance(results, dict) or not results:
        return _fail(f"{path}: 'results' must be a non-empty object")
    schema = payload.get("schema")
    if schema == RESILIENCE_SCHEMA:
        problems += _check_resilience(path, results)
    elif schema == COMM_SCHEMA:
        problems += _check_comm(path, results)
    elif schema == ACCURACY_SCHEMA:
        problems += _check_accuracy(path, results)
    elif schema == HIER_SCHEMA:
        problems += _check_hier(path, results)
    elif schema == SERVING_SCHEMA:
        problems += _check_serving(path, results)
    elif schema == OBS_SCHEMA:
        problems += _check_obs(path, results)
    elif schema == ANALYSIS_SCHEMA:
        problems += _check_analysis(path, results)
    elif schema == AGG_TIME_SCHEMA or schema is None:
        # None: legacy agg_time files predate the schema tag — still
        # validate the grid, with the missing-field problem noted above
        problems += _check_agg_time(path, results)
    else:
        problems.append(
            f"{path}: unrecognised schema {schema!r}; known: "
            f"{[AGG_TIME_SCHEMA, RESILIENCE_SCHEMA, COMM_SCHEMA, ACCURACY_SCHEMA, HIER_SCHEMA, SERVING_SCHEMA, OBS_SCHEMA, ANALYSIS_SCHEMA]}")
    return problems


def main() -> None:
    paths = sys.argv[1:] or ["BENCH_agg_time.json"]
    failed = False
    for path in paths:
        problems = check(path)
        if problems:
            failed = True
            for p in problems:
                print(f"BENCH check FAILED: {p}", file=sys.stderr)
            continue
        with open(path) as fh:
            n_rows = len(json.load(fh)["results"])
        print(f"{path}: OK ({n_rows} rules)")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
