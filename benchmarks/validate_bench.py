"""Validate benchmark trajectory JSON files (CI gate).

Usage: python -m benchmarks.validate_bench [FILE ...]

Defaults to ``BENCH_agg_time.json``.  Four schemas are known, dispatched on
the payload's ``schema`` field:

* agg_time (``rule -> 'n=<n>,d=<d>' -> us_per_call``) — must contain the
  four apply substrate rows (multi_bulyan[xla|pallas|fused|sharded]) the
  perf trajectory exists to track;
* resilience (``sim.resilience.v1``) — rule × attack campaign cells from
  ``benchmarks/resilience.py``, each with finite honest-mean deviation,
  byzantine selection mass in [0, 1] and a finite final loss;
* comm (``comm.v1``) — codec × (n, d) wire cells from
  ``benchmarks/bandwidth.py``: positive byte counts and round times, and
  the acceptance ordering wire_bytes fp32 > bf16 > qsgd int8 *strict* on
  every (n, d) point the three rows share;
* accuracy (``accuracy.v1``) — rule × per-worker-batch cells from
  ``benchmarks/accuracy.py``, accuracies in [0, 1].

Fails (exit 1) when a file is missing, is not JSON, or deviates from its
schema.
"""
from __future__ import annotations

import json
import math
import re
import sys

REQUIRED_ROWS = ("multi_bulyan[xla]", "multi_bulyan[pallas]",
                 "multi_bulyan[fused]", "multi_bulyan[sharded]")
_KEY_RE = re.compile(r"^n=\d+,d=\d+$")
_BATCH_RE = re.compile(r"^b=\d+$")

AGG_TIME_SCHEMA = "rule -> 'n=<n>,d=<d>' -> us_per_call"
RESILIENCE_SCHEMA = "sim.resilience.v1"
RESILIENCE_FIELDS = ("honest_dev_mean", "honest_dev_max", "byz_mass_mean",
                     "final_loss", "loss_delta_post")
COMM_SCHEMA = "comm.v1"
COMM_FIELDS = ("wire_bytes", "bytes_per_worker", "us_per_call",
               "ratio_vs_fp32")
COMM_ORDER = ("fp32", "bf16", "qsgd:bits=8")   # strictly decreasing bytes
ACCURACY_SCHEMA = "accuracy.v1"
ACCURACY_FIELDS = ("acc_mean", "acc_std")


def _fail(msg: str) -> "list[str]":
    return [msg]


def _check_agg_time(path: str, results: dict) -> "list[str]":
    problems = []
    for rule, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"rule {rule!r}: empty or non-object grid")
            continue
        for key, us in grid.items():
            if not _KEY_RE.match(key):
                problems.append(f"rule {rule!r}: bad grid key {key!r} "
                                "(want 'n=<n>,d=<d>')")
            if not isinstance(us, (int, float)) or not math.isfinite(us) \
                    or us <= 0:
                problems.append(f"rule {rule!r} [{key}]: us_per_call must be "
                                f"a positive finite number, got {us!r}")
    for row in REQUIRED_ROWS:
        if row not in results:
            problems.append(f"missing required substrate row {row!r}")
    return problems


def _check_resilience(path: str, results: dict) -> "list[str]":
    problems = []
    for rule, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"rule {rule!r}: empty or non-object attack grid")
            continue
        for attack, cell in grid.items():
            if not isinstance(cell, dict):
                problems.append(f"{rule}/{attack}: cell must be an object")
                continue
            missing = [f for f in RESILIENCE_FIELDS if f not in cell]
            if missing:
                problems.append(f"{rule}/{attack}: missing {missing}")
            for f in RESILIENCE_FIELDS:
                v = cell.get(f)
                if v is None:
                    continue
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    problems.append(f"{rule}/{attack}: {f} must be finite, "
                                    f"got {v!r}")
            bm = cell.get("byz_mass_mean")
            if isinstance(bm, (int, float)) and not 0.0 <= bm <= 1.0:
                problems.append(f"{rule}/{attack}: byz_mass_mean {bm} "
                                "outside [0, 1]")
            hd = cell.get("honest_dev_mean")
            if isinstance(hd, (int, float)) and hd < 0.0:
                problems.append(f"{rule}/{attack}: negative honest_dev_mean")
    return problems


def _check_comm(path: str, results: dict) -> "list[str]":
    problems = []
    for codec, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"codec {codec!r}: empty or non-object grid")
            continue
        for ckey, cell in grid.items():
            if not _KEY_RE.match(ckey):
                problems.append(f"codec {codec!r}: bad grid key {ckey!r} "
                                "(want 'n=<n>,d=<d>')")
            if not isinstance(cell, dict):
                problems.append(f"{codec}/{ckey}: cell must be an object")
                continue
            missing = [f for f in COMM_FIELDS if f not in cell]
            if missing:
                problems.append(f"{codec}/{ckey}: missing {missing}")
            for f in COMM_FIELDS:
                v = cell.get(f)
                if v is None:
                    continue
                if not isinstance(v, (int, float)) or not math.isfinite(v) \
                        or v <= 0:
                    problems.append(f"{codec}/{ckey}: {f} must be a "
                                    f"positive finite number, got {v!r}")
    missing_rows = [c for c in COMM_ORDER if c not in results]
    if missing_rows:
        problems.append(f"missing required codec row(s) {missing_rows} "
                        f"(the fp32 > bf16 > int8 ordering gate needs them)")
        return problems
    shared = set.intersection(*(set(results[c]) for c in COMM_ORDER))
    if len(shared) < 2:
        problems.append(
            f"need >= 2 shared (n, d) points across {COMM_ORDER}, "
            f"got {sorted(shared)}")
    for ckey in sorted(shared):
        sizes = [results[c][ckey].get("wire_bytes", 0) for c in COMM_ORDER]
        if not (isinstance(sizes[0], (int, float))
                and sizes[0] > sizes[1] > sizes[2] > 0):
            problems.append(
                f"[{ckey}]: wire_bytes not strictly ordered "
                f"fp32 > bf16 > qsgd int8: {dict(zip(COMM_ORDER, sizes))}")
    return problems


def _check_accuracy(path: str, results: dict) -> "list[str]":
    problems = []
    for rule, grid in results.items():
        if not isinstance(grid, dict) or not grid:
            problems.append(f"rule {rule!r}: empty or non-object grid")
            continue
        for bkey, cell in grid.items():
            if not _BATCH_RE.match(bkey):
                problems.append(f"rule {rule!r}: bad grid key {bkey!r} "
                                "(want 'b=<batch>')")
            if not isinstance(cell, dict):
                problems.append(f"{rule}/{bkey}: cell must be an object")
                continue
            missing = [f for f in ACCURACY_FIELDS if f not in cell]
            if missing:
                problems.append(f"{rule}/{bkey}: missing {missing}")
            acc = cell.get("acc_mean")
            if acc is not None and (not isinstance(acc, (int, float))
                                    or not 0.0 <= acc <= 1.0):
                problems.append(f"{rule}/{bkey}: acc_mean {acc!r} "
                                "outside [0, 1]")
            std = cell.get("acc_std")
            if std is not None and (not isinstance(std, (int, float))
                                    or std < 0.0 or not math.isfinite(std)):
                problems.append(f"{rule}/{bkey}: bad acc_std {std!r}")
    for rule in ("average", "multi_bulyan"):
        if rule not in results:
            problems.append(f"missing required rule row {rule!r}")
    return problems


def check(path: str) -> "list[str]":
    """Return a list of problems (empty = valid)."""
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except FileNotFoundError:
        return _fail(f"{path}: missing — run `python -m benchmarks.run`")
    except json.JSONDecodeError as e:
        return _fail(f"{path}: not valid JSON ({e})")
    if not isinstance(payload, dict) or "results" not in payload:
        return _fail(f"{path}: top level must be an object with 'results'")
    problems = []
    if "schema" not in payload:
        problems.append(f"{path}: missing 'schema' field")
    results = payload["results"]
    if not isinstance(results, dict) or not results:
        return _fail(f"{path}: 'results' must be a non-empty object")
    schema = payload.get("schema")
    if schema == RESILIENCE_SCHEMA:
        problems += _check_resilience(path, results)
    elif schema == COMM_SCHEMA:
        problems += _check_comm(path, results)
    elif schema == ACCURACY_SCHEMA:
        problems += _check_accuracy(path, results)
    elif schema == AGG_TIME_SCHEMA or schema is None:
        # None: legacy agg_time files predate the schema tag — still
        # validate the grid, with the missing-field problem noted above
        problems += _check_agg_time(path, results)
    else:
        problems.append(
            f"{path}: unrecognised schema {schema!r}; known: "
            f"{[AGG_TIME_SCHEMA, RESILIENCE_SCHEMA, COMM_SCHEMA, ACCURACY_SCHEMA]}")
    return problems


def main() -> None:
    paths = sys.argv[1:] or ["BENCH_agg_time.json"]
    failed = False
    for path in paths:
        problems = check(path)
        if problems:
            failed = True
            for p in problems:
                print(f"BENCH check FAILED: {p}", file=sys.stderr)
            continue
        with open(path) as fh:
            n_rows = len(json.load(fh)["results"])
        print(f"{path}: OK ({n_rows} rules)")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
