"""Closed-loop robust serving throughput: async buffer vs lockstep rounds.

The claim under test (DESIGN.md §13): with stragglers in the worker pool,
the bounded-staleness buffer sustains strictly higher closed-loop QPS than
the synchronous round — the sync round pays the slowest worker's latency
every round, the async round pays a fixed admission deadline and charges
late workers against the byzantine budget instead of the clock.

Grid: staleness bound τ × byzantine contract f, both modes per cell.
Worker latencies come from a seeded lognormal straggler model
(``repro.serve.loadgen`` — this benchmark never sleeps); the aggregation
compute per round is *measured* on the real jitted service round, and all
staleness accounting (overstale slots, plan reuse, the f haircut) is
replayed through the real ``repro.serve.buffer``.

Persists ``BENCH_serving.json``
(schema ``serving.v2``: mode row -> "tau=<t>,f=<f>" -> cell) for
``benchmarks/validate_bench.py``'s async-beats-sync ordering gate.  v2
adds per-cell ``round_us_p50/p95/p99`` — v1 collapsed the rounds to a
mean before any percentile could exist, hiding the straggler tail the
staleness bound is there to control.

CSV: name,us_per_call,derived (value column = closed-loop QPS).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

from repro.serve.loadgen import LoadConfig, run_closed_loop

SERVING_JSON = "BENCH_serving.json"
SCHEMA = "serving.v2"

TAUS = (1, 2, 4)
FS = (0, 2)
BASE = LoadConfig(n=11, d=65536, rounds=40, microbatch=8, seed=0)

SMOKE_TAUS = (1,)
SMOKE_FS = (2,)
SMOKE_BASE = LoadConfig(n=11, d=4096, rounds=10, microbatch=8, seed=0)


def write_json(results: Dict[str, Dict[str, Dict[str, float]]],
               meta: Dict[str, float], path: str = SERVING_JSON) -> None:
    payload = {"schema": SCHEMA, "meta": meta, "results": results}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)


def run(csv_rows: List[str], *, smoke: bool = False,
        json_path: str = SERVING_JSON
        ) -> Dict[str, Dict[str, Dict[str, float]]]:
    base, taus, fs = (SMOKE_BASE, SMOKE_TAUS, SMOKE_FS) if smoke \
        else (BASE, TAUS, FS)
    rows = (f"{base.gar}[sync]", f"{base.gar}[async]")
    results: Dict[str, Dict[str, Dict[str, float]]] = {r: {} for r in rows}
    for f in fs:
        for tau in taus:
            cfg = dataclasses.replace(base, tau=tau, f=f)
            for mode, row in zip(("sync", "async"), rows):
                cell = run_closed_loop(cfg, mode)
                results[row][f"tau={tau},f={f}"] = cell
                csv_rows.append(
                    f"serving/{row}/tau={tau}/f={f},{cell['qps']:.1f},"
                    f"qps_round_us={cell['round_us']:.0f}_"
                    f"stale={cell['stale_rounds']}")
            ratio = (results[rows[1]][f"tau={tau},f={f}"]["qps"]
                     / max(results[rows[0]][f"tau={tau},f={f}"]["qps"],
                           1e-9))
            csv_rows.append(
                f"serving/async_over_sync_qps/tau={tau}/f={f},"
                f"{ratio:.2f},closed_loop_speedup")
    meta = {"n": base.n, "d": base.d, "rounds": base.rounds,
            "microbatch": base.microbatch, "mean_ms": base.mean_ms,
            "stragglers": base.stragglers,
            "straggler_mult": base.straggler_mult,
            "deadline_quantile": base.deadline_quantile}
    write_json(results, meta, json_path)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=SERVING_JSON)
    args = ap.parse_args()
    rows: List[str] = []
    run(rows, smoke=args.smoke, json_path=args.json)
    print("name,us_per_call,derived")
    print("\n".join(rows))
