"""Fig 2 reproduction: GAR aggregation time as a function of (n, d).

Paper protocol (§V-A): n gradients ~ U(0,1)^d; 7 timed runs per (n, d);
drop the 2 farthest from the median; report mean±std of the remaining 5.
Hardware differs (the paper uses a GTX 1080 Ti; this container is CPU-only)
so absolute times differ — the claims under test are the SHAPES:

* O(d) scaling: aggregation time linear in d for every rule (Thm 2(ii));
* O(n²) scaling in the number of workers for (MULTI-)KRUM/BULYAN;
* MEDIAN's advantage shrinks as d grows (the paper's crossover argument).

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gar

# CPU-sized version of the paper's grid (paper: n up to 39, d up to 1e7)
NS = (7, 11, 15, 19, 23)
DS = (100_000, 1_000_000)
RULES = ("median", "multi_krum", "multi_bulyan")


def _f_for(n: int) -> int:
    return max(1, (n - 3) // 4)  # the paper's f = floor((n-3)/4)


def _timed(fn, *args, reps: int = 7, drop: int = 2) -> Tuple[float, float]:
    out = fn(*args)
    jax.block_until_ready(out)  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    med = np.median(times)
    keep = times[np.argsort(np.abs(times - med))][: reps - drop]
    return float(keep.mean()), float(keep.std())


def run(csv_rows: List[str]) -> Dict[str, Dict[Tuple[int, int], float]]:
    rng = np.random.default_rng(0)
    results: Dict[str, Dict[Tuple[int, int], float]] = {r: {} for r in RULES}
    jitted = {name: jax.jit(gar.get_gar(name), static_argnames=("f",))
              for name in RULES}
    for d in DS:
        for n in NS:
            G = jnp.asarray(rng.uniform(size=(n, d)).astype(np.float32))
            f = _f_for(n)
            for name in RULES:
                mean, std = _timed(lambda g: jitted[name](g, f=f), G)
                results[name][(n, d)] = mean
                csv_rows.append(
                    f"agg_time/{name}/n={n}/d={d},{mean*1e6:.1f},"
                    f"std_us={std*1e6:.1f}")
    # derived claims
    for name in RULES:
        r = results[name]
        # O(d): time(d=1e6)/time(d=1e5) ≈ 10 for linear scaling (n fixed 15)
        ratio_d = r[(15, DS[1])] / max(r[(15, DS[0])], 1e-9)
        csv_rows.append(f"agg_time/{name}/d_scaling_ratio,{ratio_d:.2f},"
                        f"linear_target=10.0")
    # crossover: median vs multi_bulyan advantage shrinking with d
    for d in DS:
        adv = results["median"][(15, d)] / results["multi_bulyan"][(15, d)]
        csv_rows.append(f"agg_time/median_over_multibulyan/d={d},{adv:.3f},"
                        "higher_means_mb_faster")
    return results


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)
    print("\n".join(rows))
