"""Fig 2 reproduction: GAR aggregation time as a function of (n, d).

Paper protocol (§V-A): n gradients ~ U(0,1)^d; 7 timed runs per (n, d);
drop the 2 farthest from the median; report mean±std of the remaining 5.
Hardware differs (the paper uses a GTX 1080 Ti; this container is CPU-only)
so absolute times differ — the claims under test are the SHAPES:

* O(d) scaling: aggregation time linear in d for every rule (Thm 2(ii));
* O(n²) scaling in the number of workers for (MULTI-)KRUM/BULYAN;
* MEDIAN's advantage shrinks as d grows (the paper's crossover argument).

On top of the paper's grid this times the apply substrates for
multi_bulyan — ``[xla]`` (unfused tensordots + coordinate phase),
``[pallas]`` (materialised einsums + coord_select kernel), ``[fused]``
(single fused_select kernel, no (θ, d) HBM intermediates) and ``[sharded]``
(the whole stats→plan→apply pipeline mesh-native through shard_map over
the host mesh — DESIGN.md §10) — and persists everything to
``BENCH_agg_time.json`` so later PRs have a perf trajectory to diff
against (schema: rule -> "n=<n>,d=<d>" -> us_per_call).  On CPU the
Pallas rows run in interpret mode and the sharded row usually sees a 1×1
host mesh: those absolute numbers measure schedule + partitioning
overhead, not the hardware — the TPU claims are the HBM-traffic count and
the n/W row-block split of the distance phase.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import functools
import json
import time
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import api, gar

# CPU-sized version of the paper's grid (paper: n up to 39, d up to 1e7)
NS = (7, 11, 15, 19, 23)
DS = (100_000, 1_000_000)
RULES = ("median", "multi_krum", "multi_bulyan")
# apply-substrate comparison rows (the fused-path trajectory).  Timed on
# a reduced (n, d) product — n ∈ {11, 15} × d ∈ {4096, 1e5, 1e6}:
# interpret-mode Pallas costs hundreds of ms per call at d=1e6, so the
# full Fig-2 grid would dwarf the rule rows.  The d=4096 cell anchors the
# small-d end of the dispatch table; the deep cells are the monotonicity
# evidence (us_per_call/d non-increasing — validate_bench gates on it).
PATHS = (
    ("multi_bulyan[xla]", dict(use_pallas=False, fused=False)),
    ("multi_bulyan[pallas]", dict(use_pallas=True, fused=False)),
    # "force" pins the fused kernel regardless of the dispatch table —
    # these rows ARE the crossover measurement kernels.dispatch reads
    ("multi_bulyan[fused]", dict(use_pallas=True, fused="force")),
    ("multi_bulyan[sharded]", dict(sharded=True)),
)
PATH_NS = (11, 15)
PATH_DS = (4096,) + DS
BENCH_JSON = "BENCH_agg_time.json"

SMOKE_NS = (11,)
SMOKE_DS = (4096,)


def _f_for(n: int) -> int:
    return max(1, (n - 3) // 4)  # the paper's f = floor((n-3)/4)


def _timed(fn, *args, reps: int = 7, drop: int = 2) -> Tuple[float, float]:
    out = fn(*args)
    jax.block_until_ready(out)  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    med = np.median(times)
    keep = times[np.argsort(np.abs(times - med))][: reps - drop]
    return float(keep.mean()), float(keep.std())


def _path_fn(f: int, sharded: bool = False, **kw):
    if sharded:
        from repro.launch.mesh import make_host_mesh
        kw["mesh_ctx"] = api.MeshContext.for_mesh(make_host_mesh())
    return jax.jit(functools.partial(
        api.aggregate_tree, f=f, name="multi_bulyan", **kw))


def write_json(results: Dict[str, Dict[Tuple[int, int], float]],
               path: str = BENCH_JSON) -> None:
    payload = {
        "schema": "rule -> 'n=<n>,d=<d>' -> us_per_call",
        "results": {
            rule: {f"n={n},d={d}": us * 1e6 for (n, d), us in grid.items()}
            for rule, grid in results.items()
        },
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)


def run(csv_rows: List[str], *, smoke: bool = False,
        json_path: str = BENCH_JSON) -> Dict[str, Dict[Tuple[int, int], float]]:
    rng = np.random.default_rng(0)
    ns, ds = (SMOKE_NS, SMOKE_DS) if smoke else (NS, DS)
    path_ns = ns if smoke else PATH_NS
    reps, drop = (3, 1) if smoke else (7, 2)
    path_reps, path_drop = (3, 1) if smoke else (5, 1)
    rows = list(RULES) + [name for name, _ in PATHS]
    results: Dict[str, Dict[Tuple[int, int], float]] = {r: {} for r in rows}
    jitted = {name: jax.jit(gar.get_gar(name), static_argnames=("f",))
              for name in RULES}
    for d in ds:
        for n in ns:
            G = jnp.asarray(rng.uniform(size=(n, d)).astype(np.float32))
            f = _f_for(n)
            for name in RULES:
                mean, std = _timed(lambda g: jitted[name](g, f=f), G,
                                   reps=reps, drop=drop)
                results[name][(n, d)] = mean
                csv_rows.append(
                    f"agg_time/{name}/n={n}/d={d},{mean*1e6:.1f},"
                    f"std_us={std*1e6:.1f}")
    path_ds = ds if smoke else PATH_DS
    for d in path_ds:
        for n in path_ns:
            G = jnp.asarray(rng.uniform(size=(n, d)).astype(np.float32))
            f = _f_for(n)
            for name, kw in PATHS:
                mean, std = _timed(_path_fn(f, **kw), G,
                                   reps=path_reps, drop=path_drop)
                results[name][(n, d)] = mean
                csv_rows.append(
                    f"agg_time/{name}/n={n}/d={d},{mean*1e6:.1f},"
                    f"std_us={std*1e6:.1f}")
    # derived claims (full grid only — the smoke grid has a single point)
    if not smoke:
        for name in RULES:
            r = results[name]
            # O(d): time(d=1e6)/time(d=1e5) ≈ 10 for linear scaling (n = 15)
            ratio_d = r[(15, ds[1])] / max(r[(15, ds[0])], 1e-9)
            csv_rows.append(f"agg_time/{name}/d_scaling_ratio,{ratio_d:.2f},"
                            f"linear_target=10.0")
        # crossover: median vs multi_bulyan advantage shrinking with d
        for d in ds:
            adv = results["median"][(15, d)] / results["multi_bulyan"][(15, d)]
            csv_rows.append(
                f"agg_time/median_over_multibulyan/d={d},{adv:.3f},"
                "higher_means_mb_faster")
        # fusion win: fused vs two-step pallas apply at the largest point
        big = (max(path_ns), max(path_ds))
        speedup = (results["multi_bulyan[pallas]"][big]
                   / max(results["multi_bulyan[fused]"][big], 1e-9))
        csv_rows.append(f"agg_time/fused_over_pallas_speedup,{speedup:.2f},"
                        "interpret_mode_schedule_only")
    write_json(results, json_path)
    return results


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)
    print("\n".join(rows))
