"""Resilience sweep: attack-schedule campaigns through the sim engine.

Rewritten (PR 3) from standalone single-shot GAR measurements to full
campaigns: every (rule × attack) cell runs a warmup -> attack switch
scenario through ``repro.sim.run_campaign`` and reports the *post-switch*
plan-level telemetry — honest-mean deviation, byzantine selection mass and
loss progress — which is the paper's robustness story measured end to end
(GAR + optimizer + schedule) instead of on isolated gradient stacks.

CSV rows: ``resilience/<rule>/<attack>,<honest_dev_mean>,<derived>`` where
the value column is the post-switch mean relative deviation of the
aggregate from the honest mean (0 = oracle; averaging under attack is
pulled ~z·f/n·σ/||g|| away).

Persists ``BENCH_resilience.json``::

    {"schema": "sim.resilience.v1",
     "results": {rule: {attack: {"honest_dev_mean": .., "honest_dev_max": ..,
                                 "byz_mass_mean": .., "final_loss": ..,
                                 "loss_delta_post": ..}}}}

``benchmarks/validate_bench.py`` gates this schema in CI.
"""
from __future__ import annotations

import json
import os
from typing import List

from repro.sim import run_campaign, switch_scenario

RULES = ("average", "median", "multi_krum", "multi_bulyan")
ATTACKS = ("sign_flip", "little_is_enough:z=1.5", "little_is_enough:z=4.0",
           "omniscient")
SMOKE_RULES = ("average", "multi_bulyan")
SMOKE_ATTACKS = ("little_is_enough:z=4.0",)

N, F = 11, 2


def run(csv_rows: List[str], *, smoke: bool = False,
        json_path: str = "BENCH_resilience.json") -> None:
    rules = SMOKE_RULES if smoke else RULES
    attacks = SMOKE_ATTACKS if smoke else ATTACKS
    pre, post = (8, 8) if smoke else (12, 16)

    results: dict = {}
    for rule in rules:
        results[rule] = {}
        for attack in attacks:
            sc = switch_scenario(rule, pre=pre, post=post, attack=attack,
                                 n_workers=N, f=F)
            r = run_campaign(sc)
            ph_pre, ph_post = r.summary["phases"][0], r.summary["phases"][-1]
            cell = {
                "honest_dev_mean": round(ph_post["honest_dev_mean"], 6),
                "honest_dev_max": round(ph_post["honest_dev_max"], 6),
                "byz_mass_mean": round(ph_post["byz_mass_mean"], 6),
                "final_loss": round(ph_post["loss_last"], 6),
                # loss progress while under attack (negative = learning)
                "loss_delta_post": round(
                    ph_post["loss_last"] - ph_pre["loss_last"], 6),
            }
            results[rule][attack] = cell
            csv_rows.append(
                f"resilience/{rule}/{attack},"
                f"{cell['honest_dev_mean']:.4f},"
                f"byz_mass={cell['byz_mass_mean']:.4f}"
                f"_dloss={cell['loss_delta_post']:+.3f}")

    payload = {
        "schema": "sim.resilience.v1",
        "protocol": {"n_workers": N, "f": F, "pre_steps": pre,
                     "post_steps": post, "smoke": smoke,
                     "scenario": "switch (none -> attack), tiny dense LM"},
        "results": results,
    }
    tmp = json_path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, json_path)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows, smoke=bool(int(os.environ.get("SMOKE", "0"))))
    print("\n".join(rows))
