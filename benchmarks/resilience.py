"""Lemma 1 / Definitions 2-3 validation: cone angle + leeway measurements.

Measures, over controlled gradient distributions:
* empirical sin(angle(E[GAR], g)) vs the Lemma-1 bound η(n,f)·√d·σ/||g||;
* the per-coordinate leeway of MULTI-BULYAN vs MULTI-KRUM under the
  omniscient attack (the √d-leeway story of §II) across dimensions;
* slowdown (Thm 1(ii)/2(iii)): variance of the aggregate vs averaging.

CSV: name,us_per_call,derived (value column = measurement).
"""
from __future__ import annotations

from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import attacks, gar, theory

N, F = 15, 3
SIGMA = 0.05
TRIALS = 30


def run(csv_rows: List[str]) -> None:
    rng = np.random.default_rng(0)

    # ---- cone angle vs Lemma 1 bound
    for d in (64, 512):
        g = np.ones(d, np.float32)
        bound = theory.sin_alpha(N, F, d, SIGMA, float(np.linalg.norm(g)))
        for rule in ("multi_krum", "multi_bulyan"):
            aggs = []
            for t in range(TRIALS):
                correct = (g[None] + SIGMA * rng.normal(size=(N - F, d))
                           ).astype(np.float32)
                byz = attacks.omniscient_reverse(jnp.asarray(correct), F,
                                                 jax.random.key(t))
                stack = jnp.concatenate(
                    [byz.astype(jnp.float32), jnp.asarray(correct)], 0)
                aggs.append(np.asarray(gar.aggregate(stack, F, rule)))
            mean_agg = np.mean(aggs, axis=0)
            cos = theory.cone_cosine(jnp.asarray(mean_agg), jnp.asarray(g))
            sin_emp = float(np.sqrt(max(0.0, 1 - cos ** 2)))
            ok = sin_emp <= bound
            csv_rows.append(f"resilience/cone/{rule}/d={d},{sin_emp:.4f},"
                            f"lemma1_bound={bound:.4f}_ok={int(ok)}")

    # ---- strong-resilience leeway: per-coordinate deviation across d
    for rule in ("multi_krum", "multi_bulyan"):
        gaps = []
        for d in (64, 1024):
            per = []
            for t in range(10):
                g = np.ones(d, np.float32)
                correct = (g[None] + SIGMA * rng.normal(size=(N - F, d))
                           ).astype(np.float32)
                byz = attacks.omniscient_reverse(jnp.asarray(correct), F,
                                                 jax.random.key(100 + t))
                stack = jnp.concatenate(
                    [byz.astype(jnp.float32), jnp.asarray(correct)], 0)
                agg = np.asarray(gar.aggregate(stack, F, rule))
                per.append(np.mean(np.min(np.abs(agg[None] - correct), 0)))
            gaps.append(float(np.mean(per)))
        growth = gaps[1] / max(gaps[0], 1e-12)
        csv_rows.append(f"resilience/leeway_growth_64to1024/{rule},"
                        f"{growth:.3f},sqrt_d_would_be_4.0")

    # ---- slowdown: variance of aggregate / variance of averaging
    d = 256
    g = np.zeros(d, np.float32)
    stacks = [jnp.asarray((g[None] + rng.normal(size=(N, d))).astype(np.float32))
              for _ in range(120)]
    var_avg = np.var(np.stack([np.asarray(gar.average(s)) for s in stacks]), 0).mean()
    for rule, slow_fn in (("multi_krum", theory.multi_krum_slowdown),
                          ("multi_bulyan", theory.multi_bulyan_slowdown)):
        var = np.var(np.stack([np.asarray(gar.aggregate(s, F, rule))
                               for s in stacks]), 0).mean()
        # variance ratio ≈ n_used/n = predicted slowdown
        emp = var_avg / var
        pred = slow_fn(N, F)
        csv_rows.append(f"resilience/slowdown/{rule},{emp:.3f},"
                        f"theory={pred:.3f}")


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)
    print("\n".join(rows))
