"""Microbatched robust serving (DESIGN.md §13).

The robust serving ensemble (``dist.serving.make_robust_serve_step``)
fuses replica logits one request batch at a time.  This module packs many
independent decode requests — each at its *own* absolute position — into
one fixed-size microbatch, decodes all ``n`` replicas in lockstep, and
fuses the resulting (n, B, V) logit stack with a **single** plan/apply
through the shared :class:`~repro.core.api.AggregatorBackend`: one (n, n)
statistics pass and one apply over the whole microbatch instead of B
separate per-request GAR invocations.

The cache PartitionSpecs extend ``dist/sharding.cache_specs`` with the
leading replica axis playing the worker role (replicas over pod×data, the
cache length axis over ``model``) — KV-cache-aware layout end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import api
from repro.dist import sharding as DSH
from repro import models as MD

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class RequestBatch:
    """A fixed-size microbatch of decode requests.

    ``tokens``/``pos`` are (B,) int32 — each request's next input token
    and its absolute decode position; ``active`` is the (B,) bool validity
    mask (False = padding slot).  Static B keeps the serve step's jit
    cache warm regardless of instantaneous load.
    """

    tokens: Array
    pos: Array
    active: Array

    @property
    def size(self) -> int:
        return int(self.tokens.shape[0])


def pack_requests(tokens: Sequence[int], pos: Sequence[int],
                  size: int) -> RequestBatch:
    """Pack up to ``size`` requests into one padded :class:`RequestBatch`."""
    k = len(tokens)
    if k != len(pos):
        raise ValueError(f"tokens/pos length mismatch ({k} vs {len(pos)})")
    if k > size:
        raise ValueError(f"{k} requests exceed microbatch size {size}")
    pad = size - k
    return RequestBatch(
        tokens=jnp.asarray(list(tokens) + [0] * pad, jnp.int32),
        pos=jnp.asarray(list(pos) + [0] * pad, jnp.int32),
        active=jnp.asarray([True] * k + [False] * pad, jnp.bool_))


# ------------------------------------------------------------------- specs
def replica_param_specs(stacked_params: PyTree, params: PyTree,
                        mesh: Mesh) -> PyTree:
    """Specs for replica-stacked params: (n, *param) — the replica axis
    over pod×data plus the leaf's tensor-parallel spec shifted right
    (identical layout to the trainer's gradient stack)."""
    del stacked_params  # layout depends only on the unstacked leaves
    return DSH.grad_stack_specs(params, mesh)


def replica_cache_specs(stacked_cache: PyTree, mesh: Mesh) -> PyTree:
    """KV-cache specs with a leading replica axis: leaves
    ``(n, n_groups, batch, length, ...)``.

    The replica axis (the byzantine worker role) shards over pod×data;
    the cache *length* axis — dim 3 of attention KV leaves, one right of
    ``dist/sharding.cache_specs``'s dim 2 — over ``model``, so decode
    attention stays chunk-local partial softmax per length shard.  The
    request batch axis stays replicated: microbatches are small and the
    fused logit aggregation wants whole rows per device.
    """
    lead = DSH._worker_axes(mesh)

    def leaf(x):
        entries = [None] * x.ndim              # dim 1: the group stack
        entries[0] = lead
        if x.ndim >= 5:                        # (n, ng, b, length, heads, hd)
            entries[3] = "model"
        return DSH.sanitize_spec(P(*entries), x.shape, mesh)

    return jax.tree.map(leaf, stacked_cache)


# -------------------------------------------------------------------- step
def make_microbatch_serve_step(cfg: ArchConfig, rcfg: RobustConfig, *,
                               window: int = 0, seq_chunks: int = 1,
                               backend: Optional[api.AggregatorBackend] = None):
    """Build the microbatched robust decode step.

    ``(stacked_params, stacked_caches, rb: RequestBatch) ->
    ((B, V) fused logits, new stacked_caches)``.

    Each of the B requests decodes at its own ``rb.pos`` (vmap over the
    cache batch axis with per-lane scalar positions), all n replicas run
    in lockstep, and the (n, B, V) logit stack is fused with one shared
    plan/apply — padded (inactive) slots are zeroed first so they
    contribute nothing to the replica distance statistics.
    """
    rcfg.validate()
    if backend is None:
        backend = api.AggregatorBackend.for_config(rcfg)

    def one_request(p, tok, cache_row, pr):
        # re-insert the batch axis the vmap stripped: decode runs at B=1
        c1 = jax.tree.map(lambda x: jnp.expand_dims(x, 1), cache_row)
        logits, c1 = MD.decode_fn(p, cfg, tok[None], c1, pr,
                                  window=window, seq_chunks=seq_chunks)
        return logits[0], jax.tree.map(lambda x: x[:, 0], c1)

    def one_replica(p, c, rb: RequestBatch):
        cache_axes = jax.tree.map(lambda _: 1, c)
        return jax.vmap(one_request, in_axes=(None, 0, cache_axes, 0),
                        out_axes=(0, cache_axes))(p, rb.tokens, c, rb.pos)

    def step(stacked_params, stacked_caches, rb: RequestBatch):
        logits, caches = jax.vmap(
            lambda p, c: one_replica(p, c, rb))(stacked_params,
                                                stacked_caches)
        # (n, B, V); inactive slots must not perturb the (n, n) statistics
        logits = logits * rb.active[None, :, None].astype(logits.dtype)
        return backend(logits), caches

    return step
