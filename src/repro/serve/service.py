"""The async plan/apply aggregation service (DESIGN.md §13).

:class:`AsyncAggService` bundles the shared
:class:`~repro.core.api.AggregatorBackend` with a staleness bound: the
*plan service* runs on the buffered statistics (O(n²), d-free), the
*apply service* applies the covered plan to the buffered gradient stack.
Both the synchronous trainers and ``make_robust_serve_step`` consume the
same backend; this module adds the bounded-staleness round on top
(``repro.serve.buffer``) and the trainer step that threads its state
through ``TrainerState.bstate``.

The service loop is deliberately collective-free: cross-worker data moves
through the buffer (admission is a masked ``where``), never through
blocking collectives — ``analysis/lint.py`` rule R006 enforces this
statically on every async service function.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import api
from repro.core import theory
from repro import models as MD
from repro import obs as OBS
from repro.optim.optimizers import Optimizer
from repro.serve import buffer as BUF
from repro.dist.trainer import (TrainerState, _honest_mean_dev,
                                as_trainer_state, inject_byzantine)

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AsyncAggService:
    """Plan service + apply service over a bounded-staleness buffer.

    ``backend`` is the one shared aggregation pipeline; ``tau`` the
    staleness bound (a slot older than ``tau`` rounds is overstale and
    spends contract-f budget — ``core.theory.staleness_budget``).
    """

    backend: api.AggregatorBackend
    tau: int

    def __post_init__(self):
        # config-time gate: n is unknown here, but tau must be sane
        if self.tau < 0:
            raise ValueError(f"staleness bound tau must be >= 0, "
                             f"got {self.tau}")

    @property
    def obs(self) -> Optional[OBS.ObsConfig]:
        """The backend's observability config — one switchboard for every
        consumer of the pipeline (DESIGN.md §14)."""
        return self.backend.obs

    def budget(self, n: int) -> theory.StalenessBudget:
        return theory.staleness_budget(n, self.backend.f, self.tau,
                                       rule=self.backend.gar)

    def init_state(self, grads_like: PyTree) -> BUF.BufferState:
        return BUF.init_buffer_state(grads_like, self.backend, tau=self.tau)

    # ------------------------------------------------------------ services
    def plan(self, state: BUF.BufferState
             ) -> Tuple[api.AggPlan, Dict[str, Array]]:
        """The plan service on the current buffer (no admission)."""
        info = BUF.staleness_info(state.age, tau=self.tau,
                                  f=self.backend.f)
        plan, stats = self.backend.plan_stats(state.grads)
        plan = api.select_plan(info["admissible"], plan, state.plan)
        info = dict(info, stats=stats)
        return plan, info

    def apply(self, plan: api.AggPlan, state: BUF.BufferState) -> PyTree:
        """The apply service: the covered plan over the buffered stack."""
        return self.backend.apply(plan, state.grads)

    def round(self, state: BUF.BufferState, grads: PyTree, fresh: Array
              ) -> Tuple[PyTree, BUF.BufferState, Dict[str, Array]]:
        """One full async round: admit → plan → apply."""
        return BUF.buffered_round(state, self.backend, grads, fresh,
                                  tau=self.tau)


def with_buffer(tstate: TrainerState, service: AsyncAggService,
                params: PyTree, n_workers: int) -> TrainerState:
    """Seed the ``bstate`` slot of a :class:`TrainerState` for the async
    trainer (stacked gradient shapes mirror the params)."""
    stacked = jax.tree.map(
        lambda p: jnp.zeros((n_workers,) + p.shape, p.dtype), params)
    return dataclasses.replace(tstate, bstate=service.init_state(stacked))


def make_async_train_step(cfg: ArchConfig, rcfg: RobustConfig,
                          opt: Optimizer, lr_fn, *, tau: int,
                          window: int = 0, chunk_q: int = 1024,
                          attack: str = "none",
                          attack_f: Optional[int] = None,
                          telemetry: bool = False,
                          obs: Optional[OBS.ObsConfig] = None):
    """Build the bounded-staleness async trainer step.

    Signature ``(params, state, batch, key, fresh) -> (params, state,
    metrics)`` — ``fresh`` is the (n,) bool delivery mask of the round
    (True = the worker's gradient arrived by the deadline).  Workers that
    missed keep their buffered slot; slots older than ``tau`` rounds are
    overstale and haircut the byzantine budget
    (``core.theory.StalenessBudget``).  The buffer state lives in
    ``TrainerState.bstate`` — seed it with :func:`with_buffer`.

    v1 scope: the async path composes with attacks and telemetry but not
    with transforms / codecs / hierarchical aggregation / the mesh-native
    (spmd) path — those raise in the synchronous trainer's richer builder
    and stay synchronous for now.

    ``obs`` (an enabled ``repro.obs.ObsConfig``) records the serve-side
    registry into ``TrainerState.mstate``: admission / overstale /
    degradation counters, the per-slot staleness-age histogram, the
    haircut gauge (``f_defended``), plus the stats→plan→select_plan→apply
    span ring (DESIGN.md §14).  Disabled/None is the bitwise
    uninstrumented step.
    """
    rcfg.validate()
    backend = api.AggregatorBackend.for_config(rcfg, needs_dists=telemetry,
                                               obs=obs)
    service = AsyncAggService(backend=backend, tau=tau)
    obs_live = OBS.obs_on(obs)
    obs_trace = obs_live and obs.trace
    theory.staleness_budget(rcfg.n_workers, rcfg.f, tau, rule=rcfg.gar)
    f_eff = rcfg.f if attack_f is None else attack_f
    if not 0 <= f_eff <= rcfg.f:
        raise ValueError(
            f"attack_f must be in [0, f] (attack_f={f_eff}, f={rcfg.f})")

    def worker_loss(p, wb):
        return MD.loss_fn(p, cfg, wb, window=window, chunk_q=chunk_q)

    def step(params, state, batch, key, fresh):
        state = as_trainer_state(state)
        if state.bstate is None:
            raise ValueError("async trainer needs TrainerState.bstate; "
                             "seed it with serve.service.with_buffer()")
        mstate = state.mstate
        if obs_live and mstate is None:
            mstate = OBS.init_serve_obs(obs, rcfg.n_workers, tau,
                                        telemetry=telemetry)
        obs_round = state.opt.step
        losses, grads = jax.vmap(
            lambda wb: jax.value_and_grad(worker_loss)(params, wb))(batch)
        grads = inject_byzantine(grads, f_eff, attack, key)
        agg, bstate, info = service.round(state.bstate, grads, fresh)
        lr = lr_fn(state.opt.step)
        new_params, new_opt = opt.update(agg, state.opt, params, lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g.astype(jnp.float32) ** 2)
            for g in jax.tree.leaves(agg)))
        metrics = {
            "loss": jnp.mean(losses),
            "loss_per_worker": losses,
            "lr": jnp.asarray(lr, jnp.float32),
            "agg_grad_norm": gnorm,
        }
        if telemetry:
            diag = bstate.plan.diagnostics(info["stats"])
            diag["byz_mass"] = jnp.sum(diag["selection"][:f_eff])
            # deviation vs the honest rows of the *buffered* stack — the
            # values the aggregate was actually computed from
            diag["honest_dev"] = _honest_mean_dev(agg, bstate.grads, f_eff)
            diag["admitted"] = fresh.astype(jnp.float32)
            diag["overstale"] = info["overstale"].astype(jnp.float32)
            diag["staleness_age"] = info["age"].astype(jnp.float32)
            diag["n_overstale"] = info["n_overstale"].astype(jnp.float32)
            diag["f_defended"] = info["f_defended"].astype(jnp.float32)
            diag["plan_reused"] = info["plan_reused"].astype(jnp.float32)
            metrics["telemetry"] = diag
        if obs_live:
            m = mstate["m"]
            m = OBS.inc(m, "rounds")
            m = OBS.inc(m, "admitted", jnp.sum(fresh.astype(jnp.float32)))
            m = OBS.inc(m, "overstale_slots", info["n_overstale"])
            m = OBS.inc(m, "degraded", info["plan_reused"])
            m = OBS.set_gauge(m, "loss", metrics["loss"])
            m = OBS.set_gauge(m, "agg_grad_norm", gnorm)
            m = OBS.set_gauge(m, "f_defended", info["f_defended"])
            m = OBS.observe(m, "agg_grad_norm", gnorm)
            m = OBS.observe(m, "staleness_age", info["age"])
            if telemetry:
                m = OBS.set_gauge(m, "byz_mass", diag["byz_mass"])
                m = OBS.set_gauge(m, "suspicion", OBS.update_suspicion(
                    m.gauges["suspicion"], diag["selection"],
                    obs.suspicion_ema))
            t = mstate["t"]
            if obs_trace:
                # the round's pipeline in program order; select_plan marks
                # the degradation branch (payload = plan_reused)
                t = OBS.record(t, OBS.PH_STATS, obs_round)
                t = OBS.record(t, OBS.PH_PLAN, obs_round,
                               info["f_defended"])
                t = OBS.record(t, OBS.PH_SELECT_PLAN, obs_round,
                               info["plan_reused"])
                t = OBS.record(t, OBS.PH_APPLY, obs_round, gnorm)
            mstate = {"m": m, "t": t}
        return (new_params,
                dataclasses.replace(state, opt=new_opt, bstate=bstate,
                                    mstate=mstate),
                metrics)

    return step
