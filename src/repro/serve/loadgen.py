"""Closed-loop throughput model for the async aggregation service.

The benchmark question (``benchmarks/serving.py``): at a fixed in-flight
request budget, does the bounded-staleness buffer sustain higher QPS than
the synchronous lockstep round under realistic straggler latency?

Honest framing (like ``comm/transport.py``'s simulated wire): worker
*arrival latencies* are drawn from a seeded lognormal straggler model —
this module never sleeps — while the aggregation compute per round is
**measured** by timing the real jitted ``AsyncAggService.round``, and the
stale-admission accounting comes from replaying the arrival schedule
through the **real** buffer (every ``n_overstale`` / ``plan_reused``
number in BENCH_serving.json was produced by ``repro.serve.buffer``, not
by arithmetic on the side).

* synchronous round: wall = slowest worker's latency + aggregation;
* async round: wall = the admission deadline + aggregation; workers that
  miss deliver into a later round (their slot goes stale, the haircut
  applies).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Tuple

import numpy as np

Array = Any


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """One closed-loop serving experiment.

    Latency model: per round each worker's gradient takes
    ``mean_ms · LogNormal(0, jitter)`` — except the last ``stragglers``
    (honest) workers, slowed by ``straggler_mult`` — and the async
    deadline is the ``deadline_quantile`` of the non-straggler latency
    distribution.  ``microbatch`` requests are served per completed round.
    """

    n: int = 11
    f: int = 2
    d: int = 4096
    tau: int = 1
    rounds: int = 40
    microbatch: int = 8
    gar: str = "multi_bulyan"
    seed: int = 0
    mean_ms: float = 20.0
    jitter: float = 0.25
    stragglers: int = 2
    straggler_mult: float = 4.0
    deadline_quantile: float = 0.9


def worker_latencies(cfg: LoadConfig) -> np.ndarray:
    """(rounds, n) per-gradient compute latencies in ms (seeded)."""
    rng = np.random.default_rng(cfg.seed)
    lat = cfg.mean_ms * rng.lognormal(0.0, cfg.jitter,
                                      size=(cfg.rounds, cfg.n))
    if cfg.stragglers:
        # stragglers sit on the last rows: byzantine rows come first by
        # the inject_byzantine convention, and a straggling *honest*
        # worker is the interesting case for the staleness haircut
        lat[:, cfg.n - cfg.stragglers:] *= cfg.straggler_mult
    return lat


def deadline_ms(cfg: LoadConfig, lat: np.ndarray) -> float:
    """Admission deadline: a quantile of the non-straggler latencies."""
    fast = lat[:, : cfg.n - cfg.stragglers] if cfg.stragglers else lat
    return float(np.quantile(fast, cfg.deadline_quantile))


def arrival_masks(cfg: LoadConfig, lat: np.ndarray, round_wall_ms: float,
                  cut_ms: float) -> np.ndarray:
    """(rounds, n) bool delivery masks of the closed arrival loop.

    Round ``r`` spans ``[r·wall, (r+1)·wall)``; a worker delivers into
    round ``r`` when its in-flight gradient finishes by ``r·wall + cut``.
    On delivery it immediately starts the next gradient — a worker slower
    than the cut therefore delivers every second (third, …) round, which
    is exactly the bounded-staleness admission the buffer models.
    """
    fresh = np.zeros((cfg.rounds, cfg.n), dtype=bool)
    finish = lat[0].copy()                       # first gradients start at 0
    job = np.zeros(cfg.n, dtype=int)
    for r in range(cfg.rounds):
        cut = r * round_wall_ms + cut_ms
        for w in range(cfg.n):
            if finish[w] <= cut:
                fresh[r, w] = True
                job[w] = min(job[w] + 1, cfg.rounds - 1)
                finish[w] = max(finish[w], r * round_wall_ms) + \
                    lat[job[w], w]
    return fresh


def _make_round(cfg: LoadConfig):
    """The real jitted service round on an (n, d) single-leaf stack."""
    import jax
    import jax.numpy as jnp
    from repro.core import api
    from repro.serve.service import AsyncAggService

    backend = api.AggregatorBackend(gar=cfg.gar, f=cfg.f)
    svc = AsyncAggService(backend=backend, tau=cfg.tau)
    grads_like = jnp.zeros((cfg.n, cfg.d), jnp.float32)
    state0 = svc.init_state(grads_like)
    round_fn = jax.jit(lambda s, g, fr: svc.round(s, g, fr))

    key = jax.random.key(cfg.seed)

    def grads_for(r: int):
        k = jax.random.fold_in(key, r)
        g = jax.random.normal(k, (cfg.n, cfg.d), jnp.float32)
        # first f rows drift: exercise a non-trivial selection
        return g.at[: cfg.f].multiply(5.0)

    return svc, state0, round_fn, grads_for


def replay_buffer(cfg: LoadConfig, fresh: np.ndarray
                  ) -> Tuple[Dict[str, float], np.ndarray]:
    """Replay an arrival schedule through the real buffer.

    Returns (accounting dict, measured per-round aggregation µs — a
    ``(rounds,)`` array).  The timing is measured on the same jitted
    round the accounting comes from (warm-up call excluded); keeping the
    per-round samples instead of a single mean is what lets the
    benchmark report honest p50/p95/p99 round latency — a mean hides
    exactly the tail a staleness bound exists to control.
    """
    import jax

    svc, state, round_fn, grads_for = _make_round(cfg)
    # warm-up/compile on round 0 inputs
    import jax.numpy as jnp
    fr0 = jnp.asarray(fresh[0])
    jax.block_until_ready(round_fn(state, grads_for(0), fr0)[0])

    n_over = np.zeros(cfg.rounds)
    reused = np.zeros(cfg.rounds)
    f_def = np.zeros(cfg.rounds)
    agg_us = np.zeros(cfg.rounds)
    for r in range(cfg.rounds):
        t0 = time.perf_counter()
        agg, state, info = round_fn(state, grads_for(r),
                                    jnp.asarray(fresh[r]))
        jax.block_until_ready(agg)
        agg_us[r] = (time.perf_counter() - t0) * 1e6
        n_over[r] = int(info["n_overstale"])
        reused[r] = bool(info["plan_reused"])
        f_def[r] = int(info["f_defended"])
    acct = {
        "stale_rounds": int(np.sum(n_over > 0)),
        "reused_rounds": int(np.sum(reused)),
        "n_overstale_max": int(np.max(n_over)),
        "f_defended_mean": float(np.mean(f_def)),
        "admitted_frac": float(np.mean(fresh)),
    }
    return acct, agg_us


def run_closed_loop(cfg: LoadConfig, mode: str) -> Dict[str, float]:
    """One (mode, tau, f) cell of the serving benchmark.

    ``round_us`` is the per-round mean; the ``round_us_p50/p95/p99``
    fields are percentiles over the *per-round* latency vector — in sync
    mode each round's wall is its slowest worker plus that round's
    measured aggregation, in async mode the fixed admission deadline
    plus the round's measured aggregation, so the tail the percentiles
    expose is real (the pre-v2 benchmark collapsed the rounds to a mean
    before any percentile could be taken — the serving.v2 bugfix).
    """
    if mode not in ("sync", "async"):
        raise ValueError(f"mode must be sync|async, got {mode!r}")
    lat = worker_latencies(cfg)
    if mode == "sync":
        # lockstep: every round waits for the slowest worker; everyone
        # is always fresh, the buffer degenerates to pass-through
        fresh = np.ones((cfg.rounds, cfg.n), dtype=bool)
        acct, agg_us = replay_buffer(cfg, fresh)
        waits_ms = np.max(lat, axis=1)
        round_us = waits_ms * 1000.0 + agg_us
    else:
        cut = deadline_ms(cfg, lat)
        # round wall needs agg_us: measure once on an all-fresh replay,
        # then replay the actual arrival schedule for the accounting
        _, warm_us = replay_buffer(cfg, np.ones((cfg.rounds, cfg.n), bool))
        wall_ms = cut + float(np.mean(warm_us)) / 1000.0
        fresh = arrival_masks(cfg, lat, wall_ms, cut)
        acct, agg_us = replay_buffer(cfg, fresh)
        round_us = cut * 1000.0 + agg_us
    total_s = float(np.sum(round_us)) / 1e6
    p50, p95, p99 = np.percentile(round_us, [50.0, 95.0, 99.0])
    return {
        "qps": cfg.microbatch * cfg.rounds / total_s,
        "round_us": float(np.mean(round_us)),
        "round_us_p50": float(p50),
        "round_us_p95": float(p95),
        "round_us_p99": float(p99),
        "agg_us": float(np.mean(agg_us)),
        **acct,
    }
