"""repro.serve — async bounded-staleness aggregation service (DESIGN.md §13).

The plan/apply split of the robust aggregation pipeline, packaged as a
service: a bounded-staleness gradient buffer (``buffer``), the plan/apply
service loop and the async trainer step built on it (``service``),
microbatched robust serving that fuses many decode requests through one
shared plan (``batching``), and the closed-loop throughput model behind
``BENCH_serving.json`` (``loadgen``).
"""
from repro.serve.batching import (RequestBatch, make_microbatch_serve_step,
                                  pack_requests, replica_cache_specs,
                                  replica_param_specs)
from repro.serve.buffer import (BufferState, admit, buffered_round,
                                init_buffer_state, staleness_info)
from repro.serve.loadgen import LoadConfig, run_closed_loop
from repro.serve.service import (AsyncAggService, make_async_train_step,
                                 with_buffer)

__all__ = [
    "AsyncAggService",
    "BufferState",
    "LoadConfig",
    "RequestBatch",
    "admit",
    "buffered_round",
    "init_buffer_state",
    "make_async_train_step",
    "make_microbatch_serve_step",
    "pack_requests",
    "replica_cache_specs",
    "replica_param_specs",
    "run_closed_loop",
    "staleness_info",
    "with_buffer",
]
