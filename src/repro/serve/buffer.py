"""Bounded-staleness async gradient buffer (DESIGN.md §13).

The buffer is the asynchrony boundary of the plan/apply service split:
workers deliver gradients whenever they finish, the round deadline fires
regardless, and a worker that missed it simply keeps its *previous* row in
the buffered stack — admitted into the next plan instead of blocking this
one.  Every slot carries an int32 age (rounds since last delivery); rows
older than the staleness bound ``tau`` are *overstale* and are charged
against the contract ``f`` (``core.theory.StalenessBudget`` — the round-
based resilience argument of Chen et al., arXiv 1705.05491).

Everything is static-shape and jit-pure: admission is a masked ``where``
per leaf, ages are a single (n,) vector, and the previous round's
:class:`~repro.core.api.AggPlan` rides along so an inadmissible round
(more overstale rows than ``f``) can degrade to it without retracing.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import api

Array = jax.Array
PyTree = Any


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("grads", "age", "plan"),
    meta_fields=())
@dataclasses.dataclass(frozen=True)
class BufferState:
    """Per-round state of the async aggregation buffer.

    * ``grads`` — stacked pytree, every leaf ``(n, ...)``: each worker's
      most recently delivered gradient (its buffer slot);
    * ``age``   — (n,) int32: rounds since the slot was last refreshed
      (0 = delivered this round);
    * ``plan``  — the :class:`~repro.core.api.AggPlan` the service applied
      last round (the degradation target for inadmissible rounds).
    """

    grads: PyTree
    age: Array
    plan: api.AggPlan


def init_buffer_state(grads_like: PyTree, backend: api.AggregatorBackend,
                      *, tau: int) -> BufferState:
    """Empty buffer: zero slots, every worker overstale until it delivers.

    Ages start at ``tau + 1`` so a worker that never delivered counts
    against the budget from round one (its zero row is as untrustworthy as
    any other stale value).  The seed plan is the backend's plan on
    all-zero statistics — structurally identical to every later plan, so
    the degradation ``where`` never changes tree shape.
    """
    leaves = jax.tree.leaves(grads_like)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    grads = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), grads_like)
    age = jnp.full((n,), tau + 1, jnp.int32)
    needs = backend.aggregator.needs_dists or backend.needs_dists
    stats = api.AggStats(
        n=n, f=backend.f,
        dists=jnp.zeros((n, n), jnp.float32) if needs else None,
        sq_norms=None)
    return BufferState(grads=grads, age=age, plan=backend.plan(stats))


def admit(state: BufferState, grads: PyTree, fresh: Array) -> BufferState:
    """One round of admissions: overwrite the slots of workers whose
    gradient arrived by the deadline (``fresh`` — (n,) bool), age the rest.

    Late arrivals are not lost — the caller feeds them as ``fresh`` next
    round, which is exactly the bounded-staleness admission rule: a late
    gradient enters the *next* plan instead of blocking this one.
    """

    def take(slot, new):
        m = fresh.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new.astype(slot.dtype), slot)

    return dataclasses.replace(
        state,
        grads=jax.tree.map(take, state.grads, grads),
        age=jnp.where(fresh, 0, state.age + 1).astype(jnp.int32))


def staleness_info(age: Array, *, tau: int, f: int) -> Dict[str, Array]:
    """The jnp mirror of :class:`~repro.core.theory.StalenessBudget`.

    * ``overstale``  — (n,) bool: age > tau;
    * ``n_overstale`` — int32 count;
    * ``f_defended`` — ``max(f - n_overstale, 0)``: byzantine defense left
      after the staleness haircut (never exceeds the contract f);
    * ``admissible`` — bool: ``n_overstale <= f`` — past that the round's
      plan is not covered by the contract and must be degraded.
    """
    overstale = age > tau
    n_over = jnp.sum(overstale).astype(jnp.int32)
    f_arr = jnp.asarray(f, jnp.int32)
    return {
        "overstale": overstale,
        "n_overstale": n_over,
        "f_defended": jnp.maximum(f_arr - jnp.minimum(n_over, f_arr), 0),
        "admissible": n_over <= f_arr,
    }


def buffered_round(state: BufferState, backend: api.AggregatorBackend,
                   grads: PyTree, fresh: Array, *, tau: int
                   ) -> Tuple[PyTree, BufferState, Dict[str, Array]]:
    """Admit → plan → degrade-if-inadmissible → apply: one async round.

    The plan is always computed at the contract ``f`` over the full
    buffered stack (static shapes, jit-cache stable); when the round is
    inadmissible the *previous* plan is selected instead
    (:func:`~repro.core.api.select_plan`) and applied to the current
    buffer — serving continues on the last covered selection.

    Returns ``(aggregate, new_state, info)`` where ``info`` carries the
    staleness telemetry (:func:`staleness_info` plus ``admitted`` — the
    delivery mask — ``plan_reused`` and the round's :class:`AggStats`).
    """
    state = admit(state, grads, fresh)
    info = staleness_info(state.age, tau=tau, f=backend.f)
    plan, stats = backend.plan_stats(state.grads)
    plan = api.select_plan(info["admissible"], plan, state.plan)
    agg = backend.apply(plan, state.grads)
    info = dict(info, admitted=fresh,
                plan_reused=jnp.logical_not(info["admissible"]),
                stats=stats, age=state.age)
    return agg, dataclasses.replace(state, plan=plan), info
