"""Static per-tile VMEM / HBM-traffic estimator for the Pallas kernels.

Mirrors the exact BlockSpec/grid arithmetic of ``kernels/ops.py`` — the
padding, the ``autotune_d_tile`` budget model and ``_select_scratch_rows``
are *called*, not re-derived, so the estimate and the autotuner can never
drift apart silently (that agreement is the §12 cross-check).

For each kernel × (n, d) point the estimator emits the chosen ``d_tile``,
grid depth, the per-grid-step VMEM working set (double-buffered operand
tiles + scratch + fixed residents, the same model the autotuner budgets
against) and the HBM read/write traffic, plus two diagnoses:

* ``over_budget`` — the *full-d* working set exceeds the VMEM budget, so
  the kernel must tile (always true for the benchmark-scale stacks);
* ``grid_bound`` — the grid is deeper than :data:`GRID_STEPS_THRESHOLD`,
  the regime where per-step dispatch overhead and the fused kernel's
  re-read of its replicated extraction operands dominate the byte
  savings.  This is the measured BENCH_agg_time.json d=1e6 cliff: at
  n=15 the fused kernel wins at d=1e5 (13 grid steps) and loses 3.9× at
  d=1e6 (123 steps) while moving only 10× the bytes.

:func:`predicted_crossover` turns the threshold into a per-n numel
crossover (``threshold × d_tile``) and reports the ratio against the
*measured* dispatch table (``kernels/dispatch.py``) — the two must agree
within 2× for the static model to be considered calibrated.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.kernels import dispatch as kdispatch
from repro.kernels import ops

#: grid depth past which the fused select kernel is dispatch/re-read bound
#: rather than bandwidth bound: the geometric midpoint of the measured
#: bracketing grid depths at n=15 — 13 steps (d=1e5, fused wins) and
#: 123 steps (d=1e6, fused loses 3.9×): sqrt(13·123) ≈ 40.  Owned by the
#: autotuner (``kernels/ops.DEEP_GRID_STEPS`` — past it the tile cap lifts
#: to amortise the per-step overhead) and aliased here so estimator and
#: autotuner share one regime boundary.
GRID_STEPS_THRESHOLD = ops.DEEP_GRID_STEPS

_PAYLOAD_ITEMSIZE = {"int8": 1, "bfloat16": 2}


def f_for_bench(n: int) -> int:
    """The benchmark grid's f convention (benchmarks/agg_time.py)."""
    return max(1, (n - 3) // 4)


def _pad(x: int, m: int) -> int:
    return x + (-x) % m


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    """Static footprint of one kernel launch at one (n, d) point."""

    kernel: str
    n: int
    d: int
    d_tile: int
    grid_steps: int
    vmem_bytes: int          # per-grid-step working set
    vmem_budget: int
    hbm_read_bytes: int
    hbm_write_bytes: int
    over_budget: bool        # full-d working set > budget (must tile)
    tile_over_budget: bool   # even a single tile busts the budget
    grid_bound: bool         # grid deeper than GRID_STEPS_THRESHOLD

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _finish(kernel: str, n: int, d: int, d_tile: int, per_lane_rows: int,
            fixed_bytes: int, read_fn, write_bytes: int) -> KernelEstimate:
    """Assemble the estimate from the autotuner's own cost model.

    ``per_lane_rows`` is the 4-byte-row count per lane of d_tile exactly
    as ``autotune_d_tile`` sees it (2×rows double-buffered operands +
    scratch rows); ``read_fn(d_pad, grid)`` gives the HBM read bytes.
    """
    grid = -(-d // d_tile)
    d_pad = grid * d_tile
    vmem = per_lane_rows * 4 * d_tile + fixed_bytes
    vmem_full = per_lane_rows * 4 * d_pad + fixed_bytes
    return KernelEstimate(
        kernel=kernel, n=n, d=d, d_tile=d_tile, grid_steps=grid,
        vmem_bytes=vmem, vmem_budget=ops.VMEM_BUDGET_BYTES,
        hbm_read_bytes=read_fn(d_pad, grid), hbm_write_bytes=write_bytes,
        over_budget=vmem_full > ops.VMEM_BUDGET_BYTES,
        tile_over_budget=vmem > ops.VMEM_BUDGET_BYTES,
        grid_bound=grid > GRID_STEPS_THRESHOLD)


def estimate_fused_select(n: int, d: int, *, f: Optional[int] = None,
                          d_tile: Optional[int] = None) -> KernelEstimate:
    """Fused Bulyan apply: (n, d) stack + two (θ, n) plans -> (d,)."""
    f = f_for_bench(n) if f is None else f
    theta = n - 2 * f - 2
    if theta < 1:
        raise ValueError(f"n={n}, f={f}: theta={theta} < 1")
    n_pad = _pad(n, 8)
    scratch = ops._select_scratch_rows(theta)
    fixed = 2 * theta * n_pad * 4
    if d_tile is None:
        # the wrapper's own tile policy (base cap + deep-grid lift) — the
        # estimate must live on the exact tile the kernel launches with
        d_tile = ops.fused_select_d_tile(n_pad, d, theta)
    # x tile streamed per step (read once); the replicated (θ, n) weight
    # pair is re-fetched every grid step (constant index_map) — the
    # re-read term that, with dispatch overhead, produces the deep-grid
    # cliff; the (1, d_tile) output writes back once per step.
    return _finish(
        "fused_select", n, d, d_tile,
        per_lane_rows=2 * n_pad + scratch, fixed_bytes=fixed,
        read_fn=lambda d_pad, grid: n_pad * d_pad * 4 + grid * fixed,
        write_bytes=_pad(d, d_tile) * 4)


def estimate_pairwise_stats(n: int, d: int, *,
                            d_tile: Optional[int] = None) -> KernelEstimate:
    """Single-pass stats: (n, d) -> ((n, n) raw sq-dists, (n,) norms)."""
    n_pad = _pad(n, 8)
    fixed = n_pad * (n_pad + 8) * 4       # resident (n, n) acc + norms row
    if d_tile is None:
        d_tile = ops.autotune_d_tile(n_pad, d, fixed_bytes=fixed)
    return _finish(
        "pairwise_stats", n, d, d_tile,
        per_lane_rows=2 * n_pad, fixed_bytes=fixed,
        read_fn=lambda d_pad, grid: n_pad * d_pad * 4,
        write_bytes=(n_pad * n_pad + n_pad) * 4)


def estimate_dequant_stats(n: int, d: int, *, dtype: str = "int8",
                           d_tile: Optional[int] = None) -> KernelEstimate:
    """Fused dequantize→stats on an (n, d) int8/bf16 payload."""
    if dtype not in _PAYLOAD_ITEMSIZE:
        raise ValueError(f"payload dtype must be one of "
                         f"{sorted(_PAYLOAD_ITEMSIZE)}, got {dtype!r}")
    item = _PAYLOAD_ITEMSIZE[dtype]
    n_pad = _pad(n, 8)
    fixed = n_pad * (n_pad + 8) * 4
    if d_tile is None:
        # same autotune call the wrapper makes: the tile is budgeted for
        # the *decoded* fp32 rows so the accumulation order (and bitwise
        # parity with decode-then-pairwise_stats) is preserved (§9)
        d_tile = ops.autotune_d_tile(n_pad, d, fixed_bytes=fixed)
    # payload tiles stream at the narrow itemsize; the widened fp32 rows
    # live only in VMEM (that is the point of the kernel), modelled by
    # the same 2×n_pad fp32 rows the autotuner budgets
    return _finish(
        "dequant_stats", n, d, d_tile,
        per_lane_rows=2 * n_pad, fixed_bytes=fixed,
        read_fn=lambda d_pad, grid: n_pad * d_pad * item + n_pad * 4,
        write_bytes=(n_pad * n_pad + n_pad) * 4)


_ESTIMATORS = {
    "fused_select": estimate_fused_select,
    "pairwise_stats": estimate_pairwise_stats,
    "dequant_stats": estimate_dequant_stats,
}


def estimate(kernel: str, n: int, d: int, **kw) -> KernelEstimate:
    if kernel not in _ESTIMATORS:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"known: {sorted(_ESTIMATORS)}")
    return _ESTIMATORS[kernel](n, d, **kw)


def predicted_crossover(n: int, *, f: Optional[int] = None) -> Dict:
    """Static fused-vs-XLA crossover numel for one n, vs the measured one.

    The asymptotic tile (d → ∞) times the grid-bound threshold gives the
    numel past which the fused kernel is predicted to lose; the measured
    counterpart is ``kernels/dispatch.py``'s table.  ``ratio`` is
    predicted/measured — within [0.5, 2] the static model matches the
    benchmark.
    """
    est = estimate_fused_select(n, 10 ** 9, f=f)     # asymptotic tile
    predicted = GRID_STEPS_THRESHOLD * est.d_tile
    measured = kdispatch.FUSED_MAX_NUMEL.get(
        n, kdispatch.DEFAULT_FUSED_MAX_NUMEL)
    return {"n": n, "d_tile": est.d_tile,
            "grid_threshold": GRID_STEPS_THRESHOLD,
            "predicted_numel": predicted, "measured_numel": measured,
            "ratio": predicted / measured if measured else math.inf}


def bench_points(bench_results: dict, row: str = "multi_bulyan[fused]"
                 ) -> List[Dict]:
    """The committed (n, d) grid points of one BENCH_agg_time.json row."""
    pts = []
    for key, us in sorted(bench_results.get(row, {}).items()):
        kv = dict(p.split("=") for p in key.split(","))
        pts.append({"key": key, "n": int(kv["n"]), "d": int(kv["d"]),
                    "us_per_call": us})
    return pts


def diagnose_cliff(bench_results: dict) -> Dict:
    """Re-derive the measured d=1e6 cliff as a grid-overhead diagnosis.

    Estimates every committed ``multi_bulyan[fused]`` point, calibrates
    an implied bytes-per-µs over the *non-grid-bound* points (geometric
    mean), and reports each point's measured-vs-traffic-implied slowdown.
    The cliff claim holds when every grid-bound point runs ≥ 2× slower
    than its traffic implies and every in-budget point is within 2×.
    """
    pts = bench_points(bench_results)
    if not pts:
        return {"points": [], "holds": False,
                "detail": "no multi_bulyan[fused] row in benchmark"}
    for p in pts:
        est = estimate_fused_select(p["n"], p["d"])
        p["estimate"] = est.to_json()
        p["bytes"] = est.hbm_read_bytes + est.hbm_write_bytes
    calib = [p for p in pts if not p["estimate"]["grid_bound"]]
    if not calib:
        return {"points": pts, "holds": False,
                "detail": "no non-grid-bound calibration points"}
    log_bw = sum(math.log(p["bytes"] / p["us_per_call"]) for p in calib) \
        / len(calib)
    bytes_per_us = math.exp(log_bw)
    holds = True
    for p in pts:
        implied = p["us_per_call"] * bytes_per_us
        p["traffic_slowdown"] = implied / p["bytes"]
        ok = (p["traffic_slowdown"] >= 2.0) if p["estimate"]["grid_bound"] \
            else (0.5 <= p["traffic_slowdown"] <= 2.0)
        p["consistent"] = ok
        holds = holds and ok
    return {"points": pts, "bytes_per_us": bytes_per_us, "holds": holds,
            "detail": "grid-bound points run >=2x slower than their "
                      "HBM traffic implies; in-budget points within 2x"}
