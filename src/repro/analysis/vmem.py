"""Static per-macro-step VMEM / HBM-traffic estimator for the Pallas kernels.

Mirrors the exact BlockSpec/grid arithmetic of ``kernels/ops.py`` — the
padding, the two-level ``(d_tile, macro_tile)`` policies
(``fused_select_tiles`` / ``_stats_tiles``) and ``_select_scratch_rows``
are *called*, not re-derived, so the estimate and the tile policy can
never drift apart silently (that agreement is the §12 cross-check).

For each kernel × (n, d) point the estimator emits the chosen inner
``d_tile`` and outer ``macro_tile``, the outer grid depth, the per-macro-
step VMEM working set (double-buffered streamed lanes + per-window
intermediates + fixed residents — the same model ``two_level_macro``
budgets against) and the HBM read/write traffic, plus two diagnoses:

* ``over_budget`` — the *full-d* working set exceeds the VMEM budget, so
  the kernel must tile (always true for the benchmark-scale stacks);
* ``tile_over_budget`` — even a single macro step busts the budget
  (never true for a policy-chosen launch; flags hand-picked tiles).

The single-level era's ``grid_bound`` diagnosis is retired with the cliff
it described: the fused kernel re-fetched its replicated (θ, n) weight
pair once per ``d_tile``-wide grid step, so past ~40 steps the per-step
dispatch + re-read overhead beat the byte savings (the measured d=1e6
loss).  The two-level kernels read the replicated operands once per
``macro_tile`` block — the re-read term shrinks by ``macro/d_tile`` (≥
an order of magnitude at benchmark scale) and the grid depth at d = 1e6
drops from ~123 steps to ~21, so the hot path stays traffic-bound:
:func:`diagnose_traffic_linearity` checks that claim against the
committed benchmark, and :func:`predicted_crossover` checks the residual
overhead model against the measured dispatch table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.kernels import dispatch as kdispatch
from repro.kernels import ops

#: outer grid depth at which per-step overhead (dispatch + replicated-
#: operand fetch) would again rival the byte savings.  Inherited from the
#: single-level era's measured bracketing at n=15 — 13 steps (fused won)
#: vs 123 steps (fused lost 3.9×), geometric midpoint ≈ 40: the per-step
#: cost is a property of the *step*, not of how many lanes it carries, so
#: the depth carries over while each two-level step now spans
#: ``macro_tile`` lanes instead of ``d_tile``.
OVERHEAD_GRID_STEPS = 40

_PAYLOAD_ITEMSIZE = {"int8": 1, "bfloat16": 2}


def f_for_bench(n: int) -> int:
    """The benchmark grid's f convention (benchmarks/agg_time.py)."""
    return max(1, (n - 3) // 4)


def _pad(x: int, m: int) -> int:
    return x + (-x) % m


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    """Static footprint of one kernel launch at one (n, d) point."""

    kernel: str
    n: int
    d: int
    d_tile: int              # inner compute window
    macro_tile: int          # outer streamed block (== d_tile: single-level)
    windows: int             # inner d_tile windows per macro step
    grid_steps: int          # OUTER grid depth (macro blocks)
    vmem_bytes: int          # per-macro-step working set
    vmem_budget: int
    hbm_read_bytes: int
    hbm_write_bytes: int
    over_budget: bool        # full-d working set > budget (must tile)
    tile_over_budget: bool   # even a single macro step busts the budget

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _finish(kernel: str, n: int, d: int, d_tile: int, macro_tile: int,
            rows: int, out_rows: int, scratch_rows: int, fixed_bytes: int,
            read_fn, write_bytes: int) -> KernelEstimate:
    """Assemble the estimate from the tile policy's own cost model.

    Per macro step: ``2·(rows+out_rows)·4·macro`` double-buffered streamed
    lanes + ``(scratch_rows+rows)·4·d_tile`` per-window intermediates
    (incl. the fp32 widening of the current window) + ``fixed_bytes``
    residents — byte-for-byte the ``ops.two_level_macro`` budget.
    ``read_fn(d_pad, grid)`` gives the HBM read bytes for the padded
    stack at the *outer* grid depth.
    """
    if macro_tile % d_tile:
        raise ValueError(
            f"macro_tile {macro_tile} not a multiple of d_tile {d_tile}")
    grid = -(-d // macro_tile)
    d_pad = grid * macro_tile
    stream = 2 * (rows + out_rows) * 4
    window = (scratch_rows + rows) * 4 * d_tile
    vmem = stream * macro_tile + window + fixed_bytes
    vmem_full = stream * d_pad + window + fixed_bytes
    return KernelEstimate(
        kernel=kernel, n=n, d=d, d_tile=d_tile, macro_tile=macro_tile,
        windows=macro_tile // d_tile, grid_steps=grid,
        vmem_bytes=vmem, vmem_budget=ops.VMEM_BUDGET_BYTES,
        hbm_read_bytes=read_fn(d_pad, grid), hbm_write_bytes=write_bytes,
        over_budget=vmem_full > ops.VMEM_BUDGET_BYTES,
        tile_over_budget=vmem > ops.VMEM_BUDGET_BYTES)


def estimate_fused_select(n: int, d: int, *, f: Optional[int] = None,
                          d_tile: Optional[int] = None,
                          macro_tile: Optional[int] = None
                          ) -> KernelEstimate:
    """Fused Bulyan apply: (n, d) stack + two (θ, n) plans -> (d,)."""
    f = f_for_bench(n) if f is None else f
    theta = n - 2 * f - 2
    if theta < 1:
        raise ValueError(f"n={n}, f={f}: theta={theta} < 1")
    n_pad = _pad(n, 8)
    scratch = ops._select_scratch_rows(theta)
    fixed = 2 * theta * n_pad * 4
    if d_tile is None:
        # the wrapper's own two-level policy — the estimate must live on
        # the exact (d_tile, macro_tile) pair the kernel launches with
        d_tile, auto_macro = ops.fused_select_tiles(n_pad, d, theta)
        if macro_tile is None:
            macro_tile = auto_macro
    elif macro_tile is None:
        macro_tile = d_tile
    # x streams once; the replicated (θ, n) weight pair is fetched once
    # per OUTER grid step (constant index_map on the macro grid) — the
    # residual of the retired per-d_tile re-read term, now amortised over
    # macro_tile lanes; the (1, macro) output block writes back per step.
    return _finish(
        "fused_select", n, d, d_tile, macro_tile,
        rows=n_pad, out_rows=1, scratch_rows=scratch, fixed_bytes=fixed,
        read_fn=lambda d_pad, grid: n_pad * d_pad * 4 + grid * fixed,
        write_bytes=_pad(d, macro_tile) * 4)


def estimate_pairwise_stats(n: int, d: int, *,
                            d_tile: Optional[int] = None,
                            macro_tile: Optional[int] = None
                            ) -> KernelEstimate:
    """Single-pass stats: (n, d) -> ((n, n) raw sq-dists, (n,) norms)."""
    n_pad = _pad(n, 8)
    fixed = n_pad * (n_pad + 8) * 4       # resident (n, n) acc + norms row
    if d_tile is None:
        # same policy call the wrapper makes: the inner tile is the PR-2
        # autotune value (tile boundaries ARE the float accumulation
        # order), only the macro block is new
        d_tile, auto_macro = ops._stats_tiles(n_pad, d)
        if macro_tile is None:
            macro_tile = auto_macro
    elif macro_tile is None:
        macro_tile = d_tile
    # accumulators are grid-resident (out_rows=0, counted in fixed); the
    # stack streams exactly once — no per-step re-read term at all
    return _finish(
        "pairwise_stats", n, d, d_tile, macro_tile,
        rows=n_pad, out_rows=0, scratch_rows=0, fixed_bytes=fixed,
        read_fn=lambda d_pad, grid: n_pad * d_pad * 4,
        write_bytes=(n_pad * n_pad + n_pad) * 4)


def estimate_dequant_stats(n: int, d: int, *, dtype: str = "int8",
                           d_tile: Optional[int] = None,
                           macro_tile: Optional[int] = None
                           ) -> KernelEstimate:
    """Fused dequantize→stats on an (n, d) int8/bf16 payload."""
    if dtype not in _PAYLOAD_ITEMSIZE:
        raise ValueError(f"payload dtype must be one of "
                         f"{sorted(_PAYLOAD_ITEMSIZE)}, got {dtype!r}")
    item = _PAYLOAD_ITEMSIZE[dtype]
    n_pad = _pad(n, 8)
    fixed = n_pad * (n_pad + 8) * 4
    if d_tile is None:
        # _dequant_tiles == _stats_tiles: the tile is budgeted for the
        # *decoded* fp32 rows so the accumulation order (and bitwise
        # parity with decode-then-pairwise_stats) is preserved (§9)
        d_tile, auto_macro = ops._dequant_tiles(n_pad, d)
        if macro_tile is None:
            macro_tile = auto_macro
    elif macro_tile is None:
        macro_tile = d_tile
    # payload blocks stream at the narrow itemsize; the widened fp32 rows
    # live only in VMEM, one d_tile window at a time — modelled by the
    # same (scratch+rows)·d_tile term the policy budgets
    return _finish(
        "dequant_stats", n, d, d_tile, macro_tile,
        rows=n_pad, out_rows=0, scratch_rows=0, fixed_bytes=fixed,
        read_fn=lambda d_pad, grid: n_pad * d_pad * item + n_pad * 4,
        write_bytes=(n_pad * n_pad + n_pad) * 4)


_ESTIMATORS = {
    "fused_select": estimate_fused_select,
    "pairwise_stats": estimate_pairwise_stats,
    "dequant_stats": estimate_dequant_stats,
}


def estimate(kernel: str, n: int, d: int, **kw) -> KernelEstimate:
    if kernel not in _ESTIMATORS:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"known: {sorted(_ESTIMATORS)}")
    return _ESTIMATORS[kernel](n, d, **kw)


def predicted_crossover(n: int, *, f: Optional[int] = None) -> Dict:
    """Static fused-vs-XLA crossover numel for one n, vs the measured one.

    The asymptotic macro block (d → ∞) times the overhead grid depth
    gives the numel past which residual per-step overhead *could* rival
    the byte savings; the measured counterpart is ``kernels/dispatch.py``'s
    table.  Since the two-level rewrite the benchmark has no measured
    loss point — the table is right-censored at the largest measured win
    — so calibration is one-sided there: the model must predict the win
    region extends at least to the measured frontier (``ratio >= 1``).
    Against a genuinely bracketed crossover (a measured loss exists, as
    in the single-level era) the two-sided [0.5, 2] band applies.
    """
    est = estimate_fused_select(n, 10 ** 9, f=f)     # asymptotic tiles
    predicted = OVERHEAD_GRID_STEPS * est.macro_tile
    measured = kdispatch.FUSED_MAX_NUMEL.get(
        n, kdispatch.DEFAULT_FUSED_MAX_NUMEL)
    _, lose = kdispatch.MEASURED_POINTS.get(n, (0, None))
    censored = lose is None
    ratio = predicted / measured if measured else math.inf
    calibrated = (ratio >= 1.0) if censored else (0.5 <= ratio <= 2.0)
    return {"n": n, "d_tile": est.d_tile, "macro_tile": est.macro_tile,
            "grid_threshold": OVERHEAD_GRID_STEPS,
            "predicted_numel": predicted, "measured_numel": measured,
            "censored": censored, "ratio": ratio, "calibrated": calibrated}


def bench_points(bench_results: dict, row: str = "multi_bulyan[fused]"
                 ) -> List[Dict]:
    """The committed (n, d) grid points of one BENCH_agg_time.json row."""
    pts = []
    for key, us in sorted(bench_results.get(row, {}).items()):
        kv = dict(p.split("=") for p in key.split(","))
        pts.append({"key": key, "n": int(kv["n"]), "d": int(kv["d"]),
                    "us_per_call": us})
    return pts


def diagnose_traffic_linearity(bench_results: dict,
                               row: str = "multi_bulyan[fused]") -> Dict:
    """The cliff-is-closed check: fused cost must track HBM traffic in d.

    Estimates every committed ``multi_bulyan[fused]`` point and computes
    its achieved bytes-per-µs.  The single-level cliff's signature was
    throughput *collapsing* with depth — at n=15 the d=1e6 point moved
    10× the bytes of d=1e5 but ran 38× longer.  With operand residency
    the deep points must sustain their bandwidth: for each n, the
    largest-d point's bytes-per-µs must be within 2× of the best point
    of that n (small-d points are allowed to be overhead-dominated in
    the *other* direction — a fixed plan/launch cost over few bytes —
    which is amortisation, not a cliff).  Replaces the retired
    ``diagnose_cliff``, whose grid-bound/2×-slowdown split described the
    single-level re-read regime.
    """
    pts = bench_points(bench_results, row)
    if not pts:
        return {"points": [], "holds": False,
                "detail": f"no {row} row in benchmark"}
    for p in pts:
        est = estimate_fused_select(p["n"], p["d"])
        p["estimate"] = est.to_json()
        p["bytes"] = est.hbm_read_bytes + est.hbm_write_bytes
        p["bytes_per_us"] = p["bytes"] / p["us_per_call"]
    log_bw = sum(math.log(p["bytes_per_us"]) for p in pts) / len(pts)
    holds = True
    by_n: Dict[int, List[Dict]] = {}
    for p in pts:
        by_n.setdefault(p["n"], []).append(p)
    for n, group in sorted(by_n.items()):
        peak = max(p["bytes_per_us"] for p in group)
        deepest = max(group, key=lambda p: p["d"])
        for p in group:
            p["throughput_vs_peak"] = p["bytes_per_us"] / peak
            p["deepest"] = p is deepest
            # only the deepest point carries the cliff claim; shallower
            # points are reported but not gated
            p["consistent"] = (p["throughput_vs_peak"] >= 0.5
                               if p is deepest else True)
            holds = holds and p["consistent"]
    return {"points": pts, "bytes_per_us": math.exp(log_bw), "holds": holds,
            "detail": "deepest-d point per n sustains >=0.5x the peak "
                      "measured bytes/us of that n — cost stays linear "
                      "in traffic, no deep-grid cliff"}
