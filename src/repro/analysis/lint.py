"""AST lint enforcing the repo's static coding contracts (DESIGN.md §12).

Five rule families, each a shipped-bug class or a contract the rest of the
stack silently depends on:

* **R001 import-time-device-work** — no ``jnp.*`` / ``jax.random.*`` /
  device calls at module import.  Import must be side-effect free: the
  test harness, ``launch/dryrun.py`` and ``launch/analyze.py`` all set
  platform/device flags *before* importing repro modules, which only
  works if importing a module never touches the backend.  (Attribute
  access like ``jax.Array`` or ``jnp.inf`` is fine — only *calls* run
  device work.)
* **R002 tracer-python-branch** — no Python ``if``/``while`` whose test
  calls into ``jnp``/``jax.lax``/``jax.nn``: under jit the result is a
  tracer and the branch either crashes or silently bakes one side into
  the trace.  Use ``jnp.where`` / ``lax.cond``.  Static dtype predicates
  (``jnp.issubdtype`` etc.) are exempt — they run on dtypes, not values.
* **R003 bad-registry-spec** — spec-string literals handed to the
  attack/codec/hier registries (``get_attack("sign_flip:scale=3.0")``,
  ``attack=...``/``codec=...``/``hier=...`` keyword literals) are parsed
  and bound against the *real* registry signatures at lint time, so a
  typo'd kwarg fails in CI instead of at step time.
* **R004 state-integer-index** — ``TrainerState`` is a registered
  dataclass accessed by field name; positional indexing (``state[0]``)
  silently breaks every time a field is added (the PR-5 unification
  exists precisely so slots can move).
* **R005 jit-static-config** — functions jitted at definition site must
  declare their bool/str config parameters in ``static_argnames``, and
  must not resolve the backend (``jax.default_backend()`` /
  ``jax.devices()``) inside the traced body — the PR-2 ``interpret``
  bug: a backend choice baked into a trace goes silently stale when the
  default backend changes.
* **R006 async-blocking-collective** — no blocking collectives
  (``jax.lax.psum`` / ``pmean`` / ``all_gather`` / ``all_to_all`` /
  ``ppermute``) inside the async service loop: any function whose name
  mentions ``async``, or anything under ``repro/serve``.  The bounded
  staleness contract (DESIGN.md §13) is that the plan/apply services
  never *wait* on workers — cross-worker data moves through the buffer's
  masked admission, and a collective in that loop silently reintroduces
  the lockstep barrier the subsystem exists to remove.
* **R007 debug-io-in-step** — no host debug I/O (``jax.debug.print`` /
  ``jax.debug.callback`` / ``jax.experimental.io_callback`` / bare
  ``print``) inside jitted step functions: functions jit-decorated at
  definition site, or named ``step`` / ``*_step`` (the trainer-builder
  closures).  Each such call is a host round-trip per step — it
  serialises the dispatch pipeline and silently destroys the perf the
  benchmarks measure.  Observability belongs in the in-graph registry
  (``repro.obs``, DESIGN.md §14), which is exempt by path: it is the
  sanctioned channel, and its record ops are pure ``jnp``.

``lint_source`` lints one source string; ``lint_paths`` walks files and
directories.  Both are pure AST passes — linted code is never imported.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, List, Optional, Sequence, Set, Tuple

RULE_IDS = ("R001", "R002", "R003", "R004", "R005", "R006", "R007")

#: calls that touch devices / the backend when *executed* (R001 at module
#: scope, R005 inside jitted bodies for the backend-resolving subset)
_DEVICE_CALL_PREFIXES = ("jnp.", "jax.numpy.", "jax.random.")
_DEVICE_CALLS = frozenset({
    "jax.devices", "jax.local_devices", "jax.device_count",
    "jax.local_device_count", "jax.device_put", "jax.make_mesh",
    "jax.default_backend", "jax.eval_shape",
})
_BACKEND_CALLS = frozenset({
    "jax.default_backend", "jax.devices", "jax.local_devices",
    "jax.device_count", "jax.local_device_count",
})
#: value-free dtype predicates: safe to branch on in Python (R002 exempt)
_STATIC_PREDICATES = frozenset({
    "jnp.issubdtype", "jax.numpy.issubdtype", "jnp.result_type",
    "jnp.promote_types", "jnp.dtype", "jnp.finfo", "jnp.iinfo",
})
_TRACER_CALL_PREFIXES = ("jnp.", "jax.numpy.", "jax.lax.", "jax.nn.")
#: registry getters whose first positional string literal is a spec
_SPEC_GETTERS = {"get_attack": "attack", "get_wire_attack": "attack",
                 "get_adaptive": "attack", "get_codec": "codec"}
#: keyword names carrying spec literals anywhere in the tree
_SPEC_KWARGS = {"attack": "attack", "codec": "codec", "hier": "hier"}
_STATE_NAMES = frozenset({"state", "tstate", "trainer_state"})


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.key' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _walk_pruned(node: ast.AST, prune: Tuple[type, ...]) -> Iterable[ast.AST]:
    """ast.walk that does not descend into ``prune`` node types."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if not isinstance(child, prune):
            stack.extend(ast.iter_child_nodes(child))


_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


# ------------------------------------------------------------------ R001
def _rule_import_time(tree: ast.Module, path: str) -> List[Violation]:
    out = []

    def scan_body(body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue                   # bodies run at call time
            if isinstance(stmt, ast.ClassDef):
                scan_body(stmt.body)       # class bodies run at import
                continue
            for node in _walk_pruned(stmt, _FUNC_NODES):
                if not isinstance(node, ast.Call):
                    continue
                name = _dotted(node.func)
                if name is None:
                    continue
                if name.startswith(_DEVICE_CALL_PREFIXES) \
                        or name in _DEVICE_CALLS:
                    out.append(Violation(
                        "R001", path, node.lineno,
                        f"device/array work at module import: {name}() — "
                        "hoist into a function (imports must be "
                        "side-effect free)"))

    scan_body(tree.body)
    return out


# ------------------------------------------------------------------ R002
def _rule_tracer_branch(tree: ast.Module, path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.If, ast.While, ast.IfExp)):
            continue
        for sub in ast.walk(node.test):
            if not isinstance(sub, ast.Call):
                continue
            name = _dotted(sub.func)
            if name is None or name in _STATIC_PREDICATES:
                continue
            if name.startswith(_TRACER_CALL_PREFIXES):
                kw = "while" if isinstance(node, ast.While) else "if"
                out.append(Violation(
                    "R002", path, node.lineno,
                    f"Python `{kw}` branches on {name}(...) — a tracer "
                    "under jit; use jnp.where / lax.cond"))
    return out


# ------------------------------------------------------------------ R003
def _check_spec(kind: str, spec: str) -> Optional[str]:
    """Bind one spec literal against the real registries.

    Returns an error message, or None when the spec is valid (or when the
    registries cannot be imported — the lint must not require jax)."""
    try:
        if kind == "attack":
            if spec in ("", "none"):
                return None
            from repro.core import attacks as ATK
            errors = []
            for getter in (ATK.get_attack, ATK.get_wire_attack,
                           ATK.get_adaptive):
                try:
                    getter(spec)
                    return None
                except Exception as e:          # noqa: BLE001 — collect
                    errors.append(str(e))
            return errors[0]
        if kind == "codec":
            if spec in ("", "none"):
                return None
            from repro.comm import codecs as CC
            try:
                CC.get_codec(spec)
                return None
            except Exception as e:              # noqa: BLE001
                return str(e)
        if kind == "hier":
            from repro.hier import GroupConfig
            try:
                GroupConfig.from_spec(spec)
                return None
            except Exception as e:              # noqa: BLE001
                return str(e)
    except ImportError:
        return None
    return None


def _rule_registry_specs(tree: ast.Module, path: str) -> List[Violation]:
    out = []

    def check(kind: str, spec: str, lineno: int) -> None:
        err = _check_spec(kind, spec)
        if err is not None:
            out.append(Violation(
                "R003", path, lineno,
                f"{kind} spec {spec!r} does not bind against the "
                f"registry: {err}"))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func) or ""
        tail = name.rsplit(".", 1)[-1]
        if tail in _SPEC_GETTERS and node.args \
                and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, str):
            check(_SPEC_GETTERS[tail], node.args[0].value, node.lineno)
        if tail == "from_spec" and node.args \
                and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, str) \
                and "g=" in node.args[0].value:
            check("hier", node.args[0].value, node.lineno)
        for kw in node.keywords:
            if kw.arg in _SPEC_KWARGS \
                    and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                check(_SPEC_KWARGS[kw.arg], kw.value.value, kw.value.lineno)
    return out


# ------------------------------------------------------------------ R004
def _rule_state_index(tree: ast.Module, path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Subscript):
            continue
        base = node.value
        name = base.id if isinstance(base, ast.Name) else (
            base.attr if isinstance(base, ast.Attribute) else None)
        if name not in _STATE_NAMES:
            continue
        idx = node.slice
        if isinstance(idx, ast.UnaryOp) and isinstance(idx.op, ast.USub):
            idx = idx.operand
        if isinstance(idx, ast.Constant) and isinstance(idx.value, int) \
                and not isinstance(idx.value, bool):
            out.append(Violation(
                "R004", path, node.lineno,
                f"TrainerState indexed positionally ({name}[...]) — "
                "access fields by name; slots move when the dataclass "
                "grows"))
    return out


# ------------------------------------------------------------------ R005
def _static_names_from(value: ast.AST) -> Set[str]:
    names: Set[str] = set()
    if isinstance(value, ast.Constant) and isinstance(value.value, str):
        names.add(value.value)
    elif isinstance(value, (ast.Tuple, ast.List)):
        for el in value.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                names.add(el.value)
    return names


def _jit_decorator(dec: ast.AST) -> Optional[Set[str]]:
    """static_argnames of a jit decorator, or None if not a jit."""
    if _dotted(dec) == "jax.jit":
        return set()
    if not isinstance(dec, ast.Call):
        return None
    fname = _dotted(dec.func)
    if fname == "jax.jit":
        target = dec
    elif fname in ("functools.partial", "partial") and dec.args \
            and _dotted(dec.args[0]) == "jax.jit":
        target = dec
    else:
        return None
    names: Set[str] = set()
    for kw in target.keywords:
        if kw.arg == "static_argnames":
            names |= _static_names_from(kw.value)
    return names


def _config_typed(arg: ast.arg, default: Optional[ast.AST]) -> bool:
    """bool/str-annotated or bool/str-defaulted: a config, not an array."""
    ann = arg.annotation
    if ann is not None:
        ann_name = _dotted(ann) or (
            ann.value if isinstance(ann, ast.Constant) else None)
        if ann_name in ("bool", "str"):
            return True
    if isinstance(default, ast.Constant) \
            and isinstance(default.value, (bool, str)):
        return True
    return False


def _rule_jit_static(tree: ast.Module, path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        static: Optional[Set[str]] = None
        for dec in node.decorator_list:
            s = _jit_decorator(dec)
            if s is not None:
                static = s if static is None else static | s
        if static is None:
            continue
        a = node.args
        pos_defaults = [None] * (len(a.args) - len(a.defaults)) \
            + list(a.defaults)
        for arg, default in list(zip(a.args, pos_defaults)) \
                + list(zip(a.kwonlyargs, a.kw_defaults)):
            if arg.arg in static:
                continue
            if _config_typed(arg, default):
                out.append(Violation(
                    "R005", path, arg.lineno,
                    f"jit'd {node.name}(): config parameter "
                    f"{arg.arg!r} (bool/str) is traced — declare it in "
                    "static_argnames or it bakes stale into the trace"))
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) \
                    and (_dotted(sub.func) or "") in _BACKEND_CALLS:
                out.append(Violation(
                    "R005", path, sub.lineno,
                    f"jit'd {node.name}() resolves the backend inside "
                    f"the trace ({_dotted(sub.func)}()) — resolve "
                    "outside jit and pass it as a static argument "
                    "(the PR-2 interpret bug class)"))
    return out


# ------------------------------------------------------------------ R006
#: blocking cross-worker collectives — each one is a synchronisation
#: barrier over the worker axis, which the async service loop must never
#: contain (late workers are handled by buffer admission, not by waiting)
_BLOCKING_COLLECTIVES = frozenset({
    "jax.lax.psum", "jax.lax.pmean", "jax.lax.pmax", "jax.lax.pmin",
    "jax.lax.all_gather", "jax.lax.all_to_all", "jax.lax.ppermute",
    "lax.psum", "lax.pmean", "lax.pmax", "lax.pmin",
    "lax.all_gather", "lax.all_to_all", "lax.ppermute",
})
_SERVE_PATH_MARKERS = (os.path.join("repro", "serve"),)


def _rule_async_collective(tree: ast.Module, path: str) -> List[Violation]:
    out = []
    norm = path.replace("\\", "/")
    serve_file = any(m.replace("\\", "/") in norm
                     for m in _SERVE_PATH_MARKERS)

    def scan(node: ast.AST, where: str) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) \
                    and (_dotted(sub.func) or "") in _BLOCKING_COLLECTIVES:
                out.append(Violation(
                    "R006", path, sub.lineno,
                    f"blocking collective {_dotted(sub.func)}() inside "
                    f"{where} — the async service must never barrier on "
                    "the worker axis; route cross-worker data through "
                    "the staleness buffer's masked admission"))

    if serve_file:
        scan(tree, "repro/serve (the async service package)")
        return out
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and "async" in node.name.lower():
            scan(node, f"async service function {node.name}()")
    return out


# ------------------------------------------------------------------ R007
#: host debug I/O — each call is a host round-trip from inside the step
_DEBUG_IO_CALLS = frozenset({
    "jax.debug.print", "jax.debug.callback", "jax.debug.breakpoint",
    "jax.experimental.io_callback", "io_callback", "print",
})
#: repro.obs is the sanctioned observability channel (pure-jnp record ops;
#: host I/O only in its export layer, which no step ever traces)
_OBS_PATH_MARKERS = (os.path.join("repro", "obs"),)


def _rule_debug_io(tree: ast.Module, path: str) -> List[Violation]:
    out = []
    norm = path.replace("\\", "/")
    if any(m.replace("\\", "/") in norm for m in _OBS_PATH_MARKERS):
        return out

    def is_step(node) -> bool:
        if node.name == "step" or node.name.endswith("_step"):
            return True
        return any(_jit_decorator(d) is not None
                   for d in node.decorator_list)

    seen: Set[Tuple[int, str]] = set()
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                or not is_step(node):
            continue
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = _dotted(sub.func) or ""
            if name in _DEBUG_IO_CALLS and (sub.lineno, name) not in seen:
                seen.add((sub.lineno, name))
                out.append(Violation(
                    "R007", path, sub.lineno,
                    f"host debug I/O {name}() inside step function "
                    f"{node.name}() — a host round-trip per step; record "
                    "into the repro.obs registry/span ring instead "
                    "(DESIGN.md §14)"))
    return out


#: rule id -> one-line description (R000 is the parse-failure sentinel)
RULES = {
    "R000": "file must parse",
    "R001": "no jnp/device work at module import time",
    "R002": "no Python branching on tracer-valued predicates",
    "R003": "registry spec strings must resolve against the registry",
    "R004": "TrainerState is accessed by field name, never by index",
    "R005": "jit'd config/flag params must be declared static",
    "R006": "no blocking collectives inside the async service loop",
    "R007": "no host debug I/O inside jitted step functions (use "
            "repro.obs)",
}


# ------------------------------------------------------------------ driver
def lint_source(src: str, path: str = "<string>") -> List[Violation]:
    """Lint one source string; returns violations sorted by position."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation("R000", path, e.lineno or 0,
                          f"syntax error: {e.msg}")]
    out: List[Violation] = []
    out += _rule_import_time(tree, path)
    out += _rule_tracer_branch(tree, path)
    out += _rule_registry_specs(tree, path)
    out += _rule_state_index(tree, path)
    out += _rule_jit_static(tree, path)
    out += _rule_async_collective(tree, path)
    out += _rule_debug_io(tree, path)
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_paths(paths: Iterable[str]) -> List[Violation]:
    """Lint files and (recursively) directories of ``*.py`` files."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                files += [os.path.join(root, n) for n in sorted(names)
                          if n.endswith(".py")]
        else:
            files.append(p)
    out: List[Violation] = []
    for fp in files:
        with open(fp, encoding="utf-8") as fh:
            out += lint_source(fh.read(), fp)
    return out
