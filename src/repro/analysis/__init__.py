"""Static contract verification for the repro codebase (DESIGN.md §12).

Three passes, none of which runs device code:

* :mod:`repro.analysis.lint` — AST lint with repo-specific rules
  (R001–R005): import-time device work, Python branches on tracers,
  registry spec strings, TrainerState indexing, jit static-argument
  hygiene (the PR-2 ``interpret``-baked-at-trace-time bug class).
* :mod:`repro.analysis.jaxpr_audit` — traces the aggregation paths and
  walks the jaxprs to *prove* the sharding contracts (C201–C205): no
  full (n, d) all-gather inside the apply shard body, the §9 decode
  invariant, the §10 tp-reshape seam, and single-compile trace caching.
* :mod:`repro.analysis.vmem` — static per-tile VMEM/HBM-traffic
  estimates for the Pallas kernels, cross-checked against the
  ``autotune_d_tile`` budget and the measured BENCH_agg_time.json
  crossover.

``repro.launch.analyze`` runs all three and writes the ``analysis.v1``
report (ANALYSIS.json); ``--strict`` makes any violation fatal, which is
how CI gates every kernel/sharding PR.
"""
from repro.analysis.lint import (  # noqa: F401
    Violation, lint_paths, lint_source)

__all__ = ["Violation", "lint_paths", "lint_source"]
