"""Jaxpr auditors: statically prove the sharding contracts (DESIGN.md §12).

Each auditor traces a real aggregation path with ``jax.make_jaxpr`` — no
arrays are materialised beyond the eager plan statistics — and walks the
jaxpr (recursing through pjit / shard_map / scan sub-jaxprs) looking for
the exact primitive signature of a shipped or near-missed bug class:

* **C201 apply-shard-gather** — inside the apply ``shard_map`` body the
  only admitted reshard is the worker-row gather of one d-shard: every
  ``all_gather`` must stay ≤ (n_pad, d_pad/M) and must never gather the
  model axis (which would re-materialise full d per device, §3/§10).
* **C202 decode-invariant** — the §9 contract: an encoded wire payload
  (int8/bf16 + per-row multiplier) is dequantized *inside* shard bodies;
  a full-stack narrow→fp32 ``convert_element_type`` outside any shard
  body is the replicated (n, d) fp32 stack the design forbids.
* **C203 tp-reshape-seam** — the §10 blowup signature: a leaf whose
  param dim is constrained to the model axis reaching a rank-reducing
  reshape (``_leaf2d``'s flatten) — GSPMD cannot shard the merged dim
  and silently replicates (the measured 79.8 GB vs 10.4 GB dry-run).
  Taint flows from ``sharding_constraint`` equations (and optional
  explicit invar taint) through elementwise/transpose/broadcast ops to
  any merging reshape.  ``tp_seam_self_test`` proves the auditor is
  live by requiring it to trip on a synthetic tp-pinned leaf.
* **C204 single-compile** — each jitted step must lower exactly once
  per configuration: repeated same-shape calls must add zero backend
  compiles (counted via jax's monitoring events) and leave exactly one
  entry in the trace cache — the regression gate for the PR-2
  baked-trace bug class and for accidental retrace-per-step bugs.
* **C205 hier-decode** — the §11 grouped path decodes per-group row
  slices; a narrow→fp32 convert of the *full* n-row payload outside the
  group loop would defeat the two-level wire budget.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp

try:                                      # event-counting backend (private
    from jax._src import monitoring      # but stable across 0.4.x)
except ImportError:                      # pragma: no cover - future jax
    monitoring = None

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_NARROW_DTYPES = ("int8", "uint8", "bfloat16")


@dataclasses.dataclass
class ContractResult:
    contract: str                        # e.g. "C201-apply-shard-gather"
    status: str                          # "proven" | "violated"
    detail: str
    violations: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.status == "proven"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _result(contract: str, violations: List[str], detail: str
            ) -> ContractResult:
    return ContractResult(
        contract=contract,
        status="violated" if violations else "proven",
        detail=detail, violations=violations)


# ------------------------------------------------------------ jaxpr walking
def _as_open(obj):
    """Unwrap ClosedJaxpr -> Jaxpr; pass Jaxpr through; else None."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(obj, "eqns") and hasattr(obj, "invars"):
        return obj
    return None


def _sub_jaxprs(eqn) -> Iterable:
    for v in eqn.params.values():
        for item in (v if isinstance(v, (list, tuple)) else (v,)):
            sub = _as_open(item)
            if sub is not None:
                yield sub


def iter_eqns(jaxpr, in_shard: bool = False):
    """Yield (eqn, in_shard_body) over a jaxpr and all sub-jaxprs."""
    jaxpr = _as_open(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn, in_shard
        inner = in_shard or eqn.primitive.name == "shard_map"
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, inner)


def _axis_names(eqn) -> Sequence[str]:
    ax = eqn.params.get("axis_name", ())
    return ax if isinstance(ax, (tuple, list)) else (ax,)


def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


# ------------------------------------------------------------------ C201
def gather_violations(closed, *, allowed: int,
                      model_axis: Optional[str]
                      ) -> "tuple[list[str], int]":
    """In-shard all_gather checks shared by C201 and the fixtures."""
    violations, gathers = [], 0
    for eqn, in_shard in iter_eqns(closed):
        if eqn.primitive.name != "all_gather" or not in_shard:
            continue
        gathers += 1
        out = eqn.outvars[0].aval
        if model_axis is not None and model_axis in _axis_names(eqn):
            violations.append(
                f"all_gather over the model axis {model_axis!r} inside "
                f"the apply shard body (output {out.shape}) "
                "re-materialises full d per device")
        elif _numel(out.shape) > allowed:
            violations.append(
                f"all_gather result {out.shape} "
                f"({_numel(out.shape):,} elements) exceeds the per-device "
                f"bound n_pad x d_pad/M = {allowed:,}")
    return violations, gathers


def audit_apply_gather(grads, f: int = 1, rule: str = "multi_bulyan", *,
                       mesh_ctx) -> ContractResult:
    """C201: the apply shard body gathers at most (n_pad, d_pad/M)."""
    from repro.core import api
    agg = api.get_aggregator(rule)
    stats = api.compute_stats(grads, f, needs_dists=agg.needs_dists,
                              mesh_ctx=mesh_ctx)
    agg.validate(stats.n, stats.f)
    plan = agg.plan(stats)
    closed = jax.make_jaxpr(
        lambda g: agg.apply(plan, g, mesh_ctx=mesh_ctx))(grads)

    W, M = mesh_ctx.worker_size, mesh_ctx.model_size
    allowed = 0
    for leaf in jax.tree.leaves(grads):
        n = leaf.shape[0]
        n_pad = -(-n // W) * W
        numel = _numel(leaf.shape[1:])
        d_pad = -(-numel // M) * M
        allowed = max(allowed, n_pad * (d_pad // M))

    violations, gathers = gather_violations(
        closed, allowed=allowed, model_axis=mesh_ctx.model_axis)
    if gathers == 0:
        violations.append("no all_gather found inside a shard body — the "
                          "apply path was not exercised under the mesh")
    return _result(
        "C201-apply-shard-gather", violations,
        f"{gathers} in-shard gather(s) audited against the "
        f"(n_pad, d_pad/M) bound of {allowed:,} elements "
        f"(rule={rule}, mesh W={W} M={M})")


# ------------------------------------------------------------------ C202
def full_stack_decodes(closed, n: int, *, require_in_shard: bool
                        ) -> "tuple[list[str], int]":
    """Narrow→fp32 converts of a full n-row stack, + total decode count."""
    violations, decodes = [], 0
    for eqn, in_shard in iter_eqns(closed):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = eqn.invars[0].aval
        out = eqn.outvars[0].aval
        if str(src.dtype) not in _NARROW_DTYPES \
                or str(out.dtype) != "float32":
            continue
        decodes += 1
        if require_in_shard and in_shard:
            continue
        if len(out.shape) >= 2 and int(out.shape[0]) >= n:
            where = "outside any shard body" if require_in_shard \
                else "over the full worker stack"
            violations.append(
                f"{src.dtype}->{out.dtype} materialisation of the full "
                f"{tuple(int(s) for s in out.shape)} stack {where}")
    return violations, decodes


def audit_decode_invariant(grads, f: int = 1, rule: str = "multi_bulyan", *,
                           mesh_ctx, codec_spec: str = "qsgd:bits=8"
                           ) -> ContractResult:
    """C202: encoded payloads dequantize per shard, never replicated."""
    from repro.comm import codecs as CC
    from repro.core import api
    codec = CC.get_codec(codec_spec)
    enc, _res = codec.encode(grads, key=jax.random.key(0))
    closed = jax.make_jaxpr(
        lambda e: api.aggregate_tree(e, f, rule, mesh_ctx=mesh_ctx))(enc)
    violations, decodes = full_stack_decodes(closed, enc.n,
                                              require_in_shard=True)
    if decodes == 0:
        violations.append(f"no {codec_spec} dequantization found in the "
                          "trace — the encoded path was not exercised")
    return _result(
        "C202-decode-invariant", violations,
        f"{decodes} narrow->fp32 convert(s) audited; all full-stack "
        f"decodes confined to shard bodies (codec={codec_spec}, "
        f"rule={rule})")


# ------------------------------------------------------------------ C203
_ELEMENTWISE_SAFE = True  # same-shape ops propagate taint


def _taint_walk(jaxpr, taint: Dict, model_axis: str,
                violations: List[str]) -> None:
    jaxpr = _as_open(jaxpr)

    def get(v) -> Set[int]:
        if hasattr(v, "val"):           # Literal
            return set()
        return taint.get(v, set())

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "sharding_constraint":
            spec = getattr(eqn.params.get("sharding"), "spec", None)
            dims = set()
            if spec is not None:
                for i, entry in enumerate(spec):
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    if model_axis in axes:
                        dims.add(i)
            dims |= get(eqn.invars[0])
            if dims:
                taint[eqn.outvars[0]] = dims
            continue
        if name == "shard_map":
            continue                    # explicit layout inside the body
        in_taints = [get(v) for v in eqn.invars]
        if not any(in_taints):
            # still recurse: sub-jaxprs may contain their own constraints
            for sub in _sub_jaxprs(eqn):
                _taint_walk(sub, taint, model_axis, violations)
            continue
        src_idx = next(i for i, t in enumerate(in_taints) if t)
        dims = in_taints[src_idx]
        src = eqn.invars[src_idx].aval
        if name == "reshape":
            out = eqn.outvars[0].aval
            if len(out.shape) != len(src.shape):
                violations.append(
                    f"reshape {tuple(int(s) for s in src.shape)} -> "
                    f"{tuple(int(s) for s in out.shape)} merges dims "
                    f"{sorted(dims)} constrained to the "
                    f"{model_axis!r} axis — GSPMD replicates the merged "
                    "dim (the §10 tp-flatten seam)")
            else:
                taint[eqn.outvars[0]] = dims
            continue
        if name == "transpose":
            perm = eqn.params["permutation"]
            taint[eqn.outvars[0]] = {perm.index(d) for d in dims}
            continue
        if name == "broadcast_in_dim":
            bdims = eqn.params["broadcast_dimensions"]
            taint[eqn.outvars[0]] = {bdims[d] for d in dims
                                     if d < len(bdims)}
            continue
        subs = list(_sub_jaxprs(eqn))
        if subs and len(subs) >= 1:
            for sub in subs:
                sub = _as_open(sub)
                if len(sub.invars) == len(eqn.invars):
                    inner: Dict = {
                        sv: t for sv, t in zip(sub.invars, in_taints) if t}
                    inner_all = dict(taint)
                    inner_all.update(inner)
                    _taint_walk(sub, inner_all, model_axis, violations)
                    for ov, sv in zip(eqn.outvars, sub.outvars):
                        t = inner_all.get(sv) if not hasattr(sv, "val") \
                            else None
                        if t:
                            taint[ov] = t
            continue
        # same-shape ops (elementwise, convert, pad with zero-width...)
        for ov in eqn.outvars:
            if tuple(ov.aval.shape) == tuple(src.shape):
                taint[ov] = dims


def audit_tp_seam(closed, *, model_axis: str = "model",
                  invar_taint: Optional[Dict[int, Set[int]]] = None,
                  label: str = "") -> ContractResult:
    """C203: no rank-reducing reshape of a model-axis-constrained dim."""
    jaxpr = _as_open(closed)
    taint: Dict = {}
    for idx, dims in (invar_taint or {}).items():
        taint[jaxpr.invars[idx]] = set(dims)
    violations: List[str] = []
    _taint_walk(jaxpr, taint, model_axis, violations)
    what = f" ({label})" if label else ""
    return _result(
        "C203-tp-reshape-seam", violations,
        f"taint from sharding_constraint eqns on the {model_axis!r} axis "
        f"propagated to every reshape{what}")


def tp_seam_self_test(model_axis: str = "model") -> ContractResult:
    """The auditor must trip on the synthetic §10 signature.

    A (n, d1, d2) leaf with its last param dim tainted as model-sharded,
    flattened by the exact ``_leaf2d`` reshape — status "proven" here
    means the self-test PASSED (the auditor correctly reported the
    violation); "violated" means the auditor has gone blind.
    """
    leaf = jax.ShapeDtypeStruct((8, 16, 128), jnp.float32)
    closed = jax.make_jaxpr(lambda x: x.reshape(x.shape[0], -1))(leaf)
    res = audit_tp_seam(closed, model_axis=model_axis,
                        invar_taint={0: {2}}, label="self-test")
    tripped = not res.ok
    return ContractResult(
        contract="C203-self-test",
        status="proven" if tripped else "violated",
        detail="auditor trips on a tp-pinned (n, d1, d2) flatten",
        violations=[] if tripped else
        ["auditor failed to flag the synthetic §10 tp-flatten"])


# ------------------------------------------------------------------ C204
class CompileCounter:
    """Counts XLA backend compiles via jax's monitoring events."""

    def __init__(self) -> None:
        self.count = 0

    def _listener(self, event: str, duration: float, **kw) -> None:
        if event == _COMPILE_EVENT:
            self.count += 1

    def __enter__(self) -> "CompileCounter":
        if monitoring is not None:
            monitoring.register_event_duration_secs_listener(self._listener)
        return self

    def __exit__(self, *exc) -> bool:
        if monitoring is not None:
            monitoring._unregister_event_duration_listener_by_callback(
                self._listener)
        return False


def audit_single_compile(fn: Callable, make_args: Callable[[], tuple], *,
                         label: str, repeats: int = 2) -> ContractResult:
    """C204: a jitted step lowers once; identical calls hit the cache.

    ``fn`` must be the jitted callable itself (so its trace cache can be
    inspected); ``make_args`` returns fresh same-shape arguments per
    call.
    """
    with CompileCounter() as warm:
        fn(*make_args())
    with CompileCounter() as rest:
        for _ in range(repeats):
            fn(*make_args())
    cache = fn._cache_size() if hasattr(fn, "_cache_size") else None
    violations = []
    if rest.count > 0:
        violations.append(
            f"{label}: {rest.count} backend compile(s) on {repeats} "
            "repeated identical-shape calls — the step retraces")
    if cache is not None and cache != 1:
        violations.append(
            f"{label}: trace cache holds {cache} entries after "
            "identical-config calls (want exactly 1)")
    return _result(
        "C204-single-compile", violations,
        f"{label}: {warm.count} compile(s) on first call, {rest.count} on "
        f"{repeats} repeats, cache size {cache}")


# ------------------------------------------------------------------ C205
def audit_hier_decode(grads, f: int = 1, spec: str = "g=7",
                      rule: str = "multi_bulyan",
                      codec_spec: str = "qsgd:bits=8") -> ContractResult:
    """C205: the grouped path decodes per-group slices, never full-n."""
    from repro.comm import codecs as CC
    from repro.hier import GroupConfig, hier_aggregate_tree
    codec = CC.get_codec(codec_spec)
    enc, _res = codec.encode(grads, key=jax.random.key(0))
    cfg = GroupConfig.from_spec(spec, rule=rule)
    closed = jax.make_jaxpr(
        lambda e: hier_aggregate_tree(e, f, cfg)[0])(enc)
    violations, decodes = full_stack_decodes(closed, enc.n,
                                              require_in_shard=False)
    if decodes == 0:
        violations.append("no dequantization found in the grouped trace")
    return _result(
        "C205-hier-decode", violations,
        f"{decodes} narrow->fp32 convert(s) audited; every decode is a "
        f"per-group row slice (< n={enc.n} rows; {spec}, "
        f"codec={codec_spec})")
