"""Memory-bounded cross entropy.

The naive loss materialises (tokens × vocab) logits in fp32 — at 65k tokens
× 152k vocab that is ~40 GB per device, twice (forward residual + backward
dlogits).  ``chunked_xent`` computes the loss over token chunks inside a
rematerialised ``lax.map``: the backward pass recomputes each chunk's logits
on the fly, so peak logit memory is one chunk (~0.3 GB at chunk 512).

This is load-bearing for the dry-run memory budget of every train_4k
combination (EXPERIMENTS.md §Perf, iteration 0).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import modules as M

Array = jax.Array


def _chunk_nll(readout_params: dict, tied: bool, xc: Array, yc: Array,
               wc: Array) -> Array:
    """Sum of masked NLL over one chunk.  xc: (c, d); yc, wc: (c,)."""
    if tied:
        logits = M.embedding_attend(readout_params["embed"], xc)
    else:
        logits = M.linear_apply(readout_params["lm_head"], xc)
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, yc[:, None], axis=-1)[:, 0]
    return jnp.sum((logz - gold) * wc)


def chunked_xent(x: Array, labels: Array, readout_params: dict, *,
                 tied: bool, mask: Optional[Array] = None,
                 chunk: int = 4096) -> Array:
    """Mean next-token NLL.  x: (B, S, d); labels: (B, S)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    yf = labels.reshape(t)
    wf = jnp.ones((t,), jnp.float32) if mask is None else mask.reshape(t).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(wf), 1.0)

    if t <= chunk:
        return _chunk_nll(readout_params, tied, xf, yf, wf) / denom

    pad = (-t) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        yf = jnp.pad(yf, (0, pad))
        wf = jnp.pad(wf, (0, pad))
    nc = xf.shape[0] // chunk
    xs = xf.reshape(nc, chunk, d)
    ys = yf.reshape(nc, chunk)
    ws = wf.reshape(nc, chunk)

    body = jax.checkpoint(
        functools.partial(_chunk_nll, readout_params, tied))
    sums = jax.lax.map(lambda args: body(*args), (xs, ys, ws))
    return jnp.sum(sums) / denom
