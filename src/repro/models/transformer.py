"""Decoder-only LM assembly: dense / GQA / MoE / SSM / hybrid / VLM-prefix.

Layers are grouped into *superblocks* of ``period`` layers (period = 1 for
homogeneous stacks, = hybrid period (lcm'd with the MoE interleave) for
jamba-style models).  Superblock parameters are stacked along a leading axis
and the stack is traversed with ``lax.scan`` — a 94-layer model lowers to a
single scanned block, keeping HLO size and compile time flat (required for
the 40-combo dry-run).

Three execution modes share the same parameters:
* ``apply_lm``    — full-sequence forward (training loss / logits).
* ``prefill``     — full-sequence forward that also emits the layer caches
                    and only the last-position logits.
* ``decode_step`` — one token against the cache (full or ring-buffer window).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import modules as M
from repro.models import mlp as F
from repro.models import moe as E
from repro.models import ssm as S

Array = jax.Array
PyTree = Any


# ------------------------------------------------------------ layer specs
def layer_specs(cfg: ArchConfig) -> List[Tuple[str, Optional[str]]]:
    """Per-layer (mixer, mlp) kinds for one superblock period."""
    if cfg.family == "ssm":
        return [("mamba", None)]
    period = 1
    if cfg.hybrid is not None:
        period = cfg.hybrid.period
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.every)
    specs: List[Tuple[str, Optional[str]]] = []
    for i in range(period):
        if cfg.hybrid is not None:
            mixer = "attn" if (i % cfg.hybrid.period) == cfg.hybrid.attn_index else "mamba"
        else:
            mixer = "attn"
        if cfg.d_ff == 0 and cfg.moe is None:
            mlp_kind: Optional[str] = None
        elif cfg.moe is not None and (i % cfg.moe.every) == cfg.moe.every - 1:
            mlp_kind = "moe"
        else:
            mlp_kind = "dense"
        specs.append((mixer, mlp_kind))
    return specs


def n_groups(cfg: ArchConfig) -> int:
    period = len(layer_specs(cfg))
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period


# ------------------------------------------------------------------ init
def _layer_init(key, cfg: ArchConfig, mixer: str, mlp_kind: Optional[str]) -> dict:
    km, kf = jax.random.split(key)
    p: dict = {"norm1": M.norm_init(cfg.norm, cfg.d_model)}
    if mixer == "attn":
        p["attn"] = A.attn_init(km, cfg)
    else:
        p["mamba"] = S.mamba_init(km, cfg)
    if mlp_kind is not None:
        p["norm2"] = M.norm_init(cfg.norm, cfg.d_model)
        if mlp_kind == "moe":
            p["moe"] = E.moe_init(kf, cfg.d_model, cfg.moe, cfg.activation)
        else:
            p["mlp"] = F.mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.activation)
    return p


def _group_init(key, cfg: ArchConfig) -> dict:
    specs = layer_specs(cfg)
    keys = jax.random.split(key, len(specs))
    return {f"l{i}": _layer_init(k, cfg, mx, mk)
            for i, (k, (mx, mk)) in enumerate(zip(keys, specs))}


def init_lm(key, cfg: ArchConfig) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    ng = n_groups(cfg)
    groups = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_group_init(k, cfg) for k in jax.random.split(kb, ng)],
    )
    params = {
        "embed": M.embedding_init(ke, cfg.vocab_size, cfg.d_model),
        "groups": groups,
        "final_norm": M.norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = M.linear_init(kh, cfg.d_model, cfg.vocab_size,
                                          stddev=1.0 / math.sqrt(cfg.d_model))
    return params


# --------------------------------------------------------------- forward
def _layer_apply(p: dict, x: Array, cfg: ArchConfig, mixer: str,
                 mlp_kind: Optional[str], *, positions: Array,
                 window: int, chunk_q: int, emit_cache: bool,
                 cache_len: int = 0) -> Tuple[Array, Array, Optional[dict]]:
    """Returns (x, aux_loss, cache_or_None)."""
    h = M.norm_apply(cfg.norm, p["norm1"], x)
    cache = None
    if mixer == "attn":
        b, s, _ = h.shape
        q, k, v = A.project_qkv(p["attn"], h, cfg, positions=positions)
        if cfg.sharding_strategy == "tp_attn_batch":
            # batch-shard the attention inner loop over the model axis
            # (heads don't divide the mesh — EXPERIMENTS.md §Perf hc-1)
            q, k, v = A.batch_shard_qkv(q, k, v)
        out = A.attend_full(q, k, v, causal=True, window=window, chunk_q=chunk_q)
        out = M.linear_apply(p["attn"]["o"], out.reshape(b, s, -1))
        if emit_cache:
            cache = A.cache_from_prefill(k, v, cache_len, window)
    else:
        out = S.mamba_apply(p["mamba"], h, cfg)
        if emit_cache:
            # prefill emits the final recurrent state for decode continuation
            cache = _mamba_prefill_cache(p["mamba"], h, cfg)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if mlp_kind is not None:
        h2 = M.norm_apply(cfg.norm, p["norm2"], x)
        if mlp_kind == "moe":
            y, aux = E.moe_apply(p["moe"], h2, cfg.moe, cfg.activation)
        else:
            y = F.mlp_apply(p["mlp"], h2, cfg.activation)
        x = x + y
    return x, aux, cache


def _mamba_prefill_cache(p: dict, h_normed: Array, cfg: ArchConfig) -> dict:
    """Recompute the final (conv, h) state after a full-sequence pass.

    Cheap relative to the mixer itself: one extra pass over the projections
    for the last few tokens plus a state reduction; exactness is tested in
    tests/test_serving.py.
    """
    ssm = cfg.ssm
    b, s, d = h_normed.shape
    di = ssm.expand * d
    xz = M.linear_apply(p["in_proj"], h_normed)
    x_raw, _ = jnp.split(xz, 2, axis=-1)
    conv_hist = x_raw[:, -(ssm.d_conv - 1):].astype(jnp.float32)
    xc = jax.nn.silu(S._causal_conv(x_raw, p["conv_w"], p["conv_b"]))
    decay, inp, _ = S._ssm_inputs(p, xc, ssm, d)
    # final state = sum_t (prod_{u>t} decay_u) inp_t — do it as a scan over
    # chunks to bound memory (same trick as the forward pass).
    h0 = jnp.zeros((b, di, ssm.d_state), jnp.float32)
    chunk = 256
    if s > chunk and s % chunk == 0:
        nc = s // chunk
        dch = decay.reshape(b, nc, chunk, di, ssm.d_state).transpose(1, 0, 2, 3, 4)
        ich = inp.reshape(b, nc, chunk, di, ssm.d_state).transpose(1, 0, 2, 3, 4)

        def step(hc, xs):
            dc, ic = xs
            _, h_last = S._scan_chunk(hc, dc, ic)
            return h_last, ()

        h_final, _ = jax.lax.scan(step, h0, (dch, ich))
    else:
        _, h_final = S._scan_chunk(h0, decay, inp)
    return {"conv": conv_hist, "h": h_final}


def _group_apply(gp: dict, x: Array, cfg: ArchConfig, *, positions: Array,
                 window: int, chunk_q: int, emit_cache: bool,
                 cache_len: int = 0) -> Tuple[Array, Array, Optional[dict]]:
    specs = layer_specs(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    for i, (mx, mk) in enumerate(specs):
        x, aux, cache = _layer_apply(
            gp[f"l{i}"], x, cfg, mx, mk, positions=positions,
            window=window, chunk_q=chunk_q, emit_cache=emit_cache,
            cache_len=cache_len)
        aux_total = aux_total + aux
        if emit_cache:
            caches[f"l{i}"] = cache if cache is not None else {}
    return x, aux_total, (caches if emit_cache else None)


def _embed_inputs(params: dict, cfg: ArchConfig, tokens: Array,
                  prefix_embeds: Optional[Array]) -> Tuple[Array, int]:
    x = M.embedding_apply(params["embed"], tokens)
    n_prefix = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        n_prefix = prefix_embeds.shape[1]
    if cfg.rope == "none":  # absolute sinusoid (whisper-style decoder)
        x = x + M.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    return x, n_prefix


def apply_lm(params: dict, cfg: ArchConfig, tokens: Array, *,
             prefix_embeds: Optional[Array] = None, train: bool = False,
             window: int = 0, chunk_q: int = 1024,
             logits_tail: int = 0, return_hidden: bool = False,
             boundary_spec=None) -> Tuple[Array, Array]:
    """Full-sequence forward.

    Returns ``(logits, aux_loss)`` — or ``(hidden, aux_loss)`` after the
    final norm when ``return_hidden`` (the chunked loss does its own
    readout).  ``logits_tail > 0`` restricts the readout to the last
    positions (prefill wants 1; training wants 0 = all).

    ``boundary_spec``: optional PartitionSpec for the rematerialisation
    boundaries (the scan carry).  Sharding the saved residual stream over
    the model axis (ZeRO-R partitioned activations) trades one all-gather
    per group for n_groups× less activation memory — load-bearing for the
    deep/ssm archs on 16 GB chips (EXPERIMENTS.md §Perf).
    """
    x, _ = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, gp):
        x, aux = carry
        x, aux_g, _ = _group_apply(gp, x, cfg, positions=positions,
                                   window=window, chunk_q=chunk_q,
                                   emit_cache=False)
        if boundary_spec is not None:
            x = jax.lax.with_sharding_constraint(x, boundary_spec)
        return (x, aux + aux_g), ()

    scan_body = body
    if train:
        scan_body = jax.checkpoint(body)  # remat each superblock
    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["groups"])
    x = M.norm_apply(cfg.norm, params["final_norm"], x)
    if return_hidden:
        return x, aux
    if logits_tail:
        x = x[:, -logits_tail:]
    logits = _readout(params, cfg, x)
    return logits, aux


def _readout(params: dict, cfg: ArchConfig, x: Array) -> Array:
    if cfg.tie_embeddings:
        return M.embedding_attend(params["embed"], x)
    return M.linear_apply(params["lm_head"], x)


# ------------------------------------------------------------------ loss
def _readout_params(params: dict, cfg: ArchConfig) -> Tuple[dict, bool]:
    if cfg.tie_embeddings:
        return {"embed": params["embed"]}, True
    return {"lm_head": params["lm_head"]}, False


def lm_loss(params: dict, cfg: ArchConfig, batch: Dict[str, Array], *,
            window: int = 0, chunk_q: int = 1024,
            xent_chunk: int = 4096, boundary_spec=None) -> Array:
    """Next-token cross entropy (+ MoE aux), chunk-rematerialised readout.

    batch: tokens, labels, optional prefix_embeds, optional loss_mask."""
    from repro.models.losses import chunked_xent
    x, aux = apply_lm(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"), train=True,
        window=window, chunk_q=chunk_q, return_hidden=True,
        boundary_spec=boundary_spec)
    labels = batch["labels"]
    n_prefix = x.shape[1] - labels.shape[1]
    if n_prefix > 0:
        x = x[:, n_prefix:]
    rp, tied = _readout_params(params, cfg)
    loss = chunked_xent(x, labels, rp, tied=tied,
                        mask=batch.get("loss_mask"), chunk=xent_chunk)
    return loss + aux


# ----------------------------------------------------------------- caches
def init_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
               window: int = 0) -> PyTree:
    """Stacked per-group cache pytree (leading axis = n_groups)."""
    specs = layer_specs(cfg)
    length = window if window else cache_len

    def one_group():
        c = {}
        for i, (mx, _) in enumerate(specs):
            if mx == "attn":
                c[f"l{i}"] = A.init_kv_cache(batch, length, cfg.n_kv_heads,
                                             cfg.resolved_head_dim)
            else:
                c[f"l{i}"] = S.init_mamba_cache(batch, cfg)
        return c

    ng = n_groups(cfg)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[one_group() for _ in range(ng)])


def prefill(params: dict, cfg: ArchConfig, tokens: Array, *,
            prefix_embeds: Optional[Array] = None, window: int = 0,
            chunk_q: int = 1024, cache_len: int = 0) -> Tuple[Array, PyTree]:
    """Process the prompt; return (last-token logits (B, vocab), cache).

    ``cache_len``: total cache capacity (prompt + future decode steps);
    defaults to prompt length + 64.
    """
    x, _ = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    if not cache_len:
        cache_len = s + 64
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, gp):
        x, _, cache = _group_apply(gp, x, cfg, positions=positions,
                                   window=window, chunk_q=chunk_q,
                                   emit_cache=True, cache_len=cache_len)
        return x, cache

    x, caches = jax.lax.scan(body, x, params["groups"])
    x = M.norm_apply(cfg.norm, params["final_norm"], x[:, -1:])
    return _readout(params, cfg, x)[:, 0], caches


def decode_step(params: dict, cfg: ArchConfig, token: Array, cache: PyTree,
                pos: Array, *, window: int = 0,
                seq_chunks: int = 1) -> Tuple[Array, PyTree]:
    """One decode step.  token: (B,) int32; pos: scalar int32 (absolute).

    Returns (logits (B, vocab), updated cache).
    """
    x = M.embedding_apply(params["embed"], token[:, None])
    if cfg.rope == "none":
        # sinusoid for the current absolute position
        d = cfg.d_model
        dim = jnp.arange(d // 2, dtype=jnp.float32)
        inv = jnp.exp(-math.log(10000.0) * 2.0 * dim / d)
        ang = pos.astype(jnp.float32) * inv
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
        x = x + pe.astype(x.dtype)
    specs = layer_specs(cfg)

    def body(x, xs):
        gp, gc = xs
        new_c = {}
        for i, (mx, mk) in enumerate(specs):
            lp, lc = gp[f"l{i}"], gc[f"l{i}"]
            h = M.norm_apply(cfg.norm, lp["norm1"], x)
            if mx == "attn":
                out, new_c[f"l{i}"] = A.attend_cached(lp["attn"], h, lc, pos,
                                                      cfg, window=window,
                                                      seq_chunks=seq_chunks)
            else:
                out, new_c[f"l{i}"] = S.mamba_step(lp["mamba"], h, lc, cfg)
            x = x + out
            if mk is not None:
                h2 = M.norm_apply(cfg.norm, lp["norm2"], x)
                if mk == "moe":
                    y, _ = E.moe_apply(lp["moe"], h2, cfg.moe, cfg.activation)
                else:
                    y = F.mlp_apply(lp["mlp"], h2, cfg.activation)
                x = x + y
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["groups"], cache))
    x = M.norm_apply(cfg.norm, params["final_norm"], x)
    return _readout(params, cfg, x)[:, 0], new_cache
