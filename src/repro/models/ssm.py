"""Mamba-1 selective state-space mixer (falcon-mamba-7b, jamba mixers).

TPU adaptation (DESIGN.md §3): the recurrence
``h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t`` is evaluated with a *chunked
associative scan* — ``lax.scan`` over sequence chunks carrying the (B, d_i,
d_state) state, ``lax.associative_scan`` (log-depth, VPU-friendly) inside a
chunk.  This bounds the live (B, chunk, d_i, d_state) buffer instead of
materialising the full (B, S, d_i, d_state) tensor (which at 4k×8192×16 would
be ~2 GB/device) while avoiding a 4096-step sequential scan.

Decode is the O(1) single-step update with a (conv window, ssm state) cache.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import modules as M

Array = jax.Array


def mamba_init(key, cfg: ArchConfig) -> dict:
    ssm = cfg.ssm
    assert ssm is not None
    d = cfg.d_model
    di = ssm.expand * d
    dtr = ssm.resolved_dt_rank(d)
    st = ssm.d_state
    k_in, k_conv, k_x, k_dt, k_out = jax.random.split(key, 5)
    # S4D-real initialisation of A
    a = jnp.broadcast_to(jnp.arange(1, st + 1, dtype=jnp.float32)[None], (di, st))
    dt_std = dtr ** -0.5
    return {
        "in_proj": M.linear_init(k_in, d, 2 * di),
        "conv_w": M.truncated_normal(k_conv, (ssm.d_conv, di), 1.0 / math.sqrt(ssm.d_conv)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": M.linear_init(k_x, di, dtr + 2 * st),
        "dt_proj": {
            "w": M.truncated_normal(k_dt, (dtr, di), dt_std),
            # bias init so softplus(b) spans [1e-3, 1e-1] — standard mamba
            "b": jnp.log(jnp.expm1(
                jnp.exp(jax.random.uniform(k_dt, (di,),
                                           minval=math.log(1e-3),
                                           maxval=math.log(1e-1))))),
        },
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": M.linear_init(k_out, di, d),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 history: Optional[Array] = None) -> Array:
    """Depthwise causal conv1d.  x: (B, S, di); w: (K, di).

    ``history``: optional (B, K-1, di) left context (decode path).
    """
    k = w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                   # (B, S+K-1, di)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # K is 4: unrolled taps beat a conv op at this size
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(p: dict, xc: Array, ssm: SSMConfig, d_model: int):
    """Shared projections: xc (B, S, di) -> (decay, inp, C, Dx)."""
    dtr = ssm.resolved_dt_rank(d_model)
    st = ssm.d_state
    proj = M.linear_apply(p["x_proj"], xc)                    # (B, S, dtr+2st)
    dt_low, b_mat, c_mat = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(
        dt_low.astype(jnp.float32) @ p["dt_proj"]["w"] + p["dt_proj"]["b"]
    )                                                         # (B, S, di) fp32
    a = -jnp.exp(p["A_log"])                                  # (di, st)
    decay = jnp.exp(dt[..., None] * a)                        # (B, S, di, st)
    inp = (dt * xc.astype(jnp.float32))[..., None] * \
        b_mat.astype(jnp.float32)[:, :, None, :]              # (B, S, di, st)
    return decay, inp, c_mat.astype(jnp.float32)


def _scan_chunk(h0: Array, decay: Array, inp: Array) -> Tuple[Array, Array]:
    """Associative scan within a chunk.  h0: (B, di, st); others (B, C, di, st).

    Returns (h_all (B, C, di, st), h_last).
    """
    def combine(a, b):
        (da, xa), (db, xb) = a, b
        return da * db, xa * db + xb

    d_cum, x_cum = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    h_all = x_cum + d_cum * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_apply(p: dict, x: Array, cfg: ArchConfig, *,
                chunk: int = 256) -> Array:
    """Full-sequence mixer (train / prefill).  x: (B, S, d)."""
    ssm = cfg.ssm
    assert ssm is not None
    b, s, d = x.shape
    di = ssm.expand * d
    xz = M.linear_apply(p["in_proj"], x)
    xc, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xc, p["conv_w"], p["conv_b"]))

    decay, inp, c_mat = _ssm_inputs(p, xc, ssm, d)

    st = ssm.d_state
    if s > chunk and s % chunk == 0:
        nc = s // chunk
        dch = decay.reshape(b, nc, chunk, di, st).transpose(1, 0, 2, 3, 4)
        ich = inp.reshape(b, nc, chunk, di, st).transpose(1, 0, 2, 3, 4)

        def step(h, xs):
            dc, ic = xs
            h_all, h_last = _scan_chunk(h, dc, ic)
            return h_last, h_all

        h0 = jnp.zeros((b, di, st), jnp.float32)
        _, h_chunks = jax.lax.scan(step, h0, (dch, ich))
        h_seq = h_chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, di, st)
    else:
        h_seq, _ = _scan_chunk(jnp.zeros((b, di, st), jnp.float32), decay, inp)

    y = jnp.sum(h_seq * c_mat[:, :, None, :], axis=-1)        # (B, S, di)
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return M.linear_apply(p["out_proj"], y.astype(x.dtype))


# ------------------------------------------------------------------ decode
def init_mamba_cache(batch: int, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, ssm.d_state), jnp.float32),
    }


def mamba_step(p: dict, x: Array, cache: dict, cfg: ArchConfig
               ) -> Tuple[Array, dict]:
    """Single-token decode.  x: (B, 1, d)."""
    ssm = cfg.ssm
    b, _, d = x.shape
    xz = M.linear_apply(p["in_proj"], x)
    x_raw, z = jnp.split(xz, 2, axis=-1)                      # pre-conv input
    xc = jax.nn.silu(_causal_conv(x_raw, p["conv_w"], p["conv_b"],
                                  history=cache["conv"]))
    new_conv = jnp.concatenate(
        [cache["conv"][:, 1:], x_raw.astype(cache["conv"].dtype)], axis=1
    ) if ssm.d_conv > 1 else cache["conv"]
    decay, inp, c_mat = _ssm_inputs(p, xc, ssm, d)
    h = decay[:, 0] * cache["h"] + inp[:, 0]                  # (B, di, st)
    y = jnp.sum(h * c_mat[:, 0, None, :], axis=-1)            # (B, di)
    y = y + p["D"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = M.linear_apply(p["out_proj"], y.astype(x.dtype))[:, None]
    return out, {"conv": new_conv, "h": h}
