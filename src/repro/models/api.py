"""Family-dispatching model API.

One uniform surface over all 10 assigned architectures:

* ``init_model(key, cfg)``                      -> param pytree
* ``loss_fn(params, cfg, batch)``               -> scalar (training)
* ``prefill_fn(params, cfg, batch)``            -> (logits, cache)
* ``decode_fn(params, cfg, token, cache, pos)`` -> (logits, cache)
* ``make_batch_spec(cfg, shape, ...)``          -> ShapeDtypeStructs (dry-run)

The batch dict is the single currency: ``tokens``/``labels`` always; plus
``prefix_embeds`` (vlm), ``frames`` (audio), ``loss_mask`` (vlm).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as T

Array = jax.Array
PyTree = Any


def init_model(key, cfg: ArchConfig) -> PyTree:
    if cfg.is_encdec:
        return ED.init_encdec(key, cfg)
    return T.init_lm(key, cfg)


def loss_fn(params: PyTree, cfg: ArchConfig, batch: Dict[str, Array], *,
            window: int = 0, chunk_q: int = 1024, boundary_spec=None) -> Array:
    if cfg.is_encdec:
        return ED.encdec_loss(params, cfg, batch, chunk_q=chunk_q)
    return T.lm_loss(params, cfg, batch, window=window, chunk_q=chunk_q,
                     boundary_spec=boundary_spec)


def forward_fn(params: PyTree, cfg: ArchConfig, batch: Dict[str, Array], *,
               window: int = 0, chunk_q: int = 1024,
               logits_tail: int = 1) -> Array:
    """Inference forward (no cache emission) — logits for the tail positions."""
    if cfg.is_encdec:
        memory = ED.encode(params, cfg, batch["frames"], chunk_q=chunk_q)
        return ED.decode_train(params, cfg, batch["tokens"], memory,
                               window=window, chunk_q=chunk_q,
                               logits_tail=logits_tail)
    logits, _ = T.apply_lm(params, cfg, batch["tokens"],
                           prefix_embeds=batch.get("prefix_embeds"),
                           train=False, window=window, chunk_q=chunk_q,
                           logits_tail=logits_tail)
    return logits


def prefill_fn(params: PyTree, cfg: ArchConfig, batch: Dict[str, Array], *,
               window: int = 0, chunk_q: int = 1024, cache_len: int = 0
               ) -> Tuple[Array, PyTree]:
    if cfg.is_encdec:
        return ED.encdec_prefill(params, cfg, batch["frames"],
                                 batch["tokens"], window=window,
                                 chunk_q=chunk_q, cache_len=cache_len)
    return T.prefill(params, cfg, batch["tokens"],
                     prefix_embeds=batch.get("prefix_embeds"),
                     window=window, chunk_q=chunk_q, cache_len=cache_len)


def decode_fn(params: PyTree, cfg: ArchConfig, token: Array, cache: PyTree,
              pos: Array, *, window: int = 0,
              seq_chunks: int = 1) -> Tuple[Array, PyTree]:
    if cfg.is_encdec:
        return ED.encdec_decode_step(params, cfg, token, cache, pos,
                                     window=window, seq_chunks=seq_chunks)
    return T.decode_step(params, cfg, token, cache, pos, window=window,
                         seq_chunks=seq_chunks)


def init_cache_fn(params: PyTree, cfg: ArchConfig, batch: int,
                  cache_len: int, *, window: int = 0,
                  memory: Optional[Array] = None) -> PyTree:
    if cfg.is_encdec:
        assert memory is not None
        return ED.init_decode_cache(params, cfg, memory, batch, cache_len,
                                    window=window)
    return T.init_cache(cfg, batch, cache_len, window=window)


# ----------------------------------------------------------------- shapes
def decode_window(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Sliding window used for a decode shape (0 = exact full cache).

    ``long_500k`` uses the ring buffer for every attention layer
    (sub-quadratic requirement, DESIGN.md §4); shorter contexts stay exact.
    """
    if shape.kind == "decode" and shape.seq_len > 65536 and not cfg.is_attention_free:
        return cfg.long_context_window
    return 0


def make_batch(cfg: ArchConfig, shape_kind: str, batch: int, seq: int,
               key=None, as_spec: bool = False) -> Dict[str, Any]:
    """Concrete batch (smoke tests) or ShapeDtypeStruct batch (dry-run)."""
    i32 = jnp.int32

    def tok(shape):
        if as_spec:
            return jax.ShapeDtypeStruct(shape, i32)
        k = jax.random.fold_in(key, hash(str(shape)) % (2 ** 31))
        return jax.random.randint(k, shape, 0, cfg.vocab_size, dtype=i32)

    def emb(shape):
        if as_spec:
            return jax.ShapeDtypeStruct(shape, jnp.bfloat16)
        k = jax.random.fold_in(key, (hash(str(shape)) + 1) % (2 ** 31))
        return jax.random.normal(k, shape, dtype=jnp.bfloat16)

    out: Dict[str, Any] = {}
    if cfg.is_encdec:
        out["frames"] = emb((batch, cfg.n_frames, cfg.d_model))
        out["tokens"] = tok((batch, seq))
    elif cfg.n_patches:
        n_text = seq - cfg.n_patches
        assert n_text > 0, (seq, cfg.n_patches)
        out["prefix_embeds"] = emb((batch, cfg.n_patches, cfg.d_model))
        out["tokens"] = tok((batch, n_text))
    else:
        out["tokens"] = tok((batch, seq))
    if shape_kind == "train":
        out["labels"] = tok(out["tokens"].shape)
    return out
