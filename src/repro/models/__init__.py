"""Model substrate: functional transformer/MoE/SSM/hybrid/enc-dec stacks."""
from repro.models.api import (  # noqa: F401
    decode_fn,
    decode_window,
    forward_fn,
    init_cache_fn,
    init_model,
    loss_fn,
    make_batch,
    prefill_fn,
)
