"""Mixture-of-Experts layer (qwen3-moe 128e/top-8, jamba 16e/top-2).

TPU-native expert-parallel design (DESIGN.md §3/§4): experts live on the
``model`` mesh axis.  Dispatch is *capacity-based gather/scatter* rather than
the classic mesh-tf one-hot einsum — the one-hot dispatch einsum costs
``O(T·E·C·d)`` FLOPs (quadratic in tokens), whereas index gather/scatter is
pure data movement, so ``cost_analysis`` FLOPs stay ≈ active-expert FLOPs
(top_k/E of the dense-equivalent), which is what the roofline needs to see.

Tokens overflowing an expert's capacity ``C = ceil(T·k/E·cf)`` are dropped
(standard practice; the router aux loss keeps load balanced).  Dropped slots
combine as zeros, preserving the residual path.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import modules as M

Array = jax.Array


def moe_init(key, d_model: int, cfg: MoEConfig, activation: str) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, dff = cfg.n_experts, cfg.d_expert
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(dff)
    p = {
        "router": M.linear_init(kr, d_model, e, stddev=0.02),
        # expert-stacked weights: leading E axis shards over the model axis
        "w_in": M.truncated_normal(k1, (e, d_model, dff), std_in),
        "w_out": M.truncated_normal(k2, (e, dff, d_model), std_out),
    }
    if activation == "swiglu":
        p["w_gate"] = M.truncated_normal(k3, (e, d_model, dff), std_in)
    return p


def _constrain_expert_parallel(t: Array) -> Array:
    """Pin (E, C, d) dispatch buffers to expert-parallel sharding over the
    model axis (no-op outside a mesh context / non-divisible E)."""
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(t, P("model", None, None))
    except Exception:
        return t


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # multiple of 8 for lane alignment


def moe_apply(p: dict, x: Array, cfg: MoEConfig, activation: str
              ) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t, cfg)

    logits = M.linear_apply(p["router"], xf).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, k)                     # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1), axis=0
    )                                                              # (E,) frac routed
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.aux_loss_weight * e * jnp.sum(density / k * mean_prob)

    # ---- position-in-expert via cumulative one-hot over the (T*k) stream
    eid = expert_ids.reshape(t * k)                                # (T*k,)
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)               # (T*k, E)
    pos_in_e = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    keep = pos_in_e < c
    dest = eid * c + pos_in_e                                      # (T*k,) in [0, E*C)
    dest = jnp.where(keep, dest, e * c)                            # overflow -> dropped

    token_of = jnp.arange(t * k, dtype=jnp.int32) // k
    # slot -> token index (sentinel t for empty slots)
    slot_token = jnp.full((e * c + 1,), t, jnp.int32).at[dest].set(
        token_of, mode="drop")[: e * c]
    slot_gate = jnp.zeros((e * c + 1,), jnp.float32).at[dest].set(
        gate.reshape(t * k), mode="drop")[: e * c]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    expert_in = jnp.take(xpad, slot_token, axis=0).reshape(e, c, d)
    expert_in = _constrain_expert_parallel(expert_in)

    # ---- expert FFN, batched over the (sharded) expert axis
    w_in = p["w_in"].astype(x.dtype)
    w_out = p["w_out"].astype(x.dtype)
    if activation == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * jnp.einsum("ecd,edf->ecf", expert_in, w_in)
    else:
        h = M.ACTIVATIONS[activation](jnp.einsum("ecd,edf->ecf", expert_in, w_in))
    expert_out = jnp.einsum("ecf,efd->ecd", h, w_out)              # (E, C, d)
    expert_out = _constrain_expert_parallel(expert_out)

    # ---- combine: scatter-add weighted slots back to tokens
    # (gate cast BEFORE the multiply: an fp32 gate upcasts the whole (E·C, d)
    # buffer — measured as the dominant temp term on jamba, §Perf hc-2)
    gate_cast = slot_gate.astype(x.dtype)
    flat_out = expert_out.reshape(e * c, d) * gate_cast[:, None]
    y = jnp.zeros((t + 1, d), x.dtype).at[slot_token].add(flat_out)[:t]
    return y.reshape(b, s, d), aux
