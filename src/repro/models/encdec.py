"""Whisper-style encoder-decoder transformer backbone.

Per the task carve-out, the audio frontend (mel spectrogram + conv feature
extractor) is a STUB: the encoder consumes precomputed frame embeddings
``(B, n_frames, d_model)`` supplied by ``input_specs()``.  Everything from
there on is real: bidirectional encoder, causal decoder with cross attention,
prefill/decode with self-attention KV cache + precomputed cross K/V.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import modules as M
from repro.models import mlp as F

Array = jax.Array
PyTree = Any


def _enc_layer_init(key, cfg: ArchConfig) -> dict:
    ka, kf = jax.random.split(key)
    return {
        "norm1": M.norm_init(cfg.norm, cfg.d_model),
        "attn": A.attn_init(ka, cfg),
        "norm2": M.norm_init(cfg.norm, cfg.d_model),
        "mlp": F.mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _dec_layer_init(key, cfg: ArchConfig) -> dict:
    ka, kc, kf = jax.random.split(key, 3)
    return {
        "norm1": M.norm_init(cfg.norm, cfg.d_model),
        "self": A.attn_init(ka, cfg),
        "norm_x": M.norm_init(cfg.norm, cfg.d_model),
        "cross": A.attn_init(kc, cfg),
        "norm2": M.norm_init(cfg.norm, cfg.d_model),
        "mlp": F.mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def init_encdec(key, cfg: ArchConfig) -> dict:
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    enc = [_enc_layer_init(k, cfg) for k in jax.random.split(kenc, cfg.n_encoder_layers)]
    dec = [_dec_layer_init(k, cfg) for k in jax.random.split(kdec, cfg.n_layers)]
    return {
        "embed": M.embedding_init(ke, cfg.vocab_size, cfg.d_model),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": M.norm_init(cfg.norm, cfg.d_model),
        "final_norm": M.norm_init(cfg.norm, cfg.d_model),
        "lm_head": M.linear_init(kh, cfg.d_model, cfg.vocab_size,
                                 stddev=1.0 / math.sqrt(cfg.d_model)),
    }


def encode(params: dict, cfg: ArchConfig, frames: Array,
           chunk_q: int = 1024, remat: bool = False) -> Array:
    """frames: (B, n_frames, d_model) stub embeddings -> encoder memory."""
    x = frames.astype(jnp.bfloat16)
    x = x + M.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        h = M.norm_apply(cfg.norm, lp["norm1"], x)
        q, k, v = A.project_qkv(lp["attn"], h, cfg, positions=None)
        out = A.attend_full(q, k, v, causal=False, chunk_q=chunk_q)
        x = x + M.linear_apply(lp["attn"]["o"], out.reshape(b, s, -1))
        h2 = M.norm_apply(cfg.norm, lp["norm2"], x)
        x = x + F.mlp_apply(lp["mlp"], h2, cfg.activation)
        return x, ()

    x, _ = jax.lax.scan(jax.checkpoint(body) if remat else body,
                        x, params["enc_layers"])
    return M.norm_apply(cfg.norm, params["enc_norm"], x)


def decode_train(params: dict, cfg: ArchConfig, tokens: Array, memory: Array,
                 *, window: int = 0, chunk_q: int = 1024,
                 logits_tail: int = 0, emit_cache: bool = False,
                 cache_len: int = 0, return_hidden: bool = False) -> Array:
    """Teacher-forced decoder pass.  tokens: (B, S); memory: (B, Sm, d).

    ``emit_cache`` additionally returns the packed self-attn KV caches
    (prefill path)."""
    x = M.embedding_apply(params["embed"], tokens)
    x = x + M.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    b, s, _ = x.shape
    if not cache_len:
        cache_len = s + 64

    def body(x, lp):
        h = M.norm_apply(cfg.norm, lp["norm1"], x)
        q, k, v = A.project_qkv(lp["self"], h, cfg, positions=None)
        out = A.attend_full(q, k, v, causal=True, window=window, chunk_q=chunk_q)
        x = x + M.linear_apply(lp["self"]["o"], out.reshape(b, s, -1))
        hx = M.norm_apply(cfg.norm, lp["norm_x"], x)
        mkv = A.cross_kv(lp["cross"], memory, cfg)
        x = x + A.attend_cross(lp["cross"], hx, mkv, cfg)
        h2 = M.norm_apply(cfg.norm, lp["norm2"], x)
        x = x + F.mlp_apply(lp["mlp"], h2, cfg.activation)
        y = A.cache_from_prefill(k, v, cache_len, window) if emit_cache else ()
        return x, y

    scan_body = body if emit_cache or logits_tail else jax.checkpoint(body)
    x, caches = jax.lax.scan(scan_body, x, params["dec_layers"])
    x = M.norm_apply(cfg.norm, params["final_norm"], x)
    if return_hidden:
        return (x, caches) if emit_cache else x
    if logits_tail:
        x = x[:, -logits_tail:]
    logits = M.linear_apply(params["lm_head"], x)
    return (logits, caches) if emit_cache else logits


def encdec_loss(params: dict, cfg: ArchConfig, batch: Dict[str, Array], *,
                chunk_q: int = 1024) -> Array:
    from repro.models.losses import chunked_xent
    memory = encode(params, cfg, batch["frames"], chunk_q=chunk_q, remat=True)
    x = decode_train(params, cfg, batch["tokens"], memory, chunk_q=chunk_q,
                     return_hidden=True)
    return chunked_xent(x, batch["labels"], {"lm_head": params["lm_head"]},
                        tied=False)


# ---------------------------------------------------------------- serving
def _cross_kv_stack(params: dict, cfg: ArchConfig, memory: Array):
    def per_layer(lp):
        return A.cross_kv(lp["cross"], memory, cfg)

    return jax.vmap(per_layer)(params["dec_layers"])  # stacked over layers


def init_decode_cache(params: dict, cfg: ArchConfig, memory: Array,
                      batch: int, cache_len: int, *, window: int = 0) -> PyTree:
    """Empty self-attn KV cache + precomputed cross K/V per decoder layer."""
    length = window if window else cache_len
    self_c = [A.init_kv_cache(batch, length, cfg.n_kv_heads, cfg.resolved_head_dim)
              for _ in range(cfg.n_layers)]
    self_c = jax.tree.map(lambda *xs: jnp.stack(xs), *self_c)
    return {"self": self_c, "cross": _cross_kv_stack(params, cfg, memory)}


def encdec_prefill(params: dict, cfg: ArchConfig, frames: Array,
                   tokens: Array, *, window: int = 0, chunk_q: int = 1024,
                   cache_len: int = 0) -> Tuple[Array, PyTree]:
    """Encode + teacher-forced warm-up of the decoder self-attn cache."""
    memory = encode(params, cfg, frames, chunk_q=chunk_q)
    logits, self_c = decode_train(
        params, cfg, tokens, memory, window=window, chunk_q=chunk_q,
        logits_tail=1, emit_cache=True, cache_len=cache_len)
    cache = {"self": self_c, "cross": _cross_kv_stack(params, cfg, memory)}
    return logits[:, 0], cache


def encdec_decode_step(params: dict, cfg: ArchConfig, token: Array,
                       cache: PyTree, pos: Array, *, window: int = 0,
                       seq_chunks: int = 1) -> Tuple[Array, PyTree]:
    """One decoder token.  token: (B,); pos scalar int32."""
    x = M.embedding_apply(params["embed"], token[:, None])
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * 2.0 * dim / d)
    ang = pos.astype(jnp.float32) * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
    x = x + pe.astype(x.dtype)

    def body(x, xs):
        lp, sc, ckv = xs
        h = M.norm_apply(cfg.norm, lp["norm1"], x)
        out, new_sc = A.attend_cached(lp["self"], h, sc, pos, cfg,
                                      window=window, seq_chunks=seq_chunks)
        x = x + out
        hx = M.norm_apply(cfg.norm, lp["norm_x"], x)
        x = x + A.attend_cross(lp["cross"], hx, ckv, cfg)
        h2 = M.norm_apply(cfg.norm, lp["norm2"], x)
        x = x + F.mlp_apply(lp["mlp"], h2, cfg.activation)
        return x, new_sc

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"], cache["cross"]))
    x = M.norm_apply(cfg.norm, params["final_norm"], x)
    logits = M.linear_apply(params["lm_head"], x)[:, 0]
    return logits, {"self": new_self, "cross": cache["cross"]}
