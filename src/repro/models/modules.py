"""Functional parameter-pytree building blocks (no flax — per task scope).

Every module is a pair of functions: ``<name>_init(key, ...) -> params`` and
``<name>_apply(params, x, ...) -> y``.  Params are plain nested dicts of
``jnp.ndarray`` so they compose with ``jax.tree`` utilities, our sharding
rules (dist/sharding.py matches on dict paths) and the robust aggregator.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def truncated_normal(key, shape, stddev, dtype=jnp.float32) -> Array:
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ----------------------------------------------------------------- linear
def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                stddev: Optional[float] = None, dtype=jnp.float32) -> dict:
    if stddev is None:
        stddev = 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal(key, (d_in, d_out), stddev, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_apply(p: dict, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# -------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    # ~N(0, 1/sqrt(d)): keeps tied-readout logits O(1) at init
    return {"table": truncated_normal(key, (vocab, d), 1.0 / math.sqrt(d), dtype)}


def embedding_apply(p: dict, ids: Array, dtype=jnp.bfloat16) -> Array:
    return jnp.take(p["table"].astype(dtype), ids, axis=0)


def embedding_attend(p: dict, x: Array) -> Array:
    """Tied readout: x @ table.T."""
    return x @ p["table"].astype(x.dtype).T


# ------------------------------------------------------------------ norms
def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: dict, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def norm_init(kind: str, d: int) -> dict:
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def norm_apply(kind: str, p: dict, x: Array) -> Array:
    return rmsnorm_apply(p, x) if kind == "rmsnorm" else layernorm_apply(p, x)


# ------------------------------------------------------------ activations
def relu2(x: Array) -> Array:
    """Squared ReLU (nemotron-4)."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu2": relu2,
    "relu": jax.nn.relu,
}


# ------------------------------------------------------------- positional
def sinusoidal_positions(n: int, d: int, dtype=jnp.float32) -> Array:
    """Classic transformer sinusoid table (whisper encoder)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * 2.0 * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
