"""Attention: GQA multi-head attention with RoPE, KV caches, sliding window.

Three entry points:
* :func:`attend_full`   — training / prefill self-attention over a whole
  sequence, query-chunked so the (S, S) logit matrix never materialises
  beyond ``(chunk_q, S)`` per head (memory roofline control for 32k prefill).
* :func:`attend_cached` — one-token decode against a KV cache (full cache or
  sliding-window ring buffer; the ring buffer is what makes ``long_500k``
  sub-quadratic for full-attention families — DESIGN.md §4).
* :func:`attend_cross`  — encoder-decoder cross attention (whisper).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import modules as M

Array = jax.Array

_NEG = -1e30  # additive mask value (fp32 logits)


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, fraction: float, theta: float) -> Array:
    """Inverse frequencies for the rotating sub-dimension."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: Array, positions: Array, cfg: ArchConfig) -> Array:
    """Rotate ``x`` (..., S, H, head_dim) by absolute ``positions`` (..., S).

    ``rope='full'`` rotates the whole head dim (llama/qwen style, half-split
    layout); ``rope='partial'`` rotates only ``rope_fraction`` of it
    (chatglm3's 2d-RoPE: half the head dim carries rotary phase, the other
    half is position-free).  ``rope='none'`` is the identity (whisper uses
    learned/sinusoid absolute embeddings instead).
    """
    if cfg.rope == "none":
        return x
    hd = x.shape[-1]
    fraction = 1.0 if cfg.rope == "full" else cfg.rope_fraction
    inv = rope_freqs(hd, fraction, cfg.rope_theta)          # (rot/2,)
    rot = 2 * inv.shape[0]
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., S, rot/2)
    sin = jnp.sin(ang)[..., None, :]                        # (..., S, 1, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if xp.shape[-1] else out


# ------------------------------------------------------------- projection
def attn_init(key, cfg: ArchConfig, *, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    return {
        "q": M.linear_init(kq, d, cfg.n_heads * hd, bias=bias),
        "k": M.linear_init(kk, d, cfg.n_kv_heads * hd, bias=bias),
        "v": M.linear_init(kv, d, cfg.n_kv_heads * hd, bias=bias),
        "o": M.linear_init(ko, cfg.n_heads * hd, d, bias=False,
                           stddev=1.0 / math.sqrt(cfg.n_heads * hd * 2 * cfg.n_layers)),
    }


def _split_heads(x: Array, n_heads: int) -> Array:
    return x.reshape(x.shape[:-1] + (n_heads, x.shape[-1] // n_heads))


def project_qkv(p: dict, x: Array, cfg: ArchConfig,
                positions: Optional[Array] = None,
                rope_on_q: bool = True) -> Tuple[Array, Array, Array]:
    """x (B, S, d) -> q (B, S, H, hd), k/v (B, S, Hkv, hd), roped."""
    q = _split_heads(M.linear_apply(p["q"], x), cfg.n_heads)
    k = _split_heads(M.linear_apply(p["k"], x), cfg.n_kv_heads)
    v = _split_heads(M.linear_apply(p["v"], x), cfg.n_kv_heads)
    if positions is not None:
        if rope_on_q:
            q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
    return q, k, v


def _expand_kv(k: Array, n_heads: int) -> Array:
    """(B, S, Hkv, hd) -> (B, S, H, hd) by group broadcast."""
    b, s, hkv, hd = k.shape
    rep = n_heads // hkv
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, rep, hd)).reshape(
        b, s, n_heads, hd
    )


def batch_shard_qkv(q: Array, k: Array, v: Array):
    """Constrain q/k/v (B, S, H, hd) to batch-sharding over the model axis.

    Strategy knob for archs whose head count does not divide the
    tensor-parallel degree: the attention inner product then runs fully
    head-local per shard (one batch slice each), with a single relayout
    before and after instead of per-chunk logit all-reduces.  No-op when no
    'model' mesh axis is in scope (CPU tests).
    """
    from jax.sharding import PartitionSpec as P
    spec = P("model", None, None, None)
    try:
        # resolves against the mesh context at trace time; raises when no
        # mesh / no 'model' axis / non-divisible batch -> graceful no-op
        qc = jax.lax.with_sharding_constraint(q, spec)
        kc = jax.lax.with_sharding_constraint(k, spec)
        vc = jax.lax.with_sharding_constraint(v, spec)
    except Exception:
        return q, k, v
    return qc, kc, vc


def unshard_residual(x: Array) -> Array:
    """Constrain (B, S, d) back to the replicated-over-model layout."""
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(None, None, None))
    except Exception:
        return x


# ----------------------------------------------------------- full attention
def attend_full(q: Array, k: Array, v: Array, *, causal: bool = True,
                window: int = 0, chunk_q: int = 1024) -> Array:
    """Self attention over full sequences, query-chunked.

    q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd).  Returns (B, Sq, H, hd).
    ``window > 0`` restricts each query to the ``window`` most recent keys
    (sliding-window variant).
    """
    n_heads = q.shape[2]
    k = _expand_kv(k, n_heads)
    v = _expand_kv(v, n_heads)
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kt = k.transpose(0, 2, 3, 1)      # (B, H, hd, Sk)
    vt = v.transpose(0, 2, 1, 3)      # (B, H, Sk, hd)
    kpos = jnp.arange(sk)

    def block(args):
        qc, q0 = args                  # (B, cq, H, hd), scalar start index
        cq = qc.shape[1]
        qct = qc.transpose(0, 2, 1, 3)                       # (B, H, cq, hd)
        logits = jnp.einsum(
            "bhqd,bhdk->bhqk", qct.astype(jnp.float32),
            kt.astype(jnp.float32), precision=jax.lax.Precision.DEFAULT,
        ) * scale                                            # (B, H, cq, Sk)
        qpos = q0 + jnp.arange(cq)
        mask = jnp.ones((cq, sk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, _NEG)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, vt.astype(jnp.float32))
        return out.transpose(0, 2, 1, 3).astype(q.dtype)     # (B, cq, H, hd)

    if sq <= chunk_q:
        return block((q, jnp.int32(0)))
    pad = (-sq) % chunk_q
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    sqp = sq + pad
    nc = sqp // chunk_q
    qs = qp.reshape(b, nc, chunk_q, h, hd).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nc, dtype=jnp.int32) * chunk_q
    # remat each chunk: backward recomputes its probs instead of saving the
    # full (S, S) attention matrix across chunks (memory roofline control)
    out = jax.lax.map(jax.checkpoint(block), (qs, starts))   # (nc, B, cq, H, hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sqp, h, hd)
    return out[:, :sq] if pad else out


# ---------------------------------------------------------- cached decode
def cache_from_prefill(k: Array, v: Array, cache_len: int, window: int,
                       dtype=jnp.bfloat16) -> dict:
    """Pack prompt K/V (B, S, Hkv, hd) into a decode cache.

    Full cache: placed at [0, S) of a ``cache_len``-slot buffer.
    Ring buffer: the last ``min(window, S)`` tokens land in their ring slots
    (slot of absolute position p is ``p % window``).
    """
    b, s = k.shape[:2]
    if window:
        keep = min(window, s)
        kw = jnp.zeros((b, window) + k.shape[2:], dtype)
        vw = jnp.zeros_like(kw)
        pos_tail = jnp.arange(s - keep, s)
        kw = kw.at[:, pos_tail % window].set(k[:, -keep:].astype(dtype))
        vw = vw.at[:, pos_tail % window].set(v[:, -keep:].astype(dtype))
        return {"k": kw, "v": vw}
    assert cache_len >= s, (cache_len, s)
    kc = jnp.zeros((b, cache_len) + k.shape[2:], dtype).at[:, :s].set(k.astype(dtype))
    vc = jnp.zeros((b, cache_len) + v.shape[2:], dtype).at[:, :s].set(v.astype(dtype))
    return {"k": kc, "v": vc}


def init_kv_cache(batch: int, length: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    """Cache for one attention layer.  ``length`` is the max context (full
    cache) or the window size (ring buffer)."""
    shape = (batch, length, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attend_cached(p: dict, x: Array, cache: dict, pos: Array,
                  cfg: ArchConfig, *, window: int = 0,
                  seq_chunks: int = 1) -> Tuple[Array, dict]:
    """One-token decode.  x: (B, 1, d); pos: scalar int32 absolute position.

    Full cache (window == 0): write at index ``pos``, attend to [0, pos].
    Ring buffer (window > 0): write at ``pos % window``; slot validity and
    causality are reconstructed from absolute slot positions.
    """
    q, k_new, v_new = project_qkv(p, x, cfg, positions=pos[None, None]
                                  * jnp.ones((x.shape[0], 1), jnp.int32))
    slot = pos % window if window else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)

    length = k.shape[1]
    sidx = jnp.arange(length)
    if window:
        # absolute position held by slot s after the write at `pos`:
        abs_pos = pos - ((pos - sidx) % window)
        valid = abs_pos >= 0                      # since abs_pos <= pos always
    else:
        valid = sidx <= pos

    n_heads = cfg.n_heads
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    bsz = x.shape[0]
    if seq_chunks > 1 and length % seq_chunks == 0:
        # flash-style partial softmax over seq chunks: with the cache length
        # axis sharded over 'model' in `seq_chunks` blocks, the (L-sized)
        # logit/exp/value work stays shard-local and only (B, H, c, hd)
        # combine statistics cross shards — replaces the per-step all-gather
        # of the whole KV cache.  Grouped-query einsums keep the kv-head dim
        # as-is: materialising _expand_kv here all-gathers a 16×-expanded
        # cache copy per layer (measured 15 GB/step on chatglm decode —
        # EXPERIMENTS.md §Perf #13).
        lc = length // seq_chunks
        hkv = cfg.n_kv_heads
        rep = n_heads // hkv
        hd = cfg.resolved_head_dim
        kc = k.astype(jnp.float32).reshape(bsz, seq_chunks, lc, hkv, hd)
        vc = v.astype(jnp.float32).reshape(bsz, seq_chunks, lc, hkv, hd)
        qg = q.astype(jnp.float32).reshape(bsz, 1, hkv, rep, hd)
        logits = jnp.einsum("bqgrd,bckgd->bgrck", qg, kc) * scale
        vmask = valid.reshape(seq_chunks, lc)                    # (c, Lc)
        logits = jnp.where(vmask[None, None, None], logits, _NEG)
        m_c = jnp.max(logits, axis=-1)                           # (B,g,r,c)
        e = jnp.exp(logits - m_c[..., None])
        e = jnp.where(vmask[None, None, None], e, 0.0)
        s_c = jnp.sum(e, axis=-1)                                # (B,g,r,c)
        o_c = jnp.einsum("bgrck,bckgd->bgrcd", e, vc)            # (B,g,r,c,hd)
        m_g = jnp.max(m_c, axis=-1, keepdims=True)
        w_c = jnp.exp(m_c - m_g)                                 # (B,g,r,c)
        denom = jnp.sum(w_c * s_c, axis=-1)                      # (B,g,r)
        out = jnp.sum(w_c[..., None] * o_c, axis=3) / denom[..., None]
        out = out.reshape(bsz, n_heads, hd).astype(x.dtype)[:, None]
    else:
        ke = _expand_kv(k, n_heads).astype(jnp.float32)   # (B, L, H, hd)
        ve = _expand_kv(v, n_heads).astype(jnp.float32)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), ke) * scale
        logits = jnp.where(valid[None, None, None, :], logits, _NEG)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, ve).astype(x.dtype)
    out = out.reshape(bsz, 1, -1)
    y = M.linear_apply(p["o"], out)
    return y, {"k": k, "v": v}


def self_attention(p: dict, x: Array, cfg: ArchConfig, *,
                   positions: Optional[Array] = None, causal: bool = True,
                   window: int = 0, chunk_q: int = 1024) -> Array:
    """Full-sequence self attention block (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q, k, v = project_qkv(p, x, cfg, positions=positions)
    out = attend_full(q, k, v, causal=causal, window=window, chunk_q=chunk_q)
    return M.linear_apply(p["o"], out.reshape(b, s, -1))


# ------------------------------------------------------------------ cross
def attend_cross(p: dict, x: Array, memory_kv: Tuple[Array, Array],
                 cfg: ArchConfig) -> Array:
    """Cross attention against precomputed encoder K/V (B, Sm, Hkv, hd)."""
    b, s, _ = x.shape
    q = _split_heads(M.linear_apply(p["q"], x), cfg.n_heads)
    k, v = memory_kv
    out = attend_full(q, k, v, causal=False, chunk_q=max(s, 1))
    return M.linear_apply(p["o"], out.reshape(b, s, -1))


def cross_kv(p: dict, memory: Array, cfg: ArchConfig) -> Tuple[Array, Array]:
    """Precompute cross-attention K/V from encoder output (done at prefill)."""
    k = _split_heads(M.linear_apply(p["k"], memory), cfg.n_kv_heads)
    v = _split_heads(M.linear_apply(p["v"], memory), cfg.n_kv_heads)
    return k, v
