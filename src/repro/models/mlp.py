"""Dense MLP blocks: swiglu (qwen/jamba), squared-relu (nemotron), gelu (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as M

Array = jax.Array


def mlp_init(key, d_model: int, d_ff: int, activation: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "in": M.linear_init(k1, d_model, d_ff),
        "out": M.linear_init(k2, d_ff, d_model),
    }
    if activation == "swiglu":
        p["gate"] = M.linear_init(k3, d_model, d_ff)
    return p


def mlp_apply(p: dict, x: Array, activation: str) -> Array:
    if activation == "swiglu":
        h = jax.nn.silu(M.linear_apply(p["gate"], x)) * M.linear_apply(p["in"], x)
    else:
        h = M.ACTIVATIONS[activation](M.linear_apply(p["in"], x))
    return M.linear_apply(p["out"], h)
