"""repro.hier — hierarchical (grouped) robust aggregation for large n.

Robust-aggregate within ceil(n/g) groups of ≤ g workers, then robustly
aggregate the group outputs: O(n·g) selection instead of the flat path's
O(n²), with per-level byzantine budgets derived and checked by
``core.theory.split_f_budget`` (DESIGN.md §11).  ``g = n`` degenerates to
the flat rule bitwise.  Turn on per trainer with
``hier=GroupConfig(g=64)`` or ``launch/train.py --hier g=64``.
"""
from repro.hier.plan import GroupConfig, HierPlan  # noqa: F401
from repro.hier.aggregate import (  # noqa: F401
    LEADER_ENCODE_FOLD,
    hier_aggregate_tree,
)
