"""Two-level hierarchical aggregation pipeline (DESIGN.md §11).

``hier_aggregate_tree`` is the grouped counterpart of
``core.api.aggregate_tree``: per-group stats → per-group plan → per-group
apply, then the same three phases once more over the ``(n_groups, ...)``
group-aggregate stack.  Everything inside each level is the *existing*
machinery — the registry rules, the fused Pallas select kernels (with the
measured-crossover dispatch), the ``repro.comm`` codecs — composed, not
reimplemented:

* statistics never touch an (n, n) matrix — only ceil(n/g) independent
  (≤g, ≤g) matrices plus one (n_groups, n_groups) matrix, the O(n·g)
  claim ``benchmarks/hier_scale.py`` measures;
* an :class:`~repro.comm.codecs.EncodedGrads` input is sliced per group
  (``comm.codecs.slice_workers``) so group stats run on the quantized
  payloads and the fp32 stack only ever materialises one group at a time;
* with ``codec`` set, the group aggregates are re-encoded for the
  leaders→server hop (its exact byte count is returned in ``info``) and
  decoded server-side before the outer phase — the quantization the real
  two-hop wire would cost is in the aggregate, not just accounted.

The single-group case (g >= n) short-circuits the outer level entirely:
stats/plan/apply run once over rows [0, n), which is bitwise-identical to
the flat path (tests/test_hier.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import api
from repro.hier.plan import GroupConfig, HierPlan

PyTree = Any

#: fold_in tag for the leaders→server re-encode key — disjoint from the
#: trainer's reserved folds (2^31-1 transforms, 2^31-2 worker encode) and
#: from any per-leaf offset a model could reach
LEADER_ENCODE_FOLD = (1 << 31) - 3


def _slice_tree(grads: PyTree, start: int, stop: int) -> PyTree:
    return jax.tree.map(lambda x: x[start:stop], grads)


def _stack_parts(parts) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *parts)


def hier_aggregate_tree(grads: PyTree, f: int, cfg: GroupConfig, *,
                        codec: Optional[Any] = None,
                        key: Optional[jax.Array] = None,
                        coord_chunk: int = 0, use_pallas: bool = False,
                        fused: "bool | str" = True,
                        needs_dists: Optional[bool] = None,
                        obs: Optional[Any] = None,
                        obs_state: Optional[Dict[str, Any]] = None,
                        obs_round=None,
                        ) -> Tuple[PyTree, HierPlan, Dict[str, Any]]:
    """Aggregate a stacked pytree (or wire container) hierarchically.

    Returns ``(aggregate, HierPlan, info)`` where ``info`` carries what
    the trainers need beyond the plan: ``inner_stats`` (per-group
    :class:`AggStats`, for score diagnostics), ``outer_stats`` and
    ``leader_wire_bytes`` — the exact leaders→server byte count when
    ``codec`` is set (0 otherwise; the workers→leaders bytes live on the
    input container itself).

    ``cfg.budget(n, f)`` gates every level through
    ``core.theory.check_level`` and — unless ``cfg.enforce_budget`` is
    off — rejects budgets that do not cover the contract ``f``.
    ``codec`` (spec string or instance) re-encodes the group-aggregate
    stack for the second hop; error-feedback codecs are rejected (the
    leader hop has no persistent residual slot).  ``needs_dists=True``
    forces per-group distance matrices even for distance-free rules (the
    trainers' telemetry wants the score spectrum regardless of rule).

    ``obs``/``obs_state``/``obs_round`` thread the trainers' span ring
    (DESIGN.md §14) through the tree: with an enabled+tracing
    ``repro.obs.ObsConfig`` each level records its stats/plan/apply spans
    (payload = group count of the level) and the updated carry is
    returned as ``info["obs_state"]`` — otherwise ``obs_state`` passes
    through untouched.
    """
    from repro import obs as OBS
    obs_trace = (OBS.obs_on(obs) and obs.trace and obs_state is not None
                 and obs_state.get("t") is not None)

    def span(st, phase, payload):
        if not obs_trace:
            return st
        rnd = 0 if obs_round is None else obs_round
        return {**st, "t": OBS.record(st["t"], phase, rnd, payload)}

    enc = api._as_encoded(grads)
    if enc is not None:
        n = enc.n
    else:
        leaves = jax.tree.leaves(grads)
        if not leaves:
            raise ValueError("empty gradient pytree")
        n = leaves[0].shape[0]
    budget = cfg.budget(n, f)
    inner = api.get_aggregator(cfg.rule)
    inner_dists = inner.needs_dists if needs_dists is None else \
        (inner.needs_dists or needs_dists)

    if enc is not None:
        from repro.comm import codecs as CC
        slice_group = lambda s, e: CC.slice_workers(enc, s, e)  # noqa: E731
    else:
        slice_group = lambda s, e: _slice_tree(grads, s, e)     # noqa: E731

    inner_plans, inner_stats, parts = [], [], []
    for start, stop in budget.bounds():
        sub = slice_group(start, stop)
        st = api.compute_stats(sub, budget.f_inner,
                               needs_dists=inner_dists,
                               use_pallas=use_pallas)
        inner.validate(st.n, st.f)
        p = inner.plan(st)
        parts.append(inner.apply(p, sub, coord_chunk=coord_chunk,
                                 use_pallas=use_pallas, fused=fused))
        inner_plans.append(p)
        inner_stats.append(st)

    # inner level: one span triple (payload = group count), recorded after
    # the per-group loop so it depends on every group's work in program
    # order
    obs_state = span(obs_state, OBS.PH_STATS, budget.n_groups)
    obs_state = span(obs_state, OBS.PH_PLAN, budget.n_groups)
    obs_state = span(obs_state, OBS.PH_APPLY, budget.n_groups)

    info: Dict[str, Any] = {"inner_stats": tuple(inner_stats),
                            "outer_stats": None, "leader_wire_bytes": 0,
                            "obs_state": obs_state}
    if budget.n_groups == 1:
        # g >= n degenerates to the flat rule — no outer level, no second
        # wire hop; the single inner pass above is bitwise the flat path
        hplan = HierPlan(inner=tuple(inner_plans), outer=None, n=n, f=f,
                         g=cfg.g, bounds=budget.bounds(),
                         f_inner=budget.f_inner, f_outer=0,
                         rule=cfg.rule, outer_rule=cfg.rule)
        return parts[0], hplan, info

    inter = _stack_parts(parts)                   # (n_groups, ...) only
    if codec is not None:
        from repro.comm import codecs as CC
        c = CC.get_codec(codec) if isinstance(codec, str) else codec
        if c.stateful:
            raise ValueError(
                "hier leader re-encode does not support error-feedback "
                "codecs (no residual slot at the leader hop); drop ef=1 "
                "or aggregate without hier")
        k2 = None if key is None else \
            jax.random.fold_in(key, LEADER_ENCODE_FOLD)
        enc2, _ = c.encode(inter, key=k2)
        info["leader_wire_bytes"] = enc2.wire_bytes
        inter = c.decode(enc2)

    outer_name = cfg.resolve_outer_rule(budget)
    outer = api.get_aggregator(outer_name)
    ost = api.compute_stats(inter, budget.f_outer,
                            needs_dists=outer.needs_dists,
                            use_pallas=use_pallas)
    outer.validate(ost.n, ost.f)
    op = outer.plan(ost)
    agg = outer.apply(op, inter, coord_chunk=coord_chunk,
                      use_pallas=use_pallas, fused=fused)
    # outer level: a second triple over the (n_groups, ...) stack
    # (payload = 1 marks the single outer group)
    obs_state = span(obs_state, OBS.PH_STATS, 1)
    obs_state = span(obs_state, OBS.PH_PLAN, 1)
    obs_state = span(obs_state, OBS.PH_APPLY, 1)
    info["obs_state"] = obs_state
    info["outer_stats"] = ost
    hplan = HierPlan(inner=tuple(inner_plans), outer=op, n=n, f=f,
                     g=cfg.g, bounds=budget.bounds(),
                     f_inner=budget.f_inner, f_outer=budget.f_outer,
                     rule=cfg.rule, outer_rule=outer_name)
    return agg, hplan, info
