"""Hierarchical aggregation plans — group assignment + per-level AggPlans.

The flat plan phase is O(n²·θ·log n) in the worker count: at n in the
thousands (the north-star's federated fan-in) the (n, n) distance matrix
alone is the bottleneck.  The grouped scheme here robust-aggregates within
``ceil(n/g)`` groups of at most ``g`` workers, then robust-aggregates the
group outputs — O(n·g) selection work — while the per-level byzantine
budgets stay grounded in the paper's preconditions through
``core.theory.split_f_budget`` (DESIGN.md §11).

Two pieces:

* :class:`GroupConfig` — the static (hashable, jit-static) user-facing
  knob: group size ``g``, the inner rule, optionally an explicit outer
  rule and per-level f overrides.  ``hier=GroupConfig(g=64)`` on either
  trainer turns the feature on.
* :class:`HierPlan`  — the computed plan: worker→group bounds, the
  per-level budgets and one :class:`~repro.core.api.AggPlan` per group
  plus the outer plan.  A registered pytree, so it jits/vmaps like the
  flat ``AggPlan`` and composes the same telemetry surface
  (``selection_weights`` / ``diagnostics``) with per-group extras.

Group assignment is deterministic: contiguous balanced slices of the
worker axis (``core.theory.group_sizes``), larger groups first.  Workers
are addressed by row index everywhere in this repo (the byzantine-rows
-first convention of ``inject_byzantine``), so contiguity keeps every
existing attack/telemetry convention intact and makes the poisoned
-subtree scenario (all traitors in group 0) the default adversarial
placement.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.api import AggPlan, AggStats
from repro.core import theory

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GroupConfig:
    """Static configuration of the two-level grouped aggregation.

    ``g`` is the max group size; ``rule`` the inner (within-group) GAR
    from the registry.  ``outer_rule`` defaults to ``rule`` when the
    derived outer budget ``f_outer`` is positive and to plain ``average``
    when no whole group is capturable (robustness is already paid for at
    the inner level — averaging the group aggregates preserves the m/n
    slowdown claim instead of paying a second selection haircut).

    ``f_inner``/``f_outer`` override the derived per-level budgets (the
    simulator's under-provisioned poisoned-subtree campaigns);
    ``enforce_budget=False`` permits budgets that do not cover the
    contract f — every level is still individually gated through
    ``core.theory.check_level``.
    """

    g: int
    rule: str = "multi_bulyan"
    outer_rule: Optional[str] = None
    f_inner: Optional[int] = None
    f_outer: Optional[int] = None
    enforce_budget: bool = True

    @classmethod
    def from_spec(cls, spec: str, *, rule: str = "multi_bulyan"
                  ) -> "GroupConfig":
        """Parse the CLI grammar ``"g=64[,rule=...,f_inner=...,...]"``.

        Same comma-separated ``k=v`` shape as the attack/codec/transform
        spec strings.  ``rule`` is the default inner rule (the launchers
        pass their ``--gar``); ``enforce=0`` maps to
        ``enforce_budget=False``.  A bare integer is shorthand for ``g=``.
        """
        kw: Dict[str, object] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                k, v = "g", part
            else:
                k, v = (s.strip() for s in part.split("=", 1))
            if k == "enforce":
                kw["enforce_budget"] = v not in ("0", "false", "False")
            elif k in ("g", "f_inner", "f_outer"):
                kw[k] = int(v)
            elif k in ("rule", "outer_rule"):
                kw[k] = v
            else:
                raise ValueError(
                    f"unknown --hier key {k!r} in {spec!r}; expected "
                    "g/rule/outer_rule/f_inner/f_outer/enforce")
        if "g" not in kw:
            raise ValueError(f"--hier spec {spec!r} needs g=<group size>")
        kw.setdefault("rule", rule)
        return cls(**kw)  # type: ignore[arg-type]

    def budget(self, n: int, f: int) -> theory.FBudget:
        """The checked per-level f budget for an (n, f) contract."""
        return theory.split_f_budget(
            n, f, self.g, rule=self.rule, outer_rule=self.outer_rule,
            f_inner=self.f_inner, f_outer=self.f_outer,
            enforce=self.enforce_budget)

    def resolve_outer_rule(self, budget: theory.FBudget) -> str:
        if self.outer_rule is not None:
            return self.outer_rule
        return self.rule if budget.f_outer > 0 else "average"


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("inner", "outer"),
    meta_fields=("n", "f", "g", "bounds", "f_inner", "f_outer",
                 "rule", "outer_rule"))
@dataclasses.dataclass(frozen=True)
class HierPlan:
    """Static-shape output of the hierarchical plan phase.

    ``inner`` holds one flat :class:`AggPlan` per group (in worker-row
    order over the contiguous ``bounds``); ``outer`` the plan over the
    group aggregates, or ``None`` for the single-group degenerate case
    (g >= n), whose apply is the bitwise-identical flat path.  All array
    fields live inside the nested AggPlans, so a HierPlan jits and
    replicates exactly like its flat counterpart.
    """

    inner: Tuple[AggPlan, ...]
    outer: Optional[AggPlan]
    n: int
    f: int
    g: int
    bounds: Tuple[Tuple[int, int], ...]
    f_inner: int
    f_outer: int
    rule: str
    outer_rule: str

    @property
    def n_groups(self) -> int:
        return len(self.inner)

    # ------------------------------------------------------------ telemetry
    def group_selection(self) -> Array:
        """Convex (n_groups,) selection mass over group aggregates."""
        if self.outer is None:
            return jnp.ones((1,), jnp.float32)
        return self.outer.selection_weights()

    def selection_weights(self) -> Array:
        """Per-worker selection mass through both levels, convex (n,).

        Worker i's mass is (its group's outer mass) × (its inner mass
        within the group) — the share of the final aggregate its value
        flows into.  Adaptive attacks and the suspicion EMA consume this
        exactly like the flat plan's vector.
        """
        gsel = self.group_selection()
        parts = [gsel[k] * p.selection_weights()
                 for k, p in enumerate(self.inner)]
        return jnp.concatenate(parts).astype(jnp.float32)

    def diagnostics(self, inner_stats: Optional[Tuple[AggStats, ...]] = None
                    ) -> Dict[str, Array]:
        """Flat-plan diagnostics plus the per-group layer.

        Shares keys with ``AggPlan.diagnostics`` (``selection`` (n,),
        ``byz_mass``, and — when every group's stats carry distances —
        ``score_spectrum`` (n,) / ``score_gap`` / ``mean_dist`` built
        from the per-group Krum scores) and adds ``group_selection``
        (n_groups,), the outer level's per-group mass, which the
        simulator turns into per-group suspicion.
        """
        sel = self.selection_weights()
        byz = jnp.sum(sel[: self.f]) if self.f else jnp.zeros((), jnp.float32)
        out: Dict[str, Array] = {"selection": sel, "byz_mass": byz,
                                 "group_selection": self.group_selection()}
        if inner_stats is not None and \
                all(st.dists is not None for st in inner_stats):
            per = [p.diagnostics(st)
                   for p, st in zip(self.inner, inner_stats)]
            out["score_spectrum"] = jnp.sort(
                jnp.concatenate([d["score_spectrum"] for d in per]))
            out["score_gap"] = jnp.min(
                jnp.stack([d["score_gap"] for d in per]))
            out["mean_dist"] = jnp.mean(
                jnp.stack([d["mean_dist"] for d in per]))
        return out
