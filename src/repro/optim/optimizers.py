"""SGD(+momentum) and AdamW over parameter pytrees.

API mirrors optax minimally: ``init(params) -> state``;
``update(grads, state, params, lr) -> (new_params, new_state)``.
The paper's Fig 3 experiment uses SGD momentum 0.9 lr 0.1 — reproduced in
benchmarks/accuracy.py with these.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree            # first moment / momentum
    nu: Optional[PyTree]  # second moment (adam only)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[..., tuple]


def _zeros_like_f32(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def sgd(momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        mu = _zeros_like_f32(params) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads, state, params, lr):
        def upd(p, g, m):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if momentum:
                m = momentum * m + g
                step_dir = (g + momentum * m) if nesterov else m
            else:
                step_dir = g
            new_p = p.astype(jnp.float32) - lr * step_dir
            return new_p.astype(p.dtype), (m if momentum else None)

        if momentum:
            out = jax.tree.map(upd, params, grads, state.mu)
            new_params = jax.tree.map(lambda _, o: o[0], params, out,
                                      is_leaf=lambda x: isinstance(x, tuple))
            new_mu = jax.tree.map(lambda _, o: o[1], params, out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        else:
            new_params = jax.tree.map(
                lambda p, g: upd(p, g, None)[0], params, grads)
            new_mu = None
        return new_params, OptState(state.step + 1, new_mu, None)

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        _zeros_like_f32(params), _zeros_like_f32(params))

    def update(grads, state, params, lr):
        t = state.step + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            step_dir = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step_dir = step_dir + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step_dir
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        is_l = lambda x: isinstance(x, tuple)  # noqa: E731
        new_params = jax.tree.map(lambda _, o: o[0], params, out, is_leaf=is_l)
        new_mu = jax.tree.map(lambda _, o: o[1], params, out, is_leaf=is_l)
        new_nu = jax.tree.map(lambda _, o: o[2], params, out, is_leaf=is_l)
        return new_params, OptState(t, new_mu, new_nu)

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise KeyError(f"unknown optimizer {name!r}")
