"""Optimizers (hand-rolled; optax is not available in this container)."""
from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adamw,
    make_optimizer,
    sgd,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine  # noqa: F401
