"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    decay = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.float32(lr) * s / max(warmup, 1)
        return jnp.where(s < warmup, warm, decay(step - warmup))
    return fn
