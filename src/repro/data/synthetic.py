"""Synthetic deterministic data pipelines.

Two generators:

* :func:`lm_batches` — a *learnable* token stream for the LM architectures:
  tokens follow a fixed random bigram automaton, so next-token entropy is far
  below uniform and the training loss visibly decreases within a few hundred
  steps (used by examples/byzantine_training.py).
* :func:`classification_batches` — a separable Gaussian-mixture
  classification task standing in for Fashion-MNIST in the Fig 3 reproduction
  (no datasets are shipped in this container; DESIGN.md §3 table).

Workers draw disjoint slices of each global batch, matching the paper's
i.i.d.-sampling assumption; per-worker batches are what the byzantine game
aggregates over.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _bigram_table(vocab: int, seed: int, branching: int = 4) -> np.ndarray:
    """Each token can be followed by `branching` successors (uniformly)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab, branching), dtype=np.int32)


def make_lm_batch(key: Array, vocab: int, batch: int, seq: int,
                  seed: int = 1234) -> Dict[str, Array]:
    """One (tokens, labels) batch from the bigram automaton."""
    table = jnp.asarray(_bigram_table(vocab, seed))
    k0, k1 = jax.random.split(key)
    start = jax.random.randint(k0, (batch,), 0, vocab, dtype=jnp.int32)
    choices = jax.random.randint(k1, (batch, seq), 0, table.shape[1],
                                 dtype=jnp.int32)

    def step(tok, choice):
        nxt = table[tok, choice]
        return nxt, nxt

    _, seqs = jax.lax.scan(
        lambda c, ch: step(c, ch), start, choices.T)
    toks = jnp.concatenate([start[:, None], seqs.T], axis=1)  # (B, S+1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0
               ) -> Iterator[Dict[str, Array]]:
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.key(seed), step)
        yield make_lm_batch(key, vocab, batch, seq, seed=seed + 77)
        step += 1


# --------------------------------------------------------------- non-IID
def dirichlet_mixture(key: Array, n_workers: int, n_domains: int,
                      alpha: float) -> Array:
    """Per-worker Dirichlet(α) mixture over data domains -> (n_workers, K).

    Small α concentrates each worker on few domains (strong heterogeneity,
    the regime where coordinate-wise rules degrade — Yin et al. 2018);
    α → ∞ recovers i.i.d. workers.  Rows sum to 1.
    """
    if n_domains < 1:
        raise ValueError(f"n_domains must be >= 1, got {n_domains}")
    if alpha <= 0:
        raise ValueError(f"alpha must be positive, got {alpha}")
    return jax.random.dirichlet(
        key, jnp.full((n_domains,), alpha, jnp.float32), (n_workers,))


def make_noniid_lm_batch(key: Array, vocab: int, n_workers: int,
                         per_worker: int, seq: int, mixture: Array,
                         seed: int = 1234) -> Dict[str, Array]:
    """Worker-heterogeneous LM batch: ``(n_workers*per_worker, S)`` tokens.

    Domain k is its own bigram automaton (table seeded ``seed + k``); each
    of worker w's rows samples a domain from ``mixture[w]`` and walks that
    domain's automaton.  Row-major worker order, so ``split_workers`` with
    the same ``n_workers`` recovers the per-worker batches.  Deterministic
    in ``(key, mixture, seed)`` and jit-friendly (tables are constants).
    """
    n_domains = mixture.shape[1]
    if mixture.shape[0] != n_workers:
        raise ValueError(
            f"mixture rows ({mixture.shape[0]}) != n_workers ({n_workers})")
    tables = jnp.asarray(np.stack(
        [_bigram_table(vocab, seed + k) for k in range(n_domains)]))
    rows = n_workers * per_worker
    kd, k0, k1 = jax.random.split(key, 3)
    row_logits = jnp.repeat(jnp.log(mixture + 1e-20), per_worker, axis=0)
    domains = jax.random.categorical(kd, row_logits, axis=-1)      # (rows,)
    start = jax.random.randint(k0, (rows,), 0, vocab, dtype=jnp.int32)
    choices = jax.random.randint(k1, (rows, seq), 0, tables.shape[2],
                                 dtype=jnp.int32)

    def step(tok, choice):
        nxt = tables[domains, tok, choice]
        return nxt, nxt

    _, seqs = jax.lax.scan(step, start, choices.T)
    toks = jnp.concatenate([start[:, None], seqs.T], axis=1)       # (rows, S+1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def classification_batches(d_in: int, n_classes: int, batch: int, *,
                           seed: int = 0, noise: float = 1.0,
                           center_seed: int = 7777
                           ) -> Iterator[Tuple[Array, Array]]:
    """Gaussian mixture: class c centred at a fixed random unit vector.

    ``center_seed`` fixes the mixture itself — train and test iterators must
    share it (only ``seed`` varies the sampling stream), otherwise they are
    different tasks.
    """
    rng = np.random.default_rng(center_seed)
    centers = rng.normal(size=(n_classes, d_in)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    centers = jnp.asarray(centers) * 2.0
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.key(seed + 1), step)
        kx, ky = jax.random.split(key)
        labels = jax.random.randint(ky, (batch,), 0, n_classes, dtype=jnp.int32)
        x = centers[labels] + noise * jax.random.normal(kx, (batch, d_in))
        yield x, labels
        step += 1
