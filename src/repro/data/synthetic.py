"""Synthetic deterministic data pipelines.

Two generators:

* :func:`lm_batches` — a *learnable* token stream for the LM architectures:
  tokens follow a fixed random bigram automaton, so next-token entropy is far
  below uniform and the training loss visibly decreases within a few hundred
  steps (used by examples/byzantine_training.py).
* :func:`classification_batches` — a separable Gaussian-mixture
  classification task standing in for Fashion-MNIST in the Fig 3 reproduction
  (no datasets are shipped in this container; DESIGN.md §3 table).

Workers draw disjoint slices of each global batch, matching the paper's
i.i.d.-sampling assumption; per-worker batches are what the byzantine game
aggregates over.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _bigram_table(vocab: int, seed: int, branching: int = 4) -> np.ndarray:
    """Each token can be followed by `branching` successors (uniformly)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab, branching), dtype=np.int32)


def make_lm_batch(key: Array, vocab: int, batch: int, seq: int,
                  seed: int = 1234) -> Dict[str, Array]:
    """One (tokens, labels) batch from the bigram automaton."""
    table = jnp.asarray(_bigram_table(vocab, seed))
    k0, k1 = jax.random.split(key)
    start = jax.random.randint(k0, (batch,), 0, vocab, dtype=jnp.int32)
    choices = jax.random.randint(k1, (batch, seq), 0, table.shape[1],
                                 dtype=jnp.int32)

    def step(tok, choice):
        nxt = table[tok, choice]
        return nxt, nxt

    _, seqs = jax.lax.scan(
        lambda c, ch: step(c, ch), start, choices.T)
    toks = jnp.concatenate([start[:, None], seqs.T], axis=1)  # (B, S+1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0
               ) -> Iterator[Dict[str, Array]]:
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.key(seed), step)
        yield make_lm_batch(key, vocab, batch, seq, seed=seed + 77)
        step += 1


def classification_batches(d_in: int, n_classes: int, batch: int, *,
                           seed: int = 0, noise: float = 1.0,
                           center_seed: int = 7777
                           ) -> Iterator[Tuple[Array, Array]]:
    """Gaussian mixture: class c centred at a fixed random unit vector.

    ``center_seed`` fixes the mixture itself — train and test iterators must
    share it (only ``seed`` varies the sampling stream), otherwise they are
    different tasks.
    """
    rng = np.random.default_rng(center_seed)
    centers = rng.normal(size=(n_classes, d_in)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    centers = jnp.asarray(centers) * 2.0
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.key(seed + 1), step)
        kx, ky = jax.random.split(key)
        labels = jax.random.randint(ky, (batch,), 0, n_classes, dtype=jnp.int32)
        x = centers[labels] + noise * jax.random.normal(kx, (batch, d_in))
        yield x, labels
        step += 1
