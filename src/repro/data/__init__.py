"""Data pipeline (synthetic, deterministic — no external datasets in-container)."""
from repro.data.synthetic import (  # noqa: F401
    classification_batches,
    dirichlet_mixture,
    lm_batches,
    make_lm_batch,
    make_noniid_lm_batch,
)
