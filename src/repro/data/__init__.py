"""Data pipeline (synthetic, deterministic — no external datasets in-container)."""
from repro.data.synthetic import (  # noqa: F401
    classification_batches,
    lm_batches,
    make_lm_batch,
)
