"""chatglm3-6b [dense] — 2d (partial) RoPE, GQA [arXiv:2406.12793]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope="partial",               # chatglm 2d rope: half the head dim rotates
    rope_fraction=0.5,
)
