"""falcon-mamba-7b [ssm] — attention-free mamba1 [arXiv:2410.05355]."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355",
    n_layers=64,
    d_model=4096,
    n_heads=1,                    # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,                       # mamba1 blocks have no separate MLP
    vocab_size=65024,
    norm="rmsnorm",
    rope="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)
