"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, 16e top-2 MoE
[arXiv:2403.19887].

Block period 8: one attention layer (index 4) per 7 mamba mixers; the MLP is
MoE on every second layer (16 experts, top-2), dense otherwise.
"""
from repro.configs.base import ArchConfig, HybridConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    activation="swiglu",
    norm="rmsnorm",
    rope="full",
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    hybrid=HybridConfig(period=8, attn_index=4),
)
