"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    rope="full",
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536, every=1),
)
