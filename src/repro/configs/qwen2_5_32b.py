"""qwen2.5-32b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope="full",
    rope_theta=1e6,
    # 40 heads / 8 kv heads do not divide the 16-way model axis.  Strategy
    # search (EXPERIMENTS.md §Perf hillclimb 1): plain tp = 27.3 TB/dev
    # collectives (hd-contraction sharding, fp32 logit all-reduce x256);
    # zero3 = 49 TB (refuted: per-remat weight gathers dominate);
    # tp_attn_batch (batch-shard the attention inner loop only) = 7.4 TB.
    sharding_strategy="tp_attn_batch",
)
