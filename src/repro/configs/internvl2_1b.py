"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821].

The ViT/projector frontend is a stub per the task carve-out: the LM consumes
precomputed patch embeddings (B, n_patches, d_model) as a soft prefix.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,               # qwen2-style attention biases
    rope="full",
    rope_theta=1e6,
    n_patches=1024,
    # 14 heads / 2 kv heads do not divide the 16-way model axis — same
    # remedy as qwen2.5 (EXPERIMENTS.md §Perf #4): batch-shard attention.
    sharding_strategy="tp_attn_batch",
)
