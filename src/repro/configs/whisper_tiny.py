"""whisper-tiny [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a stub: ``input_specs()``
feeds precomputed frame embeddings (B, 1500, 384) to the encoder.  Decoder
positions use sinusoids (whisper's learned table is an init detail, noted in
DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=4,                   # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,                 # MHA (kv == q heads)
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    rope="none",
    n_frames=1500,                # 30 s of audio at 50 Hz after conv stride
)
