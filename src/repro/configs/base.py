"""Configuration system for repro.

Two config families:

* :class:`ArchConfig` — a full architecture description (one per assigned
  architecture in ``src/repro/configs/<id>.py``).  Frozen dataclass so it can
  be used as a static argument to ``jax.jit``.
* :class:`ShapeConfig` — an input-shape workload (train / prefill / decode).
* :class:`RobustConfig` — parameters of the paper's technique (n workers,
  f byzantine, which GAR).

Reduced variants for CPU smoke tests are produced by ``ArchConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

Family = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    n_experts: int
    top_k: int
    d_expert: int              # per-expert hidden size
    capacity_factor: float = 1.25
    every: int = 1             # MoE replaces the MLP every `every` layers
    aux_loss_weight: float = 0.01  # router load-balance auxiliary loss


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective state space configuration."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0           # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else max(1, math.ceil(d_model / 16))


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style attention/Mamba interleave.

    A block of ``period`` layers contains one attention layer at index
    ``attn_index`` (the rest are Mamba mixers).
    """

    period: int = 8
    attn_index: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identification
    name: str
    family: Family                     # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                   # citation for the config values

    # transformer backbone
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # flavour knobs
    activation: str = "swiglu"         # swiglu | relu2 | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope: str = "full"                 # full | partial | none  (partial = chatglm 2d)
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0         # fraction of head_dim that rotates
    attn_window: int = 0               # 0 = full attention; >0 = sliding window
    # Sharding strategy (not architecture): "tp" = megatron tensor parallel
    # over the model axis; "zero3" = no tensor parallelism — batch over both
    # mesh axes, weights fully sharded and all-gathered per layer group.
    # zero3 suits archs whose head count does not divide the 16-way model
    # axis (qwen2.5's 40 heads): under tp, GSPMD shards the head_dim
    # contraction and all-reduces full fp32 logits every q-chunk
    # (EXPERIMENTS.md §Perf hillclimb 1).
    sharding_strategy: str = "tp"
    # long_500k decode uses this window for full-attention families (see
    # DESIGN.md §Arch-applicability); exact attention otherwise.
    long_context_window: int = 8192

    # family-specific sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # encoder (audio enc-dec) — shares d_model/n_heads with the decoder
    n_encoder_layers: int = 0
    n_frames: int = 0                  # stub audio frontend: frames fed to encoder
    n_patches: int = 0                 # stub vision frontend: patches prefixed to LM

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim > 0 else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if long_500k decode is runnable (sub-quadratic path exists)."""
        # SSM/hybrid are natively O(1)/windowed; the full-attention families use
        # the sliding-window ring-buffer cache (DESIGN.md).
        return True

    def moe_layer_indices(self) -> Tuple[int, ...]:
        if self.moe is None:
            return ()
        return tuple(
            i for i in range(self.n_layers) if (i % self.moe.every) == self.moe.every - 1
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS).

        Exactness vs the materialised model is asserted per-arch in
        tests/test_archs.py."""
        d, v = self.d_model, self.vocab_size
        ns = (2 if self.norm == "layernorm" else 1) * d   # norm params
        total = v * d                         # embedding
        if not self.tie_embeddings:
            total += v * d                    # lm head
        for i in range(self.n_layers):
            n_norms = 1 + (1 if self._mlp_params(i) else 0)
            total += self._mixer_params(i) + self._mlp_params(i) + n_norms * ns
        total += ns                           # final norm
        if self.is_encdec:
            for _ in range(self.n_encoder_layers):
                total += self._attn_params() + self._dense_mlp_params() + 2 * ns
            total += ns                       # encoder output norm
            total += self.n_layers * (self._attn_params() + ns)  # cross + norm_x
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        e = self.moe
        per_expert = 3 * d * e.d_expert
        dense = self.param_count() - len(self.moe_layer_indices()) * (
            e.n_experts * per_expert
        )
        return dense + len(self.moe_layer_indices()) * e.top_k * per_expert

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        d = self.d_model
        di = self.ssm.expand * d
        dtr = self.ssm.resolved_dt_rank(d)
        st = self.ssm.d_state
        return (
            d * 2 * di              # in_proj
            + di * self.ssm.d_conv + di  # depthwise conv (w + b)
            + di * (dtr + 2 * st)   # x_proj
            + dtr * di + di         # dt_proj
            + di * st + di          # A_log, D
            + di * d                # out_proj
        )

    def _mixer_params(self, layer: int) -> int:
        if self.family == "ssm":
            return self._mamba_params()
        if self.family == "hybrid":
            assert self.hybrid is not None
            if layer % self.hybrid.period == self.hybrid.attn_index:
                return self._attn_params()
            return self._mamba_params()
        return self._attn_params()

    def _dense_mlp_params(self) -> int:
        mults = 3 if self.activation == "swiglu" else 2
        return mults * self.d_model * self.d_ff

    def _mlp_params(self, layer: int) -> int:
        if self.family == "ssm":
            return 0  # mamba1 blocks have no separate MLP
        if self.moe is not None and layer in self.moe_layer_indices():
            e = self.moe
            return e.n_experts * 3 * self.d_model * e.d_expert + self.d_model * e.n_experts
        if self.d_ff == 0:
            return 0
        return self._dense_mlp_params()

    def reduced(self) -> "ArchConfig":
        """Reduced same-family variant for CPU smoke tests.

        2 layers (one hybrid period when hybrid), d_model<=256, <=4 experts.
        """
        kw: dict = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=0,
        )
        if self.moe is not None:
            # capacity_factor 8: no token drops, so prefill+decode agree
            # exactly with the full forward in the smoke tests
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_expert=128, capacity_factor=8.0
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, dt_rank=16)
        if self.hybrid is not None:
            # one block period of 2: attn at index 1, mamba at 0
            kw["hybrid"] = HybridConfig(period=2, attn_index=1)
            kw["n_layers"] = 2
        if self.is_encdec:
            kw["n_encoder_layers"] = 2
            kw["n_frames"] = 16
        if self.n_patches:
            kw["n_patches"] = 8
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Parameters of the paper's technique.

    ``n_workers`` is the number of byzantine-game participants (one per
    data-parallel slice on the production mesh).  ``f`` is the contract on the
    number of byzantine workers.  ``gar`` selects the aggregation rule.
    """

    n_workers: int = 16
    f: int = 3
    gar: str = "multi_bulyan"  # any name registered in repro.core.api
    use_pallas: bool = False   # route pairwise distances / coord select via kernels
    grouped: bool = False      # hierarchical aggregation (repro.hier): the
    #                            per-level budget check (theory.split_f_budget)
    #                            owns feasibility, not the flat rule's min_n —
    #                            a grouped (n, f) may be flat-infeasible

    def __post_init__(self):
        self.validate()

    def validate(self) -> "RobustConfig":
        """Enforce the paper's resilience preconditions at construction time.

        Krum-family rules need n >= 2f+3 (Blanchard et al.), Bulyan-family
        n >= 4f+3 (El-Mhamdi et al.) — checked here against the rule's
        registered ``min_n`` capability so a bad (n, f, gar) combination
        fails with a clear error instead of deep inside aggregation.
        Returns self so call sites can chain (``cfg.validate().gar``).
        """
        if self.n_workers <= 0:
            raise ValueError(f"n_workers must be positive, got {self.n_workers}")
        if self.f < 0:
            raise ValueError(f"f must be nonnegative, got {self.f}")
        if self.f >= self.n_workers:
            raise ValueError(
                f"need more workers than byzantine ones "
                f"(n={self.n_workers}, f={self.f})")
        # lazy import: repro.core.api depends on jax; configs stay light and
        # the core package itself imports this module.
        from repro.core.api import get_aggregator
        try:
            rule = get_aggregator(self.gar)
        except KeyError as e:
            raise ValueError(e.args[0]) from None
        if not self.grouped:
            rule.validate(self.n_workers, self.f)
        return self
