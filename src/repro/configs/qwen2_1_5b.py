"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope="full",
    rope_theta=1e6,
    tie_embeddings=True,
)
