"""Config registry: the 10 assigned architectures + shape configs."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    HybridConfig,
    MoEConfig,
    RobustConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
)

_MODULES: Dict[str, str] = {
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]
