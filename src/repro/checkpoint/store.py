"""Flat-key npz checkpoint store.

Pytrees are flattened with ``jax.tree_util.tree_flatten_with_path``; each
leaf is stored under its joined key path, so restore round-trips exact tree
structure + dtypes without pickling arbitrary objects.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}  # dtypes numpy cannot serialise natively


def save(directory: str, step: int, tree: PyTree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for path, leaf in flat:
        key = _key_str(path)
        arr = np.asarray(leaf)
        if arr.dtype.name in _VIEW:
            # store as a bit-view; the original dtype is tagged in the key
            arrays[f"{key}::{arr.dtype.name}"] = arr.view(_VIEW[arr.dtype.name])
        else:
            arrays[key] = arr
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, **arrays)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def _alias_key(ks: str, key_aliases) -> Optional[str]:
    """Translate a missing key through prefix aliases (oldest-first)."""
    for new_pre, old_pre in (key_aliases or {}).items():
        if ks == new_pre:
            return old_pre
        if ks.startswith(new_pre + _SEP):
            return old_pre + ks[len(new_pre):]
    return None


def restore(directory: str, step: int, like: PyTree, *,
            key_aliases=None) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    ``key_aliases`` maps key-path *prefixes* of ``like`` to the prefixes an
    older writer used — the migration shim for layout renames (e.g. the
    PR-5 ``TrainerState`` unification reads PR-3-era checkpoints whose
    optimizer lived under a top-level ``opt`` key via
    ``{"state|opt": "opt", ...}``).  An alias is consulted only when the
    canonical key is absent, so current-layout checkpoints never take it.
    """
    import ml_dtypes

    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        tagged = {}
        for k in data.files:
            if "::" in k:
                base, dt = k.rsplit("::", 1)
                tagged[base] = data[k].view(getattr(ml_dtypes, dt))
            else:
                tagged[k] = data[k]
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kpath, leaf in flat:
            ks = _key_str(kpath)
            if ks not in tagged:
                alias = _alias_key(ks, key_aliases)
                if alias is not None and alias in tagged:
                    ks = alias
                else:
                    raise KeyError(f"checkpoint missing key {ks!r}")
            arr = tagged[ks]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {ks}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
