"""Checkpointing: npz-based save/restore of arbitrary pytrees."""
from repro.checkpoint.store import latest_step, restore, save  # noqa: F401
