import os
import sys

if "jax" not in sys.modules:                       # keep test imports inert
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Static contract verification CLI (DESIGN.md §12).

Runs the three ``repro.analysis`` passes — the AST lint (R001–R005), the
jaxpr contract auditors (C201–C205) under a forced 8-device host mesh,
and the Pallas VMEM/crossover estimator — and writes the ``analysis.v1``
report.  No accelerator is required and no training step executes: the
auditors only *trace*.

Usage:
  PYTHONPATH=src python -m repro.launch.analyze [--json ANALYSIS.json]
  PYTHONPATH=src python -m repro.launch.analyze --strict   # CI gate

``--strict`` exits nonzero on any lint violation, any violated contract,
a failed traffic-linearity diagnosis, or an uncalibrated crossover — the
gate every kernel/sharding PR must pass.
"""
import argparse
import json
from typing import Dict, List

SCHEMA = "analysis.v1"

#: the committed BENCH grid points the kernel estimates are emitted at
KERNEL_POINTS = ((11, 4096), (15, 100_000), (15, 1_000_000))

LINT_PATHS = ("src", "benchmarks", "examples")


def run_lint(root: str = ".") -> Dict:
    from repro.analysis import lint
    paths = [os.path.join(root, p) for p in LINT_PATHS
             if os.path.isdir(os.path.join(root, p))]
    violations = lint.lint_paths(paths)
    return {
        "paths": [os.path.relpath(p, root) for p in paths],
        "rules": sorted(lint.RULES),
        "violations": [v.to_json() for v in violations],
    }


def run_contracts() -> Dict:
    import jax

    from repro.analysis import jaxpr_audit as JA
    from repro.core import api

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = api.MeshContext.for_mesh(mesh)
    key = jax.random.key(0)
    grads = {"w": jax.random.normal(key, (11, 8, 32)),
             "b": jax.random.normal(jax.random.key(1), (11, 16))}

    results = [
        JA.audit_apply_gather(grads, f=2, mesh_ctx=ctx),
        JA.audit_decode_invariant(grads, f=2, mesh_ctx=ctx),
        JA.audit_tp_seam(
            jax.make_jaxpr(lambda g: api.aggregate_tree(
                g, 2, "multi_bulyan", mesh_ctx=ctx))(grads),
            label="aggregate_tree mesh path"),
        JA.tp_seam_self_test(),
        JA.audit_single_compile(
            jax.jit(lambda g: api.aggregate_tree(g, 2, "multi_bulyan")),
            lambda: (grads,), label="jitted aggregate_tree"),
        JA.audit_hier_decode(
            {"w": jax.random.normal(key, (21, 8, 32))}, f=1, spec="g=7"),
    ]
    return {r.contract: r.to_json() for r in results}


def run_kernels(bench_path: str) -> Dict:
    from repro.analysis import vmem

    kernels: Dict[str, Dict] = {}
    for kernel in ("fused_select", "pairwise_stats", "dequant_stats"):
        kernels[kernel] = {
            f"n={n},d={d}": vmem.estimate(kernel, n, d).to_json()
            for n, d in KERNEL_POINTS}
    out = {"kernels": kernels,
           "crossover": {f"n={n}": vmem.predicted_crossover(n)
                         for n in (11, 15)}}
    if os.path.isfile(bench_path):
        with open(bench_path) as fh:
            bench = json.load(fh)
        out["traffic_linearity"] = vmem.diagnose_traffic_linearity(
            bench.get("results", bench))
    else:
        out["traffic_linearity"] = {"points": [], "holds": False,
                                    "detail": f"{bench_path} not found"}
    return out


def gate_problems(report: Dict) -> List[str]:
    """Everything ``--strict`` refuses to ship."""
    problems = []
    report = report["results"]
    for v in report["lint"]["violations"]:
        problems.append(
            f"lint {v['rule']} {v['path']}:{v['line']}: {v['msg']}")
    for name, res in report["contracts"].items():
        if res["status"] != "proven":
            problems.append(f"contract {name} violated: "
                            + "; ".join(res["violations"]))
    traffic = report["analysis"]["traffic_linearity"]
    if not traffic.get("holds"):
        problems.append("vmem traffic-linearity diagnosis does not hold: "
                        f"{traffic.get('detail')}")
    for key, x in report["analysis"]["crossover"].items():
        if not x["calibrated"]:
            problems.append(
                f"crossover {key}: predicted {x['predicted_numel']} vs "
                f"measured {x['measured_numel']} (ratio {x['ratio']:.2f}, "
                f"censored={x['censored']}) — model uncalibrated")
    d1e6 = report["analysis"]["kernels"]["fused_select"].get("n=15,d=1000000")
    if d1e6 and not (d1e6["over_budget"] and not d1e6["tile_over_budget"]
                     and d1e6["macro_tile"] > d1e6["d_tile"]):
        problems.append("fused_select n=15,d=1e6 must tile (over_budget), "
                        "fit per macro step, and run a multi-window macro "
                        "block — the two-level residency claim fails")
    return problems


def build_report(root: str, bench_path: str) -> Dict:
    # the {"schema", "results"} envelope is what validate_bench gates on
    return {"schema": SCHEMA,
            "results": {"lint": run_lint(root),
                        "contracts": run_contracts(),
                        "analysis": run_kernels(bench_path)}}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="static contract verification (lint + jaxpr audits "
                    "+ VMEM estimates)")
    ap.add_argument("--root", default=".",
                    help="repo root to lint (default: cwd)")
    ap.add_argument("--bench", default="BENCH_agg_time.json",
                    help="benchmark file for the cliff diagnosis")
    ap.add_argument("--json", default="ANALYSIS.json",
                    help="report output path ('-' for stdout only)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any violation")
    args = ap.parse_args(argv)

    report = build_report(args.root, args.bench)
    problems = gate_problems(report)

    if args.json != "-":
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1, sort_keys=True)
            fh.write("\n")

    res_ = report["results"]
    nlint = len(res_["lint"]["violations"])
    print(f"lint: {nlint} violation(s) over {res_['lint']['paths']}")
    for name, res in sorted(res_["contracts"].items()):
        print(f"{name}: {res['status']} — {res['detail']}")
    traffic = res_["analysis"]["traffic_linearity"]
    print(f"vmem traffic linearity: holds={traffic.get('holds')}")
    for key, x in sorted(res_["analysis"]["crossover"].items()):
        print(f"crossover {key}: predicted numel {x['predicted_numel']:,} "
              f"vs measured {x['measured_numel']:,} "
              f"(ratio {x['ratio']:.2f}, censored={x['censored']})")
    if problems:
        print(f"\n{len(problems)} problem(s):")
        for p in problems:
            print(f"  ✗ {p}")
    else:
        print("\nall contracts proven, repo lints clean")
    if args.json != "-":
        print(f"report written to {args.json}")
    return 1 if (args.strict and problems) else 0


if __name__ == "__main__":
    sys.exit(main())
