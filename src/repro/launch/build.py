"""Builders: (step_fn, argument ShapeDtypeStructs, shardings) per workload.

Shared by the dry-run (``.lower().compile()`` on the production mesh), the
real training/serving drivers, and the roofline benchmark.  Nothing here
allocates device memory for the full configs — parameters and caches are
``jax.eval_shape`` stand-ins until a driver decides to materialise them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RobustConfig, ShapeConfig
from repro import models as MD
from repro.dist import sharding as SH
from repro.dist.trainer import make_train_step
from repro.dist.streaming import make_streaming_train_step
from repro.dist.serving import make_serve_step
from repro.launch.mesh import data_parallel_size
from repro.models import api as MAPI
from repro.optim import sgd

PyTree = Any

# archs whose n×d stacked gradient cannot exist on the mesh (DESIGN.md §5):
# they default to the streaming-global trainer (exact Algorithm 1, 2 passes).
STREAMING_ARCHS = ("qwen3-moe-235b-a22b", "jamba-1.5-large-398b")
# archs large enough that params+momentum need FSDP (both-axes) sharding.
FSDP_MIN_PARAMS = 8e9


@dataclasses.dataclass
class Workload:
    """Everything needed to lower one (arch × shape × mesh) combination."""
    name: str
    fn: Any                      # the step function (to be jit'ed)
    args: Tuple                  # ShapeDtypeStruct pytrees
    in_shardings: Tuple          # NamedSharding pytrees (same structure)
    donate: Tuple[int, ...] = ()
    static: Dict[str, Any] = dataclasses.field(default_factory=dict)


def default_robust_config(mesh: Mesh, gar: str = "multi_bulyan",
                          use_pallas: bool = False) -> RobustConfig:
    n = data_parallel_size(mesh)
    f = max(1, (n - 3) // 4)     # the paper's f = floor((n-3)/4) (§V setup)
    return RobustConfig(n_workers=n, f=f, gar=gar, use_pallas=use_pallas)


def wants_fsdp(cfg: ArchConfig) -> bool:
    return cfg.param_count() >= FSDP_MIN_PARAMS


def wants_streaming(cfg: ArchConfig) -> bool:
    return cfg.name in STREAMING_ARCHS


def param_shapes(cfg: ArchConfig) -> PyTree:
    return jax.eval_shape(
        functools.partial(MD.init_model, cfg=cfg), jax.random.key(0))


def _strategy_param_specs(cfg: ArchConfig, pshapes: PyTree,
                          mesh: Mesh, fsdp: bool) -> PyTree:
    """Dispatch parameter sharding per the arch's strategy.

    "zero3" (kept selectable for experiments) was REFUTED as a default for
    qwen2.5: per-remat layer-group weight all-gathers dominate (49 TB/dev
    vs 7.4 TB for tp_attn_batch — EXPERIMENTS.md §Perf hillclimb 1).
    "tp_attn_batch" = megatron specs + vocab-sharded embedding (the
    d-sharded gather trips a multi-pod SPMD partitioner bug) + the
    batch-sharded attention constraint applied inside the model.
    """
    if cfg.sharding_strategy == "zero3":
        return SH.zero3_param_specs(pshapes, mesh)
    pspecs = SH.param_specs(pshapes, mesh)
    if cfg.sharding_strategy == "tp_attn_batch":
        pspecs = dict(pspecs)
        vocab = pshapes["embed"]["table"].shape[0]
        spec = P("model", None) if vocab % 16 == 0 else P(None, None)
        pspecs["embed"] = {"table": spec}
    if fsdp:
        pspecs = _fsdp_specs(pshapes, pspecs, mesh)
    return pspecs


def _named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _fsdp_specs(params: PyTree, base: PyTree, mesh: Mesh) -> PyTree:
    """Extend the megatron specs with 'data' on the largest unsharded dim.

    Embedding tables are exempt: gathers from a vocab-data-sharded table
    trip an SPMD partitioner bug on the multi-pod mesh (hlo-verifier slice
    shape mismatch) and the table is small relative to the stack.
    """
    dp = mesh.shape["data"]
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_s = treedef.flatten_up_to(base)
    out = []
    for (path, leaf), spec in zip(flat_p, flat_s):
        keys = [str(getattr(p, "key", "")) for p in path]
        spec = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
        if "embed" not in keys and leaf.ndim >= 2 and leaf.size >= (1 << 20):
            dims = [i for i, s in enumerate(spec)
                    if s is None and leaf.shape[i] % dp == 0]
            if dims:
                best = max(dims, key=lambda i: leaf.shape[i])
                spec = tuple("data" if i == best else s
                             for i, s in enumerate(spec))
        out.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def train_workload(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
                   rcfg: Optional[RobustConfig] = None,
                   trainer: str = "auto",       # auto|stacked|stream_block|stream_global
                   fsdp: Optional[bool] = None,
                   gar: str = "multi_bulyan",
                   use_pallas: bool = False,
                   chunk_q: int = 1024,
                   grad_constraints: bool = True,
                   spmd: bool = False) -> Workload:
    # spmd=True lowers the mesh-native stats→plan→apply pipeline
    # (DESIGN.md §10).  Default off for the dry-run: the flatten/reshape
    # seam around the sharded apply triggers involuntary GSPMD
    # rematerializations against the committed tp grad layout (measured
    # 79.8 GB vs 10.4 GB peak/device on qwen2-1.5b×256 chips) — the §10
    # open item tracks aligning the leaf shard dim with the tp spec.
    assert shape.kind == "train"
    rcfg = rcfg or default_robust_config(mesh, gar, use_pallas)
    if fsdp is None:
        fsdp = wants_fsdp(cfg)
    if trainer == "auto":
        trainer = "stream_global" if wants_streaming(cfg) else "stacked"

    n = rcfg.n_workers
    opt = sgd(momentum=0.9)
    pshapes = param_shapes(cfg)
    oshapes = jax.eval_shape(opt.init, pshapes)

    # batch specs: (n_workers, per_worker, ...)
    assert shape.global_batch % n == 0, (shape.global_batch, n)
    per_worker = shape.global_batch // n
    flat_batch = MAPI.make_batch(cfg, "train", shape.global_batch,
                                 shape.seq_len, as_spec=True)
    batch = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, per_worker) + s.shape[1:], s.dtype),
        flat_batch)

    pspecs = _strategy_param_specs(cfg, pshapes, mesh, fsdp)
    bspecs = SH.batch_specs(batch, mesh, worker_stacked=True)
    if grad_constraints:
        if cfg.sharding_strategy == "zero3":
            gspecs = jax.tree.map(lambda s: P(None, *tuple(s)), pspecs)
        else:
            gspecs = SH.grad_stack_specs(pshapes, mesh)
    else:
        gspecs = None

    window = cfg.attn_window
    lr_fn = lambda s: jnp.float32(1e-2)  # noqa: E731
    # Remat-boundary sharding: REFUTED hypothesis (EXPERIMENTS.md §Perf it-2).
    # Constraining the scan carry to a seq-sharded layout leaks into the
    # attention dataflow: GSPMD unshards the heads and all-gathers full
    # (B, H, cq, S) fp32 logits inside the q-chunk loop (+30 TB collectives
    # on nemotron; +300 GB/device temp on falcon's mamba scan).  Boundaries
    # stay replicated; activation memory is instead controlled by the
    # q-chunk/xent remat and the transposed grad-stack layout.
    bspec = None
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if trainer == "stacked":
        # shard_map_axes names the mesh-native worker axes when the
        # caller opts into spmd=True (off by default here — see the
        # rematerialization note at the top of this function)
        fn = make_train_step(cfg, rcfg, opt, lr_fn, window=window,
                             chunk_q=chunk_q, grad_specs=gspecs,
                             boundary_spec=bspec,
                             shard_map_mesh=mesh, shard_map_axes=axes,
                             spmd=spmd)
    else:
        scope = "global" if trainer.endswith("global") else "block"
        lead = axes if len(axes) > 1 else axes[0]
        d_ax = "model" if cfg.d_model % mesh.shape["model"] == 0 else None
        dx_spec = P(lead, None, None, d_ax)
        fn = make_streaming_train_step(cfg, rcfg, opt, lr_fn, scope=scope,
                                       window=window, chunk_q=chunk_q,
                                       boundary_spec=bspec, dx_spec=dx_spec,
                                       shard_map_mesh=mesh,
                                       shard_map_axes=axes, spmd=spmd)

    key_spec = jax.eval_shape(lambda: jax.random.key(0))
    mu_shardings = _named(mesh, pspecs) if oshapes.mu is not None else None
    opt_shardings = type(oshapes)(NamedSharding(mesh, P()), mu_shardings, None)
    args = (pshapes, oshapes, batch, key_spec)
    shardings = (
        _named(mesh, pspecs),
        opt_shardings,
        _named(mesh, bspecs),
        NamedSharding(mesh, P()),
    )
    return Workload(
        name=f"{cfg.name}×{shape.name}",
        fn=fn, args=args, in_shardings=shardings,
        static={"trainer": trainer, "fsdp": fsdp, "rcfg": rcfg},
    )


def prefill_workload(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
                     chunk_q: int = 1024) -> Workload:
    assert shape.kind == "prefill"
    pshapes = param_shapes(cfg)
    pspecs = _strategy_param_specs(cfg, pshapes, mesh, wants_fsdp(cfg))
    batch = MAPI.make_batch(cfg, "prefill", shape.global_batch,
                            shape.seq_len, as_spec=True)
    bspecs = SH.batch_specs(batch, mesh, worker_stacked=False)
    window = MAPI.decode_window(cfg, shape)

    def fn(params, b):
        return MD.prefill_fn(params, cfg, b, window=window, chunk_q=chunk_q,
                             cache_len=shape.seq_len + 64)

    args = (pshapes, batch)
    shardings = (_named(mesh, pspecs), _named(mesh, bspecs))
    return Workload(name=f"{cfg.name}×{shape.name}", fn=fn, args=args,
                    in_shardings=shardings, static={"window": window})


def decode_workload(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
                    ) -> Workload:
    assert shape.kind == "decode"
    b = shape.global_batch
    window = MAPI.decode_window(cfg, shape)
    cache_len = window if window else shape.seq_len
    pshapes = param_shapes(cfg)
    pspecs = _strategy_param_specs(cfg, pshapes, mesh, wants_fsdp(cfg))

    if cfg.is_encdec:
        mem_spec = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                        jnp.bfloat16)
        cshapes = jax.eval_shape(
            lambda p, m: MAPI.init_cache_fn(p, cfg, b, cache_len,
                                            window=window, memory=m),
            pshapes, mem_spec)
    else:
        cshapes = jax.eval_shape(
            lambda: MAPI.init_cache_fn(None, cfg, b, cache_len, window=window))

    dp = data_parallel_size(mesh)
    shard_batch = (b % dp == 0) and b >= dp
    cspecs = SH.cache_specs(cshapes, mesh, shard_batch=shard_batch)

    # seq-chunked decode attention: the cache length axis is sharded over
    # 'model' (cache_specs); chunk-local partial softmax + tiny combine
    # replaces the per-step cache all-gather (EXPERIMENTS.md §Perf #13)
    chunks = mesh.shape["model"] if cache_len % mesh.shape["model"] == 0 else 1
    step = make_serve_step(cfg, window=window, seq_chunks=chunks)

    def fn(params, cache, token, pos):
        return step(params, cache, token, pos)

    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    lead = axes if len(axes) > 1 else axes[0]
    tok_spec = P(lead) if shard_batch else P()
    args = (pshapes, cshapes, tok, pos)
    shardings = (_named(mesh, pspecs), _named(mesh, cspecs),
                 NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()))
    return Workload(name=f"{cfg.name}×{shape.name}", fn=fn, args=args,
                    in_shardings=shardings,
                    static={"window": window, "shard_batch": shard_batch})


def build_workload(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                   **kw) -> Workload:
    if shape.kind == "train":
        return train_workload(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return prefill_workload(cfg, shape, mesh)
    return decode_workload(cfg, shape, mesh)
