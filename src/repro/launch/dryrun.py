import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings=…).lower(*ShapeDtypeStructs).compile()`` on the
16×16 single-pod mesh and the 2×16×16 multi-pod mesh.  No arrays are ever
allocated.  For each combination we record:

* ``compiled.memory_analysis()``  — per-device bytes (does it fit 16 GB?)
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
* collective bytes parsed from the optimized HLO (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute operand sizes)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_NAMES, SHAPES, get_config, get_shape
from repro.launch.build import build_workload
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "e4m3": 1, "e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of one 'dtype[dims]' or a (tuple, of, them)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO.

    The result shape is what lands on the wire to first order (all-reduce:
    operand==result; all-gather: result is the gathered buffer; the
    (k-1)/k ring factor is folded into the roofline's link-bandwidth term).
    Counts are whole-program (all devices' instruction stream is SPMD — the
    per-device figure is bytes/num_partitions for sharded ops, reported
    as-is and normalised by the roofline derivation).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match '%name = <shape> <op>(' with op a collective (start or fused)
        for kind in _COLLECTIVES:
            if re.search(rf"\)?\s{kind}(-start|-done)?\(", s) or \
               re.search(rf"=\s*\S+\s+{kind}(-start)?\(", s):
                if f"{kind}-done" in s:
                    continue  # avoid double count of async pairs
                eq = s.split("=", 1)
                if len(eq) != 2:
                    continue
                rhs = eq[1]
                shape_part = rhs.split(kind)[0]
                out[kind] += _shape_bytes(shape_part)
                counts[kind] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["ops"] = sum(counts.values())
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            trainer: str = "auto", gar: str = "multi_bulyan",
            verbose: bool = True, hlo_out: Optional[str] = None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    kw = {}
    if shape.kind == "train":
        kw = {"trainer": trainer, "gar": gar}
    wl = build_workload(cfg, shape, mesh, **kw)
    with mesh:
        jitted = jax.jit(wl.fn, in_shardings=wl.in_shardings)
        lowered = jitted.lower(*wl.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # trip-count-corrected per-device dot FLOPs + collective bytes
    # (cost_analysis counts while bodies once — see launch/hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze
    corrected = analyze(hlo)
    if hlo_out:
        with open(hlo_out, "w") as fh:
            fh.write(hlo)

    n_dev = mesh.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "trainer": wl.static.get("trainer", shape.kind),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collectives": coll,
        "corrected": {k: float(v) for k, v in corrected.items()},
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            result[attr] = int(v)
    if verbose:
        arg_gb = result.get("argument_size_in_bytes", 0) / 1e9
        tmp_gb = result.get("temp_size_in_bytes", 0) / 1e9
        print(f"[dryrun] {arch:24s} {shape_name:12s} {result['mesh']:8s} "
              f"OK  lower={t_lower:5.1f}s compile={t_compile:6.1f}s "
              f"args={arg_gb:7.2f}GB temp={tmp_gb:7.2f}GB "
              f"flops={corrected.get('flops', 0):.3e} "
              f"coll={corrected.get('coll.total', 0)/1e9:8.2f}GB",
              flush=True)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) combination")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--trainer", default="auto",
                    choices=("auto", "stacked", "stream_block", "stream_global"))
    ap.add_argument("--gar", default="multi_bulyan")
    ap.add_argument("--json", default=None, help="append results to this file")
    ap.add_argument("--hlo-out", default=None)
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        combos = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape (or --all) required")
        combos = [(args.arch, args.shape)]

    results, failures = [], []
    for arch, shape in combos:
        try:
            results.append(run_one(arch, shape, multi_pod=args.multi_pod,
                                   trainer=args.trainer, gar=args.gar,
                                   hlo_out=args.hlo_out))
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            print(f"[dryrun] {arch:24s} {shape:12s} FAIL {e!r}", flush=True)
            traceback.print_exc()
    if args.json:
        existing = []
        if os.path.exists(args.json):
            with open(args.json) as fh:
                existing = json.load(fh)
        with open(args.json, "w") as fh:
            json.dump(existing + results, fh, indent=1)
    print(f"[dryrun] {len(results)} OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
