"""Byzantine campaign simulator driver (repro.sim).

Runs a declarative attack-schedule campaign through the sim engine and
writes a JSON/CSV report with plan-level telemetry (per-worker selection,
Krum score spectra, honest-mean deviation, suspicion EMA).

Phases are ``STEPS=ATTACK_SPEC`` (attack specs take parameter overrides
after a colon), optionally with ``@f=K`` to lower the effective number of
byzantine workers for that phase:

  PYTHONPATH=src python -m repro.launch.simulate \\
      --gar multi_bulyan --workers 11 --f 2 \\
      --phase 20=none --phase 20=little_is_enough:z=4.0 \\
      --report campaign.json --csv campaign.csv

``--smoke`` runs the acceptance campaign from ISSUE/DESIGN §8 — a 40-step
``no_attack -> little_is_enough`` switch — for the selected robust rule AND
for plain averaging, asserts the paper's story on the traces (robust rule:
bounded post-switch honest-mean deviation, ≈ 0 byzantine selection mass;
averaging: dragged far off the honest mean), and exits non-zero otherwise.
It then sweeps codec × attack (ISSUE-4): short switch campaigns over the
``repro.comm`` wire formats — including a wire-level attack — asserting
the robust rule stays bounded on the *decoded* stack, per-phase
``WireStats`` land in the ``sim.campaign.v1`` summary, and wire bytes are
strictly ordered fp32 > bf16 > qsgd int8.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Tuple

from repro.sim import (AttackPhase, AttackSchedule, Scenario, report,
                       run_campaign, switch_scenario)

# --smoke acceptance thresholds (see tests/test_sim.py for the mirrored
# in-suite assertion): the robust rule must keep its aggregate within 2x of
# the honest-gradient scale with < 2% byzantine selection mass; averaging
# under little_is_enough:z=4 is fully captured (byzantine mass = its f/n
# share), sits >= 2x the robust rule's honest-mean deviation (measured
# ~2.4x at seed 0) and stops making loss progress.
ROBUST_DEV_MAX = 2.0
ROBUST_BYZ_MASS = 0.02
AVERAGE_DEV_FACTOR = 2.0
AVERAGE_CAPTURE = 0.75          # of its f/n share
AVERAGE_LOSS_MARGIN = 0.2


def parse_phase(text: str) -> AttackPhase:
    """``STEPS=SPEC[@f=K][@stale=W1+W2...]`` -> AttackPhase."""
    steps_s, eq, rest = text.partition("=")
    if not eq:
        raise ValueError(f"bad --phase {text!r} (want STEPS=ATTACK_SPEC)")
    try:
        steps = int(steps_s)
    except ValueError:
        raise ValueError(f"bad step count in --phase {text!r}") from None
    spec, f_eff, stale = rest, None, ()
    if "@" in rest:
        spec, *mods = rest.split("@")
        for mod in mods:
            k, _, v = mod.partition("=")
            if k == "f":
                f_eff = int(v)
            elif k == "stale":
                stale = tuple(int(w) for w in v.split("+") if w)
            else:
                raise ValueError(f"unknown phase modifier {mod!r} in "
                                 f"--phase {text!r}")
    return AttackPhase(steps=steps, attack=spec, f=f_eff,
                       stale_workers=stale)


def _smoke(args) -> int:
    """Acceptance campaign: robust rule vs averaging across the switch."""
    import numpy as np

    results = {}
    for gar in (args.gar, "average"):
        sc = switch_scenario(
            gar, pre=20, post=20, n_workers=args.workers, f=args.f,
            trainer=args.trainer, use_pallas=args.use_pallas,
            seed=args.seed)
        results[gar] = run_campaign(sc, verbose=True)
        if args.report:
            stem, dot, ext = args.report.rpartition(".")
            path = f"{stem}.{gar}.{ext}" if dot else f"{args.report}.{gar}"
            print(f"[sim] report -> {report.write_json(path, results[gar])}")

    post = slice(20, 40)
    rb, av = results[args.gar].trace, results["average"].trace
    rb_dev = float(np.mean(rb["honest_dev"][post]))
    rb_dev_max = float(np.max(rb["honest_dev"][post]))
    rb_byz = float(np.mean(rb["byz_mass"][post]))
    av_dev = float(np.mean(av["honest_dev"][post]))
    av_byz = float(np.mean(av["byz_mass"][post]))
    share = args.f / args.workers
    print(f"[sim] --smoke post-switch: {args.gar} honest_dev "
          f"mean={rb_dev:.3f} max={rb_dev_max:.3f} byz_mass={rb_byz:.4f}; "
          f"average honest_dev mean={av_dev:.3f} byz_mass={av_byz:.4f}")
    problems: List[str] = []
    if rb_dev_max > ROBUST_DEV_MAX:
        problems.append(f"{args.gar} post-switch honest_dev max {rb_dev_max:.3f} "
                        f"> {ROBUST_DEV_MAX}")
    if rb_byz > ROBUST_BYZ_MASS:
        problems.append(f"{args.gar} post-switch byzantine selection mass "
                        f"{rb_byz:.4f} > {ROBUST_BYZ_MASS}")
    if av_dev < AVERAGE_DEV_FACTOR * rb_dev:
        problems.append(f"average honest_dev {av_dev:.3f} not >= "
                        f"{AVERAGE_DEV_FACTOR}x {args.gar}'s {rb_dev:.3f}")
    if av_byz < AVERAGE_CAPTURE * share:
        problems.append(f"average byzantine mass {av_byz:.4f} below "
                        f"{AVERAGE_CAPTURE}x its f/n share {share:.3f} — "
                        f"attack did not engage?")
    rb_final = float(rb["loss"][-1])
    av_final = float(av["loss"][-1])
    if av_final < rb_final + AVERAGE_LOSS_MARGIN:
        problems.append(f"average final loss {av_final:.3f} not >= "
                        f"{args.gar}'s {rb_final:.3f} + "
                        f"{AVERAGE_LOSS_MARGIN} — averaging kept learning "
                        f"under the attack")
    problems += _smoke_codec_sweep(args)
    for p in problems:
        print(f"[sim] SMOKE FAILED: {p}", file=sys.stderr)
    if not problems:
        print("[sim] --smoke OK: robust rule bounded, byzantine rows "
              "deselected, averaging dragged off the honest mean; codec "
              "sweep bounded with ordered wire bytes")
    return 1 if problems else 0


# codec × attack sweep grid: a gradient-space attack that must survive the
# quantized wire + a wire-format attack that only exists because of it
SWEEP_CODECS = ("fp32", "bf16", "qsgd:bits=8")
SWEEP_ATTACKS = ("little_is_enough:z=4.0", "scale_poison:gain=50")
SWEEP_STEPS = 6                 # per phase — selection stabilises in 2-3


def _smoke_codec_sweep(args) -> List[str]:
    """Short codec × attack switch campaigns on the robust rule."""
    import numpy as np

    problems: List[str] = []
    bytes_per_worker = {}
    for codec in SWEEP_CODECS:
        for attack in SWEEP_ATTACKS:
            if attack.startswith("scale_poison") and codec == "fp32":
                # the identity wire has no scale sidecar — the attack
                # degenerates to payload scaling; skip the redundant cell
                continue
            sc = switch_scenario(
                args.gar, pre=SWEEP_STEPS, post=SWEEP_STEPS, attack=attack,
                n_workers=args.workers, f=args.f, trainer=args.trainer,
                use_pallas=args.use_pallas, seed=args.seed, codec=codec)
            r = run_campaign(sc)
            post = slice(SWEEP_STEPS, 2 * SWEEP_STEPS)
            byz = float(np.mean(r.trace["byz_mass"][post]))
            dev = float(np.max(r.trace["honest_dev"][post]))
            wire = r.summary.get("wire")
            print(f"[sim] codec sweep {codec} × {attack}: honest_dev "
                  f"max={dev:.3f} byz_mass={byz:.4f} "
                  f"bytes/worker={wire and wire['bytes_per_worker']}")
            tag = f"codec {codec} × {attack}"
            if wire is None or \
                    any("wire" not in ph for ph in r.summary["phases"]):
                problems.append(f"{tag}: WireStats missing from the "
                                "campaign summary phases")
                continue
            bytes_per_worker[codec] = wire["bytes_per_worker"]
            if dev > ROBUST_DEV_MAX:
                problems.append(f"{tag}: post-switch honest_dev {dev:.3f} "
                                f"> {ROBUST_DEV_MAX}")
            if byz > ROBUST_BYZ_MASS:
                problems.append(f"{tag}: byzantine selection mass "
                                f"{byz:.4f} > {ROBUST_BYZ_MASS}")
    order = [bytes_per_worker.get(c, 0) for c in SWEEP_CODECS]
    if not order[0] > order[1] > order[2] > 0:
        problems.append(
            f"wire bytes not strictly ordered fp32 > bf16 > qsgd int8: "
            f"{dict(zip(SWEEP_CODECS, order))}")
    return problems


# --smoke --async-tau churn acceptance: the same no_attack -> attack
# switch, but every round goes through the real bounded-staleness buffer
# (repro.serve) with two honest stragglers delivering only every
# ``stale_period`` rounds.  stale_period > tau+1 makes their slots
# overstale between deliveries, so the campaign actually exercises the
# effective-f haircut — asserted via the n_overstale telemetry — while
# the robust rule must hold the same deviation/selection-mass thresholds
# as the synchronous smoke.
ASYNC_SMOKE_STEPS = 8
ASYNC_STALE = (9, 10)           # honest stragglers (byz rows come first)


def _smoke_async(args) -> int:
    import numpy as np

    sched = AttackSchedule((
        AttackPhase(steps=ASYNC_SMOKE_STEPS, attack="none"),
        AttackPhase(steps=ASYNC_SMOKE_STEPS,
                    attack="little_is_enough:z=4.0",
                    stale_workers=ASYNC_STALE)))
    sc = Scenario(name="async-churn", schedule=sched, gar=args.gar,
                  n_workers=args.workers, f=args.f, seed=args.seed,
                  use_pallas=args.use_pallas,
                  async_tau=args.async_tau, stale_period=args.stale_period)
    r = run_campaign(sc, verbose=True)
    if args.report:
        print(f"[sim] report -> {report.write_json(args.report, r)}")

    post = slice(ASYNC_SMOKE_STEPS, 2 * ASYNC_SMOKE_STEPS)
    dev = float(np.max(r.trace["honest_dev"][post]))
    byz = float(np.mean(r.trace["byz_mass"][post]))
    n_over_max = float(np.max(r.trace["n_overstale"]))
    f_def_min = float(np.min(r.trace["f_defended"]))
    reused = float(np.sum(r.trace["plan_reused"]))
    print(f"[sim] async churn (tau={args.async_tau}, "
          f"period={args.stale_period}): honest_dev max={dev:.3f} "
          f"byz_mass={byz:.4f} n_overstale max={n_over_max:.0f} "
          f"f_defended min={f_def_min:.0f} plans_reused={reused:.0f}")
    problems: List[str] = []
    if dev > ROBUST_DEV_MAX:
        problems.append(f"async churn honest_dev max {dev:.3f} > "
                        f"{ROBUST_DEV_MAX}")
    if byz > ROBUST_BYZ_MASS:
        problems.append(f"async churn byzantine selection mass {byz:.4f} "
                        f"> {ROBUST_BYZ_MASS}")
    if args.stale_period > args.async_tau + 1 and n_over_max < 1:
        problems.append(
            f"stale_period {args.stale_period} > tau+1 "
            f"{args.async_tau + 1} but no overstale slot was ever "
            "charged — the churn never reached the buffer")
    if n_over_max >= 1 and f_def_min >= args.f:
        problems.append("overstale slots were charged but f_defended "
                        "never dropped below the contract — the haircut "
                        "is not wired")
    for p in problems:
        print(f"[sim] SMOKE FAILED: {p}", file=sys.stderr)
    if not problems:
        print("[sim] --smoke --async-tau OK: churn replayed through the "
              "real buffer, overstale slots haircut the budget, robust "
              "rule stayed bounded with byzantine rows deselected")
    return 1 if problems else 0


def _hier_fields(args) -> dict:
    """``--hier SPEC`` -> the Scenario hier_* field dict (empty when unset)."""
    if not args.hier:
        return {}
    from repro.hier import GroupConfig
    gc = GroupConfig.from_spec(args.hier, rule=args.gar)
    return dict(hier_g=gc.g, hier_rule=gc.rule, hier_outer_rule=gc.outer_rule,
                hier_f_inner=gc.f_inner, hier_f_outer=gc.f_outer,
                hier_enforce=gc.enforce_budget)


# --smoke --hier poisoned-subtree acceptance: the adversary owns a whole
# contiguous group (rows 0..f-1 = group 0 under the contiguous balanced
# assignment).  Three campaigns tell the story end to end:
#   defended  — within-budget hierarchy, byzantine rows deselected inside
#               their groups exactly like the flat rule;
#   captured  — deliberately under-provisioned inner budget (f_inner=1
#               against a fully colluding group, enforce=0) with a plain
#               averaging outer level: group 0's aggregate is byzantine and
#               its full 1/n_groups mass flows into the update;
#   rejected  — same under-provisioned inner budget, but a robust outer
#               rule (krum over 5 group aggregates, f_outer=1) throws the
#               captured group's aggregate away: byzantine mass back to ≈ 0,
#               group 0 gets zero outer selection mass under attack, and its
#               suspicion EMA rises every attacked step.  (Krum's one-hot
#               selection leaves most *honest* groups unselected each step
#               too, so an argmax-suspicion check would be flaky — the
#               deterministic signature is zero mass + monotone suspicion.)
HIER_SMOKE_STEPS = 6
HIER_CAPTURE_MIN = 0.2          # captured byz mass ≥ this (its share is 1/3)


def _smoke_hier(args) -> int:
    import numpy as np

    def run(name, **kw):
        sched = AttackSchedule((
            AttackPhase(steps=HIER_SMOKE_STEPS, attack="none"),
            AttackPhase(steps=HIER_SMOKE_STEPS,
                        attack="little_is_enough:z=4.0")))
        sc = Scenario(name=name, schedule=sched, gar=args.gar,
                      trainer=args.trainer, use_pallas=args.use_pallas,
                      seed=args.seed, **kw)
        r = run_campaign(sc, verbose=True)
        if args.report:
            stem, dot, ext = args.report.rpartition(".")
            path = f"{stem}.{name}.{ext}" if dot else f"{args.report}.{name}"
            print(f"[sim] report -> {report.write_json(path, r)}")
        return r

    post = slice(HIER_SMOKE_STEPS, 2 * HIER_SMOKE_STEPS)
    problems: List[str] = []

    defended = run("hier-defended", n_workers=21, f=1, hier_g=7)
    byz = float(np.mean(defended.trace["byz_mass"][post]))
    dev = float(np.max(defended.trace["honest_dev"][post]))
    print(f"[sim] hier defended: honest_dev max={dev:.3f} "
          f"byz_mass={byz:.4f}")
    if byz > ROBUST_BYZ_MASS:
        problems.append(f"hier-defended byz_mass {byz:.4f} > "
                        f"{ROBUST_BYZ_MASS}")
    if dev > ROBUST_DEV_MAX:
        problems.append(f"hier-defended honest_dev max {dev:.3f} > "
                        f"{ROBUST_DEV_MAX}")
    if "group_selection" not in defended.trace:
        problems.append("hier-defended trace missing group_selection")

    captured = run("hier-captured", n_workers=21, f=7, hier_g=7,
                   hier_f_inner=1, hier_f_outer=0, hier_enforce=False)
    byz = float(np.mean(captured.trace["byz_mass"][post]))
    print(f"[sim] hier captured (under-provisioned inner): "
          f"byz_mass={byz:.4f} (group share 1/3)")
    if byz < HIER_CAPTURE_MIN:
        problems.append(f"hier-captured byz_mass {byz:.4f} < "
                        f"{HIER_CAPTURE_MIN} — the poisoned subtree "
                        "should have flowed through the averaging outer")

    rejected = run("hier-rejected", n_workers=35, f=7, hier_g=7,
                   hier_f_inner=1, hier_f_outer=1, hier_outer_rule="krum",
                   hier_enforce=False)
    byz = float(np.mean(rejected.trace["byz_mass"][post]))
    gsel0 = float(np.mean(rejected.trace["group_selection"][post, 0]))
    gsusp0 = rejected.trace["group_suspicion"][post, 0]
    print(f"[sim] hier rejected (robust outer): byz_mass={byz:.4f} "
          f"group0_selection={gsel0:.4f} "
          f"group0_suspicion={np.round(gsusp0, 3).tolist()}")
    if byz > ROBUST_BYZ_MASS:
        problems.append(f"hier-rejected byz_mass {byz:.4f} > "
                        f"{ROBUST_BYZ_MASS} — krum outer should drop the "
                        "captured group aggregate")
    if gsel0 > ROBUST_BYZ_MASS:
        problems.append(f"hier-rejected group 0 outer selection mass "
                        f"{gsel0:.4f} > {ROBUST_BYZ_MASS} — the poisoned "
                        "subtree's aggregate should never be picked")
    if not np.all(np.diff(gsusp0) > 0):
        problems.append(f"hier-rejected group 0 suspicion not strictly "
                        f"rising under attack: {gsusp0.tolist()}")

    for p in problems:
        print(f"[sim] SMOKE FAILED: {p}", file=sys.stderr)
    if not problems:
        print("[sim] --smoke --hier OK: within-budget hierarchy bounded, "
              "under-provisioned subtree captured through an averaging "
              "outer, robust outer rejects it with group 0 at zero "
              "selection mass and rising suspicion")
    return 1 if problems else 0


def main(argv: Optional[Tuple[str, ...]] = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="run + assert the acceptance switch campaign")
    ap.add_argument("--phase", action="append", default=[],
                    metavar="STEPS=SPEC[@f=K][@stale=W1+W2]",
                    help="append a schedule phase (repeatable)")
    ap.add_argument("--gar", default="multi_bulyan")
    ap.add_argument("--workers", type=int, default=11)
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--trainer", default="stacked",
                    choices=("stacked", "stream_block", "stream_global"))
    ap.add_argument("--hier", default=None, metavar="SPEC",
                    help="two-level grouped aggregation (repro.hier), e.g. "
                         "'g=7' or 'g=7,f_inner=1,f_outer=0,enforce=0'; "
                         "with --smoke runs the poisoned-subtree "
                         "acceptance campaigns instead of the flat switch")
    ap.add_argument("--transform", action="append", default=[],
                    help="pre-aggregation transform spec (repeatable), "
                         "e.g. worker_momentum:beta=0.9")
    ap.add_argument("--codec", default=None,
                    help="wire codec spec (repro.comm), e.g. qsgd:bits=8; "
                         "enables wire attacks (scale_poison, payload_flip) "
                         "in --phase specs and per-phase WireStats in the "
                         "report")
    ap.add_argument("--async-tau", type=int, default=0, dest="async_tau",
                    help="bounded-staleness async aggregation (repro.serve): "
                         "buffer slots older than TAU rounds are overstale "
                         "and haircut the byzantine budget (0 = sync "
                         "lockstep); with --smoke runs the async churn "
                         "acceptance campaign")
    ap.add_argument("--stale-period", type=int, default=4,
                    dest="stale_period",
                    help="async churn: stale workers deliver every PERIOD "
                         "rounds (default 4)")
    ap.add_argument("--noniid-alpha", type=float, default=0.0,
                    help="Dirichlet alpha for non-IID worker data "
                         "(0 = i.i.d.)")
    ap.add_argument("--n-domains", type=int, default=4)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--report", default=None, help="JSON report path")
    ap.add_argument("--csv", default=None, help="CSV trace path")
    ap.add_argument("--name", default="campaign")
    args = ap.parse_args(argv)

    if args.smoke:
        if args.hier:
            return _smoke_hier(args)
        if args.async_tau > 0:
            return _smoke_async(args)
        return _smoke(args)

    if not args.phase:
        ap.error("need at least one --phase (or --smoke)")
    from repro.sim.scenario import DataConfig
    sc = Scenario(
        name=args.name,
        schedule=AttackSchedule(tuple(parse_phase(p) for p in args.phase)),
        n_workers=args.workers, f=args.f, gar=args.gar,
        transforms=tuple(args.transform), codec=args.codec,
        trainer=args.trainer, use_pallas=args.use_pallas,
        data=DataConfig(noniid_alpha=args.noniid_alpha,
                        n_domains=args.n_domains),
        per_worker_batch=args.per_worker_batch, seq=args.seq, lr=args.lr,
        seed=args.seed, async_tau=args.async_tau,
        stale_period=args.stale_period, **_hier_fields(args))
    print(f"[sim] {sc.name}: {sc.schedule.describe()} gar={sc.gar} "
          f"n={sc.n_workers} f={sc.f} trainer={sc.trainer}")
    result = run_campaign(sc, ckpt_dir=args.ckpt_dir, resume=args.resume,
                          verbose=True)
    if not result.summary:  # resume found every phase already completed
        print(f"[sim] nothing left to run: checkpoint already covers all "
              f"{sc.schedule.total_steps} steps")
        return 0
    s = result.summary
    print(f"[sim] done: {s['total_steps']} steps, final loss "
          f"{s['final_loss']:.4f}, honest_dev max "
          f"{s.get('honest_dev_max', float('nan')):.3f}, byz_mass mean "
          f"{s.get('byz_mass_mean', float('nan')):.4f} "
          f"({result.wall_s:.1f}s)")
    if args.report:
        print(f"[sim] report -> {report.write_json(args.report, result)}")
    if args.csv:
        print(f"[sim] trace  -> {report.write_csv(args.csv, result)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
