"""Closed-loop robust serving benchmark driver (repro.serve.loadgen).

One (mode × τ × f) sweep of the async bounded-staleness service against
the synchronous lockstep baseline, with the staleness accounting replayed
through the real gradient buffer:

  PYTHONPATH=src python -m repro.launch.serve_bench \\
      --workers 11 --f 2 --d 65536 --tau 1 2 4 --rounds 40 \\
      --json BENCH_serving.json

``--smoke`` shrinks to the CI grid (d=4096, 10 rounds, τ=1).  The JSON
(schema ``serving.v2``) is gated by ``benchmarks/validate_bench.py``:
async QPS must be strictly above sync on every (τ ≥ 1, f > 0) cell, and
every cell carries p50/p95/p99 round latency (the tail percentiles the
v1 schema's per-grid mean could not express).
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import List, Optional, Tuple

from repro.serve.loadgen import LoadConfig


def main(argv: Optional[Tuple[str, ...]] = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (d=4096, 10 rounds, tau=1)")
    ap.add_argument("--workers", type=int, default=11)
    ap.add_argument("--f", type=int, nargs="+", default=[0, 2])
    ap.add_argument("--d", type=int, default=65536)
    ap.add_argument("--tau", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--microbatch", type=int, default=8)
    ap.add_argument("--gar", default="multi_bulyan")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mean-ms", type=float, default=20.0)
    ap.add_argument("--stragglers", type=int, default=2)
    ap.add_argument("--straggler-mult", type=float, default=4.0)
    ap.add_argument("--deadline-quantile", type=float, default=0.9)
    ap.add_argument("--json", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    from benchmarks import serving as SB
    if args.smoke:
        rows: List[str] = []
        SB.run(rows, smoke=True, json_path=args.json)
        print("\n".join(rows))
        print(f"[serve_bench] --smoke -> {args.json}")
        return 0

    from repro.serve.loadgen import run_closed_loop
    base = LoadConfig(n=args.workers, d=args.d, rounds=args.rounds,
                      microbatch=args.microbatch, gar=args.gar,
                      seed=args.seed, mean_ms=args.mean_ms,
                      stragglers=args.stragglers,
                      straggler_mult=args.straggler_mult,
                      deadline_quantile=args.deadline_quantile)
    rows = (f"{args.gar}[sync]", f"{args.gar}[async]")
    results = {r: {} for r in rows}
    for f in args.f:
        for tau in args.tau:
            cfg = dataclasses.replace(base, tau=tau, f=f)
            for mode, row in zip(("sync", "async"), rows):
                cell = run_closed_loop(cfg, mode)
                results[row][f"tau={tau},f={f}"] = cell
                print(f"[serve_bench] {row} tau={tau} f={f}: "
                      f"qps={cell['qps']:.1f} "
                      f"round p50={cell['round_us_p50']:.0f}us "
                      f"p95={cell['round_us_p95']:.0f}us "
                      f"p99={cell['round_us_p99']:.0f}us "
                      f"stale_rounds={cell['stale_rounds']} "
                      f"f_defended={cell['f_defended_mean']:.1f}")
    meta = {"n": base.n, "d": base.d, "rounds": base.rounds,
            "microbatch": base.microbatch, "mean_ms": base.mean_ms,
            "stragglers": base.stragglers,
            "straggler_mult": base.straggler_mult,
            "deadline_quantile": base.deadline_quantile}
    SB.write_json(results, meta, args.json)
    print(f"[serve_bench] -> {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
