"""Optimized-HLO cost analyzer with while-loop trip-count correction.

``compiled.cost_analysis()`` counts each while (scan) body ONCE —
demonstrably: a scanned 8×matmul reports 1 matmul of FLOPs.  Since our
models lower layer stacks, attention q-chunks, ssm chunks and the streaming
backward as scans, raw cost_analysis under-reports by 1-2 orders of
magnitude.  XLA leaves the ground truth in the text though: every while op
carries ``backend_config={"known_trip_count":{"n":...}}``.

This module re-derives, from ``compiled.as_text()``:

* dot FLOPs (2 · |result| · |contraction|), trip-count-weighted;
* collective bytes per kind (result-shape bytes — the per-device program's
  local shapes, i.e. per-device wire bytes to first order),
  trip-count-weighted;
* per-kind/per-op counts.

Computation graph handling: ``while`` bodies/conditions are multiplied by
their trip count; ``fusion``/``call``/``conditional`` callees are counted at
multiplicity 1 per call site.  Each computation is resolved once
(memoised), so deep nesting stays linear.
"""
from __future__ import annotations

import collections
import json
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_CALLSITE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=%?([\w\.\-{}, %]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """(elements, bytes) of the FIRST array shape in the string."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0, 0
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0, 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES[dt]


def _all_shapes_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and "->" in line and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    comps["__entry__"] = cur
                continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                cur.lines.append(line)
    return comps


def _dot_flops(line: str, shapes: Dict[str, str]) -> int:
    """2 · |result| · |contraction| for a dot line."""
    eq = line.split("=", 1)
    if len(eq) != 2:
        return 0
    rhs = eq[1].strip()
    result_elems, _ = _shape_elems_bytes(rhs.split(" dot(")[0])
    # contraction size from lhs operand shape + lhs_contracting_dims
    ops_m = re.search(r"dot\(([^)]*)\)", rhs)
    cdim_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    if not ops_m or not cdim_m:
        return 2 * result_elems  # degenerate
    lhs_name = ops_m.group(1).split(",")[0].strip().lstrip("%")
    lhs_shape = shapes.get(lhs_name, "")
    dims = _shape_dims(lhs_shape)
    contraction = 1
    if cdim_m.group(1):
        for i in cdim_m.group(1).split(","):
            i = int(i)
            if i < len(dims):
                contraction *= dims[i]
    return 2 * result_elems * contraction


def analyze(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)
    # symbol tables (op name -> result type string) per computation
    tables: Dict[str, Dict[str, str]] = {}
    for cname, comp in comps.items():
        tab = {}
        for line in comp.lines:
            m = _DEF_RE.match(line)
            if m:
                tab[m.group(1)] = m.group(2)
        tables[cname] = tab

    memo: Dict[str, Dict[str, float]] = {}

    def resolve(cname: str) -> Dict[str, float]:
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        out = collections.defaultdict(float)
        memo[cname] = out  # guard (recursion on malformed graphs)
        if comp is None:
            return out
        tab = tables[cname]
        for line in comp.lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            # ---- while: multiply body+cond by trip count
            if re.search(r"\bwhile\(", rhs):
                trip_m = _TRIP_RE.search(rhs)
                trip = int(trip_m.group(1)) if trip_m else 1
                body_m = re.search(r"body=%?([\w\.\-]+)", rhs)
                cond_m = re.search(r"condition=%?([\w\.\-]+)", rhs)
                for ref, mult in ((body_m, trip), (cond_m, trip)):
                    if ref:
                        sub = resolve(ref.group(1))
                        for k, v in sub.items():
                            out[k] += mult * v
                continue
            # ---- fusion / call / reduce etc: callees at multiplicity 1
            for attr in ("calls", "to_apply"):
                am = re.search(rf"{attr}=%?([\w\.\-]+)", rhs)
                if am:
                    sub = resolve(am.group(1))
                    for k, v in sub.items():
                        out[k] += v
            cm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if cm:  # conditional: worst case = max over branches (take sum/|b|? use max)
                branches = [b.strip().lstrip("%") for b in cm.group(1).split(",")]
                subs = [resolve(b) for b in branches]
                keys = set().union(*[s.keys() for s in subs]) if subs else set()
                for k in keys:
                    out[k] += max(s.get(k, 0.0) for s in subs)
            # ---- local costs
            if " dot(" in rhs:
                out["flops"] += _dot_flops(line, tab)
                out["dot_ops"] += 1
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(-start)?\(", rhs):
                    shape_part = rhs.split(kind)[0]
                    out[f"coll.{kind}"] += _all_shapes_bytes(shape_part)
                    out["coll.total"] += _all_shapes_bytes(shape_part)
                    out["coll.ops"] += 1
                    break
        return out

    entry = comps.get("__entry__")
    result = dict(resolve(entry.name)) if entry else {}
    return result


def analyze_file(path: str) -> Dict[str, float]:
    with open(path) as fh:
        return analyze(fh.read())


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze_file(sys.argv[1]), indent=1))
