"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run overrides the
device count via XLA_FLAGS before first jax init while tests/benches must
see the single real CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """("data", "model") mesh over whatever host devices exist.

    The device count is factored into the most-square (data, model) split
    with data <= model — so the forced-8-device CPU mesh
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) becomes 2×4
    and exercises *both* the worker-axis and the d-axis sharding of the
    mesh-native aggregation path (DESIGN.md §10); a single real device
    degenerates to 1×1.
    """
    n = len(jax.devices())
    data = 1
    while n % (data * 2) == 0 and data * 2 <= n // (data * 2):
        data *= 2
    return jax.make_mesh((data, n // data), ("data", "model"))


def data_parallel_size(mesh: Mesh) -> int:
    """Number of byzantine-game workers the mesh supports (pod×data)."""
    size = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        size *= mesh.shape["pod"]
    return size
