"""Observability report driver: validate + digest obs.v1 snapshots.

Reads the snapshot ``launch/train.py --obs`` (or the sim engine's
``CampaignResult.obs``) wrote, schema-validates it, and prints a compact
digest: counters, gauges, histogram mass, the span-ring tail.  With
``--kernels`` it additionally runs the Pallas stats/apply kernels at a
small (n, d) grid under a :class:`repro.obs.KernelProfiler` and reports
each launch's chosen ``d_tile`` / grid depth next to the
``analysis/vmem.py`` prediction (and XLA's measured temp bytes where the
backend exposes them).

Usage:
  PYTHONPATH=src python -m repro.launch.obs_report \\
      --snapshot obs_snapshot.json [--trace obs_trace.json] \\
      [--validate] [--kernels]

``--validate`` exits 1 on any schema problem — CI runs it on the smoke
snapshot; ``--trace`` additionally checks the Chrome-trace file parses
and counts its events.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Tuple

from repro import obs as OBS

#: (n, d) grid for --kernels: one shallow and one multi-step launch per
#: kernel, small enough for CPU interpret mode
KERNEL_POINTS = ((11, 4096), (15, 65536))


def _digest(snap) -> None:
    m = snap.get("metrics") or {}
    print(f"[obs_report] schema={snap.get('schema')} "
          f"meta={json.dumps(snap.get('meta', {}), sort_keys=True)}")
    for name, v in sorted((m.get("counters") or {}).items()):
        print(f"[obs_report] counter {name} = {v:g}")
    for name, v in sorted((m.get("gauges") or {}).items()):
        flat = v if isinstance(v, list) else [v]
        if len(flat) > 4:
            print(f"[obs_report] gauge {name} = "
                  f"[{flat[0]:.4g} .. {flat[-1]:.4g}] ({len(flat)} slots)")
        else:
            print(f"[obs_report] gauge {name} = "
                  f"{[round(float(x), 4) for x in flat]}")
    for name, h in sorted((m.get("hists") or {}).items()):
        total = sum(h["counts"])
        print(f"[obs_report] hist {name}: {total} obs over "
              f"{len(h['edges']) + 1} buckets, counts={h['counts']}")
    recs = (snap.get("trace") or {}).get("records", [])
    print(f"[obs_report] span ring: {len(recs)} records retained")
    for r in recs[-8:]:
        print(f"[obs_report]   seq={r['seq']:>5} round={r['round']:>5} "
              f"{r['phase']:<12} payload={r['payload']:.4g}")
    sv = snap.get("serve")
    if sv:
        print(f"[obs_report] serve: rounds={sv.get('rounds')} "
              f"round_us p50/p95/p99 = "
              f"{sv['round_us']['p50']:.0f}/{sv['round_us']['p95']:.0f}/"
              f"{sv['round_us']['p99']:.0f}")


def _kernel_report(points: Tuple[Tuple[int, int], ...]) -> None:
    for rec in OBS.profile_points(points):
        pred = rec["vmem_predicted"]
        meas = rec["vmem_measured"]
        print(f"[obs_report] kernel {rec['kernel']:<15} "
              f"n={rec['n']:<4} d={rec['d']:<8} "
              f"d_tile={rec['d_tile']:<6} macro={rec['macro_tile']:<6} "
              f"grid={rec['grid_steps']:<3} "
              f"vmem_pred={'-' if pred is None else pred} "
              f"vmem_meas={'-' if meas is None else meas} "
              f"over_budget={rec['over_budget']}")


def main(argv: Optional[Tuple[str, ...]] = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--snapshot", default="obs_snapshot.json",
                    help="obs.v1 snapshot to digest")
    ap.add_argument("--trace", default=None,
                    help="Chrome-trace JSON to check (optional)")
    ap.add_argument("--validate", action="store_true",
                    help="exit 1 on any schema problem")
    ap.add_argument("--kernels", action="store_true",
                    help="profile the Pallas kernel launch configs at a "
                         "small (n, d) grid (runs the real kernels)")
    args = ap.parse_args(argv)

    problems = []
    try:
        with open(args.snapshot) as fh:
            snap = json.load(fh)
    except FileNotFoundError:
        problems.append(f"{args.snapshot}: missing — run "
                        "`python -m repro.launch.train --obs` first")
        snap = None
    except json.JSONDecodeError as e:
        problems.append(f"{args.snapshot}: not valid JSON ({e})")
        snap = None
    if snap is not None:
        problems += [f"{args.snapshot}: {p}"
                     for p in OBS.validate_snapshot(snap)]
        _digest(snap)

    if args.trace:
        try:
            with open(args.trace) as fh:
                doc = json.load(fh)
            events = doc.get("traceEvents")
            if not isinstance(events, list) or not events:
                problems.append(f"{args.trace}: no traceEvents")
            else:
                n_dev = sum(1 for e in events if e.get("pid") == 1
                            and e.get("ph") == "X")
                print(f"[obs_report] trace: {len(events)} events "
                      f"({n_dev} device-logical) — open at "
                      "https://ui.perfetto.dev")
        except FileNotFoundError:
            problems.append(f"{args.trace}: missing")
        except json.JSONDecodeError as e:
            problems.append(f"{args.trace}: not valid JSON ({e})")

    if args.kernels:
        _kernel_report(KERNEL_POINTS)

    for p in problems:
        print(f"[obs_report] PROBLEM: {p}")
    if problems and args.validate:
        return 1
    if not problems:
        print("[obs_report] OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
