"""Serving driver: prefill a batch of prompts and decode new tokens.

Usage (reduced config on CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \\
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.dist.serving import generate
from repro import models as MD


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="chatglm3-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window size (0 = full cache)")
    ap.add_argument("--sample", default="greedy", choices=("greedy", "categorical"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.key(args.seed)
    params = MD.init_model(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[serve] arch={cfg.name} params={n_params:,}")

    kp, kt = jax.random.split(key)
    extra = {}
    if cfg.is_encdec:
        extra["frames"] = jax.random.normal(
            kp, (args.batch, cfg.n_frames, cfg.d_model), dtype=jnp.bfloat16)
    if cfg.n_patches:
        extra["prefix_embeds"] = jax.random.normal(
            kp, (args.batch, cfg.n_patches, cfg.d_model), dtype=jnp.bfloat16)
    prompt = jax.random.randint(kt, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    t0 = time.time()
    out = generate(params, cfg, prompt, args.new_tokens,
                   window=args.window, chunk_q=min(args.prompt_len, 512),
                   sample=args.sample,
                   key=None if args.sample == "greedy" else key,
                   extra_batch=extra or None)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"[serve] generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("[serve] first sequence:", out[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
