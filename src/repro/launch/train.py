"""End-to-end training driver.

Runs byzantine-robust training of a selectable architecture on the local
device(s).  On this CPU container it is used with reduced configs
(``--reduced``) and the ~100M example (examples/byzantine_training.py); on a
real TPU slice the same driver takes the production mesh path.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \\
      --steps 100 --workers 12 --f 2 --gar multi_bulyan --attack sign_flip
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import ARCH_NAMES, RobustConfig, get_config
from repro.data import lm_batches
from repro.dist import init_train_state, make_train_step, split_workers
from repro.dist.streaming import make_streaming_train_step
from repro import models as MD
from repro import obs as OBS
from repro.optim import make_optimizer, warmup_cosine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke-scale variant (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--workers", type=int, default=11)
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--gar", default="multi_bulyan")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route stats + bulyan apply through the Pallas "
                         "kernels (fused fast path; interpret mode on CPU)")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--codec", default=None,
                    help="wire codec spec (repro.comm): qsgd:bits=8, bf16, "
                         "signsgd, topk:frac=0.01[,ef=1], fp32; attacks "
                         "then hit the wire format (scale_poison, "
                         "payload_flip are wire-level attacks)")
    ap.add_argument("--trainer", default="stacked",
                    choices=("stacked", "stream_block", "stream_global"))
    ap.add_argument("--hier", default=None, metavar="SPEC",
                    help="two-level grouped aggregation (repro.hier): "
                         "'g=64' groups workers into ceil(n/64) groups, "
                         "robust-aggregates within each, then across the "
                         "group outputs — O(n*g) instead of O(n^2) "
                         "selection. Optional keys: rule=, outer_rule=, "
                         "f_inner=, f_outer=, enforce=0 "
                         "(DESIGN.md §11)")
    ap.add_argument("--mesh", default="none",
                    choices=("none", "host", "production"),
                    help="run aggregation mesh-native (DESIGN.md §10): "
                         "'host' factors the local devices into a "
                         "(data, model) mesh (use XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 to "
                         "exercise real sharding on CPU), 'production' "
                         "builds the 256-chip pod mesh")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke preset: --reduced, 3 steps, log every "
                         "step")
    ap.add_argument("--obs", action="store_true",
                    help="jit-safe runtime observability (DESIGN.md §14): "
                         "in-graph metrics registry + span ring in the "
                         "step, host wall-clock spans around it; drains "
                         "to an obs.v1 snapshot + a Perfetto/Chrome trace "
                         "after the run")
    ap.add_argument("--obs-json", default="obs_snapshot.json",
                    help="obs.v1 snapshot output path (with --obs)")
    ap.add_argument("--obs-trace", default="obs_trace.json",
                    help="Chrome-trace output path (with --obs); open at "
                         "https://ui.perfetto.dev")
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    if args.smoke:
        args.reduced = True
        args.steps = min(args.steps, 3)
        args.log_every = 1

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encdec and args.trainer != "stacked":
        raise SystemExit("enc-dec supports only the stacked trainer")

    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_host_mesh, make_production_mesh
        mesh = make_host_mesh() if args.mesh == "host" \
            else make_production_mesh()

    hier = None
    if args.hier:
        from repro.hier import GroupConfig
        hier = GroupConfig.from_spec(args.hier, rule=args.gar)
        budget = hier.budget(args.workers, args.f)
        print(f"[train] hier: {budget.n_groups} groups "
              f"{list(budget.group_sizes)} f_inner={budget.f_inner} "
              f"f_outer={budget.f_outer} inner={hier.rule} "
              f"outer={hier.resolve_outer_rule(budget)}")
    rcfg = RobustConfig(n_workers=args.workers, f=args.f, gar=args.gar,
                        use_pallas=args.use_pallas,
                        grouped=hier is not None)
    key = jax.random.key(args.seed)
    params = MD.init_model(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params:,} workers={args.workers} "
          f"f={args.f} gar={args.gar} attack={args.attack} "
          f"codec={args.codec} trainer={args.trainer} "
          f"pallas={args.use_pallas}")
    if mesh is not None:
        print(f"[train] mesh={args.mesh} shape={dict(mesh.shape)} "
              f"(worker axis sharded over "
              f"{'pod×data' if 'pod' in mesh.axis_names else 'data'}, "
              f"d over model)")
    if args.codec:
        if hier is not None:
            from repro.comm import hier_wire_stats
            for ws in hier_wire_stats(args.codec, params, n=args.workers,
                                      g=hier.g):
                print(f"[train] wire[{ws.level}]: {ws.n} x "
                      f"{ws.bytes_per_worker:,} B/step "
                      f"({ws.compression:.1f}x vs fp32)")
        else:
            from repro.comm import wire_stats
            ws = wire_stats(args.codec, params, n=args.workers)
            print(f"[train] wire: {ws.bytes_per_worker:,} B/worker/step "
                  f"({ws.compression:.1f}x vs fp32, "
                  f"{ws.chunks_per_worker} chunk(s) of {ws.chunk_bytes:,} B)")

    opt = make_optimizer(args.optimizer,
                         **({"momentum": 0.9} if args.optimizer == "sgd" else {}))
    # seeds the adaptive-attack feedback slot when --attack is adaptive and
    # the error-feedback residual when --codec has ef=1 (plain OptState
    # otherwise)
    state = init_train_state(opt, params, n_workers=args.workers,
                             attack=args.attack, attack_f=args.f,
                             codec=args.codec)
    lr_fn = warmup_cosine(args.lr, warmup=max(args.steps // 20, 1),
                          total_steps=args.steps)
    chunk_q = min(args.seq, 512)
    # ring sized to retain the whole run (3-4 records/step); the jitted
    # steps lazily seed TrainerState.mstate at trace time, so no carry
    # surgery is needed here (unlike the sim engine's scan)
    obs = OBS.ObsConfig(enabled=True, ring=max(128, 4 * args.steps)) \
        if args.obs else None
    if args.trainer == "stacked":
        step_fn = make_train_step(cfg, rcfg, opt, lr_fn, chunk_q=chunk_q,
                                  attack=args.attack, codec=args.codec,
                                  shard_map_mesh=mesh, hier=hier, obs=obs)
    else:
        scope = "global" if args.trainer.endswith("global") else "block"
        step_fn = make_streaming_train_step(cfg, rcfg, opt, lr_fn,
                                            scope=scope, chunk_q=chunk_q,
                                            attack=args.attack,
                                            codec=args.codec,
                                            shard_map_mesh=mesh, hier=hier,
                                            obs=obs)
    step_fn = jax.jit(step_fn)
    tracer = OBS.SpanTracer() if args.obs else None

    global_batch = args.workers * args.per_worker_batch
    data = lm_batches(cfg.vocab_size, global_batch, args.seq, seed=args.seed)
    t0 = time.time()
    loss = float("nan")
    for i in range(args.steps):
        batch = next(data)
        if cfg.is_encdec:
            b = batch["tokens"].shape[0]
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(key, 10_000 + i),
                (b, cfg.n_frames, cfg.d_model), dtype=jnp.bfloat16)
        if cfg.n_patches:
            b = batch["tokens"].shape[0]
            batch["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 20_000 + i),
                (b, cfg.n_patches, cfg.d_model), dtype=jnp.bfloat16)
        wb = split_workers(batch, args.workers)
        if tracer is not None:
            with tracer.span("step", round=i):
                params, state, metrics = step_fn(params, state, wb,
                                                 jax.random.fold_in(key, i))
                jax.block_until_ready(metrics["loss"])
        else:
            params, state, metrics = step_fn(params, state, wb,
                                             jax.random.fold_in(key, i))
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"[train] step {i:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        path = save(args.ckpt_dir, args.steps, {"params": params})
        print(f"[train] checkpoint -> {path}")
    if args.obs and state.mstate is not None:
        recs = OBS.drain(state.mstate.get("t"))
        snap = OBS.snapshot(
            metrics=state.mstate["m"], trace_records=recs,
            meta={"source": "launch.train", "arch": cfg.name,
                  "trainer": args.trainer, "steps": args.steps,
                  "workers": args.workers, "f": args.f, "gar": args.gar,
                  "attack": args.attack})
        OBS.write_snapshot(args.obs_json, snap)
        n_ev = OBS.export_chrome_trace(
            args.obs_trace, device_records=recs, host_spans=tracer.spans,
            meta={"source": "launch.train", "arch": cfg.name})
        print(f"[train] obs: {len(recs)} span records, "
              f"counters {snap['metrics']['counters']} "
              f"-> {args.obs_json}, {n_ev} trace events -> "
              f"{args.obs_trace}")
    print(f"[train] done: final loss {loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
