"""Span tracing of the stats→plan→apply→select_plan pipeline.

XLA has no in-graph wall clock, so device-side "timestamps" here are
*logical*: each record is (seq, round, phase, payload), written into a
fixed-capacity ring buffer that rides in the scan carry as a registered
pytree (:class:`TraceState`).  ``seq`` is the monotone record counter —
it orders records across ring wraparound — ``round`` is the optimizer
step the span belongs to, ``phase`` indexes :data:`PHASES`, and
``payload`` is one phase-specific scalar (selection mass, grad norm,
plan_reused flag, ...).

Wall-clock time is attached **host-side**, at drain: the launch layer
wraps its jitted step calls in a :class:`SpanTracer` (ordinary
``perf_counter`` spans around device dispatch), and
:func:`export_chrome_trace` lays the drained logical records out against
those host anchors.  This is the same honest framing the serving loadgen
uses (it never sleeps): we report the device pipeline's *structure* from
in-graph records and its *duration* from host timing, and never pretend
an in-graph number is a nanosecond.

The exported JSON is the Chrome trace-event format — load it at
https://ui.perfetto.dev (or chrome://tracing) directly.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: Pipeline phases, in program order.  ``select_plan`` is the async
#: degradation branch (DESIGN.md §13); synchronous trainers record the
#: first three.
PHASES = ("stats", "plan", "apply", "select_plan")
PH_STATS, PH_PLAN, PH_APPLY, PH_SELECT_PLAN = range(len(PHASES))

_COLS = 4  # (seq, round, phase, payload)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("slots", "head"),
    meta_fields=("capacity",))
@dataclasses.dataclass(frozen=True)
class TraceState:
    """Fixed-capacity span ring: ``slots`` is (capacity, 4) float32.

    ``head`` counts records ever written; the live window is the last
    ``min(head, capacity)`` records and ``head % capacity`` is the next
    write position.  Storing seq as float32 keeps the ring a single
    homogeneous array; it is exact up to 2^24 records — far beyond any
    ring's retention window.
    """

    capacity: int
    slots: Array
    head: Array


def init_trace(capacity: int) -> TraceState:
    return TraceState(capacity=int(capacity),
                      slots=jnp.zeros((int(capacity), _COLS), jnp.float32),
                      head=jnp.zeros((), jnp.int32))


def record(trace: Optional[TraceState], phase: int, round_idx,
           payload=0.0) -> Optional[TraceState]:
    """Append one span record (pure ``jnp``; ``None`` passes through).

    ``phase`` is a static int from :data:`PHASES`; ``round_idx`` and
    ``payload`` may be traced scalars.
    """
    if trace is None:
        return trace
    pos = trace.head % trace.capacity
    row = jnp.stack([
        trace.head.astype(jnp.float32),
        jnp.asarray(round_idx, jnp.float32),
        jnp.float32(phase),
        jnp.asarray(payload, jnp.float32),
    ])
    return dataclasses.replace(
        trace,
        slots=trace.slots.at[pos].set(row),
        head=trace.head + 1)


def drain(trace: Optional[TraceState]) -> List[Dict[str, Any]]:
    """Host-side: the live window, oldest first (wraparound-safe).

    Records evicted by ring overwrite are gone — that is the contract:
    the ring bounds carry memory, the drain returns whatever survived,
    in seq order.
    """
    if trace is None:
        return []
    slots = np.asarray(trace.slots)
    head = int(trace.head)
    n = min(head, trace.capacity)
    if n == 0:
        return []
    live = slots[np.argsort(slots[:, 0])] if head > trace.capacity \
        else slots[:n]
    out = []
    for seq, rnd, ph, payload in live:
        out.append({
            "seq": int(seq),
            "round": int(rnd),
            "phase": PHASES[int(ph)],
            "payload": float(payload),
        })
    return out


class SpanTracer:
    """Host-side wall-clock spans (``perf_counter``, microseconds).

    The launch layer brackets each jitted step call::

        tracer = SpanTracer()
        with tracer.span("step", round=i):
            loss = step(params, state, ...)  # block_until_ready inside

    These anchor the logical device records in the exported trace.
    """

    def __init__(self):
        self.spans: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()

    @contextmanager
    def span(self, name: str, **args):
        start = time.perf_counter()
        try:
            yield
        finally:
            end = time.perf_counter()
            self.spans.append({
                "name": name,
                "ts_us": (start - self._t0) * 1e6,
                "dur_us": (end - start) * 1e6,
                "args": {k: _jsonable(v) for k, v in args.items()},
            })


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def export_chrome_trace(path: str, *,
                        device_records: Sequence[Dict[str, Any]] = (),
                        host_spans: Sequence[Dict[str, Any]] = (),
                        meta: Optional[Dict[str, Any]] = None) -> int:
    """Write a Chrome-trace/Perfetto JSON file; returns the event count.

    Host spans become pid 0 / tid 0 duration events at their measured
    wall-clock offsets.  Logical device records become pid 1 duration
    events on one track per phase: each round is laid out inside its
    host ``step`` span when one with a matching ``round`` arg exists
    (phases split the span evenly, in pipeline order), else on a uniform
    1 ms/round grid.  The layout is reconstruction, not measurement —
    ``args.logical`` is set on every device event to say so.
    """
    events: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": 0,
         "args": {"name": "host (wall clock)"}},
        {"name": "process_name", "ph": "M", "pid": 1,
         "args": {"name": "device pipeline (logical, host-anchored)"}},
    ]
    for s in host_spans:
        events.append({
            "name": s["name"], "ph": "X", "pid": 0, "tid": 0,
            "ts": round(float(s["ts_us"]), 3),
            "dur": round(float(s["dur_us"]), 3),
            "cat": "host", "args": dict(s.get("args", {})),
        })

    anchors = {}
    for s in host_spans:
        rnd = s.get("args", {}).get("round")
        if rnd is not None:
            anchors[int(rnd)] = (float(s["ts_us"]), float(s["dur_us"]))

    by_round: Dict[int, List[Dict[str, Any]]] = {}
    for r in device_records:
        by_round.setdefault(int(r["round"]), []).append(r)
    for rnd, recs in sorted(by_round.items()):
        recs = sorted(recs, key=lambda r: r["seq"])
        ts0, dur = anchors.get(rnd, (rnd * 1000.0, 1000.0))
        slot = dur / max(len(recs), 1)
        for k, r in enumerate(recs):
            events.append({
                "name": r["phase"], "ph": "X", "pid": 1,
                "tid": PHASES.index(r["phase"]),
                "ts": round(ts0 + k * slot, 3),
                "dur": round(slot, 3),
                "cat": "device-logical",
                "args": {"seq": r["seq"], "round": r["round"],
                         "payload": r["payload"], "logical": True,
                         "anchored": rnd in anchors},
            })
    for tid, phase in enumerate(PHASES):
        events.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": tid, "args": {"name": phase}})

    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "exporter": "repro.obs.trace",
            "note": ("device events are logical ring records laid out "
                     "against host wall-clock anchors; XLA has no "
                     "in-graph clock"),
            **(meta or {}),
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return len(events)
