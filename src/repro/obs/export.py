"""Host-side drain: ``obs.v1`` snapshots, serve percentiles, phase digests.

Everything in this module runs *after* device work: it consumes drained
``MetricsState`` / ``TraceState`` pytrees, kernel profiler records and
host span lists, and produces the structured ``obs.v1`` JSON snapshot
that ``launch/obs_report.py`` prints and CI schema-validates.  Nothing
here is jit-traceable, and nothing in ``repro.obs.metrics`` /
``repro.obs.trace`` does host I/O — that is the §14 contract boundary.

The per-phase campaign digest (:func:`phase_summary`) lives here too:
it is the summary half of the old ``sim/telemetry.py`` (which now
delegates), so the campaign reports and the live registry share one
metrics substrate.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

SCHEMA = "obs.v1"


# ------------------------------------------------------------- percentiles
def percentiles(xs) -> Dict[str, float]:
    """p50/p95/p99 of a sample vector (linear interpolation, numpy)."""
    xs = np.asarray(xs, np.float64)
    if xs.size == 0:
        raise ValueError("percentiles of an empty sample")
    p50, p95, p99 = np.percentile(xs, [50.0, 95.0, 99.0])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99)}


# ---------------------------------------------------------- registry drain
def metrics_to_json(mstate) -> Optional[Dict[str, Any]]:
    """Drain a ``MetricsState`` to plain JSON (floats/ints/lists).

    Histograms carry their spec edges alongside the counts so the
    snapshot is self-describing — a reader never needs the producing
    code to interpret the buckets.
    """
    if mstate is None:
        return None
    return {
        "counters": {k: float(np.asarray(v))
                     for k, v in sorted(mstate.counters.items())},
        "gauges": {k: np.asarray(v).astype(np.float64).tolist()
                   for k, v in sorted(mstate.gauges.items())},
        "hists": {k: {"edges": list(mstate.spec.hist_edges(k)),
                      "counts": np.asarray(v).astype(np.int64).tolist()}
                  for k, v in sorted(mstate.hists.items())},
    }


def serve_metrics(round_us, *, agg_us=None,
                  ages=None, tau: Optional[int] = None,
                  counters: Optional[Dict[str, float]] = None
                  ) -> Dict[str, Any]:
    """Per-round serve digest: latency/QPS percentiles + staleness.

    ``round_us`` is the loadgen's per-round delivery schedule (one entry
    per completed round); QPS percentiles are the per-round reciprocal,
    so qps.p50 is the median *rate*, not 1/median latency of a mean.
    """
    round_us = np.asarray(round_us, np.float64)
    out: Dict[str, Any] = {
        "rounds": int(round_us.size),
        "round_us": percentiles(round_us),
        "round_us_mean": float(round_us.mean()),
        "qps": percentiles(1e6 / round_us),
        "qps_mean": float(round_us.size / (round_us.sum() / 1e6)),
    }
    if agg_us is not None:
        out["agg_us"] = percentiles(agg_us)
    if ages is not None:
        ages = np.asarray(ages)
        hi = int(tau) + 1 if tau is not None else int(ages.max()) + 1
        edges = [i + 0.5 for i in range(hi)]
        counts = np.bincount(
            np.searchsorted(edges, ages.ravel(), side="right"),
            minlength=len(edges) + 1)
        out["staleness"] = {"edges": edges, "counts": counts.tolist()}
    if counters:
        out["counters"] = {k: float(v) for k, v in sorted(counters.items())}
    return out


# -------------------------------------------------------------- snapshot
def snapshot(*, metrics=None, trace_records: Sequence[Dict] = (),
             kernels: Sequence[Dict] = (), serve: Optional[Dict] = None,
             meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Assemble the ``obs.v1`` structured snapshot."""
    return {
        "schema": SCHEMA,
        "meta": dict(meta or {}),
        "metrics": metrics_to_json(metrics) if not isinstance(metrics, dict)
        else metrics,
        "trace": {"records": list(trace_records),
                  "n_records": len(trace_records)},
        "kernels": list(kernels),
        "serve": serve,
    }


def validate_snapshot(snap: Any) -> List[str]:
    """Schema problems of an ``obs.v1`` snapshot ([] when valid)."""
    problems: List[str] = []
    if not isinstance(snap, dict):
        return [f"snapshot: expected object, got {type(snap).__name__}"]
    if snap.get("schema") != SCHEMA:
        problems.append(
            f"schema: expected {SCHEMA!r}, got {snap.get('schema')!r}")
    for key in ("meta", "trace", "kernels"):
        if key not in snap:
            problems.append(f"missing key {key!r}")
    m = snap.get("metrics")
    if m is not None:
        if not isinstance(m, dict):
            problems.append("metrics: expected object or null")
        else:
            for sect in ("counters", "gauges", "hists"):
                if sect not in m:
                    problems.append(f"metrics: missing {sect!r}")
            for name, h in (m.get("hists") or {}).items():
                if "edges" not in h or "counts" not in h:
                    problems.append(
                        f"metrics.hists[{name}]: needs edges + counts")
                elif len(h["counts"]) != len(h["edges"]) + 1:
                    problems.append(
                        f"metrics.hists[{name}]: {len(h['counts'])} counts "
                        f"for {len(h['edges'])} edges (want edges+1)")
    tr = snap.get("trace")
    if isinstance(tr, dict):
        recs = tr.get("records")
        if not isinstance(recs, list):
            problems.append("trace.records: expected list")
        else:
            seqs = [r.get("seq") for r in recs]
            if seqs != sorted(seqs):
                problems.append("trace.records: not in seq order")
            for r in recs:
                for key in ("seq", "round", "phase", "payload"):
                    if key not in r:
                        problems.append(f"trace record missing {key!r}")
                        break
    if not isinstance(snap.get("kernels", []), list):
        problems.append("kernels: expected list")
    sv = snap.get("serve")
    if sv is not None and isinstance(sv, dict):
        for key in ("round_us", "qps"):
            if key in sv:
                for p in ("p50", "p95", "p99"):
                    if p not in sv[key]:
                        problems.append(f"serve.{key}: missing {p}")
    return problems


def write_snapshot(path: str, snap: Dict[str, Any]) -> None:
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")


# ------------------------------------------------- campaign phase digest
def phase_summary(trace: Dict[str, np.ndarray], scenario,
                  start_step: int = 0,
                  wire: "Dict[str, Any] | None" = None) -> Dict[str, Any]:
    """Host-side per-phase digest of a campaign trace.

    Per phase: loss at entry/exit, mean/max honest-mean deviation, mean
    byzantine selection mass, the per-worker mean selection vector and the
    final suspicion vector.  The acceptance assertions
    (``launch/simulate.py --smoke``, ``tests/test_sim.py``) read these.
    ``start_step`` offsets the schedule against a resumed run's trace
    (which only covers executed steps).  ``wire`` (a
    ``repro.comm.WireStats`` dict) is repeated per phase — byte accounting
    is shape-static, so every phase of a campaign pays the same wire.

    This is the summary half of the pre-obs ``sim/telemetry.py``, moved
    verbatim: ``sim.campaign.v1`` output is byte-identical (pinned by the
    golden-summary regression in tests/test_obs.py).
    """
    phases = []
    for i, ((start, stop), p) in enumerate(
            zip(scenario.schedule.bounds(), scenario.schedule.phases)):
        start, stop = start - start_step, stop - start_step
        if stop <= 0:
            continue  # phase ran before the resume point
        stop = min(stop, len(trace["loss"]))
        if start >= stop:
            break
        sl = slice(start, stop)
        ph: Dict[str, Any] = {
            "phase": i,
            "attack": p.attack,
            "f": scenario.phase_f(p),
            "steps": stop - start,
            "loss_first": float(trace["loss"][start]),
            "loss_last": float(trace["loss"][stop - 1]),
            "loss_mean": float(np.mean(trace["loss"][sl])),
        }
        for k in ("honest_dev", "byz_mass", "score_gap", "mean_dist",
                  "n_overstale", "f_defended", "plan_reused"):
            if k in trace:
                ph[f"{k}_mean"] = float(np.mean(trace[k][sl]))
                ph[f"{k}_max"] = float(np.max(trace[k][sl]))
        if "selection" in trace:
            ph["selection_mean"] = np.mean(
                trace["selection"][sl], axis=0).tolist()
        # async staleness accounting: which workers were admitted on time
        # vs sat overstale (haircut) this phase — repro.serve telemetry
        if "admitted" in trace:
            ph["admitted_mean"] = np.mean(
                trace["admitted"][sl], axis=0).tolist()
        if "overstale" in trace:
            ph["overstale_mean"] = np.mean(
                trace["overstale"][sl], axis=0).tolist()
        if "staleness_ema" in trace:
            ph["staleness_ema_last"] = \
                trace["staleness_ema"][stop - 1].tolist()
        if "suspicion" in trace:
            ph["suspicion_last"] = trace["suspicion"][stop - 1].tolist()
        if "group_selection" in trace:
            ph["group_selection_mean"] = np.mean(
                trace["group_selection"][sl], axis=0).tolist()
        if "group_suspicion" in trace:
            ph["group_suspicion_last"] = \
                trace["group_suspicion"][stop - 1].tolist()
        if wire is not None:
            ph["wire"] = wire
        phases.append(ph)
    out: Dict[str, Any] = {
        "total_steps": int(len(trace["loss"])),
        "final_loss": float(trace["loss"][-1]),
        "phases": phases,
    }
    if "honest_dev" in trace:
        out["honest_dev_max"] = float(np.max(trace["honest_dev"]))
    if "byz_mass" in trace:
        out["byz_mass_mean"] = float(np.mean(trace["byz_mass"]))
    if wire is not None:
        out["wire"] = wire
    return out
