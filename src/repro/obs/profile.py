"""Kernel profiling hooks: what did each Pallas launch actually choose?

The ``kernels/ops.py`` public wrappers resolve their launch config —
autotuned ``d_tile``, grid depth, deep-grid lift — in Python, *outside*
jit, immediately before calling the jitted privates.  That resolution
point is the hook: with a :class:`KernelProfiler` installed, each
wrapper calls :func:`record_kernel` and the profiler captures one
:class:`KernelRecord` per launch config, pairing the chosen tile with
the ``analysis/vmem.py`` prediction for exactly that tile (closing the
loop between the §12 cost model and the live launches).

Two honesty notes, both load-bearing:

* on the hot path (wrappers called inside a jitted step) records fire
  at **trace time** — one record per distinct launch shape, not one per
  call; a shape that hits jax's compilation cache produces no new
  record.  That is the right granularity for a *static* launch config,
  and the reason the hook costs nothing per step.  Eager wrapper calls
  record once per call;
* ``vmem_measured`` comes from XLA's ``memory_analysis()`` on a real
  compile (:func:`measure_vmem`) and is ``None`` where the backend does
  not report it (CPU interpret mode) — predicted-vs-measured is only
  claimed where both numbers exist.

No profiler installed (the default) → :func:`record_kernel` returns
after one tuple check; the wrappers stay allocation-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

_ACTIVE: List["KernelProfiler"] = []


@dataclasses.dataclass(frozen=True)
class KernelRecord:
    """One distinct kernel launch config, with its vmem prediction."""

    kernel: str              # fused_select | pairwise_stats | dequant_stats
    n: int                   # stack rows (unpadded)
    d: int
    d_tile: int              # inner compute window the wrapper launched with
    macro_tile: int          # outer macro block (== d_tile -> single-level)
    grid_steps: int          # OUTER grid steps (macro blocks)
    windows: int             # inner d_tile windows per macro block
    vmem_predicted: Optional[int]   # analysis/vmem per-step working set
    vmem_budget: Optional[int]
    over_budget: Optional[bool]
    vmem_measured: Optional[int] = None   # XLA memory_analysis, if any

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class KernelProfiler:
    """Installable sink for wrapper launch records (context manager)."""

    def __init__(self):
        self.records: List[KernelRecord] = []

    def __enter__(self) -> "KernelProfiler":
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _ACTIVE.remove(self)


def record_kernel(kernel: str, *, n: int, d: int, d_tile: int,
                  macro_tile: Optional[int] = None,
                  theta: Optional[int] = None,
                  dtype: Optional[str] = None,
                  n_loc: Optional[int] = None) -> None:
    """Called by the ops wrappers after tile resolution; cheap no-op
    unless a profiler is installed."""
    if not _ACTIVE:
        return
    macro = d_tile if macro_tile is None else macro_tile
    est = _predict(kernel, n=n, d=d, d_tile=d_tile, macro_tile=macro,
                   theta=theta, dtype=dtype)
    rec = KernelRecord(
        kernel=kernel, n=n, d=d, d_tile=d_tile, macro_tile=macro,
        grid_steps=-(-d // macro), windows=macro // d_tile,
        vmem_predicted=None if est is None else est.vmem_bytes,
        vmem_budget=None if est is None else est.vmem_budget,
        over_budget=None if est is None else est.over_budget)
    for profiler in _ACTIVE:
        profiler.records.append(rec)


def _predict(kernel: str, *, n: int, d: int, d_tile: int, macro_tile: int,
             theta: Optional[int], dtype: Optional[str]):
    # lazy import: vmem imports kernels.ops at module load, and ops
    # imports this module — resolving the estimate at record time keeps
    # the cycle open
    from repro.analysis import vmem
    try:
        if kernel == "fused_select":
            if theta is None or (n - theta - 2) % 2:
                return None
            return vmem.estimate_fused_select(
                n, d, f=(n - theta - 2) // 2, d_tile=d_tile,
                macro_tile=macro_tile)
        if kernel == "pairwise_stats":
            return vmem.estimate_pairwise_stats(
                n, d, d_tile=d_tile, macro_tile=macro_tile)
        if kernel == "dequant_stats":
            return vmem.estimate_dequant_stats(
                n, d, dtype=dtype or "int8", d_tile=d_tile,
                macro_tile=macro_tile)
    except ValueError:
        return None
    return None


def measure_vmem(fn, *args, **kwargs) -> Optional[int]:
    """Compile ``fn(*args, **kwargs)`` and ask XLA for its temp bytes.

    Returns ``None`` when the backend's ``memory_analysis()`` is missing
    or unpopulated (CPU) — absence of a measurement is reported as
    absence, never as zero.
    """
    import jax
    try:
        compiled = jax.jit(fn).lower(*args, **kwargs).compile()
        mem = compiled.memory_analysis()
        if mem is None:
            return None
        size = getattr(mem, "temp_size_in_bytes", None)
        return None if size is None else int(size)
    except Exception:
        return None


def profile_points(points, *, f_fn=None) -> List[Dict[str, Any]]:
    """Run the three stats/apply kernels at given (n, d) points under a
    profiler and return record dicts with measured VMEM attached where
    the backend reports it.  Used by ``launch/obs_report.py --kernels``.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import vmem
    from repro.kernels import ops

    out: List[Dict[str, Any]] = []
    for n, d in points:
        f = vmem.f_for_bench(n) if f_fn is None else f_fn(n)
        theta = n - 2 * f - 2
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        w = jnp.asarray(rng.random((theta, n)), jnp.float32)
        payload = jnp.asarray(
            rng.integers(-127, 127, size=(n, d)), jnp.int8)
        mult = jnp.ones((n,), jnp.float32)
        with KernelProfiler() as prof:
            ops.pairwise_stats(x)
            ops.dequant_stats(payload, mult)
            ops.fused_select(x, w, w, beta=max(theta - 2 * f, 1))
        measured = {
            "pairwise_stats": measure_vmem(lambda a: ops.pairwise_stats(a),
                                           x),
            "dequant_stats": measure_vmem(
                lambda p, m: ops.dequant_stats(p, m), payload, mult),
            "fused_select": measure_vmem(
                lambda a, b, c: ops.fused_select(
                    a, b, c, beta=max(theta - 2 * f, 1)), x, w, w),
        }
        for rec in prof.records:
            out.append(dataclasses.replace(
                rec, vmem_measured=measured.get(rec.kernel)).to_json())
    return out
