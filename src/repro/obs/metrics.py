"""Device-resident metrics registry (DESIGN.md §14).

The registry is a registered-pytree :class:`MetricsState` of counters,
gauges and fixed-bucket histograms whose record ops are pure ``jnp``
updates — legal inside ``lax.scan`` / ``shard_map``, no host callbacks, no
sync.  What may be recorded in-graph is exactly what a pure function of
the step's values can be: accumulate now, *drain host-side later*
(``repro.obs.export``).

Two invariants the tests pin down:

* **disabled is free** — with ``ObsConfig(enabled=False)`` (or no config
  at all) every instrumented step builder takes the identical code path
  as the uninstrumented one: no ``MetricsState`` is created, the record
  helpers pass ``None`` through, and the emitted jaxpr is bitwise the
  uninstrumented step's (tests/test_obs.py);
* **names are static** — the metric *set* is fixed by a hashable
  :class:`MetricsSpec` at build time (it rides in the pytree's meta
  fields), so recording never changes tree structure and a scan carry
  stays shape-stable.  Recording an unknown name is a silent no-op by
  design: producers (trainer / hier / serve) record unconditionally and
  the spec decides what is kept.

The per-worker suspicion EMA that ``repro.sim`` carries through campaign
scans lives here too (:func:`update_suspicion` / :func:`update_ema`) —
``sim/telemetry.py`` re-exports them so campaigns and the live registry
share one metrics substrate.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Jit-static observability switchboard (frozen, hashable).

    Step builders close over one of these (threaded through
    ``AggregatorBackend`` so every consumer of a backend sees the same
    config); ``enabled=False`` — the default — compiles to a bitwise
    no-op of the uninstrumented step.

    * ``trace`` — also ring-buffer span records of the
      stats→plan→apply→select_plan pipeline (``repro.obs.trace``);
    * ``ring`` — span ring capacity (oldest records overwritten);
    * ``suspicion_ema`` — decay of the per-worker suspicion gauge.
    """

    enabled: bool = False
    trace: bool = True
    ring: int = 128
    suspicion_ema: float = 0.9

    def __post_init__(self):
        if self.ring < 1:
            raise ValueError(f"ring capacity must be >= 1, got {self.ring}")
        if not 0.0 <= self.suspicion_ema < 1.0:
            raise ValueError(
                f"suspicion_ema must be in [0, 1), got {self.suspicion_ema}")

    @property
    def on(self) -> bool:
        return self.enabled


def obs_on(obs: Optional[ObsConfig]) -> bool:
    """The one guard every instrumented builder uses."""
    return obs is not None and obs.enabled


@dataclasses.dataclass(frozen=True)
class MetricsSpec:
    """The static metric set: names, gauge shapes, histogram edges.

    Hashable (tuples all the way down) so it can ride in a registered
    dataclass's meta fields and in jit cache keys.  Histogram ``edges``
    are the sorted right bucket boundaries; a histogram with ``k`` edges
    has ``k + 1`` buckets — bucket ``i`` counts values ``v`` with
    ``edges[i-1] <= v < edges[i]`` under ``searchsorted(side="right")``
    semantics (bucket 0 is the underflow, bucket ``k`` the overflow).
    """

    counters: Tuple[str, ...] = ()
    gauges: Tuple[Tuple[str, Tuple[int, ...]], ...] = ()
    hists: Tuple[Tuple[str, Tuple[float, ...]], ...] = ()

    def __post_init__(self):
        # counters/gauges/hists are separate namespaces (separate dicts in
        # MetricsState) — a gauge and a histogram may share a name
        for kind, names in (("counters", self.counters),
                            ("gauges", [n for n, _ in self.gauges]),
                            ("hists", [n for n, _ in self.hists])):
            if len(names) != len(set(names)):
                raise ValueError(
                    f"duplicate {kind} names in spec: {list(names)}")
        for name, edges in self.hists:
            if len(edges) < 1 or list(edges) != sorted(edges):
                raise ValueError(
                    f"histogram {name!r}: edges must be non-empty and "
                    f"sorted, got {edges}")

    def hist_edges(self, name: str) -> Tuple[float, ...]:
        for n, edges in self.hists:
            if n == name:
                return edges
        raise KeyError(f"no histogram {name!r} in spec")


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("counters", "gauges", "hists"),
    meta_fields=("spec",))
@dataclasses.dataclass(frozen=True)
class MetricsState:
    """The device-resident registry: one array per metric.

    * ``counters[name]`` — () float32 monotone accumulator;
    * ``gauges[name]``   — float32 array of the spec's shape, last-write;
    * ``hists[name]``    — (len(edges) + 1,) int32 bucket counts.

    A plain pytree of dicts — flattens by sorted name, checkpoints
    through ``checkpoint/store.py`` under ``...|counters|<name>`` keys,
    and scans/shard_maps like any other carry.
    """

    spec: MetricsSpec
    counters: Dict[str, Array]
    gauges: Dict[str, Array]
    hists: Dict[str, Array]


def init_metrics(spec: MetricsSpec) -> MetricsState:
    return MetricsState(
        spec=spec,
        counters={n: jnp.zeros((), jnp.float32) for n in spec.counters},
        gauges={n: jnp.zeros(shape, jnp.float32)
                for n, shape in spec.gauges},
        hists={n: jnp.zeros((len(edges) + 1,), jnp.int32)
               for n, edges in spec.hists})


def inc(state: Optional[MetricsState], name: str,
        value=1.0) -> Optional[MetricsState]:
    """Counter += value (pure; no-op when disabled or name unknown)."""
    if state is None or name not in state.counters:
        return state
    c = dict(state.counters)
    c[name] = c[name] + jnp.asarray(value, jnp.float32)
    return dataclasses.replace(state, counters=c)


def set_gauge(state: Optional[MetricsState], name: str,
              value) -> Optional[MetricsState]:
    """Gauge = value (last write wins; no-op when disabled/unknown)."""
    if state is None or name not in state.gauges:
        return state
    g = dict(state.gauges)
    g[name] = jnp.asarray(value, jnp.float32).reshape(g[name].shape)
    return dataclasses.replace(state, gauges=g)


def ema_gauge(state: Optional[MetricsState], name: str, value,
              ema: float) -> Optional[MetricsState]:
    """Gauge = ema·gauge + (1-ema)·value — the suspicion-carry update."""
    if state is None or name not in state.gauges:
        return state
    g = dict(state.gauges)
    v = jnp.asarray(value, jnp.float32).reshape(g[name].shape)
    g[name] = ema * g[name] + (1.0 - ema) * v
    return dataclasses.replace(state, gauges=g)


def observe(state: Optional[MetricsState], name: str,
            value) -> Optional[MetricsState]:
    """Histogram: count every element of ``value`` into its bucket.

    Bucket index is ``searchsorted(edges, v, side="right")`` on the
    spec's static edges — exactly ``np.searchsorted``, which is what the
    numpy-reference test checks bucket counts against.
    """
    if state is None or name not in state.hists:
        return state
    edges = jnp.asarray(state.spec.hist_edges(name), jnp.float32)
    v = jnp.asarray(value, jnp.float32).ravel()
    idx = jnp.searchsorted(edges, v, side="right")
    h = dict(state.hists)
    h[name] = h[name].at[idx].add(1)
    return dataclasses.replace(state, hists=h)


# ---------------------------------------------------------- standard specs
#: log₂-spaced gradient-norm buckets: underflow < 1e-3, overflow >= ~8e3
GRAD_NORM_EDGES = tuple(float(2.0 ** e) for e in range(-10, 14))


def train_spec(n_workers: int, *, telemetry: bool = False) -> MetricsSpec:
    """The registry both synchronous trainers record into."""
    gauges = [("loss", ()), ("agg_grad_norm", ())]
    if telemetry:
        gauges += [("suspicion", (n_workers,)), ("byz_mass", ())]
    return MetricsSpec(counters=("rounds",),
                       gauges=tuple(gauges),
                       hists=(("agg_grad_norm", GRAD_NORM_EDGES),))


def serve_spec(n_workers: int, tau: int, *,
               telemetry: bool = False) -> MetricsSpec:
    """The async service registry: staleness accounting on top of train.

    The ``staleness_age`` histogram has one bucket per admissible age
    ``0..tau`` plus the overstale overflow bucket (edges at ``i + 0.5``),
    so the drained snapshot reads directly as "how stale were the slots
    each round" (DESIGN.md §13 / §14).
    """
    age_edges = tuple(float(i) + 0.5 for i in range(tau + 1))
    gauges = [("loss", ()), ("agg_grad_norm", ()), ("f_defended", ())]
    if telemetry:
        gauges += [("suspicion", (n_workers,)), ("byz_mass", ())]
    return MetricsSpec(
        counters=("rounds", "admitted", "overstale_slots", "degraded"),
        gauges=tuple(gauges),
        hists=(("agg_grad_norm", GRAD_NORM_EDGES),
               ("staleness_age", age_edges)))


# ------------------------------------------------- suspicion EMA (campaigns)
def init_suspicion(n_workers: int) -> Array:
    return jnp.zeros((n_workers,), jnp.float32)


def update_suspicion(susp: Array, selection: Array, ema: float) -> Array:
    """EMA of per-worker rejection.

    A worker's per-step rejection is ``1 - selection_i / max_j selection_j``
    (0 for the most-trusted worker, 1 for a fully rejected one) — normalised
    so weighted rules and uniform rules land on the same scale.
    """
    rej = 1.0 - selection / (jnp.max(selection) + 1e-12)
    return ema * susp + (1.0 - ema) * rej


def update_ema(prev: Array, value: Array, ema: float) -> Array:
    """Plain per-worker EMA — the suspicion-carry pattern for any 0/1
    indicator (the async service uses it on the per-round overstale mask,
    so campaigns report *sustained* staleness per worker, not one-round
    blips)."""
    return ema * prev + (1.0 - ema) * value.astype(jnp.float32)
