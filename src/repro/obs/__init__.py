"""repro.obs — jit-safe runtime observability (DESIGN.md §14).

Four pieces, one contract:

* :mod:`repro.obs.metrics` — device-resident registry (counters /
  gauges / histograms) whose record ops are pure ``jnp`` updates;
* :mod:`repro.obs.trace`   — stats→plan→apply→select_plan span ring in
  the scan carry, Chrome-trace/Perfetto export at drain;
* :mod:`repro.obs.profile` — kernel launch-config records paired with
  the ``analysis/vmem`` prediction;
* :mod:`repro.obs.export`  — the host-side drain: ``obs.v1`` snapshots,
  serve percentiles, campaign phase digests.

In-graph code may *accumulate* into the registry/ring; only the export
layer may touch the host.  ``ObsConfig(enabled=False)`` (or ``obs=None``)
makes every instrumented step builder emit the bitwise-identical jaxpr
of the uninstrumented step — observability is free until switched on.

The observed state rides in ``TrainerState.mstate`` as a plain dict
``{"m": MetricsState, "t": TraceState | None}`` so it scans, shards and
checkpoints like any other carry (:func:`init_obs_state` seeds it; step
builders auto-seed at trace time when the slot is still ``None``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.obs.metrics import (GRAD_NORM_EDGES, MetricsSpec, MetricsState,
                               ObsConfig, ema_gauge, inc, init_metrics,
                               init_suspicion, obs_on, observe, serve_spec,
                               set_gauge, train_spec, update_ema,
                               update_suspicion)
from repro.obs.trace import (PH_APPLY, PH_PLAN, PH_SELECT_PLAN, PH_STATS,
                             PHASES, SpanTracer, TraceState, drain,
                             export_chrome_trace, init_trace, record)
from repro.obs.profile import (KernelProfiler, KernelRecord, measure_vmem,
                               profile_points, record_kernel)
from repro.obs.export import (SCHEMA, metrics_to_json, percentiles,
                              phase_summary, serve_metrics, snapshot,
                              validate_snapshot, write_snapshot)

__all__ = [
    "GRAD_NORM_EDGES", "KernelProfiler", "KernelRecord", "MetricsSpec",
    "MetricsState", "ObsConfig", "PHASES", "PH_APPLY", "PH_PLAN",
    "PH_SELECT_PLAN", "PH_STATS", "SCHEMA", "SpanTracer", "TraceState",
    "drain", "ema_gauge", "export_chrome_trace", "inc", "init_metrics",
    "init_obs_state", "init_serve_obs", "init_suspicion", "init_trace",
    "init_train_obs", "measure_vmem", "metrics_to_json", "obs_on",
    "observe", "percentiles", "phase_summary", "profile_points", "record",
    "record_kernel",
    "serve_metrics", "serve_spec", "set_gauge", "snapshot", "train_spec",
    "update_ema", "update_suspicion", "validate_snapshot",
    "write_snapshot",
]


def init_obs_state(obs: Optional[ObsConfig],
                   spec: MetricsSpec) -> Optional[Dict[str, Any]]:
    """The ``mstate`` carry: ``None`` when obs is off (zero leaves)."""
    if not obs_on(obs):
        return None
    return {"m": init_metrics(spec),
            "t": init_trace(obs.ring) if obs.trace else None}


def init_train_obs(obs: Optional[ObsConfig], n_workers: int, *,
                   telemetry: bool = False) -> Optional[Dict[str, Any]]:
    """Seed the mstate both synchronous trainers expect.

    The sim engine calls this before ``lax.scan`` (a scan carry cannot
    change structure mid-trace); ``launch/train.py`` lets the step
    auto-seed instead — both paths land on the same spec.
    """
    return init_obs_state(obs, train_spec(n_workers, telemetry=telemetry))


def init_serve_obs(obs: Optional[ObsConfig], n_workers: int, tau: int, *,
                   telemetry: bool = False) -> Optional[Dict[str, Any]]:
    """Seed the mstate the async serve step expects."""
    return init_obs_state(
        obs, serve_spec(n_workers, tau, telemetry=telemetry))
