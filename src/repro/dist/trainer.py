"""Stacked byzantine-SGD trainer built on the core plan/apply Aggregator API.

One train step (DESIGN.md §3):

1. forward+backward per worker (``vmap`` over the leading worker axis of the
   batch) -> stacked gradient pytree, every leaf ``(n, ...)``;
2. :func:`inject_byzantine` overwrites the first ``f`` worker rows with the
   selected attack's proposals (gradient-level omniscient adversary);
3. the optional pre-aggregation transform pipeline (worker momentum,
   clipping, nearest-neighbour mixing — ``core.api``) rewrites the stack;
4. ``Aggregator.plan`` on the replicated (n, n) statistics, then
   ``Aggregator.apply`` leaf-by-leaf (sharding-preserving einsums +
   coordinate phase);
5. one optimizer update from the aggregated gradient.

The returned step has signature ``(params, state, batch, key) ->
(params, state, metrics)`` where ``state`` is the named
:class:`TrainerState` pytree (optimizer + transform + adaptive-attack +
error-feedback slots) — seed it with :func:`init_train_state`.  A bare
``OptState`` is accepted for convenience and coerced on entry.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import api
from repro.core import attacks as ATK
from repro import models as MD
from repro import obs as OBS
from repro.optim.optimizers import OptState, Optimizer

PyTree = Any


# --------------------------------------------------------------------- data
def split_workers(batch: PyTree, n_workers: int) -> PyTree:
    """(global_batch, ...) leaves -> (n_workers, per_worker, ...) leaves."""

    def sp(x):
        b = x.shape[0]
        if b % n_workers:
            raise ValueError(
                f"global batch {b} not divisible by n_workers={n_workers}")
        return x.reshape((n_workers, b // n_workers) + x.shape[1:])

    return jax.tree.map(sp, batch)


# ------------------------------------------------------------------ attacks
def _attack_leaf(attack_fn: ATK.Attack, leaf: jax.Array, f: int,
                 key) -> jax.Array:
    """Replace the first f worker rows of one leaf with attack proposals.

    The attack sees the (n-f, numel) stack of *correct* gradients (rows
    f..n), per the omniscient-adversary convention in ``core/attacks.py``.
    """
    correct = leaf[f:]
    flat = correct.reshape((correct.shape[0], -1)).astype(jnp.float32)
    byz = attack_fn(flat, f, key)
    byz = byz.reshape((f,) + leaf.shape[1:]).astype(leaf.dtype)
    return jnp.concatenate([byz, correct], axis=0)


def inject_byzantine(grads: PyTree, f: int, attack, key,
                     *, leaf_offset: int = 0) -> PyTree:
    """Overwrite the first ``f`` worker rows of every leaf with the attack.

    ``attack`` is an attack spec string — a bare name or ``"name:k=v,..."``
    with parameter overrides (``core.attacks.get_attack``) — or an already
    resolved ``(G, f, key) -> (f, d)`` callable (the adaptive-attack path
    passes a state-closed closure).

    Per-leaf keys are ``fold_in(key, leaf_offset + leaf_index)`` so that a
    streaming trainer processing blocks of leaves reproduces the stacked
    trainer's randomness exactly (``leaf_offset`` = the block's position in
    the full tree's leaf order).
    """
    if f == 0:
        return grads
    attack_fn = ATK.get_attack(attack) if isinstance(attack, str) else attack
    leaves, treedef = jax.tree.flatten(grads)
    out = [
        _attack_leaf(attack_fn, leaf, f,
                     jax.random.fold_in(key, leaf_offset + i))
        for i, leaf in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def inject_wire(enc, f: int, attack, key, *, leaf_offset: int = 0):
    """Overwrite the first ``f`` workers' *wire messages* with the attack.

    The wire-format counterpart of :func:`inject_byzantine`: ``attack`` is
    a wire-attack spec (``core.attacks.WIRE_ATTACKS`` — ``scale_poison``,
    ``payload_flip``) mutating payload rows and scale sidecars of a
    ``repro.comm`` :class:`EncodedGrads` container directly, after honest
    workers encoded.  Same per-leaf key convention as gradient injection
    (``fold_in(key, leaf_offset + leaf_index)``) so streaming blocks
    reproduce the stacked randomness.
    """
    if f == 0:
        return enc
    import dataclasses as _dc
    fn = ATK.get_wire_attack(attack) if isinstance(attack, str) else attack
    p_leaves, treedef = jax.tree.flatten(enc.payload)
    s_leaves = jax.tree.leaves(enc.sidecar) \
        if enc.sidecar is not None else [None] * len(p_leaves)
    new_p, new_s = [], []
    for i, (p, s) in enumerate(zip(p_leaves, s_leaves)):
        k = jax.random.fold_in(key, leaf_offset + i)
        pb, sb = fn(p[f:], None if s is None else s[f:], f, k)
        new_p.append(jnp.concatenate([pb.astype(p.dtype), p[f:]], axis=0))
        new_s.append(None if s is None else
                     jnp.concatenate([sb.astype(s.dtype), s[f:]], axis=0))
    payload = jax.tree.unflatten(treedef, new_p)
    sidecar = None if enc.sidecar is None else \
        jax.tree.unflatten(treedef, new_s)
    return _dc.replace(enc, payload=payload, sidecar=sidecar)


# -------------------------------------------------------------- state
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("opt", "tstates", "astate", "cres", "bstate", "mstate"),
    meta_fields=())
@dataclasses.dataclass(frozen=True)
class TrainerState:
    """The one trainer-state container — a named, registered jit pytree.

    * ``opt``     — the optimizer's :class:`OptState` (always present);
    * ``tstates`` — per-transform state tuple (``()`` without stateful
      transforms; ``None`` entries for stateless ones);
    * ``astate``  — adaptive-attack plan-feedback state (``None`` unless
      the attack spec is adaptive);
    * ``cres``    — error-feedback compression residual (``None`` unless
      the codec spec has ``ef=1``);
    * ``bstate``  — the async bounded-staleness buffer
      (``repro.serve.buffer.BufferState``; ``None`` on the synchronous
      trainers — seed it with ``repro.serve.service.with_buffer``);
    * ``mstate``  — the device-resident observability carry
      (``{"m": repro.obs.MetricsState, "t": TraceState | None}``;
      ``None`` unless the step was built with an enabled
      ``repro.obs.ObsConfig`` — steps auto-seed it at trace time, scans
      seed it up front with ``repro.obs.init_train_obs``).

    Unused slots are ``None``/``()`` and flatten to zero leaves, so the
    container costs nothing under jit and checkpoints by field *name*
    (``state|opt|…``) — no consumer pattern-matches slot positions.  This
    replaced the PR-3/PR-4-era positional layouts (bare ``OptState`` /
    2- / 3- / 4-tuples); ``checkpoint.store.restore`` still reads those
    via the legacy key aliases (tests/test_trainer_state.py).
    """

    opt: OptState
    tstates: Tuple = ()
    astate: Any = None
    cres: Any = None
    bstate: Any = None
    mstate: Any = None


def as_trainer_state(state) -> TrainerState:
    """Coerce a bare :class:`OptState` (the pre-PR-5 plain layout) into a
    :class:`TrainerState`; pass a TrainerState through unchanged."""
    if isinstance(state, TrainerState):
        return state
    if isinstance(state, OptState):
        return TrainerState(opt=state)
    raise TypeError(
        f"expected TrainerState (or a bare OptState), got {type(state)}; "
        "seed trainer state with dist.init_train_state")


def _resolve_codec(codec):
    """Codec spec string / instance / None -> codec instance or None."""
    if codec is None or not isinstance(codec, str):
        return codec
    from repro.comm import codecs as CC
    return CC.get_codec(codec)


def _derive_mesh_ctx(shard_map_mesh, shard_map_axes, spmd
                     ) -> Optional[api.MeshContext]:
    """Resolve the (mesh, axes, spmd) trio both trainers accept.

    ``spmd=None`` auto-enables the mesh-native path whenever a mesh is
    given; ``shard_map_axes`` overrides the worker-axis derivation from
    the mesh's axis names (the satellite fix: the parameter is honored,
    not recorded-and-dropped).
    """
    if spmd is None:
        spmd = shard_map_mesh is not None
    if not spmd:
        return None
    if shard_map_mesh is None:
        raise ValueError("spmd aggregation needs shard_map_mesh")
    return api.MeshContext.for_mesh(
        shard_map_mesh,
        worker_axes=tuple(shard_map_axes) if shard_map_axes else None)


def init_train_state(opt: Optimizer, params: PyTree,
                     transforms: Sequence[api.Transform] = (),
                     n_workers: int = 0, attack: str = "none",
                     attack_f: int = 0, codec=None) -> TrainerState:
    """Initial :class:`TrainerState` for :func:`make_train_step`.

    Plain runs get only the ``opt`` slot populated; stateful transforms
    (worker momentum) fill ``tstates`` with a per-worker state tuple
    mirroring the *stacked* gradient shapes (hence ``n_workers``); an
    adaptive attack spec (``adaptive_lie``, ``adaptive_mimic`` —
    ``core.attacks.ADAPTIVE``) fills ``astate``, seeded for ``attack_f``
    byzantine rows; an error-feedback codec spec
    (``"topk:frac=0.01,ef=1"`` — ``repro.comm.get_codec``) fills ``cres``
    with the per-worker compression residual.
    """
    opt_state = opt.init(params)
    stateful = any(t.stateful for t in transforms)
    adaptive = isinstance(attack, str) and ATK.is_adaptive(attack)
    codec_obj = _resolve_codec(codec)
    ef = codec_obj is not None and codec_obj.stateful
    if not stateful and not adaptive and not ef:
        return TrainerState(opt=opt_state)
    if n_workers <= 0:
        raise ValueError("stateful transforms / adaptive attacks / "
                         "error-feedback codecs need n_workers > 0")
    stacked = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((n_workers,) + p.shape, p.dtype),
        params)
    tstates: Tuple = ()
    if stateful:
        tstates = api.init_transform_states(transforms, stacked)
    astate = None
    if adaptive:
        astate = ATK.get_adaptive(attack).init_state(n_workers, attack_f)
    cres = codec_obj.init_residual(stacked) if ef else None
    return TrainerState(opt=opt_state, tstates=tstates, astate=astate,
                        cres=cres)


# ------------------------------------------------------------------ trainer
# The honest-mean deviation telemetry is shared between the stacked and
# streaming trainers (accumulate per block, finalise once) so the metric is
# numerically identical across substrates — campaign traces must be
# trainer-comparable.
def honest_dev_accumulate(dev_sq: jax.Array, ref_sq: jax.Array,
                          agg: PyTree, grads: PyTree, f_eff: int):
    """Add one (sub)tree's ||agg - honest_mean||² / ||honest_mean||² terms.

    ``grads`` is the stack the aggregator consumed (post-injection,
    post-transform); rows ``f_eff:`` of every leaf are the honest workers'
    values, so this measures the distance to the oracle that knew who was
    honest.
    """
    for a, g in zip(jax.tree.leaves(agg), jax.tree.leaves(grads)):
        hm = jnp.mean(g[f_eff:].astype(jnp.float32), axis=0)
        dev_sq = dev_sq + jnp.sum((a.astype(jnp.float32) - hm) ** 2)
        ref_sq = ref_sq + jnp.sum(hm ** 2)
    return dev_sq, ref_sq


def honest_dev_finalize(dev_sq: jax.Array, ref_sq: jax.Array) -> jax.Array:
    return jnp.sqrt(dev_sq) / (jnp.sqrt(ref_sq) + 1e-12)


def _honest_mean_dev(agg: PyTree, grads: PyTree, f_eff: int) -> jax.Array:
    """Relative l2 deviation of the aggregate from the honest-row mean."""
    zero = jnp.zeros((), jnp.float32)
    return honest_dev_finalize(
        *honest_dev_accumulate(zero, zero, agg, grads, f_eff))


def make_train_step(cfg: ArchConfig, rcfg: RobustConfig, opt: Optimizer,
                    lr_fn, *, window: int = 0, chunk_q: int = 1024,
                    attack: str = "none", attack_f: Optional[int] = None,
                    transforms: Sequence[api.Transform] = (),
                    codec: Optional[str] = None,
                    coord_chunk: int = 0, telemetry: bool = False,
                    grad_specs: Optional[PyTree] = None,
                    boundary_spec=None,
                    shard_map_mesh=None, shard_map_axes=None,
                    spmd: Optional[bool] = None,
                    hier=None,
                    obs: Optional[OBS.ObsConfig] = None):
    """Build the stacked-trainer step function (jit it yourself).

    ``attack`` is a spec string (``"little_is_enough:z=2.0"`` — see
    ``core.attacks.get_attack``); adaptive specs (``adaptive_lie``, …) make
    the state slot carry the attack's feedback state (seed it with
    :func:`init_train_state`).  ``attack_f`` is the number of rows the
    attack actually controls this phase (defaults to ``rcfg.f``, may be
    lower — the rule keeps defending against the full contract ``f``).

    ``codec`` puts a compressed wire between workers and aggregator
    (``repro.comm.get_codec`` specs — ``"qsgd:bits=8"``, ``"bf16"``, …):
    every worker *encodes* its gradient rows, byzantine injection then
    happens on the wire format — gradient-space attacks propose rows that
    get encoded like honest ones, wire attacks (``scale_poison``,
    ``payload_flip``) mutate payloads/sidecars directly — and the
    aggregator consumes the wire container (statistics straight off the
    quantized payloads under ``rcfg.use_pallas`` via the fused
    dequantize→stats kernel, apply on the decoded rows).  Error-feedback
    codecs (``ef=1``) thread a per-worker residual through the state
    (:func:`init_train_state`).

    ``obs`` — an enabled ``repro.obs.ObsConfig`` — makes the step record
    into the device-resident registry riding in ``TrainerState.mstate``
    (rounds counter, loss / grad-norm gauges + histogram, suspicion EMA
    under ``telemetry``) and ring-buffer stats→plan→apply span records
    (DESIGN.md §14).  Disabled or ``None`` compiles to the bitwise
    jaxpr of the uninstrumented step (tests/test_obs.py).

    With ``telemetry`` the metrics dict gains a ``"telemetry"`` sub-dict of
    plan diagnostics (``AggPlan.diagnostics``: per-worker selection mass,
    byzantine captured mass, Krum score spectrum, selection-boundary gap)
    plus ``honest_dev`` — campaign traces in ``repro.sim`` scan over these
    — and, under a codec, ``wire_bytes_per_worker``.

    ``grad_specs``/``shard_map_mesh``: optional PartitionSpec pytree pinned
    onto the stacked gradients (the transposed grad-stack layout the
    production mesh wants); ``boundary_spec`` threads to the model's remat
    boundaries.

    ``shard_map_mesh`` + ``spmd`` (default: on whenever a mesh is given)
    run the whole stats→plan→apply pipeline mesh-native (DESIGN.md §10):
    statistics shard the worker axis inside a shard_map (each device
    computes its row block of the (n, n) matrix), the apply phase shards
    d over the model axis.  ``shard_map_axes`` names the worker axes of
    that path explicitly (default: derived from the mesh's axis names —
    ``("pod", "data")`` multi-pod, ``("data",)`` otherwise).

    ``hier`` — a ``repro.hier.GroupConfig`` — replaces the flat
    stats→plan→apply with the two-level grouped pipeline (DESIGN.md §11):
    robust-aggregate within groups of ``hier.g`` workers, then over the
    group aggregates, with per-level f budgets derived and checked by
    ``core.theory.split_f_budget``.  Under a codec the group aggregates
    are re-encoded for the leaders→server hop (telemetry surfaces its
    byte count as ``leader_wire_bytes``); telemetry gains
    ``group_selection``, the outer level's per-group mass.  Not yet
    composable with the mesh-native (``spmd``) path or error-feedback
    codecs.
    """
    rcfg.validate()
    transforms = tuple(transforms)
    f_eff = rcfg.f if attack_f is None else attack_f
    if not 0 <= f_eff <= rcfg.f:
        raise ValueError(
            f"attack_f must be in [0, f] (attack_f={f_eff}, f={rcfg.f})")
    codec_obj = _resolve_codec(codec)
    wire = isinstance(attack, str) and ATK.is_wire_attack(attack)
    if wire and codec_obj is None:
        raise ValueError(
            f"wire attack {attack!r} needs a codec= wire to attack "
            f"(available codecs: see repro.comm.available_codecs())")
    adaptive = ATK.get_adaptive(attack) \
        if not wire and ATK.is_adaptive(attack) else None
    mesh_ctx = _derive_mesh_ctx(shard_map_mesh, shard_map_axes, spmd)
    # telemetry wants the score spectrum even for distance-free rules
    # (average / median campaigns report why they would have been rejected);
    # the backend is the same plan/apply pipeline robust serving and the
    # async service consume (DESIGN.md §13)
    backend = api.AggregatorBackend.for_config(
        rcfg, coord_chunk=coord_chunk, needs_dists=telemetry,
        mesh_ctx=mesh_ctx, obs=obs)
    needs_dists = backend.aggregator.needs_dists or telemetry
    obs_live = OBS.obs_on(obs)
    obs_trace = obs_live and obs.trace
    if hier is not None:
        if mesh_ctx is not None:
            raise NotImplementedError(
                "hier= is not composable with the mesh-native (spmd) "
                "aggregation path yet; drop shard_map_mesh/spmd")
        if codec_obj is not None and codec_obj.stateful:
            raise ValueError(
                "hier= does not support error-feedback codecs (the "
                "leaders→server hop has no residual slot); drop ef=1")

    def worker_loss(p, wb):
        return MD.loss_fn(p, cfg, wb, window=window, chunk_q=chunk_q,
                          boundary_spec=boundary_spec)

    def step(params, state, batch, key):
        state = as_trainer_state(state)
        opt_state, tstates = state.opt, state.tstates
        astate, cres = state.astate, state.cres
        mstate = state.mstate
        losses, grads = jax.vmap(
            lambda wb: jax.value_and_grad(worker_loss)(params, wb))(batch)
        if obs_live and mstate is None:
            # trace-time seed: the worker count is static here, and a jit
            # caller retraces once when None becomes a live carry.  Scans
            # seed up front instead (repro.obs.init_train_obs).
            mstate = OBS.init_train_obs(obs, losses.shape[0],
                                        telemetry=telemetry)
        obs_round = opt_state.step
        if adaptive is not None:
            atk = functools.partial(adaptive.propose, state=astate)
        else:
            atk = attack
        if not wire:
            # gradient-space adversary: proposes rows before encoding (it
            # controls its wire messages, so it encodes like anyone else)
            grads = inject_byzantine(grads, f_eff, atk, key)
        enc = None
        if codec_obj is not None:
            # distinct fold for quantization randomness: attack leaves use
            # fold_in(key, leaf_index), transforms 2^31-1 (below)
            ekey = jax.random.fold_in(key, 2 ** 31 - 2)
            enc, cres = codec_obj.encode(grads, key=ekey, residual=cres)
            if wire:
                enc = inject_wire(enc, f_eff, attack, key)
            # the aggregator-side view: everything downstream (transforms,
            # apply, honest_dev) sees what survived the wire
            grads = codec_obj.decode(enc)
        if grad_specs is not None and shard_map_mesh is not None:
            from jax.sharding import NamedSharding
            grads = jax.lax.with_sharding_constraint(
                grads, jax.tree.map(
                    lambda s: NamedSharding(shard_map_mesh, s), grad_specs,
                    is_leaf=lambda x: not isinstance(x, dict)))
        # distinct fold for transform randomness: inject_byzantine consumes
        # fold_in(key, leaf_index), so a keyed transform must not draw from
        # the same stream as any attack leaf
        tkey = jax.random.fold_in(key, 2 ** 31 - 1)
        grads, tstates = api.apply_transforms(
            grads, transforms, tstates or None, key=tkey,
            use_pallas=rcfg.use_pallas)
        # statistics straight off the wire container (fused dequant→stats
        # under use_pallas) unless a transform rewrote the decoded stack
        stats_src = enc if (enc is not None and not transforms) else grads
        if hier is not None:
            from repro.hier import hier_aggregate_tree
            agg, plan, hinfo = hier_aggregate_tree(
                stats_src, rcfg.f, hier, codec=codec_obj, key=key,
                coord_chunk=coord_chunk, use_pallas=rcfg.use_pallas,
                needs_dists=needs_dists, obs=obs, obs_state=mstate,
                obs_round=obs_round)
            mstate = hinfo["obs_state"]
            stats = None
        else:
            # backend.plan validates stats.n against the actual batch
            # split (which RobustConfig's construction-time check never
            # saw) before any selection runs
            stats = backend.stats(stats_src)
            if obs_trace:
                mstate = {**mstate, "t": OBS.record(
                    mstate["t"], OBS.PH_STATS, obs_round)}
            plan = backend.plan(stats)
            if obs_trace:
                mstate = {**mstate, "t": OBS.record(
                    mstate["t"], OBS.PH_PLAN, obs_round,
                    jnp.max(plan.selection_weights()))}
            agg = backend.apply(plan, grads)
        if adaptive is not None:
            astate = adaptive.update(astate, plan.selection_weights())
        lr = lr_fn(opt_state.step)
        new_params, new_opt = opt.update(agg, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(agg)))
        metrics = {
            "loss": jnp.mean(losses),
            "loss_per_worker": losses,
            "lr": jnp.asarray(lr, jnp.float32),
            "agg_grad_norm": gnorm,
        }
        if telemetry:
            diag = plan.diagnostics(hinfo["inner_stats"]) \
                if hier is not None else plan.diagnostics(stats)
            # count captured mass over the rows the attack actually holds
            # this phase (f_eff), not the rule's contract f
            diag["byz_mass"] = jnp.sum(diag["selection"][:f_eff])
            diag["honest_dev"] = _honest_mean_dev(agg, grads, f_eff)
            if enc is not None:
                diag["wire_bytes_per_worker"] = jnp.asarray(
                    enc.bytes_per_worker, jnp.float32)
            if hier is not None and codec_obj is not None:
                diag["leader_wire_bytes"] = jnp.asarray(
                    hinfo["leader_wire_bytes"], jnp.float32)
            metrics["telemetry"] = diag
        if obs_live:
            m = mstate["m"]
            m = OBS.inc(m, "rounds")
            m = OBS.set_gauge(m, "loss", metrics["loss"])
            m = OBS.set_gauge(m, "agg_grad_norm", gnorm)
            m = OBS.observe(m, "agg_grad_norm", gnorm)
            if telemetry:
                m = OBS.set_gauge(m, "byz_mass", diag["byz_mass"])
                m = OBS.set_gauge(m, "suspicion", OBS.update_suspicion(
                    m.gauges["suspicion"], diag["selection"],
                    obs.suspicion_ema))
            t = mstate["t"]
            if obs_trace:
                t = OBS.record(t, OBS.PH_APPLY, obs_round, gnorm)
            mstate = {"m": m, "t": t}
        return (new_params,
                TrainerState(opt=new_opt, tstates=tstates, astate=astate,
                             cres=cres, bstate=state.bstate,
                             mstate=mstate),
                metrics)

    return step
