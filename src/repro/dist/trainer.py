"""Stacked byzantine-SGD trainer built on the core plan/apply Aggregator API.

One train step (DESIGN.md §3):

1. forward+backward per worker (``vmap`` over the leading worker axis of the
   batch) -> stacked gradient pytree, every leaf ``(n, ...)``;
2. :func:`inject_byzantine` overwrites the first ``f`` worker rows with the
   selected attack's proposals (gradient-level omniscient adversary);
3. the optional pre-aggregation transform pipeline (worker momentum,
   clipping, nearest-neighbour mixing — ``core.api``) rewrites the stack;
4. ``Aggregator.plan`` on the replicated (n, n) statistics, then
   ``Aggregator.apply`` leaf-by-leaf (sharding-preserving einsums +
   coordinate phase);
5. one optimizer update from the aggregated gradient.

The returned step has signature ``(params, opt_state, batch, key) ->
(params, opt_state, metrics)``; when a stateful transform is configured the
state slot instead carries ``(opt_state, transform_states)`` — seed it with
:func:`init_train_state`.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import api
from repro.core import attacks as ATK
from repro import models as MD
from repro.optim.optimizers import OptState, Optimizer

PyTree = Any


# --------------------------------------------------------------------- data
def split_workers(batch: PyTree, n_workers: int) -> PyTree:
    """(global_batch, ...) leaves -> (n_workers, per_worker, ...) leaves."""

    def sp(x):
        b = x.shape[0]
        if b % n_workers:
            raise ValueError(
                f"global batch {b} not divisible by n_workers={n_workers}")
        return x.reshape((n_workers, b // n_workers) + x.shape[1:])

    return jax.tree.map(sp, batch)


# ------------------------------------------------------------------ attacks
def _attack_leaf(name: str, leaf: jax.Array, f: int, key) -> jax.Array:
    """Replace the first f worker rows of one leaf with attack proposals.

    The attack sees the (n-f, numel) stack of *correct* gradients (rows
    f..n), per the omniscient-adversary convention in ``core/attacks.py``.
    """
    correct = leaf[f:]
    flat = correct.reshape((correct.shape[0], -1)).astype(jnp.float32)
    byz = ATK.get_attack(name)(flat, f, key)
    byz = byz.reshape((f,) + leaf.shape[1:]).astype(leaf.dtype)
    return jnp.concatenate([byz, correct], axis=0)


def inject_byzantine(grads: PyTree, f: int, attack: str, key,
                     *, leaf_offset: int = 0) -> PyTree:
    """Overwrite the first ``f`` worker rows of every leaf with the attack.

    Per-leaf keys are ``fold_in(key, leaf_offset + leaf_index)`` so that a
    streaming trainer processing blocks of leaves reproduces the stacked
    trainer's randomness exactly (``leaf_offset`` = the block's position in
    the full tree's leaf order).
    """
    if f == 0:
        return grads
    leaves, treedef = jax.tree.flatten(grads)
    out = [
        _attack_leaf(attack, leaf, f,
                     jax.random.fold_in(key, leaf_offset + i))
        for i, leaf in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------------------ state packing
def _split_state(state, stateful: bool) -> Tuple[OptState, Tuple]:
    if stateful:
        opt_state, tstates = state
        return opt_state, tstates
    return state, ()


def _merge_state(opt_state: OptState, tstates: Tuple, stateful: bool):
    return (opt_state, tstates) if stateful else opt_state


def init_train_state(opt: Optimizer, params: PyTree,
                     transforms: Sequence[api.Transform] = (),
                     n_workers: int = 0):
    """Initial trainer state: OptState, or (OptState, transform states).

    Stateful transforms (worker momentum) track one slot per worker — their
    state mirrors the *stacked* gradient shapes, hence ``n_workers``.
    """
    opt_state = opt.init(params)
    if not any(t.stateful for t in transforms):
        return opt_state
    if n_workers <= 0:
        raise ValueError("stateful transforms need n_workers > 0")
    stacked = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((n_workers,) + p.shape, p.dtype),
        params)
    return opt_state, api.init_transform_states(transforms, stacked)


# ------------------------------------------------------------------ trainer
def make_train_step(cfg: ArchConfig, rcfg: RobustConfig, opt: Optimizer,
                    lr_fn, *, window: int = 0, chunk_q: int = 1024,
                    attack: str = "none",
                    transforms: Sequence[api.Transform] = (),
                    coord_chunk: int = 0,
                    grad_specs: Optional[PyTree] = None,
                    boundary_spec=None,
                    shard_map_mesh=None, shard_map_axes=None):
    """Build the stacked-trainer step function (jit it yourself).

    ``grad_specs``/``shard_map_mesh``: optional PartitionSpec pytree pinned
    onto the stacked gradients (the transposed grad-stack layout the
    production mesh wants); ``boundary_spec`` threads to the model's remat
    boundaries.  ``shard_map_axes`` names the worker axes (dry-run plumbing).
    """
    del shard_map_axes  # recorded by the builder; worker axis comes from specs
    rcfg.validate()
    aggregator = api.get_aggregator(rcfg.gar)
    transforms = tuple(transforms)
    stateful = any(t.stateful for t in transforms)

    def worker_loss(p, wb):
        return MD.loss_fn(p, cfg, wb, window=window, chunk_q=chunk_q,
                          boundary_spec=boundary_spec)

    def step(params, state, batch, key):
        opt_state, tstates = _split_state(state, stateful)
        losses, grads = jax.vmap(
            lambda wb: jax.value_and_grad(worker_loss)(params, wb))(batch)
        grads = inject_byzantine(grads, rcfg.f, attack, key)
        if grad_specs is not None and shard_map_mesh is not None:
            from jax.sharding import NamedSharding
            grads = jax.lax.with_sharding_constraint(
                grads, jax.tree.map(
                    lambda s: NamedSharding(shard_map_mesh, s), grad_specs,
                    is_leaf=lambda x: not isinstance(x, dict)))
        # distinct fold for transform randomness: inject_byzantine consumes
        # fold_in(key, leaf_index), so a keyed transform must not draw from
        # the same stream as any attack leaf
        tkey = jax.random.fold_in(key, 2 ** 31 - 1)
        grads, tstates = api.apply_transforms(
            grads, transforms, tstates or None, key=tkey,
            use_pallas=rcfg.use_pallas)
        stats = api.compute_stats(grads, rcfg.f,
                                  needs_dists=aggregator.needs_dists,
                                  use_pallas=rcfg.use_pallas)
        # guard against an out-of-band worker count: stats.n comes from the
        # actual batch split, which RobustConfig's construction-time check
        # never saw.  plan() implementations are not required to
        # self-validate (streaming.py already guards every plan call).
        aggregator.validate(stats.n, stats.f)
        plan = aggregator.plan(stats)
        agg = aggregator.apply(plan, grads, coord_chunk=coord_chunk,
                               use_pallas=rcfg.use_pallas)
        lr = lr_fn(opt_state.step)
        new_params, new_opt = opt.update(agg, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(agg)))
        metrics = {
            "loss": jnp.mean(losses),
            "loss_per_worker": losses,
            "lr": jnp.asarray(lr, jnp.float32),
            "agg_grad_norm": gnorm,
        }
        return new_params, _merge_state(new_opt, tstates, stateful), metrics

    return step
