"""Serving path: batched prefill + token-by-token decode over the KV caches.

``generate`` is the driver-facing entry point (launch/serve.py, examples);
``make_serve_step`` is the jit-ready single-token step the dry-run lowers on
the production mesh (the cache length axis model-sharded, chunk-local
partial-softmax decode attention).

``make_robust_serve_step`` is the byzantine-tolerant ensemble variant: n
model replicas decode in lockstep and their per-token logits are fused with
a registered GAR through the same plan/apply path the trainers use — with
``RobustConfig.use_pallas`` the bulyan apply runs the fused VMEM kernel, so
robust serving pays one HBM read of the (n, B·V) logit stack per token.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import api
from repro import models as MD

PyTree = Any


def make_serve_step(cfg: ArchConfig, *, window: int = 0,
                    seq_chunks: int = 1):
    """One decode step ``(params, cache, token, pos) -> (logits, cache)``."""

    def step(params, cache, token, pos):
        return MD.decode_fn(params, cfg, token, cache, pos, window=window,
                            seq_chunks=seq_chunks)

    return step


def aggregate_replica_logits(logits: jax.Array, rcfg: RobustConfig,
                             backend: "api.AggregatorBackend | None" = None
                             ) -> jax.Array:
    """(n, B, V) replica logits -> (B, V) robust consensus via rcfg.gar.

    The replica axis plays the worker role: the shared
    :class:`~repro.core.api.AggregatorBackend` plans on the (n, n)
    logit-distance matrix and applies per the plan kind (fused Pallas
    kernel for bulyan-family rules when ``rcfg.use_pallas``) — the exact
    pipeline the trainers and the async service run.  Up to f compromised
    or corrupted replicas cannot steer the served distribution outside the
    honest replicas' spread.
    """
    if backend is None:
        backend = api.AggregatorBackend.for_config(rcfg)
    return backend(logits)


def make_robust_serve_step(cfg: ArchConfig, rcfg: RobustConfig, *,
                           window: int = 0, seq_chunks: int = 1,
                           backend: "api.AggregatorBackend | None" = None):
    """Ensemble decode step over ``rcfg.n_workers`` stacked model replicas.

    ``(stacked_params, stacked_caches, token, pos) -> (logits, caches)``
    where every leaf of ``stacked_params``/``stacked_caches`` carries a
    leading replica axis of size n.  The fused (B, V) logits are the GAR
    consensus of the replicas' outputs, computed by the same
    :class:`~repro.core.api.AggregatorBackend` the trainers use (pass
    ``backend`` to share one instance across training and serving).
    """
    rcfg.validate()
    if backend is None:
        backend = api.AggregatorBackend.for_config(rcfg)

    def step(stacked_params, stacked_caches, token, pos):
        logits, caches = jax.vmap(
            lambda p, c: MD.decode_fn(p, cfg, token, c, pos, window=window,
                                      seq_chunks=seq_chunks),
        )(stacked_params, stacked_caches)
        return aggregate_replica_logits(logits, rcfg, backend), caches

    return step


def _select_token(logits: jax.Array, sample: str, key, step: int) -> jax.Array:
    if sample == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if sample == "categorical":
        if key is None:
            raise ValueError("categorical sampling needs a PRNG key")
        k = jax.random.fold_in(key, step)
        return jax.random.categorical(
            k, logits.astype(jnp.float32)).astype(jnp.int32)
    raise ValueError(f"unknown sample mode {sample!r}")


def generate(params: PyTree, cfg: ArchConfig, prompt: jax.Array,
             new_tokens: int, *, window: int = 0, chunk_q: int = 512,
             sample: str = "greedy", key=None,
             extra_batch: Optional[Dict[str, jax.Array]] = None) -> jax.Array:
    """Prefill ``prompt`` (B, S) and decode ``new_tokens`` continuations.

    ``extra_batch`` carries the family-specific inputs: ``frames`` (audio
    enc-dec) and/or ``prefix_embeds`` (vlm prefix).  Returns (B, new_tokens)
    int32.  ``window > 0`` serves from the sliding-window ring cache (the
    long_500k path); otherwise the cache holds prompt + new_tokens exactly.
    """
    batch: Dict[str, jax.Array] = {"tokens": prompt}
    if extra_batch:
        batch.update(extra_batch)

    # absolute decode positions: vlm prefix embeddings occupy cache slots
    # before the prompt tokens; the audio encoder memory does not.
    n_prefix = 0
    if not cfg.is_encdec and batch.get("prefix_embeds") is not None:
        n_prefix = batch["prefix_embeds"].shape[1]
    prompt_total = prompt.shape[1] + n_prefix
    cache_len = prompt_total + new_tokens

    logits, cache = MD.prefill_fn(params, cfg, batch, window=window,
                                  chunk_q=chunk_q, cache_len=cache_len)

    decode = jax.jit(lambda p, tok, c, pos: MD.decode_fn(
        p, cfg, tok, c, pos, window=window))

    out = []
    for t in range(new_tokens):
        tok = _select_token(logits, sample, key, t)
        out.append(tok)
        if t + 1 < new_tokens:
            logits, cache = decode(params, tok, cache,
                                   jnp.int32(prompt_total + t))
    return jnp.stack(out, axis=1).astype(jnp.int32)
