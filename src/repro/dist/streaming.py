"""Streaming Multi-Bulyan: per-block backward passes, plan reuse (DESIGN.md §5).

The stacked trainer materialises the full n×d gradient stack at once —
impossible at 398B scale.  The streaming trainer exploits the plan/apply
split: the *plan* needs only the (n, n) distance matrix, which is a sum of
per-leaf contributions and can therefore be accumulated block by block
without ever holding more than one block's worker gradients; the *apply*
phase is per-leaf anyway.  Two scopes:

* ``scope="global"`` — exact Algorithm 1: pass 1 walks the parameter blocks
  accumulating the global distance matrix (gradients discarded per block),
  the plan is computed once, pass 2 re-walks the blocks applying it.  Two
  backward passes, peak gradient memory n·d/n_blocks, bit-close to the
  stacked trainer (property-tested in tests/test_trainer.py).
* ``scope="block"`` — one pass: each block computes its own distances, plan
  and aggregate.  Half the compute, but selection is per-block (a byzantine
  worker can win in one block and lose in another) — the robustness
  guarantee degrades gracefully to per-block resilience.

Blocks are the top-level entries of the parameter pytree (embed / groups /
final_norm / lm_head for the decoder-only stack).  Per-block gradients are
taken wrt the block subtree with the rest of the parameters closed over, so
each value equals the corresponding slice of the full gradient.

With ``rcfg.use_pallas`` both trainers ride the fused kernel stack: block
statistics come from the single-pass ``pairwise_stats`` kernel (one HBM
read per leaf for distances + norms) and the bulyan apply runs entirely in
VMEM via ``fused_select`` — see DESIGN.md §7 for the fused-apply contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import api
from repro.dist.trainer import (_derive_mesh_ctx, _resolve_codec,
                                as_trainer_state, honest_dev_accumulate,
                                honest_dev_finalize, inject_byzantine,
                                inject_wire)
from repro import models as MD
from repro import obs as OBS
from repro.optim.optimizers import Optimizer

PyTree = Any


def _block_keys(params: PyTree):
    """Top-level block names in the full tree's leaf order.

    ``jax.tree.leaves`` iterates dict keys sorted, so walking sorted
    top-level keys and concatenating each subtree's leaves reproduces the
    global leaf order — which keeps per-leaf attack keys identical to the
    stacked trainer's.
    """
    if not isinstance(params, dict):
        return None  # degenerate: single block = the whole tree
    return sorted(params.keys())


def make_streaming_train_step(cfg: ArchConfig, rcfg: RobustConfig,
                              opt: Optimizer, lr_fn, *,
                              scope: str = "block", window: int = 0,
                              chunk_q: int = 1024, attack: str = "none",
                              attack_f: Optional[int] = None,
                              codec: Optional[str] = None,
                              coord_chunk: int = 0, telemetry: bool = False,
                              transforms: Sequence[api.Transform] = (),
                              boundary_spec=None, dx_spec=None,
                              shard_map_mesh=None, shard_map_axes=None,
                              spmd: Optional[bool] = None,
                              hier=None,
                              obs: Optional[OBS.ObsConfig] = None):
    """Build the streaming-trainer step function (same signature as stacked).

    ``attack`` accepts the same spec strings as the stacked trainer
    (``"little_is_enough:z=2.0"``); adaptive attacks are rejected — their
    plan feedback needs the full-stack step structure.  ``attack_f``
    (default ``rcfg.f``) is the number of rows the attack controls.

    ``codec`` puts the compressed wire (``repro.comm``) between workers and
    aggregator *per block*: each block's stack is encoded with the global
    leaf-offset key convention, so the wire payloads — and any wire attack
    on them — are identical to the stacked trainer's; pass-1 statistics
    accumulate straight off the quantized payloads (fused dequantize→stats
    under ``rcfg.use_pallas``).  Error-feedback codecs (``ef=1``) are
    rejected — their residual needs the stacked trainer's state slot.

    With ``telemetry`` the metrics gain the same ``"telemetry"`` sub-dict as
    the stacked trainer; under ``scope="block"`` the plan diagnostics are
    averaged over block plans (selection is per-block there — exactly the
    degradation the diagnostics exist to show).

    ``dx_spec`` (a PartitionSpec for the per-block stacked gradients) is
    accepted for the dry-run builder's mesh plumbing; it only matters when
    lowering on a production mesh.

    ``shard_map_mesh``/``shard_map_axes``/``spmd`` mirror the stacked
    trainer (DESIGN.md §10): pass-1 statistics accumulate each block's
    row-block contributions inside a shard_map over the worker axes, and
    the apply phase shards d over the model axis.

    ``hier`` (a ``repro.hier.GroupConfig``) runs the two-level grouped
    aggregation (DESIGN.md §11).  Under ``scope="global"`` pass 1
    accumulates ceil(n/g) per-group distance matrices block by block —
    never an (n, n) one — pass 2 applies the inner plans per group and
    stores only the ``(n_groups, ...)`` intermediates, and the outer
    phase runs once over those; ``scope="block"`` runs the full two-level
    pipeline per block.  Not composable with the mesh-native path.  The step takes and
    returns a :class:`~repro.dist.trainer.TrainerState` (only the ``opt``
    slot is live — a state carrying transform/attack/residual extras is
    rejected at trace time, since this trainer would silently never
    update them); a bare ``OptState`` is coerced on entry.

    ``obs`` mirrors the stacked trainer (DESIGN.md §14): an enabled
    ``repro.obs.ObsConfig`` threads the device-resident registry through
    ``TrainerState.mstate`` (the one extra slot this trainer *does*
    carry) and records stats→plan→apply spans per step; disabled/None
    compiles to the bitwise uninstrumented jaxpr.
    """
    if scope not in ("block", "global"):
        raise ValueError(f"scope must be 'block' or 'global', got {scope!r}")
    if transforms:
        raise NotImplementedError(
            "pre-aggregation transforms need the full stack; use the "
            "stacked trainer (dist.make_train_step) with transforms")
    from repro.core import attacks as ATK
    wire = isinstance(attack, str) and ATK.is_wire_attack(attack)
    if not wire and isinstance(attack, str) and ATK.is_adaptive(attack):
        raise NotImplementedError(
            "adaptive attacks need the stacked trainer's plan-feedback "
            "state; use dist.make_train_step")
    del dx_spec
    rcfg.validate()
    aggregator = api.get_aggregator(rcfg.gar)
    f_eff = rcfg.f if attack_f is None else attack_f
    if not 0 <= f_eff <= rcfg.f:
        raise ValueError(
            f"attack_f must be in [0, f] (attack_f={f_eff}, f={rcfg.f})")
    codec_obj = _resolve_codec(codec)
    if wire and codec_obj is None:
        raise ValueError(
            f"wire attack {attack!r} needs a codec= wire to attack")
    if codec_obj is not None and codec_obj.stateful:
        raise NotImplementedError(
            "error-feedback codecs carry a per-worker residual; use the "
            "stacked trainer (dist.make_train_step) with codec")
    mesh_ctx = _derive_mesh_ctx(shard_map_mesh, shard_map_axes, spmd)
    hier_budget = inner_agg = outer_agg = None
    if hier is not None:
        if mesh_ctx is not None:
            raise NotImplementedError(
                "hier= is not composable with the mesh-native (spmd) "
                "aggregation path yet; drop shard_map_mesh/spmd")
        # budget checked once at build time — rcfg.n_workers is the worker
        # count every block's stack will carry
        hier_budget = hier.budget(rcfg.n_workers, rcfg.f)
        inner_agg = api.get_aggregator(hier.rule)
        outer_agg = api.get_aggregator(hier.resolve_outer_rule(hier_budget))

    def worker_loss(p, wb):
        return MD.loss_fn(p, cfg, wb, window=window, chunk_q=chunk_q,
                          boundary_spec=boundary_spec)

    obs_live = OBS.obs_on(obs)
    obs_trace = obs_live and obs.trace

    def step(params, state, batch, key):
        state = as_trainer_state(state)
        if state.tstates or state.astate is not None \
                or state.cres is not None:
            raise NotImplementedError(
                "the streaming trainer carries only the opt slot; a "
                "TrainerState with live tstates/astate/cres belongs to "
                "the stacked trainer (dist.make_train_step)")
        opt_state = state.opt
        mstate = state.mstate
        if obs_live and mstate is None:
            mstate = OBS.init_train_obs(obs, rcfg.n_workers,
                                        telemetry=telemetry)
        obs_round = opt_state.step
        block_keys = _block_keys(params)

        def block_grads(p, k, with_loss=False):
            """Per-worker grads wrt block k of p (others closed over)."""
            if k is None:
                vg = jax.value_and_grad(worker_loss)
                out = jax.vmap(lambda wb: vg(p, wb))(batch)
                return out if with_loss else out[1]

            def loss_of(bp, wb):
                q = dict(p)
                q[k] = bp
                return worker_loss(q, wb)

            vg = jax.value_and_grad(loss_of)
            out = jax.vmap(lambda wb: vg(p[k], wb))(batch)
            return out if with_loss else out[1]

        blocks = [None] if block_keys is None else block_keys
        # global leaf offsets so attack randomness matches the stacked path
        offsets, off = {}, 0
        for k in blocks:
            sub = params if k is None else params[k]
            offsets[k] = off
            off += len(jax.tree.leaves(sub))

        def wire_block(g, off):
            """Injection + the simulated wire for one block's stack.

            Returns ``(enc, decoded)`` — ``enc`` is None without a codec.
            Encode keys use the global-leaf-offset convention, so payloads
            (and wire-attack randomness) match the stacked trainer's
            bit for bit.
            """
            if not wire:
                g = inject_byzantine(g, f_eff, attack, key, leaf_offset=off)
            if codec_obj is None:
                return None, g
            ekey = jax.random.fold_in(key, 2 ** 31 - 2)
            enc, _ = codec_obj.encode(g, key=ekey, leaf_offset=off)
            if wire:
                enc = inject_wire(enc, f_eff, attack, key, leaf_offset=off)
            return enc, codec_obj.decode(enc)

        plan = None
        global_diag = None
        hier_inner_plans = hier_inner_stats = None
        if hier is not None and scope == "global":
            bounds = hier_budget.bounds()
            if inner_agg.needs_dists or telemetry:
                # pass 1, grouped: accumulate ceil(n/g) per-group distance
                # matrices block by block — the (n, n) matrix never exists.
                # Per-group accumulation is leaf-by-leaf in global leaf
                # order, and each entry is a full-d reduction over one row
                # pair, so slicing rows before contracting reproduces the
                # stacked hier path's float sums exactly.
                totals = [jnp.zeros((e - s, e - s), jnp.float32)
                          for s, e in bounds]
                for k in blocks:
                    enc, g = wire_block(block_grads(params, k), offsets[k])
                    if enc is not None:
                        from repro.comm import codecs as CC
                        for gi, (s, e) in enumerate(bounds):
                            totals[gi] = totals[gi] + api.raw_pairwise_stats(
                                CC.slice_workers(enc, s, e),
                                use_pallas=rcfg.use_pallas)[0]
                    else:
                        for leaf in jax.tree.leaves(g):
                            for gi, (s, e) in enumerate(bounds):
                                totals[gi] = totals[gi] + \
                                    api.raw_pairwise_stats(
                                        leaf[s:e],
                                        use_pallas=rcfg.use_pallas)[0]
                hier_inner_stats = tuple(
                    api.AggStats(n=e - s, f=hier_budget.f_inner,
                                 dists=api.finalize_dists(t))
                    for (s, e), t in zip(bounds, totals))
            else:
                hier_inner_stats = tuple(
                    api.AggStats(n=e - s, f=hier_budget.f_inner)
                    for s, e in bounds)
            plans = []
            for st in hier_inner_stats:
                inner_agg.validate(st.n, st.f)
                plans.append(inner_agg.plan(st))
            hier_inner_plans = tuple(plans)
            if inner_agg.needs_dists or telemetry:
                # same CSE barrier as the flat global scope: pass 2 must
                # not keep pass 1's block gradients live
                params, hier_inner_plans = jax.lax.optimization_barrier(
                    (params, hier_inner_plans))
        elif scope == "global" and (aggregator.needs_dists or telemetry):
            # pass 1: accumulate the global (n, n) matrix block by block;
            # raw per-leaf contributions in global leaf order, finalised
            # once — the identical float summation the stacked path does.
            # (telemetry also routes distance-free rules through here: the
            # score spectrum is part of the campaign trace schema.)
            total = jnp.zeros((rcfg.n_workers, rcfg.n_workers), jnp.float32)
            for k in blocks:
                enc, g = wire_block(block_grads(params, k), offsets[k])
                if enc is not None:
                    total = total + api.raw_pairwise_stats(
                        enc, use_pallas=rcfg.use_pallas, mesh_ctx=mesh_ctx)[0]
                    continue
                # leaf-by-leaf into the running total: one flat left-to-
                # right float accumulation across ALL blocks' leaves, the
                # exact summation order of the stacked single pass —
                # grouping per block would reassociate the (n, n) sums by
                # up to ~last-ulp·leaves, enough to flip near-tied scores
                for leaf in jax.tree.leaves(g):
                    total = total + api.raw_pairwise_stats(
                        leaf, use_pallas=rcfg.use_pallas,
                        mesh_ctx=mesh_ctx)[0]
            stats = api.AggStats(n=rcfg.n_workers, f=rcfg.f,
                                 dists=api.finalize_dists(total))
            aggregator.validate(stats.n, stats.f)
            plan = aggregator.plan(stats)
            if telemetry:
                global_diag = plan.diagnostics(stats)
            # The barrier is what makes this a *streaming* trainer once
            # compiled: pass-2 recomputes byte-identical per-block gradient
            # subgraphs, and without it XLA CSE would dedupe them against
            # pass 1, keeping every block's gradients live across the plan
            # computation — silently restoring the n·d peak the two-pass
            # structure exists to avoid.  Tying params through the barrier
            # with the plan makes pass 2 depend on pass 1's completion.
            params, plan = jax.lax.optimization_barrier((params, plan))
        elif hier is None and not aggregator.needs_dists:
            # distance-free rules: the plan is block-independent
            stats = api.AggStats(n=rcfg.n_workers, f=rcfg.f)
            aggregator.validate(stats.n, stats.f)
            plan = aggregator.plan(stats)

        if obs_trace:
            # one span per phase per step — pass-1 stats + the (global or
            # per-block) plan; payload marks whether a global plan exists
            t = OBS.record(mstate["t"], OBS.PH_STATS, obs_round)
            t = OBS.record(t, OBS.PH_PLAN, obs_round,
                           0.0 if plan is None else 1.0)
            mstate = {**mstate, "t": t}

        # pass 2 (or the only pass): aggregate block by block; the first
        # block's value_and_grad also yields the per-worker loss metrics
        agg_blocks = {}
        inter_blocks = {}
        hm_blocks = {}
        losses = None
        block_diags = []
        wire_total = 0
        leader_total = 0
        dev_sq = jnp.zeros((), jnp.float32)
        ref_sq = jnp.zeros((), jnp.float32)
        for k in blocks:
            if losses is None:
                losses, g = block_grads(params, k, with_loss=True)
            else:
                g = block_grads(params, k)
            enc, g = wire_block(g, offsets[k])
            if enc is not None:
                wire_total += enc.wire_bytes
            if hier is not None and scope == "block":
                # the full two-level pipeline per block (selection is per
                # block AND per group — the documented scope degradation)
                from repro.hier import hier_aggregate_tree
                agg_k, hplan_k, hinfo_k = hier_aggregate_tree(
                    enc if enc is not None else g, rcfg.f, hier,
                    codec=codec_obj, key=key, coord_chunk=coord_chunk,
                    use_pallas=rcfg.use_pallas,
                    needs_dists=True if telemetry else None)
                agg_blocks[k] = agg_k
                leader_total += hinfo_k["leader_wire_bytes"]
                if telemetry:
                    block_diags.append(
                        hplan_k.diagnostics(hinfo_k["inner_stats"]))
                    dev_sq, ref_sq = honest_dev_accumulate(
                        dev_sq, ref_sq, agg_k, g, f_eff)
                continue
            if hier is not None:
                # scope == "global": apply the global inner plans per
                # group; only the (n_groups, ...) intermediate survives
                # the block — the worker-axis stack is dropped with g
                parts = [
                    inner_agg.apply(
                        pg, jax.tree.map(lambda x: x[s:e], g),
                        coord_chunk=coord_chunk,
                        use_pallas=rcfg.use_pallas)
                    for pg, (s, e) in zip(hier_inner_plans,
                                          hier_budget.bounds())]
                inter_blocks[k] = jax.tree.map(
                    lambda *xs: jnp.stack(xs, axis=0), *parts)
                if telemetry:
                    # honest means are d-sized — keep them for the
                    # deviation once the outer aggregate exists
                    hm_blocks[k] = jax.tree.map(
                        lambda x: jnp.mean(x[f_eff:].astype(jnp.float32),
                                           axis=0), g)
                continue
            block_plan = plan
            if block_plan is None or (telemetry and scope == "block"):
                stats_k = api.compute_stats(
                    enc if enc is not None else g, rcfg.f,
                    needs_dists=True, use_pallas=rcfg.use_pallas,
                    mesh_ctx=mesh_ctx)
                if block_plan is None:  # scope == "block", distance rule
                    aggregator.validate(stats_k.n, stats_k.f)
                    block_plan = aggregator.plan(stats_k)
                if telemetry:
                    block_diags.append(block_plan.diagnostics(stats_k))
            agg_blocks[k] = aggregator.apply(
                block_plan, g, coord_chunk=coord_chunk,
                use_pallas=rcfg.use_pallas, mesh_ctx=mesh_ctx)
            if telemetry:
                dev_sq, ref_sq = honest_dev_accumulate(
                    dev_sq, ref_sq, agg_blocks[k], g, f_eff)

        if hier is not None and scope == "global":
            # outer phase, once, over the stored (n_groups, ...) stack
            inter = inter_blocks[None] if block_keys is None else \
                {k: inter_blocks[k] for k in block_keys}
            outer_plan = None
            if hier_budget.n_groups == 1:
                agg = jax.tree.map(lambda x: x[0], inter)
            else:
                if codec_obj is not None:
                    from repro.hier import LEADER_ENCODE_FOLD
                    k2 = jax.random.fold_in(key, LEADER_ENCODE_FOLD)
                    enc2, _ = codec_obj.encode(inter, key=k2)
                    leader_total += enc2.wire_bytes
                    inter = codec_obj.decode(enc2)
                ost = api.compute_stats(
                    inter, hier_budget.f_outer,
                    needs_dists=outer_agg.needs_dists or telemetry,
                    use_pallas=rcfg.use_pallas)
                outer_agg.validate(ost.n, ost.f)
                outer_plan = outer_agg.plan(ost)
                agg = outer_agg.apply(outer_plan, inter,
                                      coord_chunk=coord_chunk,
                                      use_pallas=rcfg.use_pallas)
            if telemetry:
                from repro.hier import HierPlan
                hplan = HierPlan(
                    inner=hier_inner_plans, outer=outer_plan,
                    n=rcfg.n_workers, f=rcfg.f, g=hier.g,
                    bounds=hier_budget.bounds(),
                    f_inner=hier_budget.f_inner,
                    f_outer=hier_budget.f_outer, rule=hier.rule,
                    outer_rule=hier.resolve_outer_rule(hier_budget))
                global_diag = hplan.diagnostics(hier_inner_stats)
                hm = hm_blocks[None] if block_keys is None else \
                    {k: hm_blocks[k] for k in block_keys}
                for a, m in zip(jax.tree.leaves(agg), jax.tree.leaves(hm)):
                    dev_sq = dev_sq + jnp.sum(
                        (a.astype(jnp.float32) - m) ** 2)
                    ref_sq = ref_sq + jnp.sum(m ** 2)
        elif block_keys is None:
            agg = agg_blocks[None]
        else:
            agg = {k: agg_blocks[k] for k in block_keys}

        lr = lr_fn(opt_state.step)
        new_params, new_opt = opt.update(agg, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(agg)))
        metrics = {
            "loss": jnp.mean(losses),
            "loss_per_worker": losses,
            "lr": jnp.asarray(lr, jnp.float32),
            "agg_grad_norm": gnorm,
        }
        if telemetry:
            if global_diag is not None:
                diag = dict(global_diag)
            else:
                # scope == "block": selection is per-block; report the mean
                # over block plans (the per-block degradation is the point)
                diag = {kk: jnp.mean(jnp.stack([d[kk] for d in block_diags]),
                                     axis=0)
                        for kk in block_diags[0]}
            # captured mass over the rows the attack actually holds (f_eff)
            diag["byz_mass"] = jnp.sum(diag["selection"][:f_eff])
            diag["honest_dev"] = honest_dev_finalize(dev_sq, ref_sq)
            if codec_obj is not None:
                diag["wire_bytes_per_worker"] = jnp.asarray(
                    wire_total / rcfg.n_workers, jnp.float32)
            if hier is not None and codec_obj is not None:
                diag["leader_wire_bytes"] = jnp.asarray(
                    leader_total, jnp.float32)
            metrics["telemetry"] = diag
        if obs_live:
            m = mstate["m"]
            m = OBS.inc(m, "rounds")
            m = OBS.set_gauge(m, "loss", metrics["loss"])
            m = OBS.set_gauge(m, "agg_grad_norm", gnorm)
            m = OBS.observe(m, "agg_grad_norm", gnorm)
            if telemetry:
                m = OBS.set_gauge(m, "byz_mass", diag["byz_mass"])
                m = OBS.set_gauge(m, "suspicion", OBS.update_suspicion(
                    m.gauges["suspicion"], diag["selection"],
                    obs.suspicion_ema))
            t = mstate["t"]
            if obs_trace:
                t = OBS.record(t, OBS.PH_APPLY, obs_round, gnorm)
            mstate = {"m": m, "t": t}
        return (new_params,
                dataclasses.replace(state, opt=new_opt, mstate=mstate),
                metrics)

    return step
