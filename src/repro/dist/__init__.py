"""Distributed byzantine-SGD layer, built on the core plan/apply API.

* ``trainer``   — stacked n×d trainer (`make_train_step`, `split_workers`,
  `inject_byzantine`);
* ``streaming`` — per-block streaming trainer (398B enabler, DESIGN.md §5);
* ``serving``   — batched prefill/decode (`generate`, `make_serve_step`) and
  the byzantine-tolerant replica ensemble (`make_robust_serve_step`);
* ``sharding``  — PartitionSpec heuristics for the production mesh.
"""
from repro.dist.trainer import (  # noqa: F401
    TrainerState,
    as_trainer_state,
    init_train_state,
    inject_byzantine,
    make_train_step,
    split_workers,
)
from repro.dist import sharding  # noqa: F401
