"""PartitionSpec heuristics for the production mesh (DESIGN.md §3).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  The byzantine worker axis of stacked gradients/batches shards
over pod×data; parameter tensors shard tensor-parallel over ``model``.

Every spec goes through :func:`sanitize_spec` — a sharded dim whose size
does not divide the mesh axis product is dropped to replicated, so one
heuristic serves every architecture (40-head qwen2.5, 51865-vocab whisper,
…) without per-arch tables.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any

# the canonical production model-axis width, used when no mesh is given
# (param_specs(params) in tests / single-host tools)
DEFAULT_MODEL_AXIS = 16


def _axis_sizes(mesh) -> dict:
    """Axis-name -> size for a Mesh (or anything with a ``.shape`` mapping)."""
    return dict(mesh.shape)


def _entry_size(entry, sizes: dict) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    return math.prod(sizes.get(a, 1) for a in axes)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop sharding on dims the mesh cannot divide evenly.

    >>> sanitize_spec(P(None, "model"), (384, 51865), mesh)  # 51865 % 16 != 0
    PartitionSpec(None, None)
    """
    sizes = _axis_sizes(mesh)
    out = []
    for dim, entry in enumerate(tuple(spec)):
        if entry is None:
            out.append(None)
        elif dim < len(shape) and shape[dim] % _entry_size(entry, sizes) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def _worker_axes(mesh) -> Any:
    """Mesh axes carrying the byzantine worker dimension (pod×data)."""
    if mesh is not None and "pod" in _axis_sizes(mesh):
        return ("pod", "data")
    return "data"


# ------------------------------------------------------------------ params
def _tp_leaf_spec(shape, msize: int) -> P:
    """Megatron-style tensor-parallel spec for one parameter leaf.

    Shard the largest divisible dim on ``model`` (ties -> the last dim, the
    matmul output dim for the in-projections); vectors stay replicated.
    """
    if len(shape) < 2:
        return P()
    best: Optional[int] = None
    for i, s in enumerate(shape):
        if s % msize == 0 and (best is None or s >= shape[best]):
            best = i
    if best is None:
        return P()
    return P(*("model" if i == best else None for i in range(len(shape))))


def param_specs(params: PyTree, mesh: Optional[Mesh] = None) -> PyTree:
    """Tensor-parallel PartitionSpec pytree matching ``params``' structure."""
    msize = _axis_sizes(mesh)["model"] if mesh is not None else DEFAULT_MODEL_AXIS
    specs = jax.tree.map(lambda x: _tp_leaf_spec(x.shape, msize), params)
    if mesh is not None:
        specs = jax.tree.map(
            lambda x, s: sanitize_spec(s, x.shape, mesh), params, specs)
    return specs


def zero3_param_specs(params: PyTree, mesh: Mesh) -> PyTree:
    """Fully-sharded (zero-3) specs: largest dim over the whole chip count.

    Batch runs over both axes; weights shard over ``("data", "model")`` on
    their largest divisible dim and are all-gathered per layer group.
    """
    sizes = _axis_sizes(mesh)
    both = ("data", "model")

    def leaf(x):
        if x.ndim == 0:
            return P()
        best = max(range(x.ndim), key=lambda i: x.shape[i])
        cand = [both, "model", "data"]
        for c in cand:
            spec = P(*(c if i == best else None for i in range(x.ndim)))
            s = sanitize_spec(spec, x.shape, mesh)
            if tuple(s)[best] is not None:
                return s
        return P()

    del sizes
    return jax.tree.map(leaf, params)


# ----------------------------------------------------------------- batches
def batch_specs(batch: PyTree, mesh: Mesh, *,
                worker_stacked: bool = False) -> PyTree:
    """Input-batch specs: the leading (worker or batch) axis over pod×data."""
    lead = _worker_axes(mesh)

    def leaf(x):
        spec = P(*((lead,) + (None,) * (x.ndim - 1)))
        return sanitize_spec(spec, x.shape, mesh)

    del worker_stacked  # the leading axis shards either way
    return jax.tree.map(leaf, batch)


def grad_stack_specs(params: PyTree, mesh: Mesh) -> PyTree:
    """Specs for the stacked gradients: (n, *param) = worker axis over
    pod×data + the leaf's tensor-parallel spec shifted right by one."""
    lead = _worker_axes(mesh)
    pspecs = param_specs(params, mesh)

    def leaf(x, s):
        spec = P(*((lead,) + tuple(s) + (None,) * (x.ndim - len(tuple(s)))))
        return sanitize_spec(spec, (0,) + x.shape, mesh)

    return jax.tree.map(leaf, params, pspecs,
                        is_leaf=lambda v: isinstance(v, P))


def cache_specs(cache: PyTree, mesh: Mesh, *,
                shard_batch: bool = True) -> PyTree:
    """KV/state cache specs: (n_groups, batch, length, ...) leaves.

    Batch over pod×data when it divides; the cache *length* axis (dim 2 of
    attention KV leaves) over ``model`` — decode attention then runs
    chunk-local partial softmax per length shard (EXPERIMENTS.md §Perf #13).
    """
    lead = _worker_axes(mesh)

    def leaf(x):
        entries = [None] * x.ndim              # dim 0: the group stack
        if x.ndim >= 2 and shard_batch:
            entries[1] = lead
        if x.ndim >= 4:                        # (ng, b, length, heads, hd)
            entries[2] = "model"
        return sanitize_spec(P(*entries), x.shape, mesh)

    return jax.tree.map(leaf, cache)
