"""Pallas TPU kernel: fused BULYAN coordinate phase.

Per coordinate j (Algorithm 1 lines 21-24): median of the θ extracted
winners, then the average of the β entries of the θ aggregates closest to
that median.  Embarrassingly parallel over coordinates → grid over d-tiles,
each step loads two (θ, d_tile) blocks into VMEM and writes a (1, d_tile)
output row.  θ ≤ n − 2f − 2 is small (≤ 32 on our meshes), so both the
median (sorting network via ``jnp.sort`` over the θ axis) and the β-smallest
selection (O(θ²) rank-by-counting, which vectorises better on the VPU than a
data-dependent top-k) stay register/VMEM-local.

Fusing median + selection + masked mean into one kernel avoids three (θ, d)
HBM round-trips of the unfused XLA path — the memory-roofline win measured
in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(ext_ref, agr_ref, o_ref, *, beta: int):
    ext = ext_ref[...].astype(jnp.float32)           # (theta, dt)
    agr = agr_ref[...].astype(jnp.float32)           # (theta, dt)
    theta = ext.shape[0]

    srt = jnp.sort(ext, axis=0)
    if theta % 2:
        med = srt[theta // 2]
    else:
        med = 0.5 * (srt[theta // 2 - 1] + srt[theta // 2])   # (dt,)

    dist = jnp.abs(agr - med[None, :])               # (theta, dt)
    # rank by counting: rank[i] = #{k: dist[k] < dist[i]} + #{k<i: ==}
    lt = (dist[None, :, :] < dist[:, None, :]).astype(jnp.int32)
    eq = (dist[None, :, :] == dist[:, None, :]).astype(jnp.int32)
    row = jax.lax.broadcasted_iota(jnp.int32, (theta, theta, 1), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (theta, theta, 1), 1)
    eq_lower = eq * (col < row).astype(jnp.int32)    # ties -> smaller index first
    rank = jnp.sum(lt + eq_lower, axis=1)            # (theta, dt)
    sel = (rank < beta).astype(jnp.float32)
    o_ref[...] = (jnp.sum(sel * agr, axis=0) / float(beta))[None, :]


def coord_select_pallas(g_ext: Array, g_agr: Array, beta: int, *,
                        d_tile: int = 2048, interpret: bool = False) -> Array:
    """(theta, d) x2 -> (d,) fp32 fused coordinate phase."""
    if g_ext.shape != g_agr.shape:
        raise ValueError(
            f"g_ext/g_agr shapes differ: {g_ext.shape} vs {g_agr.shape}")
    if g_agr.ndim != 2:
        raise ValueError(f"expected (theta, d) inputs, got {g_agr.shape}")
    theta, d = g_agr.shape
    if not 1 <= beta <= theta:
        raise ValueError(
            f"need 1 <= beta <= theta, got beta={beta}, theta={theta}")
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    d_pad = (-d) % d_tile
    if d_pad:
        g_ext = jnp.pad(g_ext, ((0, 0), (0, d_pad)))
        g_agr = jnp.pad(g_agr, ((0, 0), (0, d_pad)))
    dp = g_agr.shape[1]
    grid = (dp // d_tile,)
    out = pl.pallas_call(
        functools.partial(_kernel, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((theta, d_tile), lambda i: (0, i)),
            pl.BlockSpec((theta, d_tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, d_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(g_ext, g_agr)
    return out[0, :d]
