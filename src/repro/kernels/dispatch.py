"""Measured-crossover dispatch between apply substrates (ROADMAP stopgap).

``BENCH_agg_time.json`` (committed full grid) shows the fused Pallas select
kernel winning the bulyan apply below ~1e5 coordinates per leaf but losing
~2x to the plain XLA substrate at d = 1e6 — the fused-select large-d cliff
(the kernel re-reads its extraction tiles once per output tile).  The
deep-grid tile lift (``ops.fused_select_d_tile``) cut the d = 1e6 point
from ~8.6 s to ~3.0 s by re-autotuning with a larger tile cap when the
grid exceeds ``ops.DEEP_GRID_STEPS`` steps, but the re-read term still
dominates there, so ``use_pallas=True`` must not blindly take the fused
path: :func:`fused_wins` consults a dispatch table of the *measured*
crossover points and the apply phase falls back to the XLA substrate
above them (``core.api._bulyan_leaf``; pass ``fused="force"`` to pin the
kernel regardless, which the substrate benchmarks do).

The baked-in table is read off the committed BENCH_agg_time.json grid:

===  ==========================  ==========================
 n    largest d fused won (us)    smallest d fused lost (us)
===  ==========================  ==========================
 11   4096   (1434 vs 4341)       —
 15   100000 (79286 vs 143981)    1000000 (3042569 vs 1425535)
===  ==========================  ==========================

Per-n thresholds are the geometric midpoint of the bracketing measured
points; n values without a measured loss point inherit the most
conservative (smallest) threshold observed.  :func:`load_measured`
recomputes the table from a fresh benchmark JSON.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Optional, Tuple

# (largest numel where fused won, smallest where it lost or None) per n,
# from the committed BENCH_agg_time.json multi_bulyan[fused|xla] rows
MEASURED_POINTS: Dict[int, Tuple[int, Optional[int]]] = {
    11: (4096, None),
    15: (100_000, 1_000_000),
}


def _threshold(win: int, lose: Optional[int], fallback: int) -> int:
    if lose is None:
        # no measured loss for this n: fused is safe at least up to the
        # global fallback (never below the largest measured win)
        return max(win, fallback)
    return int(math.sqrt(float(win) * float(lose)))


def _build_table(points: Dict[int, Tuple[int, Optional[int]]]
                 ) -> Tuple[Dict[int, int], int]:
    bracketed = [_threshold(w, l, 0) for w, l in points.values()
                 if l is not None]
    default = min(bracketed) if bracketed else 1 << 18
    table = {n: _threshold(w, l, default) for n, (w, l) in points.items()}
    return table, default


#: per-n max numel for which the fused kernel is dispatched, + the default
#: for unmeasured n (the most conservative bracketed crossover: ~316k)
FUSED_MAX_NUMEL, DEFAULT_FUSED_MAX_NUMEL = _build_table(MEASURED_POINTS)


def fused_wins(n: int, numel: int) -> bool:
    """Should a (n, numel) bulyan apply take the fused kernel?

    Static python decision (both arguments are shape-derived), so the
    dispatch costs nothing under jit and cannot retrace.
    """
    return numel <= FUSED_MAX_NUMEL.get(n, DEFAULT_FUSED_MAX_NUMEL)


def load_measured(path: str, rule: str = "multi_bulyan") -> None:
    """Refresh the dispatch table from a BENCH_agg_time.json payload.

    Reads the ``rule[fused]`` vs ``rule[xla]`` rows, rebuilds the per-n
    bracketing points and swaps the module tables in place.  Raises on a
    payload without both substrate rows.
    """
    global FUSED_MAX_NUMEL, DEFAULT_FUSED_MAX_NUMEL, MEASURED_POINTS
    with open(path) as fh:
        results = json.load(fh)["results"]
    fused, xla = results[f"{rule}[fused]"], results[f"{rule}[xla]"]
    points: Dict[int, Tuple[int, Optional[int]]] = {}
    for key, t_fused in fused.items():
        if key not in xla:
            continue
        kv = dict(p.split("=") for p in key.split(","))
        n, d = int(kv["n"]), int(kv["d"])
        win, lose = points.get(n, (0, None))
        if t_fused <= xla[key]:
            win = max(win, d)
        else:
            lose = d if lose is None else min(lose, d)
        points[n] = (win, lose)
    if not points:
        raise ValueError(f"no common {rule}[fused]/[xla] cells in {path}")
    MEASURED_POINTS = points
    FUSED_MAX_NUMEL, DEFAULT_FUSED_MAX_NUMEL = _build_table(points)
