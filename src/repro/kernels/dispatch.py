"""Measured-crossover dispatch between apply substrates (ROADMAP stopgap).

``use_pallas=True`` must not blindly take the fused kernel:
:func:`fused_wins` consults a dispatch table of *measured* crossover
points read off the committed ``BENCH_agg_time.json`` substrate grid, and
the apply phase falls back to the XLA substrate above them
(``core.api._bulyan_leaf``; pass ``fused="force"`` to pin the kernel
regardless, which the substrate benchmarks do).

In the single-level era this table existed to route d = 1e6 applies
*away* from the fused kernel: the kernel re-fetched its replicated
extraction operands once per ``d_tile``-wide grid step, so at n=15 it won
d=1e5 but lost ~2× at d=1e6.  The two-level operand-resident kernel
(``kernels/fused_select.py``) reads those operands once per macro block
and the measured loss is gone — the refreshed grid shows fused winning
every committed cell, so the table is right-censored:

===  ==========================  ==========================
 n    largest d fused won (us)    smallest d fused lost (us)
===  ==========================  ==========================
 11   1000000                     —
 15   1000000                     —
===  ==========================  ==========================

Per-n thresholds are the geometric midpoint of the bracketing measured
points where a loss exists; with no measured loss anywhere the table
falls back to the measured win frontier — the benchmark's evidence stops
there, so the dispatch does too (deeper applies take the XLA substrate
until a benchmark measures them).  :func:`load_measured` recomputes the
table from a fresh benchmark JSON.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Optional, Tuple

# (largest numel where fused won, smallest where it lost or None) per n,
# from the committed BENCH_agg_time.json multi_bulyan[fused|xla] rows
MEASURED_POINTS: Dict[int, Tuple[int, Optional[int]]] = {
    11: (1_000_000, None),
    15: (1_000_000, None),
}


def _threshold(win: int, lose: Optional[int], fallback: int) -> int:
    if lose is None:
        # no measured loss for this n: fused is safe at least up to the
        # global fallback (never below the largest measured win)
        return max(win, fallback)
    return int(math.sqrt(float(win) * float(lose)))


def _build_table(points: Dict[int, Tuple[int, Optional[int]]]
                 ) -> Tuple[Dict[int, int], int]:
    bracketed = [_threshold(w, l, 0) for w, l in points.values()
                 if l is not None]
    if bracketed:
        default = min(bracketed)
    else:
        # right-censored table (no measured loss anywhere): the win
        # frontier is as far as the evidence goes
        default = max((w for w, _ in points.values()), default=1 << 18)
    table = {n: _threshold(w, l, default) for n, (w, l) in points.items()}
    return table, default


#: per-n max numel for which the fused kernel is dispatched, + the default
#: for unmeasured n (the most conservative bracketed crossover: ~316k)
FUSED_MAX_NUMEL, DEFAULT_FUSED_MAX_NUMEL = _build_table(MEASURED_POINTS)


def fused_wins(n: int, numel: int) -> bool:
    """Should a (n, numel) bulyan apply take the fused kernel?

    Static python decision (both arguments are shape-derived), so the
    dispatch costs nothing under jit and cannot retrace.
    """
    return numel <= FUSED_MAX_NUMEL.get(n, DEFAULT_FUSED_MAX_NUMEL)


def load_measured(path: str, rule: str = "multi_bulyan") -> None:
    """Refresh the dispatch table from a BENCH_agg_time.json payload.

    Reads the ``rule[fused]`` vs ``rule[xla]`` rows, rebuilds the per-n
    bracketing points and swaps the module tables in place.  Raises on a
    payload without both substrate rows.
    """
    global FUSED_MAX_NUMEL, DEFAULT_FUSED_MAX_NUMEL, MEASURED_POINTS
    with open(path) as fh:
        results = json.load(fh)["results"]
    fused, xla = results[f"{rule}[fused]"], results[f"{rule}[xla]"]
    points: Dict[int, Tuple[int, Optional[int]]] = {}
    for key, t_fused in fused.items():
        if key not in xla:
            continue
        kv = dict(p.split("=") for p in key.split(","))
        n, d = int(kv["n"]), int(kv["d"])
        win, lose = points.get(n, (0, None))
        if t_fused <= xla[key]:
            win = max(win, d)
        else:
            lose = d if lose is None else min(lose, d)
        points[n] = (win, lose)
    if not points:
        raise ValueError(f"no common {rule}[fused]/[xla] cells in {path}")
    MEASURED_POINTS = points
    FUSED_MAX_NUMEL, DEFAULT_FUSED_MAX_NUMEL = _build_table(points)
