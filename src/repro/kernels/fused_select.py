"""Pallas TPU kernel: fully fused BULYAN apply phase.

The unfused pipeline materialises both (θ, d) intermediates in HBM:

    g_ext = w_ext @ G     # HBM write, θ·d fp32
    g_agr = w_agr @ G     # HBM write, θ·d fp32
    out   = coord_select(g_ext, g_agr, β)   # HBM read of both, write d

— three O(θ·d) HBM round-trips that dominate the memory-bound roofline
(kernels/coord_select.py header).  This kernel fuses the whole apply phase
over d-tiles: each grid step streams one (n, d_tile) block of the gradient
stack HBM→VMEM, contracts it with the small replicated (θ, n) extraction /
aggregate weight matrices on the MXU, and runs median → β-selection → mean
on the VPU while the tile is still in VMEM.  The only HBM traffic is the
one read of the stack and the (d,) output write — the same traffic plain
averaging pays, which is the paper's m/n-slowdown claim made literal.

VMEM per grid step: (n + 2θ)·d_tile·4 B for the tile and the two einsum
outputs, ~3·θ²·d_tile·4 B for the rank-counting broadcasts, plus
2·θ·n·4 B for the replicated weights (θ ≤ n ≤ 64 on our meshes → ≤ 32 KB).
``kernels/ops.py`` autotunes d_tile against this budget.

Numerics match ``core.gar.bulyan_coordinate_phase`` composed with the
weight einsums bit-for-bit in interpret mode (tested in
tests/test_substrates.py): the θ-axis median uses the same sorted values,
ties in the β-selection break by row index, and the masked mean uses the
same ``where``-sum.  The worker axis is zero-padded to a sublane multiple
of 8 (exact: padded weight columns are zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, we_ref, wa_ref, o_ref, *, beta: int):
    x = x_ref[...].astype(jnp.float32)               # (n_pad, dt)
    we = we_ref[...]                                 # (theta, n_pad) fp32
    wa = wa_ref[...]
    theta = we.shape[0]

    # extraction einsums — MXU, contraction over the worker axis.  HIGHEST:
    # ext feeds the median/selection, so it must not lose bits to bf16-pass
    # matmuls on TPU (same rationale as core.api.leaf_sqdist_contrib).
    ext = jax.lax.dot_general(
        we, x, (((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (theta, dt)
    agr = jax.lax.dot_general(
        wa, x, (((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (theta, dt)

    # coordinate phase — VPU, same math as coord_select.py's kernel
    srt = jnp.sort(ext, axis=0)
    if theta % 2:
        med = srt[theta // 2]
    else:
        med = 0.5 * (srt[theta // 2 - 1] + srt[theta // 2])   # (dt,)

    dist = jnp.abs(agr - med[None, :])               # (theta, dt)
    # rank by counting: rank[i] = #{k: dist[k] < dist[i]} + #{k<i: ==}
    lt = (dist[None, :, :] < dist[:, None, :]).astype(jnp.int32)
    eq = (dist[None, :, :] == dist[:, None, :]).astype(jnp.int32)
    row = jax.lax.broadcasted_iota(jnp.int32, (theta, theta, 1), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (theta, theta, 1), 1)
    eq_lower = eq * (col < row).astype(jnp.int32)    # ties -> smaller index first
    rank = jnp.sum(lt + eq_lower, axis=1)            # (theta, dt)
    sel = rank < beta
    o_ref[...] = (jnp.sum(jnp.where(sel, agr, 0.0), axis=0)
                  / float(beta))[None, :]


def fused_select_pallas(x: Array, w_ext: Array, w_agr: Array, beta: int, *,
                        d_tile: int = 2048, interpret: bool = False) -> Array:
    """(n, d) stack + (θ, n) plan weights -> (d,) fp32 Bulyan aggregate."""
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got shape {x.shape}")
    n, d = x.shape
    if w_ext.shape != w_agr.shape:
        raise ValueError(
            f"weight shapes differ: {w_ext.shape} vs {w_agr.shape}")
    if w_ext.ndim != 2 or w_ext.shape[1] != n:
        raise ValueError(
            f"weights must be (theta, n={n}), got {w_ext.shape}")
    theta = w_ext.shape[0]
    if not 1 <= beta <= theta:
        raise ValueError(f"need 1 <= beta <= theta, got beta={beta}, "
                         f"theta={theta}")
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    n_pad = (-n) % 8
    d_pad = (-d) % d_tile
    if n_pad or d_pad:
        x = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    if n_pad:
        w_ext = jnp.pad(w_ext, ((0, 0), (0, n_pad)))
        w_agr = jnp.pad(w_agr, ((0, 0), (0, n_pad)))
    np_, dp = x.shape
    grid = (dp // d_tile,)
    out = pl.pallas_call(
        functools.partial(_kernel, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, d_tile), lambda i: (0, i)),
            pl.BlockSpec((theta, np_), lambda i: (0, 0)),
            pl.BlockSpec((theta, np_), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(x, w_ext.astype(jnp.float32), w_agr.astype(jnp.float32))
    return out[0, :d]
