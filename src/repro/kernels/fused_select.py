"""Pallas TPU kernel: fully fused BULYAN apply phase (two-level grid).

The unfused pipeline materialises both (θ, d) intermediates in HBM:

    g_ext = w_ext @ G     # HBM write, θ·d fp32
    g_agr = w_agr @ G     # HBM write, θ·d fp32
    out   = coord_select(g_ext, g_agr, β)   # HBM read of both, write d

— three O(θ·d) HBM round-trips that dominate the memory-bound roofline
(kernels/coord_select.py header).  This kernel fuses the whole apply phase
so the only HBM traffic is the one read of the stack and the (d,) output
write — the same traffic plain averaging pays, which is the paper's
m/n-slowdown claim made literal.

Two-level grid
--------------
The outer Pallas grid walks **macro-tiles** of ``macro_tile`` lanes.  Each
macro step brings one (n, macro_tile) block of the gradient stack plus the
small replicated (θ, n) extraction / aggregate weight matrices into VMEM,
then an inner ``fori_loop`` sweeps ``macro_tile // d_tile`` lane windows of
``d_tile`` each, running the einsum → median → β-selection → mean pipeline
per window.  The weights are read from their VMEM refs **once per macro
step**, not once per window — the per-step operand re-fetch plus dispatch
overhead is exactly the term that made the single-level kernel lose to XLA
past ~40 grid steps (the BENCH_agg_time.json d=1e6 cliff).  The inner loop
is a single traced body, so its per-window cost is pure compute.

Bitwise invariance: every pipeline stage is **column-independent** — the
einsums contract over the worker axis and the median / rank-by-counting /
masked mean act per coordinate — so any (macro_tile, d_tile) partition of
the lane axis produces bit-identical output to any other, including the
single-level ``macro_tile == d_tile`` layout.  Tested over the PR-2 edge
grid in tests/test_kernels.py.

VMEM per macro step: 2 · n·macro_tile·4 B for the double-buffered stack
block, (2θ + ~3θ²)·d_tile·4 B for the per-window einsum outputs and
rank-counting broadcasts, plus 2·θ·n·4 B for the resident weights.
``kernels/ops.two_level_tiles`` sizes (macro_tile, d_tile) against this
budget.

Numerics match ``core.gar.bulyan_coordinate_phase`` composed with the
weight einsums bit-for-bit in interpret mode (tested in
tests/test_substrates.py): the θ-axis median uses the same sorted values,
ties in the β-selection break by row index, and the masked mean uses the
same ``where``-sum.  The worker axis is zero-padded to a sublane multiple
of 8 (exact: padded weight columns are zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _select_tile(x, we, wa, *, beta: int):
    """The per-window pipeline: (n_pad, dt) fp32 tile + resident weights
    -> (dt,) aggregate.  Column-independent — see module header."""
    theta = we.shape[0]

    # extraction einsums — MXU, contraction over the worker axis.  HIGHEST:
    # ext feeds the median/selection, so it must not lose bits to bf16-pass
    # matmuls on TPU (same rationale as core.api.leaf_sqdist_contrib).
    ext = jax.lax.dot_general(
        we, x, (((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (theta, dt)
    agr = jax.lax.dot_general(
        wa, x, (((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (theta, dt)

    # coordinate phase — VPU, same math as coord_select.py's kernel
    srt = jnp.sort(ext, axis=0)
    if theta % 2:
        med = srt[theta // 2]
    else:
        med = 0.5 * (srt[theta // 2 - 1] + srt[theta // 2])   # (dt,)

    dist = jnp.abs(agr - med[None, :])               # (theta, dt)
    # rank by counting: rank[i] = #{k: dist[k] < dist[i]} + #{k<i: ==}
    lt = (dist[None, :, :] < dist[:, None, :]).astype(jnp.int32)
    eq = (dist[None, :, :] == dist[:, None, :]).astype(jnp.int32)
    row = jax.lax.broadcasted_iota(jnp.int32, (theta, theta, 1), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (theta, theta, 1), 1)
    eq_lower = eq * (col < row).astype(jnp.int32)    # ties -> smaller index first
    rank = jnp.sum(lt + eq_lower, axis=1)            # (theta, dt)
    sel = rank < beta
    return jnp.sum(jnp.where(sel, agr, 0.0), axis=0) / float(beta)


def _kernel(x_ref, we_ref, wa_ref, o_ref, *, beta: int, d_tile: int,
            windows: int):
    # One read of the replicated weight pair per MACRO step; the inner
    # windows all close over the loaded values.
    we = we_ref[...]                                 # (theta, n_pad) fp32
    wa = wa_ref[...]

    def window(j, carry):
        x = x_ref[:, pl.ds(j * d_tile, d_tile)].astype(jnp.float32)
        o_ref[0, pl.ds(j * d_tile, d_tile)] = _select_tile(
            x, we, wa, beta=beta)
        return carry

    if windows == 1:
        # single-window macro: skip the loop machinery entirely — this is
        # the exact single-level kernel body, kept as the trace for small d
        window(0, 0)
    else:
        jax.lax.fori_loop(0, windows, window, 0)


@functools.lru_cache(maxsize=256)
def _build_call(np_: int, dp: int, theta: int, beta: int, d_tile: int,
                macro_tile: int, interpret: bool):
    """Cached pallas_call builder keyed on the fully resolved launch config.

    Building the call (closing the BlockSpecs over the padded geometry) is
    pure Python; caching it means repeat launches at the same geometry —
    every trainer step — skip the spec construction and reuse one callable
    identity, which also keeps the surrounding jit caches warm.
    """
    windows = macro_tile // d_tile
    return pl.pallas_call(
        functools.partial(_kernel, beta=beta, d_tile=d_tile,
                          windows=windows),
        grid=(dp // macro_tile,),
        in_specs=[
            pl.BlockSpec((np_, macro_tile), lambda i: (0, i)),
            pl.BlockSpec((theta, np_), lambda i: (0, 0)),
            pl.BlockSpec((theta, np_), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, macro_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )


def fused_select_pallas(x: Array, w_ext: Array, w_agr: Array, beta: int, *,
                        d_tile: int = 2048, macro_tile: int | None = None,
                        interpret: bool = False) -> Array:
    """(n, d) stack + (θ, n) plan weights -> (d,) fp32 Bulyan aggregate.

    ``macro_tile`` (a multiple of ``d_tile``; default ``d_tile`` — the
    single-level layout) sets the outer-grid block width; the lane axis is
    padded to a ``macro_tile`` multiple.  Output is bitwise-invariant to
    the choice (column independence — module header).
    """
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got shape {x.shape}")
    n, d = x.shape
    if w_ext.shape != w_agr.shape:
        raise ValueError(
            f"weight shapes differ: {w_ext.shape} vs {w_agr.shape}")
    if w_ext.ndim != 2 or w_ext.shape[1] != n:
        raise ValueError(
            f"weights must be (theta, n={n}), got {w_ext.shape}")
    theta = w_ext.shape[0]
    if not 1 <= beta <= theta:
        raise ValueError(f"need 1 <= beta <= theta, got beta={beta}, "
                         f"theta={theta}")
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    if macro_tile is None:
        macro_tile = d_tile
    if macro_tile % d_tile:
        raise ValueError(f"macro_tile {macro_tile} must be a multiple of "
                         f"d_tile {d_tile}")
    # never carry more macro than the (padded) operand has lanes — d_cap is
    # a d_tile multiple, so the clamp preserves the divisibility invariant
    d_cap = ((d - 1) // d_tile + 1) * d_tile
    macro_tile = min(macro_tile, d_cap)
    n_pad = (-n) % 8
    d_pad = (-d) % macro_tile
    if n_pad or d_pad:
        x = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    if n_pad:
        w_ext = jnp.pad(w_ext, ((0, 0), (0, n_pad)))
        w_agr = jnp.pad(w_agr, ((0, 0), (0, n_pad)))
    # pad/cast hoisted: only cast when the dtype actually differs — a fp32
    # caller (every plan produced by core.gar) pays no per-call convert op
    if w_ext.dtype != jnp.float32:
        w_ext = w_ext.astype(jnp.float32)
    if w_agr.dtype != jnp.float32:
        w_agr = w_agr.astype(jnp.float32)
    np_, dp = x.shape
    call = _build_call(np_, dp, theta, beta, d_tile, macro_tile, interpret)
    out = call(x, w_ext, w_agr)
    return out[0, :d]
