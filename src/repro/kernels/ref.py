"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_sqdist_ref(x: Array) -> Array:
    """(n, d) -> (n, n) squared euclidean distances, fp32, zero diagonal."""
    xf = x.astype(jnp.float32)
    sq = jnp.sum(xf * xf, axis=-1)
    gram = jnp.matmul(xf, xf.T, precision=jax.lax.Precision.HIGHEST)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    return d2 * (1.0 - jnp.eye(x.shape[0], dtype=jnp.float32))


def coord_select_ref(g_ext: Array, g_agr: Array, beta: int) -> Array:
    """Bulyan coordinate phase: median of g_ext, avg of beta closest g_agr.

    g_ext, g_agr: (theta, d) fp32 -> (d,) fp32.  Ties broken by row index
    (matches both gar.bulyan_coordinate_phase and the kernel).
    """
    med = jnp.median(g_ext.astype(jnp.float32), axis=0)
    dist = jnp.abs(g_agr.astype(jnp.float32) - med[None, :])
    order = jnp.argsort(dist, axis=0)
    ranks = jnp.argsort(order, axis=0)
    sel = ranks < beta
    return jnp.sum(jnp.where(sel, g_agr.astype(jnp.float32), 0.0), axis=0) / beta
