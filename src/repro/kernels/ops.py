"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python on the same BlockSpec schedule, which is the
validation story for the TPU target.  On TPU backends the compiled kernels
run as written.

Backend resolution happens *outside* the jit boundary: ``interpret`` is a
static argument of every jitted wrapper, so the backend choice is part of
the jit cache key instead of being baked into a trace that silently goes
stale when the default backend changes (e.g. a CPU-traced interpret=True
call surviving into a TPU run).  Scope: this protects the wrappers' own
jit caches (eager callers).  A caller that jits a whole train/serve step
traces these wrappers inline, so resolution happens at *that* trace's
time under ordinary jit semantics — pass ``interpret`` explicitly from
step-construction code if the step must pin a backend choice.

Tile policy
-----------
All streaming kernels are **two-level**: the outer Pallas grid walks
``macro_tile``-lane blocks of the stack (one HBM→VMEM transfer and one
read of the replicated operands per block), and an inner traced loop
sweeps ``d_tile``-lane compute windows inside each block.  The inner
``d_tile`` keeps per-window intermediates (rank-counting broadcasts, fp32
widenings) small; the outer ``macro_tile`` is what amortises the per-grid-
step dispatch + operand-re-read overhead that made deep single-level grids
lose to XLA at d = 1e6 (the retired ``DEEP_GRID_STEPS`` lift treated the
symptom by fattening single-level tiles; the two-level grid removes the
per-step re-read term entirely, so the hot path is monotone in d).

:func:`two_level_tiles` sizes the pair against the VMEM budget: per macro
step the working set is ``2·(rows+out_rows)·4·macro_tile`` (double-
buffered streamed lanes) + ``(scratch_rows+rows)·4·d_tile`` (per-window
intermediates incl. the fp32 widening of the current window) +
``fixed_bytes`` (replicated weights / resident accumulators).  The policy
minimises outer grid steps, breaking ties toward the larger ``d_tile``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.coord_select import coord_select_pallas
from repro.kernels.dequant_stats import (dequant_stats_pallas,
                                         dequant_stats_rect_pallas)
from repro.kernels.fused_select import fused_select_pallas
from repro.kernels.pairwise_sqdist import (pairwise_sqdist_pallas,
                                           pairwise_stats_pallas,
                                           pairwise_stats_rect_pallas)
from repro.obs import profile as _prof

Array = jax.Array

# Conservative per-step working-set budget: half of a v5e core's ~16 MB
# VMEM, leaving headroom for Pallas' input double buffering and the
# replicated small operands.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
_MAX_D_TILE = 8192
#: narrowest inner window :func:`two_level_tiles` will pick while a wider
#: one fits — sub-2048-lane windows measured up to ~1.7× slower at
#: d = 1e6 (the per-window loop overhead beats the one or two outer grid
#: steps the taller macro block saves)
_MIN_D_TILE = 2048


def autotune_d_tile(rows: int, d: int, *, scratch_rows: int = 0,
                    fixed_bytes: int = 0,
                    vmem_budget: int = VMEM_BUDGET_BYTES,
                    max_tile: int = _MAX_D_TILE) -> int:
    """Largest d_tile (multiple of 128) fitting the VMEM budget.

    ``rows`` counts the fp32 (rows, d_tile) *operand* buffers per grid step
    (double-buffered by Pallas — a 2x factor models that);
    ``scratch_rows`` counts 4-byte rows of in-kernel intermediates that
    scale with the tile width but are not double-buffered (e.g. the
    (θ, θ, d_tile) rank-counting broadcasts of the selection kernels);
    ``fixed_bytes`` covers tile-width-independent residents (the (n, n)
    accumulator, replicated weights).  Clamped to [128, max_tile] and to d
    rounded up to the 128-lane boundary — a tile wider than the padded
    operand only adds dead lanes.

    This sizes the *inner* compute window; :func:`two_level_tiles` sizes
    the (d_tile, macro_tile) pair jointly for the two-level kernels.
    """
    if rows <= 0:
        raise ValueError(f"rows must be positive, got {rows}")
    if d <= 0:
        raise ValueError(f"d must be positive, got {d}")
    d_cap = ((d - 1) // 128 + 1) * 128
    budget = max(0, vmem_budget - fixed_bytes)
    per_lane = (2 * rows + scratch_rows) * 4
    tile = (budget // per_lane // 128) * 128
    return max(128, min(tile, max_tile, d_cap))


def _select_scratch_rows(theta: int) -> int:
    """Tile-width-scaling intermediates of the selection kernels: the three
    (θ, θ) int32 rank-counting broadcasts (lt/eq/rank) plus a few fp32
    (θ,)-row temporaries (ext/agr/srt/dist)."""
    return 3 * theta * theta + 4 * theta


def two_level_macro(rows: int, d: int, d_tile: int, *,
                    out_rows: int = 1, scratch_rows: int = 0,
                    fixed_bytes: int = 0,
                    vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest macro_tile (multiple of ``d_tile``) fitting the VMEM budget.

    Per macro step: ``2·(rows+out_rows)·4·macro`` bytes of double-buffered
    streamed lanes (the stack block plus the streamed output rows — pass
    ``out_rows=0`` when the outputs are grid-resident accumulators and
    already counted in ``fixed_bytes``), ``(scratch_rows+rows)·4·d_tile``
    per-window intermediates (the ``+rows`` is the fp32 widening of the
    current window), and ``fixed_bytes`` of residents.  Clamped to at
    least one window and to d rounded up to the ``d_tile`` boundary.
    """
    d_cap = ((d - 1) // d_tile + 1) * d_tile
    rem = vmem_budget - fixed_bytes - (scratch_rows + rows) * 4 * d_tile
    lanes = rem // (2 * (rows + out_rows) * 4)
    macro = (lanes // d_tile) * d_tile
    return max(d_tile, min(macro, d_cap))


def two_level_tiles(rows: int, d: int, *, out_rows: int = 1,
                    scratch_rows: int = 0, fixed_bytes: int = 0,
                    vmem_budget: int = VMEM_BUDGET_BYTES,
                    max_tile: int = _MAX_D_TILE) -> Tuple[int, int]:
    """Joint (d_tile, macro_tile) policy for the two-level kernels.

    Sweeps lane-aligned power-of-two inner windows (128·2^k ≤ max_tile),
    sizes the largest budget-fitting macro block for each
    (:func:`two_level_macro`), and picks the pair that minimises outer
    grid steps — the per-step dispatch/operand-re-read overhead is the
    measured cost driver in both interpret and compiled modes — breaking
    ties toward the larger ``d_tile`` (fewer inner iterations for the
    same transfer schedule).
    """
    if rows <= 0:
        raise ValueError(f"rows must be positive, got {rows}")
    if d <= 0:
        raise ValueError(f"d must be positive, got {d}")
    d_cap = ((d - 1) // 128 + 1) * 128
    fits = []
    dt = 128
    while dt <= min(max_tile, d_cap):
        rem = (vmem_budget - fixed_bytes
               - (scratch_rows + rows) * 4 * dt)
        if rem >= 2 * (rows + out_rows) * 4 * dt:
            macro = two_level_macro(rows, d, dt, out_rows=out_rows,
                                    scratch_rows=scratch_rows,
                                    fixed_bytes=fixed_bytes,
                                    vmem_budget=vmem_budget)
            fits.append((dt, macro))
        dt *= 2
    if not fits:
        # degenerate budget: fall back to the minimal lane-aligned window
        return 128, 128
    # sub-1024-lane windows only when nothing wider fits the budget (or
    # the operand itself is narrower): the per-window loop overhead of
    # tiny windows outweighs the one or two outer steps they save
    wide = [c for c in fits if c[0] >= _MIN_D_TILE]
    best = None
    for dt, macro in (wide or fits):
        key = (-(-d // macro), -dt)
        if best is None or key <= best[0]:
            best = (key, dt, macro)
    return best[1], best[2]


def fused_select_tiles(n_rows: int, d: int, theta: int) -> Tuple[int, int]:
    """The fused_select (d_tile, macro_tile) policy.

    ``n_rows`` is the sublane-padded worker count.  Scratch is the
    selection pipeline's rank-counting broadcasts
    (:func:`_select_scratch_rows`); fixed bytes are the VMEM-resident
    (θ, n) weight pair.  Shared by the :func:`fused_select` wrapper and
    ``analysis/vmem.estimate_fused_select`` — one policy, one cost model.
    """
    return two_level_tiles(n_rows, d, out_rows=1,
                           scratch_rows=_select_scratch_rows(theta),
                           fixed_bytes=2 * theta * n_rows * 4)


def stats_macro_tile(n_rows: int, d: int, d_tile: int, *,
                     fixed_bytes: int) -> int:
    """The stats kernels' macro policy: the inner ``d_tile`` is pinned to
    the single-level autotune value — tile boundaries ARE the float
    accumulation order of the (n, n)/(n,) accumulators, so changing them
    would break bitwise parity with the committed artifacts — and only the
    outer macro block is sized from the residual budget.  The (n, n)
    accumulator and norm row are grid-resident (``out_rows=0``; they are
    part of ``fixed_bytes``)."""
    return two_level_macro(n_rows, d, d_tile, out_rows=0,
                           fixed_bytes=fixed_bytes)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(interpret: Optional[bool]) -> bool:
    return _interpret() if interpret is None else bool(interpret)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def _pairwise_sqdist(x: Array, *, d_tile: int, interpret: bool) -> Array:
    return pairwise_sqdist_pallas(x, d_tile=d_tile, interpret=interpret)


def pairwise_sqdist(x: Array, *, d_tile: Optional[int] = None,
                    interpret: Optional[bool] = None) -> Array:
    """(n, d) -> (n, n) fp32 squared distances (Pallas)."""
    if d_tile is None:
        n_rows = x.shape[0] + (-x.shape[0]) % 8
        d_tile = autotune_d_tile(n_rows, x.shape[1],
                                 fixed_bytes=n_rows * n_rows * 4)
    return _pairwise_sqdist(x, d_tile=d_tile, interpret=_resolve(interpret))


def _stats_tiles(n_rows: int, d: int) -> Tuple[int, int]:
    """(d_tile, macro_tile) for the square stats kernels: the PR-2
    autotune inner window (bitwise-pinned — see :func:`stats_macro_tile`)
    plus the residency macro."""
    fixed = n_rows * (n_rows + 8) * 4
    d_tile = autotune_d_tile(n_rows, d, fixed_bytes=fixed)
    return d_tile, stats_macro_tile(n_rows, d, d_tile, fixed_bytes=fixed)


@functools.partial(jax.jit,
                   static_argnames=("d_tile", "macro_tile", "interpret"))
def _pairwise_stats(x: Array, *, d_tile: int, macro_tile: int,
                    interpret: bool) -> Tuple[Array, Array]:
    return pairwise_stats_pallas(x, d_tile=d_tile, macro_tile=macro_tile,
                                 interpret=interpret)


def pairwise_stats(x: Array, *, d_tile: Optional[int] = None,
                   macro_tile: Optional[int] = None,
                   interpret: Optional[bool] = None) -> Tuple[Array, Array]:
    """Single-pass (n, d) -> ((n, n) raw sq-dists, (n,) sq-norms).

    One HBM read of the stack feeds both outputs; the distance matrix is
    raw (unclamped, diagonal not zeroed) for cross-leaf accumulation —
    finalise with ``core.api.finalize_dists``.
    """
    n_rows = x.shape[0] + (-x.shape[0]) % 8
    if d_tile is None:
        d_tile, auto_macro = _stats_tiles(n_rows, x.shape[1])
        if macro_tile is None:
            macro_tile = auto_macro
    elif macro_tile is None:
        macro_tile = d_tile
    _prof.record_kernel("pairwise_stats", n=x.shape[0], d=x.shape[1],
                        d_tile=d_tile, macro_tile=macro_tile)
    return _pairwise_stats(x, d_tile=d_tile, macro_tile=macro_tile,
                           interpret=_resolve(interpret))


@functools.partial(jax.jit,
                   static_argnames=("d_tile", "macro_tile", "interpret"))
def _pairwise_stats_rect(x_loc: Array, x_full: Array, *, d_tile: int,
                         macro_tile: int,
                         interpret: bool) -> Tuple[Array, Array]:
    return pairwise_stats_rect_pallas(x_loc, x_full, d_tile=d_tile,
                                      macro_tile=macro_tile,
                                      interpret=interpret)


def pairwise_stats_rect(x_loc: Array, x_full: Array, *,
                        d_tile: Optional[int] = None,
                        macro_tile: Optional[int] = None,
                        interpret: Optional[bool] = None
                        ) -> Tuple[Array, Array]:
    """Rectangular stats: (n_loc, d) row block × (n, d) gathered stack ->
    ((n_loc, n) raw sq-dist block, (n,) sq-norms).

    The §10 shard kernel: each device contracts only its own row block
    against the gathered stack — O(n_loc·n·d) instead of the square
    kernel's redundant O(n²·d) per device.  The default ``d_tile`` is the
    SAME autotune value :func:`pairwise_stats` derives for the full stack:
    identical tile boundaries (plus row-subset gemm determinism) make the
    block bitwise-identical to the matching rows of the square kernel
    (tests/test_kernels.py), which is what keeps ``sharded_raw_stats``
    bitwise-equal to the replicated path.
    """
    n_full = x_full.shape[0] + (-x_full.shape[0]) % 8
    n_loc = x_loc.shape[0] + (-x_loc.shape[0]) % 8
    if d_tile is None:
        d_tile, _ = _stats_tiles(n_full, x_full.shape[1])
    if macro_tile is None:
        fixed = (n_loc * n_full + n_loc * (n_full + 8)) * 4
        macro_tile = stats_macro_tile(n_loc + n_full, x_full.shape[1],
                                      d_tile, fixed_bytes=fixed)
    _prof.record_kernel("pairwise_stats_rect", n=x_full.shape[0],
                        d=x_full.shape[1], d_tile=d_tile,
                        macro_tile=macro_tile, n_loc=x_loc.shape[0])
    return _pairwise_stats_rect(x_loc, x_full, d_tile=d_tile,
                                macro_tile=macro_tile,
                                interpret=_resolve(interpret))


def _dequant_tiles(n_rows: int, d: int) -> Tuple[int, int]:
    """Same autotune call :func:`pairwise_stats` makes for the decoded
    fp32 stack — identical tile boundaries keep the float accumulation
    order, and therefore bitwise parity with decode-then-stats, intact
    (DESIGN.md §9)."""
    return _stats_tiles(n_rows, d)


@functools.partial(jax.jit,
                   static_argnames=("d_tile", "macro_tile", "interpret"))
def _dequant_stats(payload: Array, mult: Array, *, d_tile: int,
                   macro_tile: int,
                   interpret: bool) -> Tuple[Array, Array]:
    return dequant_stats_pallas(payload, mult, d_tile=d_tile,
                                macro_tile=macro_tile, interpret=interpret)


def dequant_stats(payload: Array, mult: Array, *,
                  d_tile: Optional[int] = None,
                  macro_tile: Optional[int] = None,
                  interpret: Optional[bool] = None) -> Tuple[Array, Array]:
    """Fused dequantize → single-pass stats on a quantized (n, d) payload.

    ``payload`` int8/bf16 + ``mult`` (n,) per-row dequant multipliers ->
    ((n, n) raw sq-dists, (n,) sq-norms) of the decoded rows, without the
    fp32 stack ever existing in HBM.  The default ``d_tile`` is the SAME
    autotune call :func:`pairwise_stats` makes for the decoded fp32 stack:
    identical tile boundaries keep the float accumulation order — and
    therefore bitwise parity with decode-then-``pairwise_stats`` in
    interpret mode — intact (DESIGN.md §9).
    """
    n_rows = payload.shape[0] + (-payload.shape[0]) % 8
    if d_tile is None:
        d_tile, auto_macro = _dequant_tiles(n_rows, payload.shape[1])
        if macro_tile is None:
            macro_tile = auto_macro
    elif macro_tile is None:
        macro_tile = d_tile
    _prof.record_kernel("dequant_stats", n=payload.shape[0],
                        d=payload.shape[1], d_tile=d_tile,
                        macro_tile=macro_tile, dtype=str(payload.dtype))
    return _dequant_stats(payload, mult, d_tile=d_tile,
                          macro_tile=macro_tile,
                          interpret=_resolve(interpret))


@functools.partial(jax.jit,
                   static_argnames=("d_tile", "macro_tile", "interpret"))
def _dequant_stats_rect(p_loc: Array, m_loc: Array, p_full: Array,
                        m_full: Array, *, d_tile: int, macro_tile: int,
                        interpret: bool) -> Tuple[Array, Array]:
    return dequant_stats_rect_pallas(p_loc, m_loc, p_full, m_full,
                                     d_tile=d_tile, macro_tile=macro_tile,
                                     interpret=interpret)


def dequant_stats_rect(p_loc: Array, m_loc: Array, p_full: Array,
                       m_full: Array, *, d_tile: Optional[int] = None,
                       macro_tile: Optional[int] = None,
                       interpret: Optional[bool] = None
                       ) -> Tuple[Array, Array]:
    """Rectangular fused dequantize → stats: (n_loc, d) payload block ×
    (n, d) gathered payload -> ((n_loc, n) raw sq-dist block, (n,)
    sq-norms) of the decoded rows.

    The encoded-wire counterpart of :func:`pairwise_stats_rect`; the
    default ``d_tile`` matches the square :func:`dequant_stats` autotune
    for the full payload so the block is bitwise-identical to the
    matching rows of the square kernel (tests/test_comm.py).
    """
    n_full = p_full.shape[0] + (-p_full.shape[0]) % 8
    if d_tile is None:
        d_tile, _ = _dequant_tiles(n_full, p_full.shape[1])
    if macro_tile is None:
        n_loc = p_loc.shape[0] + (-p_loc.shape[0]) % 8
        fixed = (n_loc * n_full + n_loc * (n_full + 8)) * 4
        macro_tile = stats_macro_tile(n_loc + n_full, p_full.shape[1],
                                      d_tile, fixed_bytes=fixed)
    _prof.record_kernel("dequant_stats_rect", n=p_full.shape[0],
                        d=p_full.shape[1], d_tile=d_tile,
                        macro_tile=macro_tile, n_loc=p_loc.shape[0],
                        dtype=str(p_full.dtype))
    return _dequant_stats_rect(p_loc, m_loc, p_full, m_full,
                               d_tile=d_tile, macro_tile=macro_tile,
                               interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("beta", "d_tile", "interpret"))
def _coord_select(g_ext: Array, g_agr: Array, *, beta: int, d_tile: int,
                  interpret: bool) -> Array:
    return coord_select_pallas(g_ext, g_agr, beta, d_tile=d_tile,
                               interpret=interpret)


def coord_select(g_ext: Array, g_agr: Array, beta: int, *,
                 d_tile: Optional[int] = None,
                 interpret: Optional[bool] = None) -> Array:
    """Fused Bulyan coordinate phase (Pallas) on materialised (θ, d) inputs."""
    if d_tile is None:
        theta = g_agr.shape[0]
        d_tile = autotune_d_tile(2 * theta, g_agr.shape[1],
                                 scratch_rows=_select_scratch_rows(theta))
    return _coord_select(g_ext, g_agr, beta=beta, d_tile=d_tile,
                         interpret=_resolve(interpret))


@functools.partial(jax.jit,
                   static_argnames=("beta", "d_tile", "macro_tile",
                                    "interpret"))
def _fused_select(x: Array, w_ext: Array, w_agr: Array, *, beta: int,
                  d_tile: int, macro_tile: int, interpret: bool) -> Array:
    return fused_select_pallas(x, w_ext, w_agr, beta, d_tile=d_tile,
                               macro_tile=macro_tile, interpret=interpret)


def fused_select(x: Array, w_ext: Array, w_agr: Array, beta: int, *,
                 d_tile: Optional[int] = None,
                 macro_tile: Optional[int] = None,
                 interpret: Optional[bool] = None) -> Array:
    """Fully fused Bulyan apply: (n, d) stack + (θ, n) plan -> (d,).

    Extraction einsums, median, β-selection and mean all happen in VMEM —
    no (θ, d) HBM intermediates (see kernels/fused_select.py).  The
    two-level (d_tile, macro_tile) launch geometry comes from
    :func:`fused_select_tiles`; the output is bitwise-invariant to it.
    """
    if d_tile is None:
        n_rows = x.shape[0] + (-x.shape[0]) % 8
        d_tile, auto_macro = fused_select_tiles(n_rows, x.shape[1],
                                                w_ext.shape[0])
        if macro_tile is None:
            macro_tile = auto_macro
    elif macro_tile is None:
        macro_tile = d_tile
    _prof.record_kernel("fused_select", n=x.shape[0], d=x.shape[1],
                        d_tile=d_tile, macro_tile=macro_tile,
                        theta=w_ext.shape[0])
    return _fused_select(x, w_ext, w_agr, beta=beta, d_tile=d_tile,
                         macro_tile=macro_tile, interpret=_resolve(interpret))
