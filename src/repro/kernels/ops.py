"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python on the same BlockSpec schedule, which is the
validation story for the TPU target.  On TPU backends the compiled kernels
run as written.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.coord_select import coord_select_pallas
from repro.kernels.pairwise_sqdist import pairwise_sqdist_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("d_tile",))
def pairwise_sqdist(x: Array, *, d_tile: int = 2048) -> Array:
    """(n, d) -> (n, n) fp32 squared distances (Pallas)."""
    return pairwise_sqdist_pallas(x, d_tile=d_tile, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("beta", "d_tile"))
def coord_select(g_ext: Array, g_agr: Array, beta: int, *,
                 d_tile: int = 2048) -> Array:
    """Fused Bulyan coordinate phase (Pallas)."""
    return coord_select_pallas(g_ext, g_agr, beta, d_tile=d_tile,
                               interpret=_interpret())
