"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python on the same BlockSpec schedule, which is the
validation story for the TPU target.  On TPU backends the compiled kernels
run as written.

Backend resolution happens *outside* the jit boundary: ``interpret`` is a
static argument of every jitted wrapper, so the backend choice is part of
the jit cache key instead of being baked into a trace that silently goes
stale when the default backend changes (e.g. a CPU-traced interpret=True
call surviving into a TPU run).  Scope: this protects the wrappers' own
jit caches (eager callers).  A caller that jits a whole train/serve step
traces these wrappers inline, so resolution happens at *that* trace's
time under ordinary jit semantics — pass ``interpret`` explicitly from
step-construction code if the step must pin a backend choice.

``d_tile`` defaults to the VMEM-budget autotuner (:func:`autotune_d_tile`):
the largest lane-aligned tile whose double-buffered working set fits the
budget, so wide stacks take few grid steps and narrow ones don't overshoot
VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.coord_select import coord_select_pallas
from repro.kernels.dequant_stats import dequant_stats_pallas
from repro.kernels.fused_select import fused_select_pallas
from repro.kernels.pairwise_sqdist import (pairwise_sqdist_pallas,
                                           pairwise_stats_pallas)
from repro.obs import profile as _prof

Array = jax.Array

# Conservative per-step working-set budget: half of a v5e core's ~16 MB
# VMEM, leaving headroom for Pallas' input double buffering and the
# replicated small operands.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
_MAX_D_TILE = 8192

#: grid depth past which fused_select's per-step dispatch overhead and its
#: re-read of the replicated (θ, n) extraction operands dominate the byte
#: savings — the measured BENCH_agg_time.json d=1e6 cliff (the geometric
#: midpoint of the bracketing measured grid depths at n=15).
#: ``analysis/vmem.py`` aliases this as its GRID_STEPS_THRESHOLD so the
#: autotuner and the static estimator can never disagree on the regime.
DEEP_GRID_STEPS = 40
#: lifted tile cap for deep-grid fused_select launches: 1.5× the base cap,
#: still lane-aligned and inside the VMEM budget for every benchmarked θ.
#: Going wider would push the predicted crossover (DEEP_GRID_STEPS ×
#: d_tile) past 2× the measured dispatch table at small n — the
#: calibration gate in ``analysis.v1``.
_DEEP_MAX_D_TILE = 12288


def autotune_d_tile(rows: int, d: int, *, scratch_rows: int = 0,
                    fixed_bytes: int = 0,
                    vmem_budget: int = VMEM_BUDGET_BYTES,
                    max_tile: int = _MAX_D_TILE) -> int:
    """Largest d_tile (multiple of 128) fitting the VMEM budget.

    ``rows`` counts the fp32 (rows, d_tile) *operand* buffers per grid step
    (double-buffered by Pallas — a 2x factor models that);
    ``scratch_rows`` counts 4-byte rows of in-kernel intermediates that
    scale with the tile width but are not double-buffered (e.g. the
    (θ, θ, d_tile) rank-counting broadcasts of the selection kernels);
    ``fixed_bytes`` covers tile-width-independent residents (the (n, n)
    accumulator, replicated weights).  Clamped to [128, max_tile] and to d
    rounded up to the 128-lane boundary — a tile wider than the padded
    operand only adds dead lanes.
    """
    if rows <= 0:
        raise ValueError(f"rows must be positive, got {rows}")
    if d <= 0:
        raise ValueError(f"d must be positive, got {d}")
    d_cap = ((d - 1) // 128 + 1) * 128
    budget = max(0, vmem_budget - fixed_bytes)
    per_lane = (2 * rows + scratch_rows) * 4
    tile = (budget // per_lane // 128) * 128
    return max(128, min(tile, max_tile, d_cap))


def _select_scratch_rows(theta: int) -> int:
    """Tile-width-scaling intermediates of the selection kernels: the three
    (θ, θ) int32 rank-counting broadcasts (lt/eq/rank) plus a few fp32
    (θ,)-row temporaries (ext/agr/srt/dist)."""
    return 3 * theta * theta + 4 * theta


def fused_select_d_tile(n_rows: int, d: int, theta: int) -> int:
    """The fused_select tile policy: base autotune, deep-grid lift.

    The base cap (``_MAX_D_TILE``) keeps shallow grids on the committed
    tile boundaries; when even the base tile needs more than
    :data:`DEEP_GRID_STEPS` grid steps the launch is dispatch/re-read
    bound, not bandwidth bound, so the cap lifts to
    :data:`_DEEP_MAX_D_TILE` — fewer, fatter steps amortise the per-step
    overhead and the re-fetch of the replicated (θ, n) weight pair.
    Shared by the :func:`fused_select` wrapper and
    ``analysis/vmem.estimate_fused_select`` — one policy, one cost model.
    """
    scratch = _select_scratch_rows(theta)
    fixed = 2 * theta * n_rows * 4
    base = autotune_d_tile(n_rows, d, scratch_rows=scratch,
                           fixed_bytes=fixed)
    if -(-d // base) <= DEEP_GRID_STEPS:
        return base
    return autotune_d_tile(n_rows, d, scratch_rows=scratch,
                           fixed_bytes=fixed, max_tile=_DEEP_MAX_D_TILE)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(interpret: Optional[bool]) -> bool:
    return _interpret() if interpret is None else bool(interpret)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def _pairwise_sqdist(x: Array, *, d_tile: int, interpret: bool) -> Array:
    return pairwise_sqdist_pallas(x, d_tile=d_tile, interpret=interpret)


def pairwise_sqdist(x: Array, *, d_tile: Optional[int] = None,
                    interpret: Optional[bool] = None) -> Array:
    """(n, d) -> (n, n) fp32 squared distances (Pallas)."""
    if d_tile is None:
        n_rows = x.shape[0] + (-x.shape[0]) % 8
        d_tile = autotune_d_tile(n_rows, x.shape[1],
                                 fixed_bytes=n_rows * n_rows * 4)
    return _pairwise_sqdist(x, d_tile=d_tile, interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def _pairwise_stats(x: Array, *, d_tile: int,
                    interpret: bool) -> Tuple[Array, Array]:
    return pairwise_stats_pallas(x, d_tile=d_tile, interpret=interpret)


def pairwise_stats(x: Array, *, d_tile: Optional[int] = None,
                   interpret: Optional[bool] = None) -> Tuple[Array, Array]:
    """Single-pass (n, d) -> ((n, n) raw sq-dists, (n,) sq-norms).

    One HBM read of the stack feeds both outputs; the distance matrix is
    raw (unclamped, diagonal not zeroed) for cross-leaf accumulation —
    finalise with ``core.api.finalize_dists``.
    """
    if d_tile is None:
        n_rows = x.shape[0] + (-x.shape[0]) % 8
        d_tile = autotune_d_tile(n_rows, x.shape[1],
                                 fixed_bytes=n_rows * (n_rows + 8) * 4)
    _prof.record_kernel("pairwise_stats", n=x.shape[0], d=x.shape[1],
                        d_tile=d_tile)
    return _pairwise_stats(x, d_tile=d_tile, interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def _dequant_stats(payload: Array, mult: Array, *, d_tile: int,
                   interpret: bool) -> Tuple[Array, Array]:
    return dequant_stats_pallas(payload, mult, d_tile=d_tile,
                                interpret=interpret)


def dequant_stats(payload: Array, mult: Array, *,
                  d_tile: Optional[int] = None,
                  interpret: Optional[bool] = None) -> Tuple[Array, Array]:
    """Fused dequantize → single-pass stats on a quantized (n, d) payload.

    ``payload`` int8/bf16 + ``mult`` (n,) per-row dequant multipliers ->
    ((n, n) raw sq-dists, (n,) sq-norms) of the decoded rows, without the
    fp32 stack ever existing in HBM.  The default ``d_tile`` is the SAME
    autotune call :func:`pairwise_stats` makes for the decoded fp32 stack:
    identical tile boundaries keep the float accumulation order — and
    therefore bitwise parity with decode-then-``pairwise_stats`` in
    interpret mode — intact (DESIGN.md §9).
    """
    if d_tile is None:
        n_rows = payload.shape[0] + (-payload.shape[0]) % 8
        d_tile = autotune_d_tile(n_rows, payload.shape[1],
                                 fixed_bytes=n_rows * (n_rows + 8) * 4)
    _prof.record_kernel("dequant_stats", n=payload.shape[0],
                        d=payload.shape[1], d_tile=d_tile,
                        dtype=str(payload.dtype))
    return _dequant_stats(payload, mult, d_tile=d_tile,
                          interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("beta", "d_tile", "interpret"))
def _coord_select(g_ext: Array, g_agr: Array, *, beta: int, d_tile: int,
                  interpret: bool) -> Array:
    return coord_select_pallas(g_ext, g_agr, beta, d_tile=d_tile,
                               interpret=interpret)


def coord_select(g_ext: Array, g_agr: Array, beta: int, *,
                 d_tile: Optional[int] = None,
                 interpret: Optional[bool] = None) -> Array:
    """Fused Bulyan coordinate phase (Pallas) on materialised (θ, d) inputs."""
    if d_tile is None:
        theta = g_agr.shape[0]
        d_tile = autotune_d_tile(2 * theta, g_agr.shape[1],
                                 scratch_rows=_select_scratch_rows(theta))
    return _coord_select(g_ext, g_agr, beta=beta, d_tile=d_tile,
                         interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("beta", "d_tile", "interpret"))
def _fused_select(x: Array, w_ext: Array, w_agr: Array, *, beta: int,
                  d_tile: int, interpret: bool) -> Array:
    return fused_select_pallas(x, w_ext, w_agr, beta, d_tile=d_tile,
                               interpret=interpret)


def fused_select(x: Array, w_ext: Array, w_agr: Array, beta: int, *,
                 d_tile: Optional[int] = None,
                 interpret: Optional[bool] = None) -> Array:
    """Fully fused Bulyan apply: (n, d) stack + (θ, n) plan -> (d,).

    Extraction einsums, median, β-selection and mean all happen in VMEM —
    no (θ, d) HBM intermediates (see kernels/fused_select.py).
    """
    if d_tile is None:
        n_rows = x.shape[0] + (-x.shape[0]) % 8
        d_tile = fused_select_d_tile(n_rows, x.shape[1], w_ext.shape[0])
    _prof.record_kernel("fused_select", n=x.shape[0], d=x.shape[1],
                        d_tile=d_tile, theta=w_ext.shape[0])
    return _fused_select(x, w_ext, w_agr, beta=beta, d_tile=d_tile,
                         interpret=_resolve(interpret))
