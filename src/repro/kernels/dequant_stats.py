"""Pallas TPU kernel: fused dequantize → pairwise statistics.

The wire (repro.comm) hands the aggregator *quantized* payloads — int8
QSGD/sign levels or bf16 rows — plus a per-worker dequant multiplier.  The
unfused pipeline would materialise the fp32 (n, d) stack in HBM
(``decode`` = payload · mult), then stream it back through
``pairwise_stats``: two O(n·d) HBM round-trips of the *widened* data, 4–8×
the payload's own footprint.  This kernel extends the PR-2 single-pass
stats contract one layer down the memory hierarchy: each grid step loads
one ``(n, d_tile)`` *payload* block HBM→VMEM (1–2 B/coordinate — the wire
format is also the HBM format), widens and scales it in VMEM, and emits
the tile's raw distance contribution (MXU gram) and squared-norm rows
(VPU) exactly like ``pairwise_sqdist._stats_kernel``.  The fp32 stack
never exists in HBM.

Bitwise contract (DESIGN.md §9): the in-VMEM dequantize is *exactly* the
codec's decode — ``payload.astype(f32) * mult[row]`` — and the wrapper in
``kernels/ops.py`` derives ``d_tile`` with the same autotune call
``pairwise_stats`` uses for the decoded fp32 stack, so tile boundaries and
per-tile float summation match decode-then-``pairwise_stats`` bit for bit
in interpret mode (tested on the PR-2 edge-shape grid in
tests/test_comm.py).

Row padding follows the payload dtype's sublane tile (int8 → 32, bf16 →
16, else 8); padded rows carry zero payload *and* zero multiplier, so
their contributions vanish and the ``[:n, :n]`` slice is exact.  The
distance output is raw (unclamped, diagonal kept) for cross-leaf
accumulation — finalise with ``core.api.finalize_dists``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_SUBLANES = {jnp.int8.dtype: 32, jnp.bfloat16.dtype: 16}


def _kernel(p_ref, s_ref, d_ref, o_ref):
    """One grid step: dequantize the payload tile in VMEM, contribute the
    tile's distances AND norms from that single load."""
    i = pl.program_id(0)
    mult = s_ref[...][0]                              # (n,)
    # the codec decode, in VMEM: widen then one multiply per element
    x = p_ref[...].astype(jnp.float32) * mult[:, None]   # (n, d_tile)
    # HIGHEST: score order decides selection (same rationale as
    # pairwise_sqdist._stats_kernel, whose math this mirrors exactly)
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)           # (n, n) — MXU
    sq = jnp.sum(x * x, axis=1)                       # (n,)   — VPU
    tile = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        d_ref[...] = tile
        o_ref[...] = sq[None, :]

    @pl.when(i > 0)
    def _acc():
        d_ref[...] += tile
        o_ref[...] += sq[None, :]


def dequant_stats_pallas(payload: Array, mult: Array, *, d_tile: int = 2048,
                         interpret: bool = False):
    """(n, d) quantized payload + (n,) row multipliers ->
    ((n, n) raw sq-dists, (n,) sq-norms) of the *decoded* rows.

    ``payload`` is int8 or bfloat16 (fp32 accepted for the identity
    multiplier path); ``mult`` is the codec's per-row dequant multiplier.
    Pads the worker axis to the payload dtype's sublane tile and d up to a
    multiple of ``d_tile`` (zero payload × zero mult padding is exact).
    """
    if payload.ndim != 2:
        raise ValueError(f"payload must be (n, d), got {payload.shape}")
    n, d = payload.shape
    if mult.shape != (n,):
        raise ValueError(f"mult must be ({n},), got {mult.shape}")
    sublane = _SUBLANES.get(payload.dtype, 8)
    n_pad = (-n) % sublane
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    d_pad = (-d) % d_tile
    if n_pad or d_pad:
        payload = jnp.pad(payload, ((0, n_pad), (0, d_pad)))
    if n_pad:
        mult = jnp.pad(mult, (0, n_pad))
    np_, dp = payload.shape
    grid = (dp // d_tile,)
    dists, norms = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((np_, d_tile), lambda i: (0, i)),
                  pl.BlockSpec((1, np_), lambda i: (0, 0))],
        out_specs=(pl.BlockSpec((np_, np_), lambda i: (0, 0)),
                   pl.BlockSpec((1, np_), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((np_, np_), jnp.float32),
                   jax.ShapeDtypeStruct((1, np_), jnp.float32)),
        interpret=interpret,
    )(payload, mult.astype(jnp.float32)[None, :])
    return dists[:n, :n], norms[0, :n]
