"""Pallas TPU kernels: fused dequantize → pairwise statistics.

The wire (repro.comm) hands the aggregator *quantized* payloads — int8
QSGD/sign levels or bf16 rows — plus a per-worker dequant multiplier.  The
unfused pipeline would materialise the fp32 (n, d) stack in HBM
(``decode`` = payload · mult), then stream it back through
``pairwise_stats``: two O(n·d) HBM round-trips of the *widened* data, 4–8×
the payload's own footprint.  This kernel extends the PR-2 single-pass
stats contract one layer down the memory hierarchy: each macro step loads
one ``(n, macro_tile)`` *payload* block HBM→VMEM (1–2 B/coordinate — the
wire format is also the HBM format), and an inner ``fori_loop`` widens and
scales one ``d_tile`` window at a time in VMEM, emitting the window's raw
distance contribution (MXU gram) and squared-norm rows (VPU) exactly like
``pairwise_sqdist._stats_kernel``.  The fp32 stack never exists in HBM.

Bitwise contract (DESIGN.md §9): the in-VMEM dequantize is *exactly* the
codec's decode — ``payload.astype(f32) * mult[row]`` — and the wrapper in
``kernels/ops.py`` derives ``d_tile`` with the same autotune call
``pairwise_stats`` uses for the decoded fp32 stack, so window boundaries
and per-window float summation match decode-then-``pairwise_stats`` bit
for bit in interpret mode (tested on the PR-2 edge-shape grid in
tests/test_comm.py).  The two-level layout preserves the single-level
global window order (init at window 0, left-associated accumulation
after), so ``macro_tile`` is bitwise-free, same as
``pairwise_sqdist.pairwise_stats_pallas``.

The rectangular variant (``dequant_stats_rect_pallas``) is the §10 shard
kernel for encoded wires: an (n_loc, d) payload block contracted against
the gathered (n, d) payload — O(n_loc·n·d) per device — bitwise-identical
to the matching rows of the square kernel at the same ``d_tile``.

Row padding follows the payload dtype's sublane tile (int8 → 32, bf16 →
16, else 8); padded rows carry zero payload *and* zero multiplier, so
their contributions vanish and the ``[:n, :n]`` slice is exact.  The
distance output is raw (unclamped, diagonal kept) for cross-leaf
accumulation — finalise with ``core.api.finalize_dists``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_SUBLANES = {jnp.int8.dtype: 32, jnp.bfloat16.dtype: 16}


def _kernel(p_ref, s_ref, d_ref, o_ref, *, d_tile: int, windows: int):
    """One macro step: dequantize ``windows`` payload windows in VMEM and
    contribute each window's distances AND norms from the single macro
    transfer.  Global window order matches the single-level kernel."""
    i = pl.program_id(0)
    mult = s_ref[...][0]                              # (n,) — resident

    def window(j, carry):
        p = p_ref[:, pl.ds(j * d_tile, d_tile)]
        # the codec decode, in VMEM: widen then one multiply per element
        x = p.astype(jnp.float32) * mult[:, None]     # (n, d_tile)
        # HIGHEST: score order decides selection (same rationale as
        # pairwise_sqdist._stats_kernel, whose math this mirrors exactly)
        gram = jax.lax.dot_general(
            x, x, (((1,), (1,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)       # (n, n) — MXU
        sq = jnp.sum(x * x, axis=1)                   # (n,)   — VPU
        tile = sq[:, None] + sq[None, :] - 2.0 * gram
        first = jnp.logical_and(i == 0, j == 0)

        @pl.when(first)
        def _init():
            d_ref[...] = tile
            o_ref[...] = sq[None, :]

        @pl.when(jnp.logical_not(first))
        def _acc():
            d_ref[...] += tile
            o_ref[...] += sq[None, :]

        return carry

    if windows == 1:
        window(0, 0)
    else:
        jax.lax.fori_loop(0, windows, window, 0)


def dequant_stats_pallas(payload: Array, mult: Array, *, d_tile: int = 2048,
                         macro_tile: int | None = None,
                         interpret: bool = False):
    """(n, d) quantized payload + (n,) row multipliers ->
    ((n, n) raw sq-dists, (n,) sq-norms) of the *decoded* rows.

    ``payload`` is int8 or bfloat16 (fp32 accepted for the identity
    multiplier path); ``mult`` is the codec's per-row dequant multiplier.
    Pads the worker axis to the payload dtype's sublane tile and d up to a
    multiple of ``macro_tile`` (zero payload × zero mult padding is exact).
    """
    if payload.ndim != 2:
        raise ValueError(f"payload must be (n, d), got {payload.shape}")
    n, d = payload.shape
    if mult.shape != (n,):
        raise ValueError(f"mult must be ({n},), got {mult.shape}")
    sublane = _SUBLANES.get(payload.dtype, 8)
    n_pad = (-n) % sublane
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    if macro_tile is None:
        macro_tile = d_tile
    if macro_tile % d_tile:
        raise ValueError(f"macro_tile {macro_tile} must be a multiple of "
                         f"d_tile {d_tile}")
    macro_tile = min(macro_tile, ((d - 1) // d_tile + 1) * d_tile)
    d_pad = (-d) % macro_tile
    if n_pad or d_pad:
        payload = jnp.pad(payload, ((0, n_pad), (0, d_pad)))
    if n_pad:
        mult = jnp.pad(mult, (0, n_pad))
    np_, dp = payload.shape
    dists, norms = pl.pallas_call(
        functools.partial(_kernel, d_tile=d_tile,
                          windows=macro_tile // d_tile),
        grid=(dp // macro_tile,),
        in_specs=[pl.BlockSpec((np_, macro_tile), lambda i: (0, i)),
                  pl.BlockSpec((1, np_), lambda i: (0, 0))],
        out_specs=(pl.BlockSpec((np_, np_), lambda i: (0, 0)),
                   pl.BlockSpec((1, np_), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((np_, np_), jnp.float32),
                   jax.ShapeDtypeStruct((1, np_), jnp.float32)),
        interpret=interpret,
    )(payload, mult.astype(jnp.float32)[None, :])
    return dists[:n, :n], norms[0, :n]


def _rect_kernel(pl_ref, ml_ref, pf_ref, mf_ref, d_ref, o_ref, *,
                 d_tile: int, windows: int):
    i = pl.program_id(0)
    m_loc = ml_ref[...][0]                            # (n_loc,)
    m_full = mf_ref[...][0]                           # (n,)

    def window(j, carry):
        sl = pl.ds(j * d_tile, d_tile)
        xl = pl_ref[:, sl].astype(jnp.float32) * m_loc[:, None]
        xf = pf_ref[:, sl].astype(jnp.float32) * m_full[:, None]
        gram = jax.lax.dot_general(
            xl, xf, (((1,), (1,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)       # (n_loc, n)
        sq_f = jnp.sum(xf * xf, axis=1)               # (n,)
        sq_l = jnp.sum(xl * xl, axis=1)               # (n_loc,)
        tile = sq_l[:, None] + sq_f[None, :] - 2.0 * gram
        first = jnp.logical_and(i == 0, j == 0)

        @pl.when(first)
        def _init():
            d_ref[...] = tile
            o_ref[...] = sq_f[None, :]

        @pl.when(jnp.logical_not(first))
        def _acc():
            d_ref[...] += tile
            o_ref[...] += sq_f[None, :]

        return carry

    if windows == 1:
        window(0, 0)
    else:
        jax.lax.fori_loop(0, windows, window, 0)


def dequant_stats_rect_pallas(p_loc: Array, m_loc: Array, p_full: Array,
                              m_full: Array, *, d_tile: int = 2048,
                              macro_tile: int | None = None,
                              interpret: bool = False):
    """Rectangular fused dequantize → stats: (n_loc, d) payload block +
    (n_loc,) multipliers × gathered (n, d) payload + (n,) multipliers ->
    ((n_loc, n) raw sq-dist block, (n,) sq-norms) of the decoded rows.

    At the same ``d_tile`` the block is bitwise-identical to the matching
    rows of :func:`dequant_stats_pallas` on the full payload (row-subset
    decode is elementwise, row-subset gemm and row-wise norms are
    deterministic per row).  Padded local rows (zero payload × zero mult)
    are dropped by the ``[:n_loc]`` slice.
    """
    if p_loc.ndim != 2 or p_full.ndim != 2:
        raise ValueError(f"need 2-d payloads, got {p_loc.shape} / "
                         f"{p_full.shape}")
    n_loc, d = p_loc.shape
    n, d_f = p_full.shape
    if d != d_f:
        raise ValueError(f"lane axes differ: {d} vs {d_f}")
    if m_loc.shape != (n_loc,):
        raise ValueError(f"m_loc must be ({n_loc},), got {m_loc.shape}")
    if m_full.shape != (n,):
        raise ValueError(f"m_full must be ({n},), got {m_full.shape}")
    if p_loc.dtype != p_full.dtype:
        raise ValueError(f"payload dtypes differ: {p_loc.dtype} vs "
                         f"{p_full.dtype}")
    sublane = _SUBLANES.get(p_full.dtype, 8)
    l_pad = (-n_loc) % sublane
    n_pad = (-n) % sublane
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    if macro_tile is None:
        macro_tile = d_tile
    if macro_tile % d_tile:
        raise ValueError(f"macro_tile {macro_tile} must be a multiple of "
                         f"d_tile {d_tile}")
    macro_tile = min(macro_tile, ((d - 1) // d_tile + 1) * d_tile)
    d_pad = (-d) % macro_tile
    if l_pad or d_pad:
        p_loc = jnp.pad(p_loc, ((0, l_pad), (0, d_pad)))
    if l_pad:
        m_loc = jnp.pad(m_loc, (0, l_pad))
    if n_pad or d_pad:
        p_full = jnp.pad(p_full, ((0, n_pad), (0, d_pad)))
    if n_pad:
        m_full = jnp.pad(m_full, (0, n_pad))
    lp, dp = p_loc.shape
    np_ = p_full.shape[0]
    dists, norms = pl.pallas_call(
        functools.partial(_rect_kernel, d_tile=d_tile,
                          windows=macro_tile // d_tile),
        grid=(dp // macro_tile,),
        in_specs=[pl.BlockSpec((lp, macro_tile), lambda i: (0, i)),
                  pl.BlockSpec((1, lp), lambda i: (0, 0)),
                  pl.BlockSpec((np_, macro_tile), lambda i: (0, i)),
                  pl.BlockSpec((1, np_), lambda i: (0, 0))],
        out_specs=(pl.BlockSpec((lp, np_), lambda i: (0, 0)),
                   pl.BlockSpec((1, np_), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((lp, np_), jnp.float32),
                   jax.ShapeDtypeStruct((1, np_), jnp.float32)),
        interpret=interpret,
    )(p_loc, m_loc.astype(jnp.float32)[None, :],
      p_full, m_full.astype(jnp.float32)[None, :])
    return dists[:n_loc, :n], norms[0, :n]
