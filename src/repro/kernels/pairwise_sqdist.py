"""Pallas TPU kernel: pairwise squared euclidean distances over d-tiles.

The paper's §V identifies the O(n²·d) pairwise-distance computation as the
dominant cost of (MULTI-)KRUM/BULYAN; its CUDA implementation was limited to
n ≤ 24 by on-die shared memory.  The TPU formulation (DESIGN.md §3/§6)
streams the (n, d) gradient matrix HBM→VMEM in ``(n, d_tile)`` blocks,
computes the tile's Gram matrix on the MXU (``x @ x.T`` — the only O(n²·d)
term) plus row norms on the VPU, and accumulates
``‖a‖² + ‖b‖² − 2·gram`` into the (n, n) output block, which stays resident
in VMEM across the whole grid (output revisiting).

VMEM budget per grid step: n·d_tile·4 B (x tile, fp32) + n²·4 B (acc).
With n ≤ 64 and d_tile = 2048 that is ≤ 0.5 MB + 16 KB — far below the
~16 MB VMEM of a v5e core, so d_tile can be raised to trade grid steps for
pipelining (swept in tests/bench).  The MXU contraction dim is the d_tile
axis → keep it a multiple of 128; n is padded to a multiple of 8 (sublanes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)              # (n, d_tile)
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (n, n) — MXU
    sq = jnp.sum(x * x, axis=1)                      # (n,)   — VPU
    tile = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        o_ref[...] = tile

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += tile


def pairwise_sqdist_pallas(x: Array, *, d_tile: int = 2048,
                           interpret: bool = False) -> Array:
    """(n, d) -> (n, n) fp32 squared distances (diagonal zeroed).

    Pads n up to a multiple of 8 and d up to a multiple of ``d_tile``
    (zero padding is exact for distances).
    """
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got shape {x.shape}")
    n, d = x.shape
    n_pad = (-n) % 8
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    d_pad = (-d) % d_tile
    if n_pad or d_pad:
        x = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    np_, dp = x.shape
    grid = (dp // d_tile,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((np_, d_tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((np_, np_), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.float32),
        interpret=interpret,
    )(x)
    out = out[:n, :n]
    out = jnp.maximum(out, 0.0)
    return out * (1.0 - jnp.eye(n, dtype=jnp.float32))


def _stats_kernel(x_ref, d_ref, s_ref):
    """One grid step: the d-tile's distance contribution AND its norm
    contribution from a single VMEM load of the tile."""
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)               # (n, d_tile)
    # HIGHEST: score order decides selection — no bf16 passes on TPU
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (n, n) — MXU
    sq = jnp.sum(x * x, axis=1)                      # (n,)   — VPU
    tile = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        d_ref[...] = tile
        s_ref[...] = sq[None, :]

    @pl.when(i > 0)
    def _acc():
        d_ref[...] += tile
        s_ref[...] += sq[None, :]


def pairwise_stats_pallas(x: Array, *, d_tile: int = 2048,
                          interpret: bool = False):
    """Single-pass stats: (n, d) -> ((n, n) sq-dists, (n,) sq-norms).

    The unfused path reads the stack from HBM twice — once for the distance
    gram, once for the norms.  Both outputs here are accumulated from the
    same per-tile VMEM load, halving the stats phase's HBM traffic.  The
    distance matrix is raw (no clamp, diagonal not zeroed) so callers can
    accumulate contributions across leaves and finalise once
    (``core.api.finalize_dists``) — identical float summation to the
    single-output kernel.
    """
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got shape {x.shape}")
    n, d = x.shape
    n_pad = (-n) % 8
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    d_pad = (-d) % d_tile
    if n_pad or d_pad:
        x = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    np_, dp = x.shape
    grid = (dp // d_tile,)
    dists, norms = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((np_, d_tile), lambda i: (0, i))],
        out_specs=(pl.BlockSpec((np_, np_), lambda i: (0, 0)),
                   pl.BlockSpec((1, np_), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((np_, np_), jnp.float32),
                   jax.ShapeDtypeStruct((1, np_), jnp.float32)),
        interpret=interpret,
    )(x)
    return dists[:n, :n], norms[0, :n]
