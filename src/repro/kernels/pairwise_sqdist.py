"""Pallas TPU kernel: pairwise squared euclidean distances over d-tiles.

The paper's §V identifies the O(n²·d) pairwise-distance computation as the
dominant cost of (MULTI-)KRUM/BULYAN; its CUDA implementation was limited to
n ≤ 24 by on-die shared memory.  The TPU formulation (DESIGN.md §3/§6)
streams the (n, d) gradient matrix HBM→VMEM in ``(n, d_tile)`` blocks,
computes the tile's Gram matrix on the MXU (``x @ x.T`` — the only O(n²·d)
term) plus row norms on the VPU, and accumulates
``‖a‖² + ‖b‖² − 2·gram`` into the (n, n) output block, which stays resident
in VMEM across the whole grid (output revisiting).

VMEM budget per grid step: n·d_tile·4 B (x tile, fp32) + n²·4 B (acc).
With n ≤ 64 and d_tile = 2048 that is ≤ 0.5 MB + 16 KB — far below the
~16 MB VMEM of a v5e core, so d_tile can be raised to trade grid steps for
pipelining (swept in tests/bench).  The MXU contraction dim is the d_tile
axis → keep it a multiple of 128; n is padded to a multiple of 8 (sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)              # (n, d_tile)
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (n, n) — MXU
    sq = jnp.sum(x * x, axis=1)                      # (n,)   — VPU
    tile = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        o_ref[...] = tile

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += tile


def pairwise_sqdist_pallas(x: Array, *, d_tile: int = 2048,
                           interpret: bool = False) -> Array:
    """(n, d) -> (n, n) fp32 squared distances (diagonal zeroed).

    Pads n up to a multiple of 8 and d up to a multiple of ``d_tile``
    (zero padding is exact for distances).
    """
    n, d = x.shape
    n_pad = (-n) % 8
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    d_pad = (-d) % d_tile
    if n_pad or d_pad:
        x = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    np_, dp = x.shape
    grid = (dp // d_tile,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((np_, d_tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((np_, np_), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.float32),
        interpret=interpret,
    )(x)
    out = out[:n, :n]
    out = jnp.maximum(out, 0.0)
    return out * (1.0 - jnp.eye(n, dtype=jnp.float32))
