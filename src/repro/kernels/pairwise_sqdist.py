"""Pallas TPU kernels: pairwise squared euclidean distances over d-tiles.

The paper's §V identifies the O(n²·d) pairwise-distance computation as the
dominant cost of (MULTI-)KRUM/BULYAN; its CUDA implementation was limited to
n ≤ 24 by on-die shared memory.  The TPU formulation (DESIGN.md §3/§6)
streams the (n, d) gradient matrix HBM→VMEM, computes per-window Gram
matrices on the MXU (``x @ x.T`` — the only O(n²·d) term) plus row norms on
the VPU, and accumulates ``‖a‖² + ‖b‖² − 2·gram`` into the (n, n) output
block, which stays resident in VMEM across the whole grid (output
revisiting).

Two-level grid (DESIGN.md §7): the outer Pallas grid walks
``macro_tile``-lane blocks — one HBM→VMEM transfer and one grid-step
dispatch per block — and an inner traced ``fori_loop`` sweeps
``d_tile``-lane compute windows inside the block.  Per-window float math
and the **global window order** are identical to the single-level kernel
(window g = i·windows + j initialises the accumulators at g = 0 and
accumulates left-associated after), so any ``macro_tile`` choice is
bitwise-identical to the committed single-level layout: extra zero-padded
windows at the tail add exact ``+0.0`` (squared terms are never −0.0).

The rectangular variant (``pairwise_stats_rect_pallas``) is the §10 shard
kernel: an (n_loc, d) row block contracted against the gathered (n, d)
stack — O(n_loc·n·d) per device instead of the square kernel's redundant
O(n²·d).  With the same ``d_tile`` boundaries, its output block is
bitwise-identical to the matching rows of the square kernel (row-subset
gemm and row-wise norms are deterministic per row), which is what lets
``core.api.sharded_raw_stats`` keep bitwise parity with the replicated
path (tests/test_spmd.py).

VMEM budget per macro step: n·macro_tile·4 B (streamed x block, double-
buffered) + n²·4 B (resident accumulator) + n·d_tile·4 B (the window's
fp32 widening).  ``kernels/ops.py`` sizes (d_tile, macro_tile) against
this; the MXU contraction dim is the d_tile axis → keep it a multiple of
128; n is padded to a multiple of 8 (sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)              # (n, d_tile)
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (n, n) — MXU
    sq = jnp.sum(x * x, axis=1)                      # (n,)   — VPU
    tile = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        o_ref[...] = tile

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += tile


def pairwise_sqdist_pallas(x: Array, *, d_tile: int = 2048,
                           interpret: bool = False) -> Array:
    """(n, d) -> (n, n) fp32 squared distances (diagonal zeroed).

    Pads n up to a multiple of 8 and d up to a multiple of ``d_tile``
    (zero padding is exact for distances).
    """
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got shape {x.shape}")
    n, d = x.shape
    n_pad = (-n) % 8
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    d_pad = (-d) % d_tile
    if n_pad or d_pad:
        x = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    np_, dp = x.shape
    grid = (dp // d_tile,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((np_, d_tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((np_, np_), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.float32),
        interpret=interpret,
    )(x)
    out = out[:n, :n]
    out = jnp.maximum(out, 0.0)
    return out * (1.0 - jnp.eye(n, dtype=jnp.float32))


def _stats_tile(x):
    """One window's (tile contribution, norm row) from a fp32 (rows, dt)
    view — the shared per-window math of all stats kernels."""
    # HIGHEST: score order decides selection — no bf16 passes on TPU
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (n, n) — MXU
    sq = jnp.sum(x * x, axis=1)                      # (n,)   — VPU
    return sq[:, None] + sq[None, :] - 2.0 * gram, sq


def _stats_kernel(x_ref, d_ref, s_ref, *, d_tile: int, windows: int):
    """One macro step: ``windows`` d-tile windows of distance AND norm
    contributions from a single VMEM transfer of the macro block.  Global
    window order matches the single-level kernel — bitwise contract in
    the module header."""
    i = pl.program_id(0)

    def window(j, carry):
        x = x_ref[:, pl.ds(j * d_tile, d_tile)].astype(jnp.float32)
        tile, sq = _stats_tile(x)
        first = jnp.logical_and(i == 0, j == 0)

        @pl.when(first)
        def _init():
            d_ref[...] = tile
            s_ref[...] = sq[None, :]

        @pl.when(jnp.logical_not(first))
        def _acc():
            d_ref[...] += tile
            s_ref[...] += sq[None, :]

        return carry

    if windows == 1:
        window(0, 0)
    else:
        jax.lax.fori_loop(0, windows, window, 0)


def pairwise_stats_pallas(x: Array, *, d_tile: int = 2048,
                          macro_tile: int | None = None,
                          interpret: bool = False):
    """Single-pass stats: (n, d) -> ((n, n) sq-dists, (n,) sq-norms).

    The unfused path reads the stack from HBM twice — once for the distance
    gram, once for the norms.  Both outputs here are accumulated from the
    same per-tile VMEM load, halving the stats phase's HBM traffic.  The
    distance matrix is raw (unclamped, diagonal not zeroed) so callers can
    accumulate contributions across leaves and finalise once
    (``core.api.finalize_dists``) — identical float summation to the
    single-output kernel, for every ``macro_tile`` (module header).
    """
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got shape {x.shape}")
    n, d = x.shape
    n_pad = (-n) % 8
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    if macro_tile is None:
        macro_tile = d_tile
    if macro_tile % d_tile:
        raise ValueError(f"macro_tile {macro_tile} must be a multiple of "
                         f"d_tile {d_tile}")
    macro_tile = min(macro_tile, ((d - 1) // d_tile + 1) * d_tile)
    d_pad = (-d) % macro_tile
    if n_pad or d_pad:
        x = jnp.pad(x, ((0, n_pad), (0, d_pad)))
    np_, dp = x.shape
    dists, norms = pl.pallas_call(
        functools.partial(_stats_kernel, d_tile=d_tile,
                          windows=macro_tile // d_tile),
        grid=(dp // macro_tile,),
        in_specs=[pl.BlockSpec((np_, macro_tile), lambda i: (0, i))],
        out_specs=(pl.BlockSpec((np_, np_), lambda i: (0, 0)),
                   pl.BlockSpec((1, np_), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((np_, np_), jnp.float32),
                   jax.ShapeDtypeStruct((1, np_), jnp.float32)),
        interpret=interpret,
    )(x)
    return dists[:n, :n], norms[0, :n]


def _rect_tile(xl, xf):
    """One window's rectangular (block contribution, full norm row)."""
    gram = jax.lax.dot_general(
        xl, xf, (((1,), (1,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32)          # (n_loc, n) — MXU
    sq_f = jnp.sum(xf * xf, axis=1)                  # (n,)
    sq_l = jnp.sum(xl * xl, axis=1)                  # (n_loc,)
    return sq_l[:, None] + sq_f[None, :] - 2.0 * gram, sq_f


def _rect_kernel(xl_ref, xf_ref, d_ref, s_ref, *, d_tile: int,
                 windows: int):
    i = pl.program_id(0)

    def window(j, carry):
        sl = pl.ds(j * d_tile, d_tile)
        xl = xl_ref[:, sl].astype(jnp.float32)
        xf = xf_ref[:, sl].astype(jnp.float32)
        tile, sq_f = _rect_tile(xl, xf)
        first = jnp.logical_and(i == 0, j == 0)

        @pl.when(first)
        def _init():
            d_ref[...] = tile
            s_ref[...] = sq_f[None, :]

        @pl.when(jnp.logical_not(first))
        def _acc():
            d_ref[...] += tile
            s_ref[...] += sq_f[None, :]

        return carry

    if windows == 1:
        window(0, 0)
    else:
        jax.lax.fori_loop(0, windows, window, 0)


def pairwise_stats_rect_pallas(x_loc: Array, x_full: Array, *,
                               d_tile: int = 2048,
                               macro_tile: int | None = None,
                               interpret: bool = False):
    """Rectangular single-pass stats: (n_loc, d) row block × (n, d)
    gathered stack -> ((n_loc, n) raw sq-dist block, (n,) sq-norms).

    With the same ``d_tile`` the block is bitwise-identical to the
    matching rows of :func:`pairwise_stats_pallas` on the full stack
    (module header).  Both row axes zero-pad to a sublane multiple of 8;
    padded *local* rows produce garbage rows that the ``[:n_loc]`` slice
    drops (they never mix into real rows), padded *full* rows/columns are
    exact zeros.
    """
    if x_loc.ndim != 2 or x_full.ndim != 2:
        raise ValueError(f"need 2-d operands, got {x_loc.shape} / "
                         f"{x_full.shape}")
    n_loc, d = x_loc.shape
    n, d_f = x_full.shape
    if d != d_f:
        raise ValueError(f"lane axes differ: {d} vs {d_f}")
    l_pad = (-n_loc) % 8
    n_pad = (-n) % 8
    d_tile = min(d_tile, max(128, ((d - 1) // 128 + 1) * 128))
    if macro_tile is None:
        macro_tile = d_tile
    if macro_tile % d_tile:
        raise ValueError(f"macro_tile {macro_tile} must be a multiple of "
                         f"d_tile {d_tile}")
    macro_tile = min(macro_tile, ((d - 1) // d_tile + 1) * d_tile)
    d_pad = (-d) % macro_tile
    if l_pad or d_pad:
        x_loc = jnp.pad(x_loc, ((0, l_pad), (0, d_pad)))
    if n_pad or d_pad:
        x_full = jnp.pad(x_full, ((0, n_pad), (0, d_pad)))
    lp, dp = x_loc.shape
    np_ = x_full.shape[0]
    dists, norms = pl.pallas_call(
        functools.partial(_rect_kernel, d_tile=d_tile,
                          windows=macro_tile // d_tile),
        grid=(dp // macro_tile,),
        in_specs=[pl.BlockSpec((lp, macro_tile), lambda i: (0, i)),
                  pl.BlockSpec((np_, macro_tile), lambda i: (0, i))],
        out_specs=(pl.BlockSpec((lp, np_), lambda i: (0, 0)),
                   pl.BlockSpec((1, np_), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((lp, np_), jnp.float32),
                   jax.ShapeDtypeStruct((1, np_), jnp.float32)),
        interpret=interpret,
    )(x_loc, x_full)
    return dists[:n_loc, :n], norms[0, :n]
