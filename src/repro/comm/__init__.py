"""repro.comm — compressed gradient wire formats + bandwidth accounting.

The paper's O(d) local-cost claim leaves one bottleneck unmodelled: moving
n gradients to the aggregator.  This package gives the repo a *wire*:

* ``codecs``    — encode/decode pairs over stacked gradient pytrees
  (:class:`~repro.comm.codecs.EncodedGrads`), addressed by the same
  spec-string grammar as attacks (``get_codec("qsgd:bits=8")``), with
  optional error-feedback residual state;
* ``transport`` — the simulated mesh wire: exact per-worker byte
  accounting and chunked-gather scheduling (:class:`WireStats`).

The fused dequantize→stats kernel lives in ``repro.kernels.dequant_stats``;
``core.api.compute_stats`` / ``Aggregator.apply`` accept encoded stacks
directly (DESIGN.md §9).
"""
from repro.comm.codecs import (  # noqa: F401
    CODECS,
    Codec,
    EncodedGrads,
    available_codecs,
    encoded_pairwise_stats,
    get_codec,
    is_encoded,
    slice_workers,
)
from repro.comm.transport import (  # noqa: F401
    WireStats,
    gather_stats,
    hier_wire_stats,
    wire_stats,
)
