"""Simulated mesh wire: per-worker byte accounting + chunked gather.

The container has no multi-host fabric, so the wire is *accounted*, not
transmitted: every quantity here is a static python int derived from leaf
shapes and the codec's exact ``leaf_wire_bytes``, which makes the
accounting free under jit and bit-stable across runs.  The model is the
production gather the repo's trainers imply: each of the n workers ships
its gradient row set to the aggregator over a mesh in ``chunk_bytes``
chunks (chunking bounds the aggregator's receive buffer and is what a real
ring/tree gather would pipeline).

:class:`WireStats` is what campaigns surface per phase in the
``sim.campaign.v1`` report and what ``benchmarks/bandwidth.py`` persists
per codec × (n, d) cell in ``BENCH_comm.json``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax

from repro.comm.codecs import Codec, EncodedGrads, get_codec

PyTree = Any

DEFAULT_CHUNK_BYTES = 4 << 20          # 4 MiB receive-buffer chunks


@dataclasses.dataclass(frozen=True)
class WireStats:
    """One round's wire accounting for an n-worker gather.

    ``bytes_per_worker`` is exact (codec ``leaf_wire_bytes`` summed over
    leaves); ``fp32_bytes_per_worker`` is the uncompressed reference for
    the same shapes, so ``compression`` is the end-to-end wire win.
    ``chunks_per_worker`` is how many ``chunk_bytes`` transfers the gather
    schedules per worker (the pipelining depth of the simulated wire).
    """

    codec: str
    n: int
    bytes_per_worker: int
    fp32_bytes_per_worker: int
    chunk_bytes: int
    #: hierarchy level this gather belongs to (``"workers_to_leaders"`` /
    #: ``"leaders_to_server"`` for repro.hier; None for the flat gather)
    level: Optional[str] = None

    @property
    def total_bytes(self) -> int:
        return self.n * self.bytes_per_worker

    @property
    def compression(self) -> float:
        return self.fp32_bytes_per_worker / max(self.bytes_per_worker, 1)

    @property
    def chunks_per_worker(self) -> int:
        return -(-self.bytes_per_worker // self.chunk_bytes)

    def to_json(self) -> Dict[str, Any]:
        out = {
            "codec": self.codec,
            "n_workers": self.n,
            "bytes_per_worker": self.bytes_per_worker,
            "total_bytes": self.total_bytes,
            "fp32_bytes_per_worker": self.fp32_bytes_per_worker,
            "compression": round(self.compression, 4),
            "chunk_bytes": self.chunk_bytes,
            "chunks_per_worker": self.chunks_per_worker,
        }
        if self.level is not None:
            out["level"] = self.level
        return out


def _shapes_of(grads_like: PyTree, n: Optional[int]
               ) -> Tuple[Tuple[int, ...], ...]:
    """Leaf shapes of a stacked pytree — or of a *param* pytree with the
    worker axis ``n`` prepended (the engine passes params, not grads)."""
    leaves = jax.tree.leaves(grads_like)
    if n is None:
        return tuple(tuple(x.shape) for x in leaves)
    return tuple((n,) + tuple(x.shape) for x in leaves)


def wire_stats(codec: "str | Codec", grads_like: PyTree, *,
               n: Optional[int] = None,
               chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> WireStats:
    """Byte accounting for one gather round of ``grads_like``.

    ``grads_like`` is either the stacked gradient pytree (leaves
    ``(n, ...)``; leave ``n=None``) or the *parameter* pytree with ``n``
    given, in which case the worker axis is prepended shape-only — no
    arrays are materialised.
    """
    c = get_codec(codec) if isinstance(codec, str) else codec
    shapes = _shapes_of(grads_like, n)
    if not shapes:
        raise ValueError("empty pytree")
    n_workers = shapes[0][0]
    total = sum(c.leaf_wire_bytes(s) for s in shapes)
    fp32 = sum(4 * s[0] * _numel(s) for s in shapes)
    return WireStats(codec=c.spec(), n=n_workers,
                     bytes_per_worker=total // n_workers,
                     fp32_bytes_per_worker=fp32 // n_workers,
                     chunk_bytes=chunk_bytes)


def gather_stats(enc: EncodedGrads, *,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> WireStats:
    """WireStats straight off a wire container (exact, already-encoded)."""
    c = get_codec(enc.spec)
    fp32 = sum(4 * s[0] * _numel(s) for s in enc.shapes)
    return WireStats(codec=enc.spec, n=enc.n,
                     bytes_per_worker=enc.bytes_per_worker,
                     fp32_bytes_per_worker=fp32 // enc.n,
                     chunk_bytes=chunk_bytes)


def hier_wire_stats(codec: "str | Codec", grads_like: PyTree, *,
                    n: int, g: int,
                    chunk_bytes: int = DEFAULT_CHUNK_BYTES
                    ) -> Tuple[WireStats, WireStats]:
    """Per-level byte accounting for a two-level grouped gather.

    Level 0 (``workers_to_leaders``): all ``n`` workers wire their encoded
    gradient to their group leader.  Level 1 (``leaders_to_server``): the
    ``ceil(n/g)`` leaders wire their group aggregate — same shapes, re
    encoded with the same codec — to the server.  ``grads_like`` is the
    *parameter* pytree (shape-only, as in :func:`wire_stats` with ``n``).
    The hierarchy's wire win is visible directly: the server-side fan-in
    drops from n rows to n/g rows.
    """
    import dataclasses as _dc
    from repro.core.theory import group_sizes
    n_groups = len(group_sizes(n, g))
    inner = _dc.replace(wire_stats(codec, grads_like, n=n,
                                   chunk_bytes=chunk_bytes),
                        level="workers_to_leaders")
    outer = _dc.replace(wire_stats(codec, grads_like, n=n_groups,
                                   chunk_bytes=chunk_bytes),
                        level="leaders_to_server")
    return inner, outer


def _numel(shape: Tuple[int, ...]) -> int:
    m = 1
    for s in shape[1:]:
        m *= s
    return m
