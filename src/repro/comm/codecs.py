"""Gradient wire codecs: encode/decode pairs over stacked gradient pytrees.

A codec maps the stacked gradient pytree (every leaf ``(n, ...)``) to an
:class:`EncodedGrads` wire container — payload arrays + scale/index
sidecars + the *exact* wire byte count — and back.  Codecs are addressed by
the same spec-string grammar as attacks (``core.attacks.parse_spec``):

* ``"identity"`` / ``"fp32"``     — the uncompressed reference wire;
* ``"bf16"``                      — bfloat16 truncation (lossless round
  trip for bf16 inputs, 2 B/coordinate);
* ``"qsgd:bits=8"``               — QSGD stochastic quantization (Alistarh
  et al. 2017): per-worker max-abs scale, unbiased stochastic rounding to
  ``2^(bits-1)-1`` integer levels;
* ``"signsgd"``                   — scaled sign compression (Bernstein et
  al. 2018): 1 bit/coordinate + one per-worker magnitude;
* ``"topk:frac=0.01"``            — magnitude top-k sparsification with an
  int32 index sidecar.

Any codec takes ``ef=1`` for error feedback (Karimireddy et al. 2019): the
per-worker residual ``e_t = (g_t + e_{t-1}) - decode(encode(g_t + e_{t-1}))``
is threaded through the trainer state exactly like the adaptive-attack slot
(``dist.trainer`` state layouts), so the compression error telescopes
instead of accumulating.

Encoding is per-worker-row and per-leaf; per-leaf PRNG keys follow the
``inject_byzantine`` convention (``fold_in(key, leaf_offset + i)``) so the
streaming trainer's block-by-block encode reproduces the stacked trainer's
randomness exactly.

Decode invariant (DESIGN.md §9): for every codec whose payload admits the
fused dequantize→stats kernel, ``decode`` is *exactly*
``payload.astype(f32) * sidecar_row_multiplier`` — the sidecar stores the
final per-row dequant multiplier, never a numerator/denominator pair, so
the kernel and the XLA decode path are bitwise-identical in interpret mode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attacks import parse_spec

Array = jax.Array
PyTree = Any


# ==========================================================================
# the wire container
# ==========================================================================
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("payload", "sidecar"),
    meta_fields=("spec", "n", "shapes", "wire_bytes"))
@dataclasses.dataclass(frozen=True)
class EncodedGrads:
    """One round's wire messages from all n workers.

    ``payload`` mirrors the gradient pytree structure (per-leaf quantized
    arrays; top-k leaves are ``(n, k)`` value stacks); ``sidecar`` carries
    the per-leaf per-worker dequant multipliers (or int32 indices for
    top-k), ``None`` for sidecar-free codecs.  ``shapes`` records the
    original leaf shapes in leaf order (decode needs them for top-k
    scatter); ``wire_bytes`` is the exact total byte count all n workers
    put on the wire this round — a static python int, so byte accounting
    is free under jit.
    """

    payload: PyTree
    sidecar: PyTree
    spec: str
    n: int
    shapes: Tuple[Tuple[int, ...], ...]
    wire_bytes: int

    @property
    def bytes_per_worker(self) -> int:
        return self.wire_bytes // self.n


def is_encoded(x: Any) -> bool:
    return isinstance(x, EncodedGrads)


def slice_workers(enc: EncodedGrads, start: int, stop: int) -> EncodedGrads:
    """Worker rows [start, stop) of a container, as a smaller container.

    The hierarchical aggregation's per-group view (``repro.hier``): each
    group leader sees only its members' wire messages, so group statistics
    run straight on the sliced quantized payloads — the full-n fp32 stack
    never materialises at the leader.  Rows are sliced on the worker axis
    of every payload/sidecar leaf and the byte count re-derived for the
    sub-range (codecs whose ``leaf_wire_bytes`` is row-linear — all of the
    built-ins — make this the exact per-group wire cost).
    """
    if not (0 <= start < stop <= enc.n):
        raise ValueError(
            f"bad worker slice [{start}, {stop}) for n={enc.n}")
    codec = get_codec(enc.spec)
    m = stop - start
    shapes = tuple((m,) + s[1:] for s in enc.shapes)
    payload = jax.tree.map(lambda x: x[start:stop], enc.payload)
    sidecar = None if enc.sidecar is None else \
        jax.tree.map(lambda x: x[start:stop], enc.sidecar)
    total = sum(codec.leaf_wire_bytes(s) for s in shapes)
    return EncodedGrads(payload=payload, sidecar=sidecar, spec=enc.spec,
                        n=m, shapes=shapes, wire_bytes=total)


def _leaf2d(x: Array) -> Array:
    return x.reshape((x.shape[0], -1))


def _row_shape(n: int) -> Tuple[int, ...]:
    return (n,)


# ==========================================================================
# the codec protocol
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Codec:
    """Encode/decode pair over stacked gradient pytrees.

    Subclasses implement the three leaf-level primitives on the ``(n, m)``
    2-d row view; the pytree walk, error feedback, byte totals and the
    :class:`EncodedGrads` assembly are shared here.  ``ef=1`` (spec
    ``"name:ef=1"``) turns on the error-feedback residual, which makes the
    codec *stateful* — the trainer must thread the residual pytree
    (``init_residual``) through its state.
    """

    name: str = ""
    ef: float = 0.0

    @property
    def stateful(self) -> bool:
        return bool(self.ef)

    # ------------------------------------------------------- leaf primitives
    def encode_leaf(self, x: Array, key: Optional[Array]
                    ) -> Tuple[Array, Optional[Array]]:
        """(n, m) fp32 -> (payload rows, sidecar rows or None)."""
        raise NotImplementedError

    def decode_leaf(self, payload: Array, sidecar: Optional[Array],
                    shape: Tuple[int, ...]) -> Array:
        """(payload, sidecar) -> (n, m) fp32 rows (m = prod(shape[1:]))."""
        raise NotImplementedError

    def leaf_wire_bytes(self, shape: Tuple[int, ...]) -> int:
        """Exact bytes all n workers wire for one ``(n, ...)`` leaf."""
        raise NotImplementedError

    def dequant_form(self, payload: Array, sidecar: Optional[Array]
                     ) -> Optional[Tuple[Array, Array]]:
        """(payload2d, (n,) row multipliers) when the leaf admits the fused
        dequantize→stats kernel (int8/bf16 payload × per-row multiplier);
        ``None`` routes the leaf through decode-then-stats instead."""
        return None

    # ------------------------------------------------------------ tree walk
    def init_residual(self, grads_like: PyTree) -> PyTree:
        """Zero error-feedback state mirroring the stacked gradient shapes."""
        return jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), grads_like)

    def encode(self, grads: PyTree, *, key: Optional[Array] = None,
               residual: Optional[PyTree] = None, leaf_offset: int = 0
               ) -> Tuple[EncodedGrads, Optional[PyTree]]:
        """Encode a stacked pytree; returns (wire container, new residual).

        With error feedback the encoder compresses ``g + residual`` and the
        new residual is the compression error; stateless codecs return
        ``residual`` unchanged (``None`` normally).
        """
        if self.stateful:
            if residual is None:
                raise ValueError(
                    f"codec {self.name!r} with ef=1 needs a residual pytree; "
                    "seed it with init_residual()")
            grads = jax.tree.map(
                lambda g, r: g.astype(jnp.float32) + r, grads, residual)
        leaves, treedef = jax.tree.flatten(grads)
        if not leaves:
            raise ValueError("empty gradient pytree")
        n = leaves[0].shape[0]
        payloads, sidecars, shapes = [], [], []
        total = 0
        for i, leaf in enumerate(leaves):
            if leaf.shape[0] != n:
                raise ValueError("all leaves must share the worker axis size")
            k = jax.random.fold_in(key, leaf_offset + i) \
                if key is not None else None
            p, s = self.encode_leaf(
                _leaf2d(leaf).astype(jnp.float32), k)
            payloads.append(self._payload_to_leaf_shape(p, leaf.shape))
            sidecars.append(s)
            shapes.append(tuple(leaf.shape))
            total += self.leaf_wire_bytes(tuple(leaf.shape))
        sidecar = None if all(s is None for s in sidecars) else \
            jax.tree.unflatten(treedef, sidecars)
        enc = EncodedGrads(payload=jax.tree.unflatten(treedef, payloads),
                           sidecar=sidecar, spec=self.spec(), n=n,
                           shapes=tuple(shapes), wire_bytes=total)
        if not self.stateful:
            return enc, residual
        new_residual = jax.tree.map(
            lambda g, d: g - d, grads, self.decode(enc))
        return enc, new_residual

    def decode(self, enc: EncodedGrads) -> PyTree:
        """Wire container -> fp32 stacked pytree (original leaf shapes)."""
        p_leaves, treedef = jax.tree.flatten(enc.payload)
        s_leaves = jax.tree.leaves(enc.sidecar) \
            if enc.sidecar is not None else [None] * len(p_leaves)
        out = [
            self.decode_leaf(p, s, shape).reshape(shape)
            for p, s, shape in zip(p_leaves, s_leaves, enc.shapes)
        ]
        return jax.tree.unflatten(treedef, out)

    def _payload_to_leaf_shape(self, payload: Array,
                               shape: Tuple[int, ...]) -> Array:
        """Payload rows back to the original leaf shape when size-preserving
        (keeps the wire-attack / fused-stats row view trivial)."""
        if payload.size == int(payload.shape[0]) * _numel(shape):
            return payload.reshape(shape)
        return payload

    def spec(self) -> str:
        kv = [f"{f.name}={_fmt(getattr(self, f.name))}"
              for f in dataclasses.fields(self) if f.name != "name"
              and getattr(self, f.name) != f.default]
        return self.name + (":" + ",".join(kv) if kv else "")


def _numel(shape: Tuple[int, ...]) -> int:
    m = 1
    for s in shape[1:]:
        m *= s
    return m


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else str(v)


# ==========================================================================
# the codecs
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class IdentityCodec(Codec):
    """The uncompressed fp32 wire — the byte-accounting reference."""

    name: str = "identity"

    def encode_leaf(self, x, key):
        return x, None

    def decode_leaf(self, payload, sidecar, shape):
        return _leaf2d(payload)

    def leaf_wire_bytes(self, shape):
        return 4 * shape[0] * _numel(shape)


@dataclasses.dataclass(frozen=True)
class BF16Codec(Codec):
    """bfloat16 truncation: 2 B/coordinate, no sidecar.

    Round trip is the identity on values already representable in bf16
    (fp32 -> bf16 -> fp32 keeps the 8-bit exponent, truncates mantissa).
    """

    name: str = "bf16"

    def encode_leaf(self, x, key):
        return x.astype(jnp.bfloat16), None

    def decode_leaf(self, payload, sidecar, shape):
        # exact: *1.0 keeps bitwise parity with the fused kernel's
        # payload.astype(f32) * multiplier form
        return _leaf2d(payload).astype(jnp.float32)

    def leaf_wire_bytes(self, shape):
        return 2 * shape[0] * _numel(shape)

    def dequant_form(self, payload, sidecar):
        p = _leaf2d(payload)
        return p, jnp.ones((p.shape[0],), jnp.float32)


@dataclasses.dataclass(frozen=True)
class QSGDCodec(Codec):
    """QSGD stochastic quantization (Alistarh et al. 2017), max-abs scale.

    Per worker row: ``L = 2^(bits-1) - 1`` levels, scale ``s = max|g|``,
    payload ``stochastic_round(g · L/s)`` as int8, sidecar the dequant
    multiplier ``s/L``.  Stochastic rounding (``floor(q + u)``,
    u ~ U[0,1)) makes the decode *unbiased*: ``E[decode(encode(g))] = g``
    coordinate-wise — property-tested in tests/test_comm.py.  Wire cost:
    ``bits`` per coordinate + one fp32 scale per worker per leaf.
    """

    name: str = "qsgd"
    bits: float = 8.0

    def __post_init__(self):
        b = int(self.bits)
        if not 2 <= b <= 8 or b != self.bits:
            raise ValueError(f"qsgd bits must be an integer in [2, 8], "
                             f"got {self.bits}")

    @property
    def levels(self) -> int:
        return 2 ** (int(self.bits) - 1) - 1

    def encode_leaf(self, x, key):
        if key is None:
            raise ValueError("qsgd needs a PRNG key for stochastic rounding")
        L = float(self.levels)
        scale = jnp.max(jnp.abs(x), axis=1)                      # (n,)
        mult = scale / L                                         # (n,)
        safe = jnp.where(mult > 0.0, mult, 1.0)
        q = x / safe[:, None]                                    # |q| <= L
        u = jax.random.uniform(key, x.shape, jnp.float32)
        ints = jnp.floor(q + u)                                  # unbiased
        ints = jnp.clip(ints, -L, L)
        return ints.astype(jnp.int8), mult

    def decode_leaf(self, payload, sidecar, shape):
        return _leaf2d(payload).astype(jnp.float32) * sidecar[:, None]

    def leaf_wire_bytes(self, shape):
        m = _numel(shape)
        return shape[0] * ((m * int(self.bits) + 7) // 8 + 4)

    def dequant_form(self, payload, sidecar):
        return _leaf2d(payload), sidecar


@dataclasses.dataclass(frozen=True)
class SignSGDCodec(Codec):
    """Scaled sign compression (Bernstein et al. 2018).

    1 bit per coordinate on the wire (payload container is int8 ±1; the
    byte count models the packed form) + one per-row magnitude — the
    mean |g| so the decode preserves the row's l1 mass.  Biased; pair
    with ``ef=1`` for convergence (error feedback telescopes the bias).
    """

    name: str = "signsgd"

    def encode_leaf(self, x, key):
        mult = jnp.mean(jnp.abs(x), axis=1)                      # (n,)
        sign = jnp.where(x >= 0.0, 1, -1).astype(jnp.int8)
        return sign, mult

    def decode_leaf(self, payload, sidecar, shape):
        return _leaf2d(payload).astype(jnp.float32) * sidecar[:, None]

    def leaf_wire_bytes(self, shape):
        m = _numel(shape)
        return shape[0] * ((m + 7) // 8 + 4)

    def dequant_form(self, payload, sidecar):
        return _leaf2d(payload), sidecar


@dataclasses.dataclass(frozen=True)
class TopKCodec(Codec):
    """Magnitude top-k sparsification: keep the k = ceil(frac·m) largest
    coordinates per worker row, wire (value, int32 index) pairs.

    Keeps at least ``k/m`` of every row's squared-norm mass (the retained
    coordinates are the largest).  Biased — the canonical error-feedback
    client (``topk:frac=0.01,ef=1``).
    """

    name: str = "topk"
    frac: float = 0.01

    def __post_init__(self):
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(f"topk frac must be in (0, 1], got {self.frac}")

    def row_k(self, m: int) -> int:
        return max(1, min(m, int(-(-self.frac * m // 1))))   # ceil

    def encode_leaf(self, x, key):
        k = self.row_k(x.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(x), k)                    # (n, k)
        vals = jnp.take_along_axis(x, idx, axis=1)
        return vals, idx.astype(jnp.int32)

    def decode_leaf(self, payload, sidecar, shape):
        m = _numel(shape)
        vals = _leaf2d(payload).astype(jnp.float32)       # (n, k)
        idx = _leaf2d(sidecar)
        out = jnp.zeros((vals.shape[0], m), jnp.float32)
        rows = jnp.arange(vals.shape[0])[:, None]
        return out.at[rows, idx].set(vals)

    def leaf_wire_bytes(self, shape):
        return shape[0] * self.row_k(_numel(shape)) * 8


CODECS: Dict[str, Any] = {
    "identity": IdentityCodec,
    "fp32": IdentityCodec,
    "bf16": BF16Codec,
    "qsgd": QSGDCodec,
    "signsgd": SignSGDCodec,
    "topk": TopKCodec,
}


def get_codec(spec: str) -> Codec:
    """Resolve a codec spec (``"name"`` or ``"name:k=v,..."``) to an
    instance, mirroring ``core.attacks.get_adaptive``'s validation."""
    name, kwargs = parse_spec(spec)
    try:
        cls = CODECS[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; available: {sorted(CODECS)}") from None
    fields = {f.name for f in dataclasses.fields(cls) if f.name != "name"}
    unknown = set(kwargs) - fields
    if unknown:
        raise ValueError(
            f"codec {name!r} has no parameter(s) {sorted(unknown)}; "
            f"tunable: {sorted(fields)}")
    return cls(**kwargs)


def available_codecs() -> Tuple[str, ...]:
    return tuple(sorted(set(CODECS)))


# ==========================================================================
# encoded statistics — the fused dequantize→stats entry point
# ==========================================================================
def encoded_leaf_contrib(codec: Codec, payload: Array,
                         sidecar: Optional[Array], shape: Tuple[int, ...],
                         *, use_pallas: bool = False
                         ) -> Tuple[Array, Array]:
    """One encoded leaf's raw (dists, sq_norms) contribution.

    Under ``use_pallas`` a leaf whose codec admits the dequant form
    (int8/bf16 payload × per-row multiplier) goes through the fused
    ``dequant_stats`` kernel — the fp32 rows never exist in HBM; identity
    leaves take the plain ``pairwise_stats`` kernel, everything else
    decodes then contracts (XLA).  Contract matches
    ``core.api.leaf_sqdist_contrib``: raw (unclamped, diagonal kept) so
    cross-leaf accumulation stays a plain sum.
    """
    if use_pallas:
        from repro.kernels import ops as kops
        form = codec.dequant_form(payload, sidecar)
        if form is not None:
            return kops.dequant_stats(*form)
        # no dequant form (identity / top-k): decode, then the same
        # single-pass kernel the decoded fp32 path takes
        g = codec.decode_leaf(payload, sidecar, shape)
        return kops.pairwise_stats(_leaf2d(g))
    from repro.core import api
    # original leaf shape, so the contraction (and its float summation
    # order) is exactly what decode-then-tree_pairwise_stats computes
    g = codec.decode_leaf(payload, sidecar, shape).reshape(shape)
    return api._leaf_stats_contrib(g)


def encoded_leaf_block_contrib(codec: Codec, p_loc: Array,
                               s_loc: Optional[Array], p_full: Array,
                               s_full: Optional[Array],
                               shape: Tuple[int, ...], *, row_start,
                               n_loc: int) -> Tuple[Array, Array]:
    """Row-block partial of :func:`encoded_leaf_contrib` (Pallas path).

    ``p_loc``/``s_loc`` are one device's worker rows of the payload/
    sidecar, ``p_full``/``s_full`` the gathered container — the §10 shard
    seam.  Dequant-form codecs go through the rectangular
    ``dequant_stats_rect`` kernel (O(n_loc·n·d) per device, fp32 rows
    never in HBM); everything else decodes the gathered payload once and
    takes ``pairwise_stats_rect`` on the row slice at ``row_start``.
    Either way the block is bitwise-identical to the matching rows of the
    square kernels the replicated path runs (tests/test_spmd.py).
    """
    from repro.kernels import ops as kops
    form_full = codec.dequant_form(p_full, s_full)
    if form_full is not None:
        pf2, mf = form_full
        pl2, ml = codec.dequant_form(p_loc, s_loc)
        return kops.dequant_stats_rect(pl2, ml, pf2, mf)
    g2 = _leaf2d(codec.decode_leaf(p_full, s_full, shape))
    g_loc = jax.lax.dynamic_slice_in_dim(g2, row_start, n_loc, 0)
    return kops.pairwise_stats_rect(g_loc, g2)


def encoded_raw_stats(enc: EncodedGrads, *, use_pallas: bool = False
                      ) -> Tuple[Array, Array]:
    """Raw accumulation over a wire container: ((n, n) unfinalised
    sq-dists, (n,) sq-norms) — the encoded counterpart of one full pass of
    ``core.api.raw_pairwise_stats`` (which delegates here)."""
    codec = get_codec(enc.spec)
    p_leaves = jax.tree.leaves(enc.payload)
    s_leaves = jax.tree.leaves(enc.sidecar) \
        if enc.sidecar is not None else [None] * len(p_leaves)
    total_d = jnp.zeros((enc.n, enc.n), jnp.float32)
    total_s = jnp.zeros((enc.n,), jnp.float32)
    for p, s, shape in zip(p_leaves, s_leaves, enc.shapes):
        dd, sq = encoded_leaf_contrib(codec, p, s, shape,
                                      use_pallas=use_pallas)
        total_d = total_d + dd
        total_s = total_s + sq
    return total_d, total_s


def encoded_raw_contrib(enc: EncodedGrads, *, use_pallas: bool = False
                        ) -> Array:
    """A container's raw (n, n) distance contribution (no clamp/diag) —
    the streaming trainer's per-block accumulation unit, mirroring
    ``core.api.leaf_sqdist_contrib`` so the cross-block float summation
    stays identical to the stacked encoded path."""
    return encoded_raw_stats(enc, use_pallas=use_pallas)[0]


def encoded_pairwise_stats(enc: EncodedGrads, *, use_pallas: bool = False
                           ) -> Tuple[Array, Array]:
    """Single pass over the wire container: ((n, n) sq-dists, (n,) norms).

    The encoded mirror of ``core.api.tree_pairwise_stats`` — same raw
    accumulation, finalised once; bitwise-identical to decode-then-stats
    in interpret mode for dequant-form codecs (tests/test_comm.py).
    """
    from repro.core import api
    total_d, total_s = encoded_raw_stats(enc, use_pallas=use_pallas)
    return api.finalize_dists(total_d), total_s
