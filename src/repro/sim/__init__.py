"""repro.sim — the Byzantine campaign simulator (DESIGN.md §8).

Turns the reproduction into a scenario lab: declarative
:class:`~repro.sim.scenario.Scenario` descriptions (attack schedules,
time-varying effective f, Dirichlet non-IID data, worker churn) executed by
a jit-friendly :func:`~repro.sim.engine.run_campaign` on either trainer,
with plan-level telemetry (per-worker selection, Krum score spectra,
honest-mean deviation, suspicion EMA) and JSON/CSV campaign reports.
"""
from repro.sim.engine import CampaignResult, run_campaign  # noqa: F401
from repro.sim.scenario import (  # noqa: F401
    AttackPhase,
    AttackSchedule,
    DataConfig,
    Scenario,
    switch_scenario,
)
from repro.sim import report, telemetry  # noqa: F401
