"""Declarative campaign scenarios (DESIGN.md §8).

A :class:`Scenario` is a frozen, fully serialisable description of one
byzantine training campaign: the architecture, the robust configuration,
the attack *schedule* (a sequence of :class:`AttackPhase` — per-phase attack
spec, effective f, worker churn), the data heterogeneity (Dirichlet non-IID
mixture) and the trainer substrate.  ``repro.sim.engine.run_campaign``
executes it; nothing in here imports jax — scenarios are pure data, cheap
to sweep over in benchmarks and to embed in campaign reports.

Attack specs use the ``core.attacks`` spec-string grammar
(``"little_is_enough:z=2.0"``, ``"adaptive_lie:up=1.2"``); transform specs
use the same grammar over ``core.api.TRANSFORMS``
(``"worker_momentum:beta=0.9"``, ``"clip:max_norm=1.0"``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import ArchConfig

# the tiny default campaign architecture (~1.5M params — minutes on CPU)
TINY = ArchConfig(name="sim-tiny", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512)


@dataclasses.dataclass(frozen=True)
class AttackPhase:
    """One contiguous segment of a campaign with a fixed threat model.

    ``attack``  — attack spec string (``core.attacks.get_attack`` grammar;
                  adaptive specs allowed on the stacked trainer).
    ``f``       — how many workers the adversary controls *this phase*
                  (None -> the scenario's contract ``f``; must not exceed
                  it — the rule always defends against the contract).
    ``stale_workers`` — honest-worker ids whose data is frozen to the
                  phase's first batch (straggler/churn model: a stalled
                  worker keeps resubmitting gradients of old data; the
                  trainer contract stays untouched because churn lives
                  entirely in the data fed to the step).
    """

    steps: int
    attack: str = "none"
    f: Optional[int] = None
    stale_workers: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.steps <= 0:
            raise ValueError(f"phase steps must be positive, got {self.steps}")


@dataclasses.dataclass(frozen=True)
class AttackSchedule:
    """An ordered tuple of phases; the campaign runs them back to back."""

    phases: Tuple[AttackPhase, ...]

    def __post_init__(self):
        if not self.phases:
            raise ValueError("schedule needs at least one phase")

    @property
    def total_steps(self) -> int:
        return sum(p.steps for p in self.phases)

    def bounds(self) -> Tuple[Tuple[int, int], ...]:
        """Per-phase (start, stop) global step ranges."""
        out, start = [], 0
        for p in self.phases:
            out.append((start, start + p.steps))
            start += p.steps
        return tuple(out)

    def describe(self) -> str:
        return " -> ".join(f"{p.attack}@{p.steps}" for p in self.phases)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """Worker data assignment.

    ``noniid_alpha = 0`` (default) keeps the i.i.d. single-automaton stream;
    ``> 0`` assigns each worker a Dirichlet(α) mixture over ``n_domains``
    distinct bigram automata (``data.synthetic.make_noniid_lm_batch``).
    """

    noniid_alpha: float = 0.0
    n_domains: int = 4

    def __post_init__(self):
        if self.noniid_alpha < 0:
            raise ValueError(f"noniid_alpha must be >= 0, got "
                             f"{self.noniid_alpha}")
        if self.noniid_alpha > 0 and self.n_domains < 2:
            raise ValueError("non-IID assignment needs n_domains >= 2")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One campaign: who aggregates, who attacks when, on what data."""

    name: str
    schedule: AttackSchedule
    n_workers: int = 11
    f: int = 2
    gar: str = "multi_bulyan"
    transforms: Tuple[str, ...] = ()          # transform spec strings
    codec: Optional[str] = None               # wire codec spec (repro.comm)
    trainer: str = "stacked"                  # stacked|stream_block|stream_global
    use_pallas: bool = False
    arch: ArchConfig = TINY
    data: DataConfig = DataConfig()
    per_worker_batch: int = 2
    seq: int = 64
    lr: float = 0.05
    momentum: float = 0.9
    seed: int = 0
    suspicion_ema: float = 0.9                # telemetry EMA decay
    # hierarchical (grouped) aggregation — repro.hier, DESIGN.md §11.
    # hier_g=0 keeps the flat path; > 0 groups workers by contiguous rows
    # (so a phase with f >= hier_g's inner budget concentrated in rows
    # 0..f-1 is the poisoned-subtree campaign).  hier_f_inner/hier_f_outer
    # override the derived per-level budgets and hier_enforce=False admits
    # budgets that do not cover the contract f — the deliberately
    # under-provisioned capture demonstrations.
    hier_g: int = 0
    hier_rule: Optional[str] = None           # default: the scenario gar
    hier_outer_rule: Optional[str] = None
    hier_f_inner: Optional[int] = None
    hier_f_outer: Optional[int] = None
    hier_enforce: bool = True
    # async bounded-staleness aggregation — repro.serve, DESIGN.md §13.
    # async_tau=0 keeps the synchronous lockstep path; > 0 replays every
    # phase through the real gradient buffer: each phase's stale_workers
    # miss the round deadline and deliver only every stale_period rounds,
    # slots older than async_tau rounds are overstale and haircut the
    # byzantine budget (core.theory.StalenessBudget).
    async_tau: int = 0
    stale_period: int = 4

    def __post_init__(self):
        if self.trainer not in ("stacked", "stream_block", "stream_global"):
            raise ValueError(f"unknown trainer {self.trainer!r}")
        if self.transforms and self.trainer != "stacked":
            raise ValueError(
                "pre-aggregation transforms need trainer='stacked' "
                "(the streaming trainers never hold the full stack)")
        for p in self.schedule.phases:
            f_eff = self.f if p.f is None else p.f
            if not 0 <= f_eff <= self.f:
                raise ValueError(
                    f"phase {p.attack!r}: effective f={f_eff} outside "
                    f"[0, contract f={self.f}]")
            bad = [w for w in p.stale_workers
                   if not 0 <= w < self.n_workers]
            if bad:
                raise ValueError(f"stale_workers out of range: {bad}")
        # fail on malformed specs at scenario build time, not mid-campaign
        from repro.core import attacks as ATK
        for p in self.schedule.phases:
            name, _ = ATK.parse_spec(p.attack)
            if name not in ATK.ATTACKS and name not in ATK.ADAPTIVE \
                    and name not in ATK.WIRE_ATTACKS:
                raise ValueError(
                    f"unknown attack {name!r}; available: "
                    f"{sorted(ATK.ATTACKS)} + {sorted(ATK.ADAPTIVE)} + "
                    f"wire: {sorted(ATK.WIRE_ATTACKS)}")
            if name in ATK.ADAPTIVE and self.trainer != "stacked":
                raise ValueError(
                    f"adaptive attack {name!r} needs trainer='stacked'")
            if name in ATK.WIRE_ATTACKS and self.codec is None:
                raise ValueError(
                    f"wire attack {name!r} needs a codec= wire to attack")
        if self.codec is not None:
            from repro.comm import get_codec
            c = get_codec(self.codec)   # validates the spec eagerly
            if c.stateful and self.trainer != "stacked":
                raise ValueError(
                    "error-feedback codecs (ef=1) need trainer='stacked'")
            if c.stateful and self.hier_g > 0:
                raise ValueError(
                    "hier_g > 0 does not support error-feedback codecs "
                    "(no residual slot at the leaders→server hop)")
        if self.hier_g < 0:
            raise ValueError(f"hier_g must be >= 0, got {self.hier_g}")
        if self.async_tau < 0:
            raise ValueError(
                f"async_tau must be >= 0, got {self.async_tau}")
        if self.async_tau > 0:
            if self.stale_period < 1:
                raise ValueError(
                    f"stale_period must be >= 1, got {self.stale_period}")
            if self.trainer != "stacked":
                raise ValueError(
                    "async bounded-staleness aggregation needs "
                    "trainer='stacked'")
            if self.transforms or self.codec is not None or self.hier_g > 0:
                raise ValueError(
                    "async_tau > 0 does not compose with transforms, "
                    "codecs or hierarchical aggregation yet (the v1 "
                    "service scope — DESIGN.md §13)")
            for p in self.schedule.phases:
                name, _ = ATK.parse_spec(p.attack)
                if name in ATK.ADAPTIVE:
                    raise ValueError(
                        f"adaptive attack {name!r} is not supported on "
                        f"the async service path")
        if self.hier_g > 0:
            # fail on an infeasible per-level budget at scenario build
            # time; split_f_budget raises with the offending level named
            self.hier_config().budget(self.n_workers, self.f)

    def phase_f(self, phase: AttackPhase) -> int:
        return self.f if phase.f is None else phase.f

    def hier_config(self):
        """The ``repro.hier.GroupConfig`` this scenario asks for (or None)."""
        if self.hier_g <= 0:
            return None
        from repro.hier import GroupConfig
        return GroupConfig(g=self.hier_g,
                           rule=self.hier_rule or self.gar,
                           outer_rule=self.hier_outer_rule,
                           f_inner=self.hier_f_inner,
                           f_outer=self.hier_f_outer,
                           enforce_budget=self.hier_enforce)

    def build_transforms(self):
        """Resolve transform spec strings into Transform instances."""
        from repro.core import api
        from repro.core.attacks import parse_spec
        out = []
        for spec in self.transforms:
            name, kwargs = parse_spec(spec)
            try:
                cls = api.TRANSFORMS[name]
            except KeyError:
                raise ValueError(
                    f"unknown transform {name!r}; available: "
                    f"{sorted(api.TRANSFORMS)}") from None
            out.append(cls(**kwargs))
        return tuple(out)

    def to_json(self) -> Dict[str, Any]:
        """Report-embeddable plain-dict form (arch collapsed to its name)."""
        return {
            "name": self.name,
            "n_workers": self.n_workers,
            "f": self.f,
            "gar": self.gar,
            "transforms": list(self.transforms),
            "codec": self.codec,
            "trainer": self.trainer,
            "use_pallas": self.use_pallas,
            "arch": self.arch.name,
            "data": dataclasses.asdict(self.data),
            "per_worker_batch": self.per_worker_batch,
            "seq": self.seq,
            "lr": self.lr,
            "momentum": self.momentum,
            "seed": self.seed,
            "phases": [
                {"steps": p.steps, "attack": p.attack,
                 "f": self.phase_f(p), "stale_workers": list(p.stale_workers)}
                for p in self.schedule.phases
            ],
            **({"async": {"tau": self.async_tau,
                          "stale_period": self.stale_period}}
               if self.async_tau > 0 else {}),
            **({"hier": {"g": self.hier_g,
                         "rule": self.hier_rule or self.gar,
                         "outer_rule": self.hier_outer_rule,
                         "f_inner": self.hier_f_inner,
                         "f_outer": self.hier_f_outer,
                         "enforce": self.hier_enforce}}
               if self.hier_g > 0 else {}),
        }


def switch_scenario(gar: str = "multi_bulyan", *, pre: int = 20,
                    post: int = 20, attack: str = "little_is_enough:z=4.0",
                    **kw) -> Scenario:
    """The canonical mid-run switch campaign: no_attack -> ``attack``.

    This is the acceptance scenario: the robust rule's post-switch
    honest-mean deviation must stay bounded with ≈ 0 byzantine selection,
    while plain averaging is dragged away by the same schedule.
    """
    sched = AttackSchedule((AttackPhase(steps=pre, attack="none"),
                            AttackPhase(steps=post, attack=attack)))
    return Scenario(name=f"switch-{gar}", schedule=sched, gar=gar, **kw)
