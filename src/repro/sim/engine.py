"""Campaign engine: execute a :class:`~repro.sim.scenario.Scenario`.

Structure (DESIGN.md §8): the *phase loop* is host-side python — each phase
has a different static threat model (attack spec, effective f, churn mask),
so each gets its own trainer step built by ``dist.trainer.make_train_step``
or ``dist.streaming.make_streaming_train_step`` with ``telemetry=True``.
*Within* a phase everything is one jitted ``lax.scan``: the carry is
``(params, trainer state, suspicion EMA)`` and the scanned inputs are the
phase's precomputed batch stack and per-step PRNG keys, so a phase runs as
a single XLA computation regardless of length.

Data (including the Dirichlet non-IID assignment and the straggler/churn
masks — stale workers are frozen to their phase-entry batch) is synthesised
host-side per phase; randomness is keyed by *global* step index, so traces
are bitwise-reproducible and checkpoint/resume at phase boundaries replays
the remaining phases exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs.base import RobustConfig
from repro.core import attacks as ATK
from repro.data import dirichlet_mixture, make_lm_batch, make_noniid_lm_batch
from repro.dist import init_train_state, make_train_step, split_workers
from repro.dist.streaming import make_streaming_train_step
from repro.dist.trainer import TrainerState
from repro import models as MD
from repro import obs as OBS
from repro.optim import sgd, warmup_cosine
from repro.sim import telemetry as TEL
from repro.sim.scenario import AttackPhase, Scenario

PyTree = Any

# PR-3/PR-4-era checkpoints stored the trainer-state components as
# top-level keys; the TrainerState unification nests them under "state".
# restore() consults these only when the canonical key is absent.
LEGACY_STATE_ALIASES = {"state|opt": "opt", "state|tstates": "tstates",
                        "state|cres": "cres"}


@dataclasses.dataclass
class CampaignResult:
    """A finished campaign: the stacked per-step trace + per-phase digest.

    ``trace`` maps field name -> (steps, ...) numpy array (see
    ``telemetry.step_record`` for the schema); ``summary`` is the host-side
    per-phase digest (``telemetry.summarize``).  ``start_step`` > 0 when the
    run resumed from a checkpoint (the trace covers executed steps only).
    ``wire`` is the campaign's :class:`~repro.comm.transport.WireStats`
    accounting as a plain dict (None without a codec) — ``summarize``
    repeats it per phase so the ``sim.campaign.v1`` report carries it.
    ``obs`` is the drained ``obs.v1`` snapshot when the campaign ran with
    an enabled :class:`~repro.obs.ObsConfig` (None otherwise — the report
    stays byte-identical without it).
    """

    scenario: Scenario
    trace: Dict[str, np.ndarray]
    summary: Dict[str, Any]
    start_step: int = 0
    wall_s: float = 0.0
    wire: Optional[Dict[str, Any]] = None
    obs: Optional[Dict[str, Any]] = None


def _make_batch_gen(scenario: Scenario, mixture):
    """One jitted batch generator per campaign: (steps,) indices -> batches.

    Built once and reused by every phase so same-length phases hit the jit
    cache instead of re-lowering the data scan per phase (the C204
    contract extends to data generation — the step indices are traced
    arguments, never baked-in constants).
    """
    n, pwb, seq = scenario.n_workers, scenario.per_worker_batch, scenario.seq
    vocab = scenario.arch.vocab_size
    data_key = jax.random.key(scenario.seed)

    def one(step_idx):
        k = jax.random.fold_in(data_key, step_idx)
        if mixture is not None:
            b = make_noniid_lm_batch(k, vocab, n, pwb, seq, mixture,
                                     seed=scenario.seed + 77)
        else:
            b = make_lm_batch(k, vocab, n * pwb, seq,
                              seed=scenario.seed + 77)
        return split_workers(b, n)

    return jax.jit(jax.vmap(one))


def _phase_batches(gen, phase: AttackPhase, start: int,
                   *, freeze: bool = True) -> PyTree:
    """Worker-split token batches for one phase: leaves (steps, n, pwb, ...).

    Batch randomness is keyed by the *global* step index (phase layout does
    not change the data), matching ``launch/train.py``'s per-step fold_in
    convention.  Stale (churned) workers are frozen to the phase's first
    batch — they keep resubmitting gradients computed on old data.  On the
    async path (``freeze=False``) the data stays fresh: staleness is
    modelled by the real gradient buffer instead (missed deadlines replay
    the worker's *buffered* gradient, see :func:`_phase_fresh`).
    """
    batches = gen(jnp.arange(start, start + phase.steps))
    if freeze:
        for w in phase.stale_workers:
            batches = jax.tree.map(
                lambda x: x.at[:, w].set(x[0, w]), batches)
    return batches


def _phase_fresh(scenario: Scenario, phase: AttackPhase,
                 start: int) -> jnp.ndarray:
    """(steps, n) bool delivery masks for the async buffered path.

    A phase's ``stale_workers`` miss the round deadline and deliver only
    every ``scenario.stale_period`` rounds (keyed by *global* step so
    resume replays the same arrival schedule); everyone else delivers
    every round.
    """
    fresh = np.ones((phase.steps, scenario.n_workers), dtype=bool)
    for w in phase.stale_workers:
        for t in range(phase.steps):
            fresh[t, w] = (start + t) % scenario.stale_period == 0
    return jnp.asarray(fresh)


def run_campaign(scenario: Scenario, *, ckpt_dir: Optional[str] = None,
                 resume: bool = False, verbose: bool = False,
                 obs: Optional[OBS.ObsConfig] = None) -> CampaignResult:
    """Run a scenario end to end; returns the trace + summary.

    ``ckpt_dir`` enables checkpointing at phase boundaries (params,
    optimizer state, transform states, suspicion EMA — keyed by global
    step).  With ``resume`` the engine restores the latest phase-boundary
    checkpoint and replays only the remaining phases; the returned trace
    then starts at ``start_step``.

    ``obs`` (an enabled :class:`~repro.obs.ObsConfig`) seeds the in-graph
    metrics registry + span ring into ``TrainerState.mstate`` *before*
    the phase scans (the scan carry structure is fixed, so the engine
    cannot rely on the steps' lazy trace-time seeding), threads the
    config through every step builder, and drains the registry into
    ``CampaignResult.obs`` as an ``obs.v1`` snapshot.  The registry
    rides the phase-boundary checkpoints with the rest of the state, so
    resumed campaigns keep their counters.
    """
    t0 = time.time()
    cfg = scenario.arch
    rcfg = RobustConfig(n_workers=scenario.n_workers, f=scenario.f,
                        gar=scenario.gar, use_pallas=scenario.use_pallas,
                        grouped=scenario.hier_g > 0)
    transforms = scenario.build_transforms()
    total_steps = scenario.schedule.total_steps

    key = jax.random.key(scenario.seed)
    params = MD.init_model(key, cfg)
    opt = sgd(momentum=scenario.momentum)
    hier = scenario.hier_config()
    wire = None
    if scenario.codec is not None:
        if hier is not None:
            # two-hop accounting: workers→leaders + leaders→server
            from repro.comm import hier_wire_stats
            lv0, lv1 = hier_wire_stats(scenario.codec, params,
                                       n=scenario.n_workers,
                                       g=scenario.hier_g)
            wire = {"levels": [lv0.to_json(), lv1.to_json()]}
        else:
            from repro.comm import wire_stats
            wire = wire_stats(scenario.codec, params,
                              n=scenario.n_workers).to_json()
    # attack state is per-phase (seeded at each phase entry below), so the
    # cross-phase TrainerState carries astate=None between phases; the
    # error-feedback residual (like transform states) is cross-phase
    tstate: TrainerState = init_train_state(
        opt, params, transforms, n_workers=scenario.n_workers,
        codec=scenario.codec)
    if scenario.async_tau > 0:
        # the campaign replays through the real bounded-staleness buffer:
        # seed the TrainerState-resident round state (DESIGN.md §13)
        from repro.core import api
        from repro.serve import service as SRV
        svc = SRV.AsyncAggService(
            backend=api.AggregatorBackend.for_config(rcfg, needs_dists=True),
            tau=scenario.async_tau)
        tstate = SRV.with_buffer(tstate, svc, params, scenario.n_workers)
    if OBS.obs_on(obs):
        ms = OBS.init_serve_obs(obs, scenario.n_workers, scenario.async_tau,
                                telemetry=True) \
            if scenario.async_tau > 0 else \
            OBS.init_train_obs(obs, scenario.n_workers, telemetry=True)
        tstate = dataclasses.replace(tstate, mstate=ms)
    susp = TEL.init_suspicion(scenario.n_workers)
    stale_ema = TEL.init_suspicion(scenario.n_workers)
    gsusp = None
    if hier is not None:
        n_groups = hier.budget(scenario.n_workers, scenario.f).n_groups
        gsusp = TEL.init_suspicion(n_groups)
    lr_fn = warmup_cosine(scenario.lr, warmup=max(total_steps // 20, 1),
                          total_steps=total_steps)

    mixture = None
    if scenario.data.noniid_alpha > 0:
        mixture = dirichlet_mixture(
            jax.random.fold_in(key, 424242), scenario.n_workers,
            scenario.data.n_domains, scenario.data.noniid_alpha)

    start_step = 0
    if ckpt_dir and resume:
        latest = latest_step(ckpt_dir)
        boundary_steps = {stop for _, stop in scenario.schedule.bounds()}
        if latest is not None and latest not in boundary_steps:
            raise ValueError(
                f"checkpoint step {latest} is not a phase boundary of "
                f"schedule {scenario.schedule.describe()!r}")
        if latest is not None:
            like = {"params": params, "state": tstate, "susp": susp}
            if gsusp is not None:
                like["gsusp"] = gsusp
            if scenario.async_tau > 0:
                like["stale"] = stale_ema
            loaded = restore(ckpt_dir, latest, like,
                             key_aliases=LEGACY_STATE_ALIASES)
            params, tstate = loaded["params"], loaded["state"]
            susp = loaded["susp"]
            gsusp = loaded.get("gsusp", gsusp)
            stale_ema = loaded.get("stale", stale_ema)
            start_step = latest
            if verbose:
                print(f"[sim] resumed {scenario.name} at step {latest}")

    chunk_q = min(scenario.seq, 512)
    phase_traces = []
    batch_gen = _make_batch_gen(scenario, mixture)

    # one jitted scan runner per distinct (attack, f) config: a second
    # phase with an identical config reuses the runner and hits its trace
    # cache instead of re-lowering the whole step (the C204 contract —
    # the phase index rides in the carry so it never bakes into the trace)
    runners = {}

    is_async = scenario.async_tau > 0

    def _make_runner(attack: str, f_eff: int):
        if is_async:
            from repro.serve.service import make_async_train_step
            step_fn = make_async_train_step(
                cfg, rcfg, opt, lr_fn, tau=scenario.async_tau,
                chunk_q=chunk_q, attack=attack, attack_f=f_eff,
                telemetry=True, obs=obs)
        elif scenario.trainer == "stacked":
            step_fn = make_train_step(
                cfg, rcfg, opt, lr_fn, chunk_q=chunk_q, attack=attack,
                attack_f=f_eff, transforms=transforms,
                codec=scenario.codec, telemetry=True, hier=hier, obs=obs)
        else:
            scope = "global" if scenario.trainer.endswith("global") else \
                "block"
            step_fn = make_streaming_train_step(
                cfg, rcfg, opt, lr_fn, scope=scope, chunk_q=chunk_q,
                attack=attack, attack_f=f_eff,
                codec=scenario.codec, telemetry=True, hier=hier, obs=obs)

        def body(carry, xs):
            p, st, sp, gsp, stale, pi = carry
            batch, k, fresh = xs
            if is_async:
                p, st, m = step_fn(p, st, batch, k, fresh)
                stale = TEL.update_ema(stale, m["telemetry"]["overstale"],
                                       scenario.suspicion_ema)
            else:
                p, st, m = step_fn(p, st, batch, k)
            sp = TEL.update_suspicion(sp, m["telemetry"]["selection"],
                                      scenario.suspicion_ema)
            if gsp is not None:
                gsp = TEL.update_suspicion(
                    gsp, m["telemetry"]["group_selection"],
                    scenario.suspicion_ema)
            return (p, st, sp, gsp, stale, pi), TEL.step_record(
                m, sp, pi, gsusp=gsp, stale=stale if is_async else None)

        return jax.jit(lambda c, xs: jax.lax.scan(body, c, xs))

    for phase_idx, ((start, stop), phase) in enumerate(
            zip(scenario.schedule.bounds(), scenario.schedule.phases)):
        if stop <= start_step:
            continue  # phase fully covered by the restored checkpoint
        f_eff = scenario.phase_f(phase)
        adaptive = ATK.is_adaptive(phase.attack)
        rkey = (phase.attack, f_eff)
        if rkey not in runners:
            runners[rkey] = _make_runner(phase.attack, f_eff)
        runner = runners[rkey]

        astate = None
        if adaptive:
            astate = ATK.get_adaptive(phase.attack).init_state(
                scenario.n_workers, f_eff)
        # both trainers speak TrainerState; the adaptive-attack slot is
        # phase-local, everything else carries across phases
        state = dataclasses.replace(tstate, astate=astate)

        batches = _phase_batches(batch_gen, phase, start,
                                 freeze=not is_async)
        keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(
            jnp.arange(start, stop))
        fresh = _phase_fresh(scenario, phase, start) if is_async else \
            jnp.ones((stop - start, scenario.n_workers), bool)
        (params, state, susp, gsusp, stale_ema, _), rec = runner(
            (params, state, susp, gsusp, stale_ema,
             jnp.asarray(phase_idx, jnp.int32)),
            (batches, keys, fresh))
        tstate = dataclasses.replace(state, astate=None)
        phase_traces.append(jax.device_get(rec))
        if verbose:
            tr = phase_traces[-1]
            print(f"[sim] {scenario.name} phase {phase_idx} "
                  f"({phase.attack}, f={f_eff}, steps {start}-{stop}): "
                  f"loss {tr['loss'][0]:.4f} -> {tr['loss'][-1]:.4f} "
                  f"honest_dev {np.mean(tr['honest_dev']):.3f} "
                  f"byz_mass {np.mean(tr['byz_mass']):.3f}", flush=True)
        if ckpt_dir:
            ck = {"params": params, "state": tstate, "susp": susp}
            if gsusp is not None:
                ck["gsusp"] = gsusp
            if scenario.async_tau > 0:
                ck["stale"] = stale_ema
            save(ckpt_dir, stop, ck)

    trace = TEL.concat_traces(phase_traces)
    summary = TEL.summarize(trace, scenario, start_step, wire=wire) \
        if trace else {}
    obs_snap = None
    if OBS.obs_on(obs) and tstate.mstate is not None:
        t = tstate.mstate.get("t")
        obs_snap = OBS.snapshot(
            metrics=tstate.mstate["m"],
            trace_records=OBS.drain(t) if t is not None else (),
            meta={"source": "sim.engine", "scenario": scenario.name,
                  "trainer": scenario.trainer,
                  "async_tau": scenario.async_tau})
    return CampaignResult(scenario=scenario, trace=trace, summary=summary,
                          start_step=start_step, wall_s=time.time() - t0,
                          wire=wire, obs=obs_snap)
