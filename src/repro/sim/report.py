"""Campaign reports: JSON (machine) and CSV (spreadsheet) serialisation.

JSON schema (``sim.campaign.v1``)::

    {
      "schema":   "sim.campaign.v1",
      "scenario": {...},            # Scenario.to_json()
      "summary":  {...},            # telemetry.summarize() per-phase digest
      "per_step": {field: [...]}    # scalar trace fields, one list per field
    }

Vector trace fields (``selection``, ``suspicion``, ``score_spectrum``,
``loss_per_worker``) are summarised per phase in ``summary`` and kept out of
``per_step`` to bound report size; pass ``full_trace=True`` to embed them.
``benchmarks/validate_bench.py`` knows this schema.
"""
from __future__ import annotations

import csv
import json
import os
from typing import Any, Dict

import numpy as np


SCHEMA = "sim.campaign.v1"


def result_to_json(result, *, full_trace: bool = False) -> Dict[str, Any]:
    per_step: Dict[str, Any] = {}
    for k, v in result.trace.items():
        arr = np.asarray(v)
        if arr.ndim == 1 or full_trace:
            per_step[k] = np.round(arr.astype(np.float64), 6).tolist()
    out = {
        "schema": SCHEMA,
        "scenario": result.scenario.to_json(),
        "start_step": int(result.start_step),
        "wall_s": round(float(result.wall_s), 3),
        "summary": result.summary,
        "per_step": per_step,
    }
    # observability snapshot rides along only when the campaign ran with
    # --obs: reports without it stay byte-identical to pre-obs output
    if getattr(result, "obs", None) is not None:
        out["obs"] = result.obs
    return out


def write_json(path: str, result, *, full_trace: bool = False) -> str:
    payload = result_to_json(result, full_trace=full_trace)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path


def write_csv(path: str, result) -> str:
    """One row per step, one column per scalar trace field."""
    scalars = {k: np.asarray(v) for k, v in result.trace.items()
               if np.asarray(v).ndim == 1}
    fields = sorted(scalars)
    steps = len(next(iter(scalars.values()))) if scalars else 0
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["step"] + fields)
        for i in range(steps):
            w.writerow([i + result.start_step] +
                       [f"{float(scalars[k][i]):.6g}" for k in fields])
    os.replace(tmp, path)
    return path
