"""Campaign telemetry: per-step trace records + cross-step suspicion EMA.

The per-step plan diagnostics come from ``AggPlan.diagnostics`` through the
trainer's ``telemetry=True`` metrics (``selection``, ``byz_mass``,
``score_spectrum``, ``score_gap``, ``mean_dist``, ``honest_dev``).  This
module owns what a single plan cannot: the *suspicion EMA* — a per-worker
exponential moving average of rejection — carried through the campaign scan,
and the host-side summarisation of a finished trace into the per-phase
numbers the reports and acceptance assertions read.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def init_suspicion(n_workers: int) -> Array:
    return jnp.zeros((n_workers,), jnp.float32)


def update_suspicion(susp: Array, selection: Array, ema: float) -> Array:
    """EMA of per-worker rejection.

    A worker's per-step rejection is ``1 - selection_i / max_j selection_j``
    (0 for the most-trusted worker, 1 for a fully rejected one) — normalised
    so weighted rules and uniform rules land on the same scale.
    """
    rej = 1.0 - selection / (jnp.max(selection) + 1e-12)
    return ema * susp + (1.0 - ema) * rej


def update_ema(prev: Array, value: Array, ema: float) -> Array:
    """Plain per-worker EMA — the suspicion-carry pattern for any 0/1
    indicator (the async service uses it on the per-round overstale mask,
    so campaigns report *sustained* staleness per worker, not one-round
    blips)."""
    return ema * prev + (1.0 - ema) * value.astype(jnp.float32)


def step_record(metrics: Dict[str, Any], susp: Array,
                phase_idx: int, gsusp: "Array | None" = None,
                stale: "Array | None" = None) -> Dict[str, Array]:
    """Assemble one scan output slot from the trainer metrics.

    Everything is a fixed-shape fp32/int32 array so ``lax.scan`` stacks the
    records into the ``(steps, ...)`` campaign trace.  ``gsusp`` — the
    per-*group* suspicion EMA carried by hierarchical campaigns — rides
    along as ``group_suspicion`` when present (the per-group selection
    itself arrives through the diagnostics dict as ``group_selection``).
    """
    diag = metrics["telemetry"]
    rec = {
        "loss": metrics["loss"].astype(jnp.float32),
        "loss_per_worker": metrics["loss_per_worker"].astype(jnp.float32),
        "lr": metrics["lr"],
        "agg_grad_norm": metrics["agg_grad_norm"].astype(jnp.float32),
        "suspicion": susp,
        "phase": jnp.asarray(phase_idx, jnp.int32),
    }
    if gsusp is not None:
        rec["group_suspicion"] = gsusp
    if stale is not None:
        rec["staleness_ema"] = stale
    for k, v in diag.items():
        rec[k] = jnp.asarray(v, jnp.float32)
    return rec


def concat_traces(traces: Sequence[Dict[str, np.ndarray]]
                  ) -> Dict[str, np.ndarray]:
    """Concatenate per-phase stacked traces along the step axis (host-side)."""
    traces = [t for t in traces if t]
    if not traces:
        return {}
    keys = set(traces[0])
    for t in traces[1:]:
        keys &= set(t)
    return {k: np.concatenate([np.asarray(t[k]) for t in traces], axis=0)
            for k in sorted(keys)}


def summarize(trace: Dict[str, np.ndarray], scenario,
              start_step: int = 0,
              wire: "Dict[str, Any] | None" = None) -> Dict[str, Any]:
    """Host-side per-phase digest of a campaign trace.

    Per phase: loss at entry/exit, mean/max honest-mean deviation, mean
    byzantine selection mass, the per-worker mean selection vector and the
    final suspicion vector.  The acceptance assertions
    (``launch/simulate.py --smoke``, ``tests/test_sim.py``) read these.
    ``start_step`` offsets the schedule against a resumed run's trace
    (which only covers executed steps).  ``wire`` (a
    ``repro.comm.WireStats`` dict) is repeated per phase — byte accounting
    is shape-static, so every phase of a campaign pays the same wire.
    """
    phases = []
    for i, ((start, stop), p) in enumerate(
            zip(scenario.schedule.bounds(), scenario.schedule.phases)):
        start, stop = start - start_step, stop - start_step
        if stop <= 0:
            continue  # phase ran before the resume point
        stop = min(stop, len(trace["loss"]))
        if start >= stop:
            break
        sl = slice(start, stop)
        ph: Dict[str, Any] = {
            "phase": i,
            "attack": p.attack,
            "f": scenario.phase_f(p),
            "steps": stop - start,
            "loss_first": float(trace["loss"][start]),
            "loss_last": float(trace["loss"][stop - 1]),
            "loss_mean": float(np.mean(trace["loss"][sl])),
        }
        for k in ("honest_dev", "byz_mass", "score_gap", "mean_dist",
                  "n_overstale", "f_defended", "plan_reused"):
            if k in trace:
                ph[f"{k}_mean"] = float(np.mean(trace[k][sl]))
                ph[f"{k}_max"] = float(np.max(trace[k][sl]))
        if "selection" in trace:
            ph["selection_mean"] = np.mean(
                trace["selection"][sl], axis=0).tolist()
        # async staleness accounting: which workers were admitted on time
        # vs sat overstale (haircut) this phase — repro.serve telemetry
        if "admitted" in trace:
            ph["admitted_mean"] = np.mean(
                trace["admitted"][sl], axis=0).tolist()
        if "overstale" in trace:
            ph["overstale_mean"] = np.mean(
                trace["overstale"][sl], axis=0).tolist()
        if "staleness_ema" in trace:
            ph["staleness_ema_last"] = \
                trace["staleness_ema"][stop - 1].tolist()
        if "suspicion" in trace:
            ph["suspicion_last"] = trace["suspicion"][stop - 1].tolist()
        if "group_selection" in trace:
            ph["group_selection_mean"] = np.mean(
                trace["group_selection"][sl], axis=0).tolist()
        if "group_suspicion" in trace:
            ph["group_suspicion_last"] = \
                trace["group_suspicion"][stop - 1].tolist()
        if wire is not None:
            ph["wire"] = wire
        phases.append(ph)
    out: Dict[str, Any] = {
        "total_steps": int(len(trace["loss"])),
        "final_loss": float(trace["loss"][-1]),
        "phases": phases,
    }
    if "honest_dev" in trace:
        out["honest_dev_max"] = float(np.max(trace["honest_dev"]))
    if "byz_mass" in trace:
        out["byz_mass_mean"] = float(np.mean(trace["byz_mass"]))
    if wire is not None:
        out["wire"] = wire
    return out
