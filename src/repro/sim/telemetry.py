"""Campaign telemetry: per-step trace records + cross-step suspicion EMA.

The per-step plan diagnostics come from ``AggPlan.diagnostics`` through the
trainer's ``telemetry=True`` metrics (``selection``, ``byz_mass``,
``score_spectrum``, ``score_gap``, ``mean_dist``, ``honest_dev``).  This
module owns the campaign-scan *record schema* (``step_record``) and the
host-side trace concatenation; the accumulator math itself — the suspicion
EMA and the per-phase digest — lives in ``repro.obs`` (metrics registry /
export) and is re-exported here so campaign code keeps its historical
import path while the obs registry is the single implementation
(DESIGN.md §14).
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.export import phase_summary as _phase_summary
from repro.obs.metrics import (init_suspicion, update_ema,  # noqa: F401
                               update_suspicion)

Array = jax.Array


def step_record(metrics: Dict[str, Any], susp: Array,
                phase_idx: int, gsusp: "Array | None" = None,
                stale: "Array | None" = None) -> Dict[str, Array]:
    """Assemble one scan output slot from the trainer metrics.

    Everything is a fixed-shape fp32/int32 array so ``lax.scan`` stacks the
    records into the ``(steps, ...)`` campaign trace.  ``gsusp`` — the
    per-*group* suspicion EMA carried by hierarchical campaigns — rides
    along as ``group_suspicion`` when present (the per-group selection
    itself arrives through the diagnostics dict as ``group_selection``).
    """
    diag = metrics["telemetry"]
    rec = {
        "loss": metrics["loss"].astype(jnp.float32),
        "loss_per_worker": metrics["loss_per_worker"].astype(jnp.float32),
        "lr": metrics["lr"],
        "agg_grad_norm": metrics["agg_grad_norm"].astype(jnp.float32),
        "suspicion": susp,
        "phase": jnp.asarray(phase_idx, jnp.int32),
    }
    if gsusp is not None:
        rec["group_suspicion"] = gsusp
    if stale is not None:
        rec["staleness_ema"] = stale
    for k, v in diag.items():
        rec[k] = jnp.asarray(v, jnp.float32)
    return rec


def concat_traces(traces: Sequence[Dict[str, np.ndarray]]
                  ) -> Dict[str, np.ndarray]:
    """Concatenate per-phase stacked traces along the step axis (host-side)."""
    traces = [t for t in traces if t]
    if not traces:
        return {}
    keys = set(traces[0])
    for t in traces[1:]:
        keys &= set(t)
    return {k: np.concatenate([np.asarray(t[k]) for t in traces], axis=0)
            for k in sorted(keys)}


def summarize(trace: Dict[str, np.ndarray], scenario,
              start_step: int = 0,
              wire: "Dict[str, Any] | None" = None) -> Dict[str, Any]:
    """Host-side per-phase digest of a campaign trace.

    Per phase: loss at entry/exit, mean/max honest-mean deviation, mean
    byzantine selection mass, the per-worker mean selection vector and the
    final suspicion vector.  The acceptance assertions
    (``launch/simulate.py --smoke``, ``tests/test_sim.py``) read these.
    ``start_step`` offsets the schedule against a resumed run's trace
    (which only covers executed steps).  ``wire`` (a
    ``repro.comm.WireStats`` dict) is repeated per phase — byte accounting
    is shape-static, so every phase of a campaign pays the same wire.

    Delegates to ``repro.obs.export.phase_summary`` — the digest logic
    moved with the rest of the accumulators; the ``sim.campaign.v1``
    output is byte-identical (tests/test_obs.py golden fixture).
    """
    return _phase_summary(trace, scenario, start_step, wire=wire)
