"""Gradient Aggregation Rules (GARs) — the paper's contribution.

All rules take a stacked gradient matrix ``G`` of shape ``(n, d)`` (n workers,
d coordinates) and return the aggregated gradient ``(d,)``.  Everything is
jit-safe (static shapes, masked ``lax`` control flow) and coordinate-sharded:
under ``pjit`` the ``d`` axis can live on the ``model`` mesh axis; the only
cross-shard reduction is the pairwise-distance accumulation (see DESIGN.md §3).

Implemented rules
-----------------
* ``average``            — the non-robust optimum (paper's baseline).
* ``coordinate_median``  — MEDIAN baseline from §V.
* ``trimmed_mean``       — classic robust baseline (Yin et al. 2018).
* ``krum``               — Blanchard et al. 2017 (m = 1).
* ``multi_krum``         — paper §III: average of the m = n-f-2 best-scored.
* ``bulyan``             — El-Mhamdi et al. 2018, on top of iterated Krum.
* ``multi_bulyan``       — paper §IV / Algorithm 1: Bulyan over MULTI-KRUM.

The Multi-Bulyan extraction loop follows Algorithm 1 exactly: θ = n-2f-2
rounds; round r runs MULTI-KRUM over the remaining pool of k = n-r gradients
with m_r = k-f-2, records the single *winner* (extracted from the pool) into
``G_ext`` and the m_r-average into ``G_agr``; the coordinate phase takes the
median of ``G_ext`` and averages, per coordinate, the β = θ-2f values of
``G_agr`` closest to that median.

The sequential pool removal of Algorithm 1 is re-expressed as a masked
``lax.fori_loop`` (dead entries get +inf distance/score) so shapes stay
static under jit; equivalence with a literal sequential-removal reference is
property-tested in ``tests/test_gar_semantics.py``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_INF = jnp.inf


# --------------------------------------------------------------------------
# differentiable ordering helpers
#
# This jax build's sort JVP is broken (GatherDimensionNumbers
# operand_batching_dims TypeError), so every sort/median on a differentiable
# value goes through argsort-on-stopped-keys + take_along_axis: the ordering
# is piecewise-constant in the inputs anyway, and the gather VJP is intact.
# --------------------------------------------------------------------------
def _sort_by_value(x: Array, axis: int = 0) -> Array:
    idx = jnp.argsort(jax.lax.stop_gradient(x), axis=axis)
    return jnp.take_along_axis(x, idx, axis=axis)


def _median_axis0(x: Array) -> Array:
    s = _sort_by_value(x, axis=0)
    n = x.shape[0]
    if n % 2:
        return s[n // 2]
    return 0.5 * (s[n // 2 - 1] + s[n // 2])


# --------------------------------------------------------------------------
# distances & scores
# --------------------------------------------------------------------------
def pairwise_sqdist(G: Array, *, precision=jax.lax.Precision.HIGHEST) -> Array:
    """(n, d) -> (n, n) matrix of squared euclidean distances.

    Uses the gram-matrix decomposition ``||a-b||² = ||a||² + ||b||² - 2 a·b``
    so the O(n²d) inner product rides the MXU.  fp32 accumulation.
    ``kernels/pairwise_sqdist.py`` is the Pallas version of this exact
    contraction; this is the XLA/ref path.
    """
    Gf = G.astype(jnp.float32)
    sq = jnp.sum(Gf * Gf, axis=-1)                       # (n,)
    gram = jnp.matmul(Gf, Gf.T, precision=precision)     # (n, n)
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    # numerical floor: distances are nonnegative; zero the diagonal exactly.
    d2 = jnp.maximum(d2, 0.0)
    n = G.shape[0]
    return d2 * (1.0 - jnp.eye(n, dtype=d2.dtype))


def krum_scores(dists: Array, f: int, alive: Optional[Array] = None,
                n_neighbors: Optional[Array] = None) -> Array:
    """Krum score per worker: sum of sq-distances to its nearest neighbours.

    ``dists``: (n, n) pairwise squared distances.
    ``alive``: optional (n,) bool mask of pool membership (dead workers are
    excluded both as scorers and as neighbour candidates).
    ``n_neighbors``: number of neighbours (k - f - 2 where k = pool size);
    may be a traced scalar — the sum-of-smallest is computed with a sorted
    prefix mask so it does not need to be static.
    """
    n = dists.shape[0]
    if alive is None:
        alive = jnp.ones((n,), dtype=bool)
    k_pool = jnp.sum(alive.astype(jnp.int32))
    if n_neighbors is None:
        n_neighbors = k_pool - f - 2
    eye = jnp.eye(n, dtype=bool)
    valid = alive[None, :] & ~eye                      # candidate neighbours of i
    masked = jnp.where(valid, jax.lax.stop_gradient(dists), _INF)
    srt = jnp.sort(masked, axis=1)                     # (n, n) ascending
    take = jnp.arange(n)[None, :] < n_neighbors        # first n_neighbors cols
    scores = jnp.sum(jnp.where(take, srt, 0.0), axis=1)
    return jnp.where(alive, scores, _INF)


def _select_smallest_mask(scores: Array, m) -> Array:
    """Boolean mask of the m smallest-score entries (ties broken by index).

    ``m`` may be traced.  Implemented by rank comparison: rank(i) = number of
    entries strictly smaller, plus number of equal entries with smaller index.
    """
    n = scores.shape[0]
    idx = jnp.arange(n)
    lt = scores[None, :] < scores[:, None]
    eq = (scores[None, :] == scores[:, None]) & (idx[None, :] < idx[:, None])
    rank = jnp.sum(lt | eq, axis=1)
    return rank < m


# --------------------------------------------------------------------------
# baselines
# --------------------------------------------------------------------------
def average(G: Array, f: int = 0) -> Array:
    """Plain averaging — the fastest but non-byzantine-resilient rule."""
    del f
    return jnp.mean(G, axis=0)


def coordinate_median(G: Array, f: int = 0) -> Array:
    """Coordinate-wise median (the MEDIAN baseline of §V)."""
    del f
    return _median_axis0(G)


def trimmed_mean(G: Array, f: int) -> Array:
    """Coordinate-wise trimmed mean: drop the f largest and f smallest."""
    n = G.shape[0]
    if n <= 2 * f:
        raise ValueError(f"trimmed_mean needs n > 2f (n={n}, f={f})")
    srt = _sort_by_value(G, axis=0)
    return jnp.mean(srt[f:n - f], axis=0)


# --------------------------------------------------------------------------
# Krum family
# --------------------------------------------------------------------------
def multi_krum_mask(G: Array, f: int, m: Optional[int] = None,
                    dists: Optional[Array] = None) -> Tuple[Array, Array]:
    """Return (selection mask (n,), scores (n,)) of MULTI-KRUM.

    m defaults to the paper's m̃ = n - f - 2.
    """
    n = G.shape[0]
    if n < 2 * f + 3:
        raise ValueError(f"multi-krum needs n >= 2f+3 (n={n}, f={f})")
    if m is None:
        m = n - f - 2
    if dists is None:
        dists = pairwise_sqdist(G)
    # selection is piecewise-constant in G: the aggregate's gradient flows
    # through the selected average only, never through the plan
    scores = jax.lax.stop_gradient(krum_scores(dists, f))
    return _select_smallest_mask(scores, m), scores


def krum(G: Array, f: int, dists: Optional[Array] = None) -> Array:
    """Krum: the single gradient with the smallest score."""
    mask, _ = multi_krum_mask(G, f, m=1, dists=dists)
    w = mask.astype(G.dtype)
    return (w @ G) / jnp.sum(w)


def multi_krum(G: Array, f: int, m: Optional[int] = None,
               dists: Optional[Array] = None) -> Array:
    """MULTI-KRUM: average of the m best-scored gradients (§III)."""
    mask, _ = multi_krum_mask(G, f, m=m, dists=dists)
    w = mask.astype(jnp.float32)
    return ((w @ G.astype(jnp.float32)) / jnp.sum(w)).astype(G.dtype)


# --------------------------------------------------------------------------
# Bulyan family
# --------------------------------------------------------------------------
def extraction_plan(dists: Array, f: int, theta: int,
                    multi: bool = True) -> Tuple[Array, Array]:
    """θ rounds of (MULTI-)KRUM extraction, in *score space only*.

    The plan depends only on the (n, n) distance matrix — an O(n²·θ·log n)
    scalar computation, replicated on every shard.  Applying the plan to the
    actual gradients is then a pair of tiny einsums per leaf, which is what
    lets the whole Bulyan pipeline shard over the model axis (DESIGN.md §3).

    Returns ``(ext_weights, agr_weights)``, each ``(theta, n)`` row-stochastic:
    * ``ext_weights[r]`` — one-hot row selecting the round-r winner
      (Algorithm 1 line 19, first output);
    * ``agr_weights[r]`` — uniform weights over the round-r MULTI-KRUM
      selection of size m_r = (n-r)-f-2 if ``multi``, else the winner one-hot
      (classic BULYAN).
    """
    n = dists.shape[0]

    def round_fn(r, carry):
        alive, w_ext, w_agr = carry
        k_pool = n - r
        m_r = k_pool - f - 2
        scores = krum_scores(dists, f, alive=alive, n_neighbors=m_r)
        winner = jnp.argmin(scores)
        one_hot = jnp.zeros((n,), jnp.float32).at[winner].set(1.0)
        if multi:
            sel = _select_smallest_mask(scores, m_r).astype(jnp.float32)
            agr = sel / jnp.maximum(jnp.sum(sel), 1.0)
        else:
            agr = one_hot
        w_ext = w_ext.at[r].set(one_hot)
        w_agr = w_agr.at[r].set(agr)
        alive = alive.at[winner].set(False)
        return alive, w_ext, w_agr

    alive0 = jnp.ones((n,), dtype=bool)
    z = jnp.zeros((theta, n), dtype=jnp.float32)
    dists = jax.lax.stop_gradient(dists)   # plan is not differentiated
    _, w_ext, w_agr = jax.lax.fori_loop(0, theta, round_fn, (alive0, z, z))
    return jax.lax.stop_gradient(w_ext), jax.lax.stop_gradient(w_agr)


def _extraction_rounds(G: Array, f: int, theta: int,
                       dists: Optional[Array] = None,
                       multi: bool = True) -> Tuple[Array, Array]:
    """Apply the extraction plan to an (n, d) stack -> (G_ext, G_agr)."""
    dists = pairwise_sqdist(G) if dists is None else dists
    w_ext, w_agr = extraction_plan(dists, f, theta, multi=multi)
    Gf = G.astype(jnp.float32)
    return w_ext @ Gf, w_agr @ Gf


def bulyan_coordinate_phase(G_ext: Array, G_agr: Array, beta: int) -> Array:
    """BULYAN's coordinate phase (Algorithm 1 lines 21-24).

    Per coordinate j: median M[j] of ``G_ext[:, j]``; average the β entries of
    ``G_agr[:, j]`` closest to M[j].  Purely coordinate-local → shards freely
    over the model axis.  ``kernels/coord_select.py`` is the Pallas version.
    """
    theta = G_agr.shape[0]
    med = _median_axis0(G_ext)
    dist = jax.lax.stop_gradient(jnp.abs(G_agr - med[None]))  # (theta, ...)
    order = jnp.argsort(dist, axis=0)                   # (theta, ...)
    ranks = jnp.argsort(order, axis=0)                  # rank of each entry
    sel = ranks < beta
    return jnp.sum(jnp.where(sel, G_agr, 0.0), axis=0) / float(beta)


def _bulyan_family(G: Array, f: int, *, multi: bool,
                   dists: Optional[Array] = None) -> Array:
    n = G.shape[0]
    if n < 4 * f + 3:
        raise ValueError(f"bulyan needs n >= 4f+3 (n={n}, f={f})")
    theta = n - 2 * f - 2
    beta = theta - 2 * f
    g_ext, g_agr = _extraction_rounds(G, f, theta, dists=dists, multi=multi)
    out = bulyan_coordinate_phase(g_ext, g_agr, beta)
    return out.astype(G.dtype)


def bulyan(G: Array, f: int, dists: Optional[Array] = None) -> Array:
    """Classic BULYAN: iterated Krum extraction + coordinate phase."""
    return _bulyan_family(G, f, multi=False, dists=dists)


def multi_bulyan(G: Array, f: int, dists: Optional[Array] = None) -> Array:
    """MULTI-BULYAN (Algorithm 1): BULYAN over MULTI-KRUM aggregates."""
    return _bulyan_family(G, f, multi=True, dists=dists)


# --------------------------------------------------------------------------
# legacy registry (deprecation shims over repro.core.api)
#
# The raw rule functions above stay as the numerical primitives (and the
# reference surface for tests/test_gar_semantics.py); dispatch-by-name now
# lives in the plan/apply Aggregator registry in ``core/api.py``.  GARS and
# ``aggregate`` are kept so old call sites keep working — ``aggregate``
# routes through the registry and is bitwise-identical to it.
# --------------------------------------------------------------------------
GARS: dict[str, Callable[..., Array]] = {
    "average": average,
    "median": coordinate_median,
    "trimmed_mean": trimmed_mean,
    "krum": krum,
    "multi_krum": multi_krum,
    "bulyan": bulyan,
    "multi_bulyan": multi_bulyan,
}


def get_gar(name: str) -> Callable[..., Array]:
    try:
        return GARS[name]
    except KeyError:
        raise KeyError(f"unknown GAR {name!r}; available: {sorted(GARS)}") from None


def aggregate(G: Array, f: int, name: str = "multi_bulyan") -> Array:
    """Aggregate an (n, d) gradient stack with the named rule.

    .. deprecated:: use :func:`repro.core.api.aggregate_matrix` / the
       Aggregator registry (this shim delegates to it).
    """
    from repro.core import api  # local import: api imports this module
    return api.aggregate_matrix(G, f, name)
