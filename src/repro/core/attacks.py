"""Byzantine worker attack library.

An attack is a function ``(G_correct, f, key) -> G_byz`` mapping the stack of
the n-f correct gradients ``(n-f, d)`` to the ``(f, d)`` byzantine proposals.
Attacks may collude and may read every correct gradient first (omniscient
adversary, as in the paper's worst-case analysis).

The stack handed to the GAR is ``concat([G_byz, G_correct])`` by convention
(GARs are permutation-invariant — property-tested).

Attacks are addressed by *spec string*: a bare registry name
(``"little_is_enough"``) or a name with keyword overrides
(``"little_is_enough:z=2.0"``, ``"sign_flip:scale=5"``) — campaign schedules
(``repro.sim``) rely on this to vary attack parameters per phase without new
registry entries.  :func:`get_attack` resolves either form.

Adaptive attacks (``ADAPTIVE`` registry) additionally carry a small state
pytree across steps and receive *plan feedback* — the previous round's
per-worker selection weights — so they can probe the defence: the adaptive
little-is-enough tunes its z to sit just under the rejection threshold, the
adaptive mimic copies whichever honest worker the plan trusts most.  The
stacked trainer threads their state (``dist.trainer.make_train_step``).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Attack = Callable[[Array, int, Array], Array]
PyTree = Any


def no_attack(G: Array, f: int, key: Array) -> Array:
    """f extra honest-like gradients (resampled mean) — the 'mild' case."""
    del key
    g = jnp.mean(G, axis=0)
    return jnp.broadcast_to(g, (f,) + g.shape)


def sign_flip(G: Array, f: int, key: Array, scale: float = 1.0) -> Array:
    """Send the negated mean gradient, scaled."""
    del key
    g = -scale * jnp.mean(G, axis=0)
    return jnp.broadcast_to(g, (f,) + g.shape)


def gaussian_noise(G: Array, f: int, key: Array, sigma: float = 10.0) -> Array:
    """Pure noise of large magnitude."""
    d = G.shape[-1]
    return sigma * jax.random.normal(key, (f, d), dtype=G.dtype)


def inf_attack(G: Array, f: int, key: Array) -> Array:
    """Huge-magnitude vectors (hardware-fault / overflow model)."""
    del key
    g = jnp.mean(G, axis=0)
    return jnp.broadcast_to(1e30 * jnp.sign(g + 1e-30), (f,) + g.shape).astype(G.dtype)


def little_is_enough(G: Array, f: int, key: Array, z: float = 1.5) -> Array:
    """Baruch et al. 2019 'A Little Is Enough'.

    Shift the mean by z standard deviations per coordinate — small enough to
    pass distance tests, consistently wrong in direction.  This is the attack
    the paper's §VI discusses; it stresses the variance condition.
    """
    del key
    mu = jnp.mean(G, axis=0)
    sd = jnp.std(G, axis=0)
    g = mu - z * sd
    return jnp.broadcast_to(g, (f,) + g.shape)


def mimic(G: Array, f: int, key: Array) -> Array:
    """All byzantine workers copy one correct gradient (breaks i.i.d. spread)."""
    del key
    return jnp.broadcast_to(G[0], (f,) + G[0].shape)


def omniscient_reverse(G: Array, f: int, key: Array, eps: float = 0.1) -> Array:
    """Approximate the 'most legitimate but harmful vector' of §II-b.

    Start from the true (mean) gradient and bend it toward its negation while
    staying within the empirical point cloud radius — a cheap stand-in for
    the Ω(nd/ε) regression attack described in the paper.
    """
    del key
    mu = jnp.mean(G, axis=0)
    radius = jnp.sqrt(jnp.max(jnp.sum((G - mu[None]) ** 2, axis=1)))
    direction = -mu / (jnp.linalg.norm(mu) + 1e-30)
    g = mu + (1.0 - eps) * radius * direction
    return jnp.broadcast_to(g, (f,) + g.shape)


ATTACKS: Dict[str, Attack] = {
    "none": no_attack,
    "sign_flip": sign_flip,
    "gaussian": gaussian_noise,
    "inf": inf_attack,
    "little_is_enough": little_is_enough,
    "mimic": mimic,
    "omniscient": omniscient_reverse,
}


def parse_spec(spec: str) -> Tuple[str, Dict[str, float]]:
    """Split ``"name:k1=v1,k2=v2"`` into ``(name, {k1: v1, ...})``.

    Values are parsed as floats (every attack/transform knob is numeric).
    A bare name parses to ``(name, {})``.
    """
    name, _, rest = spec.partition(":")
    kwargs: Dict[str, float] = {}
    for item in filter(None, rest.split(",")):
        k, eq, v = item.partition("=")
        if not eq or not k:
            raise ValueError(
                f"bad spec item {item!r} in {spec!r} (want key=value)")
        try:
            kwargs[k] = float(v)
        except ValueError:
            raise ValueError(
                f"non-numeric value {v!r} for {k!r} in spec {spec!r}") from None
    return name, kwargs


def _bind_kwargs(fn: Callable, name: str, kwargs: Dict[str, float]) -> Attack:
    """Validate override names against the attack's signature, then bind."""
    if not kwargs:
        return fn
    params = inspect.signature(fn).parameters
    tunable = {k for k, p in params.items() if p.default is not p.empty}
    unknown = set(kwargs) - tunable
    if unknown:
        raise ValueError(
            f"attack {name!r} has no parameter(s) {sorted(unknown)}; "
            f"tunable: {sorted(tunable)}")

    def bound(G: Array, f: int, key: Array) -> Array:
        return fn(G, f, key, **kwargs)

    bound.__name__ = name
    return bound


def get_attack(spec: str) -> Attack:
    """Resolve an attack spec (``"name"`` or ``"name:k=v,..."``) to a callable.

    Bare names return the registry function itself (back-compat); specs with
    overrides return a wrapper with the kwargs bound and validated.
    """
    name, kwargs = parse_spec(spec)
    try:
        fn = ATTACKS[name]
    except KeyError:
        raise KeyError(
            f"unknown attack {name!r}; available: {sorted(ATTACKS)} "
            f"(adaptive: {sorted(ADAPTIVE)})") from None
    return _bind_kwargs(fn, name, kwargs)


def apply_attack(G_correct: Array, f: int, name: str, key: Array) -> Array:
    """Return the full (n, d) stack: byzantine rows first, then correct."""
    if f == 0:
        return G_correct
    byz = get_attack(name)(G_correct, f, key)
    return jnp.concatenate([byz.astype(G_correct.dtype), G_correct], axis=0)


# --------------------------------------------------------------------------
# adaptive (plan-feedback) attacks
#
# Signature contract: ``init_state(n, f)`` returns a small jit-carryable
# pytree of fp32 scalars/vectors; ``propose(G, f, key, state)`` maps the
# (n-f, d) correct stack to (f, d) proposals exactly like a static attack;
# ``update(state, selection)`` consumes the aggregation plan's per-worker
# selection weights (convex (n,) vector, byzantine rows first) *after* the
# round and returns the next state.  All three are pure and shape-static so
# the trainer can carry the state through ``lax.scan``.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdaptiveAttack:
    name: str = ""

    def init_state(self, n: int, f: int) -> PyTree:
        raise NotImplementedError

    def propose(self, G: Array, f: int, key: Array, state: PyTree) -> Array:
        raise NotImplementedError

    def update(self, state: PyTree, selection: Array) -> PyTree:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AdaptiveLittleIsEnough(AdaptiveAttack):
    """Little-is-enough with a feedback-tuned z (Baruch et al. + probing).

    While the byzantine rows keep winning at least half their uniform share
    of the selection mass, push z up by ``up`` (more damage); once the plan
    starts rejecting them, back off by ``down`` until re-admitted.  The z
    trajectory hugs the defence's rejection threshold — the worst case the
    static attack only hits when its fixed z is hand-tuned.
    """

    name: str = "adaptive_lie"
    z0: float = 1.0
    up: float = 1.15
    down: float = 0.7
    z_min: float = 0.25
    z_max: float = 16.0

    def init_state(self, n: int, f: int) -> PyTree:
        return {"z": jnp.asarray(self.z0, jnp.float32),
                "share": jnp.asarray(f / max(n, 1), jnp.float32)}

    def propose(self, G: Array, f: int, key: Array, state: PyTree) -> Array:
        del key
        mu = jnp.mean(G, axis=0)
        sd = jnp.std(G, axis=0)
        g = mu - state["z"] * sd
        return jnp.broadcast_to(g, (f,) + g.shape).astype(G.dtype)

    def update(self, state: PyTree, selection: Array) -> PyTree:
        # byzantine rows come first by the inject_byzantine convention; the
        # caller passes the full (n,) convex selection vector
        n = selection.shape[0]
        f_rows = jnp.maximum(
            jnp.round(state["share"] * n).astype(jnp.int32), 1)
        byz_mass = jnp.sum(
            jnp.where(jnp.arange(n) < f_rows, selection, 0.0))
        selected = byz_mass >= 0.5 * state["share"]
        z = jnp.where(selected, state["z"] * self.up, state["z"] * self.down)
        return {"z": jnp.clip(z, self.z_min, self.z_max),
                "share": state["share"]}


@dataclasses.dataclass(frozen=True)
class AdaptiveMimic(AdaptiveAttack):
    """Mimic steered by the plan: copy the most-trusted honest worker.

    Tracks an EMA of each honest worker's selection weight and clones the
    current argmax — concentrating the byzantine mass on the gradient the
    defence demonstrably favours, which skews krum-family selection without
    ever tripping a distance test (Karimireddy et al. 2022 style).
    """

    name: str = "adaptive_mimic"
    ema: float = 0.9

    def init_state(self, n: int, f: int) -> PyTree:
        return {"trust": jnp.zeros((n - f,), jnp.float32)}

    def propose(self, G: Array, f: int, key: Array, state: PyTree) -> Array:
        del key
        target = jnp.argmax(state["trust"])
        g = jax.lax.dynamic_index_in_dim(G, target, axis=0, keepdims=False)
        return jnp.broadcast_to(g, (f,) + g.shape).astype(G.dtype)

    def update(self, state: PyTree, selection: Array) -> PyTree:
        n_honest = state["trust"].shape[0]
        honest_sel = selection[selection.shape[0] - n_honest:]
        trust = self.ema * state["trust"] + (1.0 - self.ema) * honest_sel
        return {"trust": trust}


ADAPTIVE: Dict[str, Callable[..., AdaptiveAttack]] = {
    "adaptive_lie": AdaptiveLittleIsEnough,
    "adaptive_mimic": AdaptiveMimic,
}


# --------------------------------------------------------------------------
# wire-format attacks (the repro.comm attack surface)
#
# With a codec on the wire the adversary controls its *messages*, not its
# gradients: the payload integers and the scale sidecar are separate fields
# a GAR only sees after decode.  A wire attack is
# ``(P_correct, S_correct, f, key) -> (P_byz, S_byz)`` per leaf, where
# ``P_correct`` is the (n-f, ...) stack of honest payload rows and
# ``S_correct`` the matching sidecar rows (``None`` for sidecar-free
# codecs).  Byzantine rows must stay *wire-legal* (same dtype/shape) — the
# attack model is a malicious worker, not a corrupted channel.  The
# interesting asymmetry: a tiny, honest-looking payload with a poisoned
# scale multiplies through the decode, which distance tests only catch
# after dequantization — exactly the interaction repro.comm exists to
# measure.
# --------------------------------------------------------------------------
WireAttack = Callable[[Array, Optional[Array], int, Array],
                      Tuple[Array, Optional[Array]]]


def scale_poison(P: Array, S: Optional[Array], f: int, key: Array,
                 gain: float = 100.0) -> Tuple[Array, Optional[Array]]:
    """Honest-looking payload, poisoned sidecar: copy a correct worker's
    payload rows verbatim and inflate the dequant multiplier by ``gain``
    (negated — the decoded rows point ``-gain×`` along a correct
    gradient).  Sidecar-free codecs fall back to scaling the payload
    itself (saturating in int8 — the wire stays legal)."""
    del key
    Pb = jnp.broadcast_to(P[:1], (f,) + P.shape[1:])
    if S is None or not jnp.issubdtype(S.dtype, jnp.floating):
        scaled = -gain * P[:1].astype(jnp.float32)
        if jnp.issubdtype(P.dtype, jnp.integer):
            info = jnp.iinfo(P.dtype)
            scaled = jnp.clip(jnp.round(scaled), info.min, info.max)
        Pb = jnp.broadcast_to(scaled.astype(P.dtype), (f,) + P.shape[1:])
        Sb = None if S is None else jnp.broadcast_to(S[:1], (f,) + S.shape[1:])
        return Pb, Sb
    Sb = jnp.broadcast_to(-gain * S[:1], (f,) + S.shape[1:]).astype(S.dtype)
    return Pb, Sb


def payload_flip(P: Array, S: Optional[Array], f: int, key: Array
                 ) -> Tuple[Array, Optional[Array]]:
    """Negate a correct worker's payload rows, keep its sidecar: the wire
    form of ``sign_flip``, invisible to any scale-level sanity check."""
    del key
    if jnp.issubdtype(P.dtype, jnp.integer):
        info = jnp.iinfo(P.dtype)
        neg = jnp.clip(-P[:1].astype(jnp.int32), info.min, info.max)
        Pb = jnp.broadcast_to(neg.astype(P.dtype), (f,) + P.shape[1:])
    else:
        Pb = jnp.broadcast_to(-P[:1], (f,) + P.shape[1:]).astype(P.dtype)
    Sb = None if S is None else jnp.broadcast_to(S[:1], (f,) + S.shape[1:])
    return Pb, Sb


WIRE_ATTACKS: Dict[str, WireAttack] = {
    "scale_poison": scale_poison,
    "payload_flip": payload_flip,
}


def is_wire_attack(spec: str) -> bool:
    return parse_spec(spec)[0] in WIRE_ATTACKS


def get_wire_attack(spec: str) -> WireAttack:
    """Resolve a wire-attack spec to a callable (same grammar as attacks)."""
    name, kwargs = parse_spec(spec)
    try:
        fn = WIRE_ATTACKS[name]
    except KeyError:
        raise KeyError(
            f"unknown wire attack {name!r}; "
            f"available: {sorted(WIRE_ATTACKS)}") from None
    if not kwargs:
        return fn
    params = inspect.signature(fn).parameters
    tunable = {k for k, p in params.items() if p.default is not p.empty}
    unknown = set(kwargs) - tunable
    if unknown:
        raise ValueError(
            f"wire attack {name!r} has no parameter(s) {sorted(unknown)}; "
            f"tunable: {sorted(tunable)}")

    def bound(P, S, f, key):
        return fn(P, S, f, key, **kwargs)

    bound.__name__ = name
    return bound


def is_adaptive(spec: str) -> bool:
    return parse_spec(spec)[0] in ADAPTIVE


def get_adaptive(spec: str) -> AdaptiveAttack:
    """Resolve an adaptive attack spec to a configured instance."""
    name, kwargs = parse_spec(spec)
    try:
        cls = ADAPTIVE[name]
    except KeyError:
        raise KeyError(
            f"unknown adaptive attack {name!r}; "
            f"available: {sorted(ADAPTIVE)}") from None
    fields = {fl.name for fl in dataclasses.fields(cls) if fl.name != "name"}
    unknown = set(kwargs) - fields
    if unknown:
        raise ValueError(
            f"adaptive attack {name!r} has no parameter(s) {sorted(unknown)}; "
            f"tunable: {sorted(fields)}")
    return cls(**kwargs)
