"""Byzantine worker attack library.

An attack is a function ``(G_correct, f, key) -> G_byz`` mapping the stack of
the n-f correct gradients ``(n-f, d)`` to the ``(f, d)`` byzantine proposals.
Attacks may collude and may read every correct gradient first (omniscient
adversary, as in the paper's worst-case analysis).

The stack handed to the GAR is ``concat([G_byz, G_correct])`` by convention
(GARs are permutation-invariant — property-tested).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array
Attack = Callable[[Array, int, Array], Array]


def no_attack(G: Array, f: int, key: Array) -> Array:
    """f extra honest-like gradients (resampled mean) — the 'mild' case."""
    del key
    g = jnp.mean(G, axis=0)
    return jnp.broadcast_to(g, (f,) + g.shape)


def sign_flip(G: Array, f: int, key: Array, scale: float = 1.0) -> Array:
    """Send the negated mean gradient, scaled."""
    del key
    g = -scale * jnp.mean(G, axis=0)
    return jnp.broadcast_to(g, (f,) + g.shape)


def gaussian_noise(G: Array, f: int, key: Array, sigma: float = 10.0) -> Array:
    """Pure noise of large magnitude."""
    d = G.shape[-1]
    return sigma * jax.random.normal(key, (f, d), dtype=G.dtype)


def inf_attack(G: Array, f: int, key: Array) -> Array:
    """Huge-magnitude vectors (hardware-fault / overflow model)."""
    del key
    g = jnp.mean(G, axis=0)
    return jnp.broadcast_to(1e30 * jnp.sign(g + 1e-30), (f,) + g.shape).astype(G.dtype)


def little_is_enough(G: Array, f: int, key: Array, z: float = 1.5) -> Array:
    """Baruch et al. 2019 'A Little Is Enough'.

    Shift the mean by z standard deviations per coordinate — small enough to
    pass distance tests, consistently wrong in direction.  This is the attack
    the paper's §VI discusses; it stresses the variance condition.
    """
    del key
    mu = jnp.mean(G, axis=0)
    sd = jnp.std(G, axis=0)
    g = mu - z * sd
    return jnp.broadcast_to(g, (f,) + g.shape)


def mimic(G: Array, f: int, key: Array) -> Array:
    """All byzantine workers copy one correct gradient (breaks i.i.d. spread)."""
    del key
    return jnp.broadcast_to(G[0], (f,) + G[0].shape)


def omniscient_reverse(G: Array, f: int, key: Array, eps: float = 0.1) -> Array:
    """Approximate the 'most legitimate but harmful vector' of §II-b.

    Start from the true (mean) gradient and bend it toward its negation while
    staying within the empirical point cloud radius — a cheap stand-in for
    the Ω(nd/ε) regression attack described in the paper.
    """
    del key
    mu = jnp.mean(G, axis=0)
    radius = jnp.sqrt(jnp.max(jnp.sum((G - mu[None]) ** 2, axis=1)))
    direction = -mu / (jnp.linalg.norm(mu) + 1e-30)
    g = mu + (1.0 - eps) * radius * direction
    return jnp.broadcast_to(g, (f,) + g.shape)


ATTACKS: Dict[str, Attack] = {
    "none": no_attack,
    "sign_flip": sign_flip,
    "gaussian": gaussian_noise,
    "inf": inf_attack,
    "little_is_enough": little_is_enough,
    "mimic": mimic,
    "omniscient": omniscient_reverse,
}


def get_attack(name: str) -> Attack:
    try:
        return ATTACKS[name]
    except KeyError:
        raise KeyError(f"unknown attack {name!r}; available: {sorted(ATTACKS)}") from None


def apply_attack(G_correct: Array, f: int, name: str, key: Array) -> Array:
    """Return the full (n, d) stack: byzantine rows first, then correct."""
    if f == 0:
        return G_correct
    byz = get_attack(name)(G_correct, f, key)
    return jnp.concatenate([byz.astype(G_correct.dtype), G_correct], axis=0)
