"""Plan/apply aggregation API — the public seam of the whole system.

The paper's O(d) claim for multi-Bulyan rests on a structural split that this
module promotes to the public API (DESIGN.md §3):

* ``plan(stats)``  — runs on the replicated ``(n, n)`` squared-distance
  matrix / per-worker norms only.  O(n²·θ·log n) scalar work, no touch of
  the d axis, returns *static-shape* weight matrices.
* ``apply(plan, grads)`` — sharding-preserving per-leaf einsums plus the
  purely coordinate-local phase over the d axis.  No communication on the
  model axis.

Every GAR is an :class:`Aggregator` registered via :func:`register_gar` with
capability flags (``needs_dists``, ``coordinate_local``, ``min_n``).  The
legacy entry points ``core.gar.aggregate`` and ``core.robust.tree_aggregate``
are thin shims over this registry (bitwise-identical outputs — tested in
``tests/test_agg_api.py``).

A composable pre-aggregation :class:`Transform` stage runs on the stacked
gradients *before* the GAR sees them — worker momentum (Farhadkhani et al.
2022), per-worker clipping, nearest-neighbour mixing.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import gar as G

Array = jax.Array
PyTree = Any


# ==========================================================================
# statistics (the plan's only input)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class AggStats:
    """Replicated per-round statistics the selection plan is computed from.

    ``dists`` is the global (n, n) squared-distance matrix (fp32), present
    only when the rule's ``needs_dists`` flag is set; ``sq_norms`` the per
    worker squared l2 norms.  Both are O(n²) scalars — tiny next to d.
    """

    n: int
    f: int
    dists: Optional[Array] = None
    sq_norms: Optional[Array] = None


def _leaf_stats_contrib(leaf: Array) -> Tuple[Array, Array]:
    """One leaf's raw (dists, sq_norms) contribution — the XLA formula.

    Contraction over all parameter dims: sharded dims reduce locally + one
    psum under GSPMD.  HIGHEST: distances between near-identical honest
    gradients must not lose bits to bf16-pass matmuls on TPU — score order
    decides selection.  The single shared implementation keeps the
    streaming pass-1 path (leaf_sqdist_contrib) and the stacked path
    (tree_pairwise_stats) on the exact same float summation.
    """
    x = leaf.astype(jnp.float32)
    axes = _param_axes(x)
    sq = jnp.sum(x * x, axis=axes)
    gram = jax.lax.dot_general(
        x, x, ((axes, axes), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32) if x.ndim == 2 else \
        jnp.tensordot(x, x, axes=(axes, axes),
                      precision=jax.lax.Precision.HIGHEST)
    return sq[:, None] + sq[None, :] - 2.0 * gram, sq


def leaf_sqdist_contrib(leaf: Array, *, use_pallas: bool = False) -> Array:
    """One leaf's raw contribution to the global (n, n) distance matrix.

    Raw (unclamped, diagonal kept) so cross-leaf accumulation stays a plain
    sum; callers finalise with :func:`finalize_dists`.
    """
    if use_pallas:
        from repro.kernels import ops as kops
        # raw contribution, matching this function's contract — streaming
        # pass 1 accumulates the exact float sum the stacked path's
        # tree_pairwise_stats produces.  The kernel still writes its (1, n)
        # norm output (pallas_call is opaque to XLA DCE); that extra VMEM
        # write is noise next to the tile loads.
        return kops.pairwise_stats(_leaf2d(leaf))[0]
    return _leaf_stats_contrib(leaf)[0]


def finalize_dists(total: Array) -> Array:
    """Numerical floor + exact-zero diagonal on an accumulated (n, n) sum."""
    total = jnp.maximum(total, 0.0)
    n = total.shape[0]
    return total * (1.0 - jnp.eye(n, dtype=total.dtype))


def tree_pairwise_sqdist(grads: PyTree, *, use_pallas: bool = False) -> Array:
    """Sum of per-leaf pairwise squared distances -> global (n, n) matrix."""
    return tree_pairwise_stats(grads, use_pallas=use_pallas)[0]


def tree_pairwise_stats(grads: PyTree, *, use_pallas: bool = False
                        ) -> Tuple[Array, Array]:
    """Single pass over the stack: (global (n, n) sq-dists, (n,) sq-norms).

    On the Pallas path every leaf is read from HBM exactly once — the
    ``pairwise_stats`` kernel emits that leaf's raw distance contribution
    and its norm contribution from the same VMEM tile load; both are
    accumulated across leaves and the distances finalised once.  The XLA
    path shares the gram intermediate so the norms also cost no extra read.
    """
    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    total_d = jnp.zeros((n, n), dtype=jnp.float32)
    total_s = jnp.zeros((n,), dtype=jnp.float32)
    for leaf in leaves:
        if use_pallas:
            from repro.kernels import ops as kops
            dd, sq = kops.pairwise_stats(_leaf2d(leaf))
        else:
            dd, sq = _leaf_stats_contrib(leaf)
        total_d = total_d + dd
        total_s = total_s + sq
    return finalize_dists(total_d), total_s


def tree_sq_norms(grads: PyTree) -> Array:
    """Per-worker squared l2 norms across every leaf -> (n,) fp32."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    total = jnp.zeros((n,), dtype=jnp.float32)
    for leaf in leaves:
        x = leaf.astype(jnp.float32)
        total = total + jnp.sum(x * x, axis=_param_axes(x))
    return total


def _as_encoded(grads: PyTree):
    """The wire container, or None for a plain pytree.

    Cheap duck check first so the common path never imports ``repro.comm``
    (``core`` stays the bottom layer; the comm subsystem imports only
    ``core.attacks``, so the lazy import is cycle-free).
    """
    if type(grads).__name__ != "EncodedGrads":
        return None
    from repro.comm import codecs as CC
    return grads if CC.is_encoded(grads) else None


def compute_stats(grads: PyTree, f: int, *, needs_dists: bool = True,
                  needs_norms: bool = False, use_pallas: bool = False,
                  dists: Optional[Array] = None) -> AggStats:
    """Build the :class:`AggStats` a rule's ``plan`` consumes.

    Only what the capability flags ask for is computed — ``average`` pays
    zero extra collectives, distance rules pay the one (n, n) all-reduce.
    When distances are needed the single-pass kernel also yields the norms
    as a free byproduct of the same HBM read, so ``sq_norms`` is populated
    whenever ``dists`` is computed here.

    ``grads`` may be a ``repro.comm`` :class:`EncodedGrads` wire container:
    statistics then run straight on the quantized payloads — through the
    fused dequantize→stats kernel under ``use_pallas`` (DESIGN.md §9) —
    without materialising the decoded stack here.
    """
    enc = _as_encoded(grads)
    if enc is not None:
        from repro.comm import codecs as CC
        norms = None
        if needs_dists and dists is None:
            dists, norms = CC.encoded_pairwise_stats(enc,
                                                     use_pallas=use_pallas)
        if needs_norms and norms is None:
            norms = CC.encoded_pairwise_stats(enc, use_pallas=use_pallas)[1]
        return AggStats(n=enc.n, f=f, dists=dists, sq_norms=norms)
    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    for leaf in leaves:
        if leaf.shape[0] != n:
            raise ValueError("all leaves must share the worker axis size")
    norms = None
    if needs_dists and dists is None:
        dists, norms = tree_pairwise_stats(grads, use_pallas=use_pallas)
    if needs_norms and norms is None:
        norms = tree_sq_norms(grads)
    return AggStats(n=n, f=f, dists=dists, sq_norms=norms)


# ==========================================================================
# plans
# ==========================================================================
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("weights", "w_ext", "w_agr"),
    meta_fields=("kind", "n", "f", "beta"))
@dataclasses.dataclass(frozen=True)
class AggPlan:
    """Static-shape output of a rule's selection phase.

    ``kind`` picks the apply path:
    * ``"mean"``       — plain per-leaf mean over the worker axis;
    * ``"weighted"``   — one (n,) convex weight vector, per-leaf tensordot;
    * ``"coordinate"`` — no weights; the rule is purely coordinate-local
      over the raw stack (median / trimmed mean);
    * ``"bulyan"``     — (θ, n) extraction + aggregate weight matrices and
      the β count for the coordinate phase.

    Every field is either a static python int/str or an array whose shape
    depends only on (n, f) — never on d — so plans jit cleanly and replicate
    for free.
    """

    kind: str
    n: int
    f: int
    weights: Optional[Array] = None       # (n,) for kind == "weighted"
    w_ext: Optional[Array] = None         # (theta, n) for kind == "bulyan"
    w_agr: Optional[Array] = None         # (theta, n) for kind == "bulyan"
    beta: int = 0

    # ------------------------------------------------------------ telemetry
    def selection_weights(self) -> Array:
        """Per-worker selection mass as one convex (n,) fp32 vector.

        * ``weighted`` — the plan's weight vector itself;
        * ``bulyan``   — the mean over extraction rounds of the (θ, n)
          aggregate-weight rows (each row convex, so the mean is too): the
          mass each worker contributes to the values entering the coordinate
          phase;
        * ``mean`` / ``coordinate`` — uniform 1/n (every worker's value
          participates; coordinate rules have no worker-level selection).
        """
        if self.kind == "weighted":
            return self.weights.astype(jnp.float32)
        if self.kind == "bulyan":
            return jnp.mean(self.w_agr.astype(jnp.float32), axis=0)
        return jnp.full((self.n,), 1.0 / self.n, jnp.float32)

    def diagnostics(self, stats: Optional[AggStats] = None) -> Dict[str, Array]:
        """Jit-safe per-round diagnostics of *why* the plan chose what it did.

        Returns a dict of fp32 arrays whose shapes depend only on (n, f):

        * ``selection``      — convex (n,) selection mass per worker;
        * ``byz_mass``       — scalar: mass on the first f rows (byzantine
          rows come first by the ``inject_byzantine`` convention, so under
          attack this is the adversary's captured share);
        * ``score_spectrum`` — (n,) ascending Krum scores (needs ``stats``
          with the distance matrix; -inf-free, +inf for dead entries);
        * ``score_gap``      — scalar: min score among zero-mass workers
          minus max score among selected ones — the margin by which the
          selection boundary held (0 when everyone is selected);
        * ``mean_dist``      — scalar: mean off-diagonal pairwise sq-dist.

        Score fields are omitted when ``stats``/``stats.dists`` is absent.
        The suspicion EMA built on these lives in ``repro.sim.telemetry``
        (it needs cross-step state a single plan does not have).
        """
        sel = self.selection_weights()
        byz = jnp.sum(sel[: self.f]) if self.f else jnp.zeros((), jnp.float32)
        out: Dict[str, Array] = {"selection": sel, "byz_mass": byz}
        if stats is not None and stats.dists is not None:
            scores = G.krum_scores(stats.dists, self.f)
            picked = sel > 0.0
            sel_max = jnp.max(jnp.where(picked, scores, -jnp.inf))
            rej_min = jnp.min(jnp.where(picked, jnp.inf, scores))
            gap = jnp.where(jnp.all(picked), 0.0, rej_min - sel_max)
            n = stats.dists.shape[0]
            off = jnp.sum(stats.dists) / (n * (n - 1)) if n > 1 else \
                jnp.zeros((), jnp.float32)
            out.update(score_spectrum=jnp.sort(scores),
                       score_gap=gap.astype(jnp.float32),
                       mean_dist=off.astype(jnp.float32))
        return out


# --------------------------------------------------------------- leaf math
def _leaf2d(x: Array) -> Array:
    """(n, ...) -> (n, numel) view — Pallas/coord-chunk paths only.

    Under pjit, reshaping a param-dim-sharded leaf is NOT sharding
    preserving (GSPMD replicates the flattened stack); the default paths
    operate on the unreshaped leaves via tensordot.
    """
    return x.reshape((x.shape[0], -1))


def _param_axes(leaf: Array):
    return tuple(range(1, leaf.ndim))


def _weighted_mean_leaf(w: Array, leaf: Array) -> Array:
    """(n,) weights (summing to 1) applied over the worker axis of a leaf."""
    x = leaf.astype(jnp.float32)
    return jnp.tensordot(w, x, axes=(0, 0)).astype(leaf.dtype)


def _bulyan_leaf(w_ext: Array, w_agr: Array, beta: int,
                 leaf: Array, coord_chunk: int = 0,
                 use_pallas: bool = False, fused: bool = True) -> Array:
    """Apply an extraction plan + coordinate phase to one gradient leaf.

    Default path is sharding-preserving: (theta, n) @ (n, ...) tensordots
    keep the parameter-dim sharding, and the coordinate phase is purely
    elementwise/axis-0 over (theta, ...).

    With ``use_pallas`` and ``fused`` (the production fast path) the whole
    apply phase runs in the ``fused_select`` kernel: extraction einsums +
    coordinate phase per d-tile in VMEM, no (θ, numel) HBM intermediates.
    ``fused=False`` keeps the two-step Pallas path (materialised einsums +
    ``coord_select``) for benchmarking the fusion win.
    """
    if use_pallas and fused:
        from repro.kernels import ops as kops
        x = _leaf2d(leaf).astype(jnp.float32)      # (n, numel)
        out = kops.fused_select(x, w_ext, w_agr, beta)
        return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    if use_pallas or coord_chunk:
        x = _leaf2d(leaf).astype(jnp.float32)      # (n, numel)

        def phase(xc: Array) -> Array:             # (n, c) -> (c,)
            # HIGHEST: substrate parity — the fused kernel contracts at
            # HIGHEST, and g_ext feeds the selection-deciding median
            g_ext = jnp.matmul(w_ext, xc,
                               precision=jax.lax.Precision.HIGHEST)
            g_agr = jnp.matmul(w_agr, xc,
                               precision=jax.lax.Precision.HIGHEST)
            if use_pallas:
                from repro.kernels import ops as kops
                return kops.coord_select(g_ext, g_agr, beta)
            return G.bulyan_coordinate_phase(g_ext, g_agr, beta)

        numel = x.shape[1]
        if coord_chunk and numel > coord_chunk:
            pad = (-numel) % coord_chunk
            xp = jnp.pad(x, ((0, 0), (0, pad)))
            chunks = xp.reshape(x.shape[0], -1, coord_chunk).transpose(1, 0, 2)
            out = jax.lax.map(phase, chunks).reshape(-1)[:numel]
        else:
            out = phase(x)
        return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    x = leaf.astype(jnp.float32)
    g_ext = jnp.tensordot(w_ext, x, axes=(1, 0),   # (theta, ...)
                          precision=jax.lax.Precision.HIGHEST)
    g_agr = jnp.tensordot(w_agr, x, axes=(1, 0),
                          precision=jax.lax.Precision.HIGHEST)
    return G.bulyan_coordinate_phase(g_ext, g_agr, beta).astype(leaf.dtype)


# ==========================================================================
# the Aggregator protocol + registry
# ==========================================================================
class Aggregator:
    """Two-phase GAR: ``plan`` on the (n, n) statistics, ``apply`` on d.

    Capability flags (class attributes):
    * ``needs_dists``       — plan consumes the pairwise-distance matrix;
    * ``coordinate_local``  — apply never mixes coordinates (shards freely);
    * ``min_n(f)``          — the paper's resilience precondition, with its
      human-readable ``min_n_formula`` for error messages.
    """

    name: str = ""
    needs_dists: bool = False
    coordinate_local: bool = True
    min_n_formula: str = "1"

    @staticmethod
    def min_n(f: int) -> int:
        return 1

    # ------------------------------------------------------------- phases
    def validate(self, n: int, f: int) -> None:
        if n < self.min_n(f):
            raise ValueError(
                f"{self.name} requires n >= {self.min_n_formula} "
                f"(n={n}, f={f}, need n >= {self.min_n(f)})")

    def plan(self, stats: AggStats) -> AggPlan:
        raise NotImplementedError

    def apply(self, plan: AggPlan, grads: PyTree, *, coord_chunk: int = 0,
              use_pallas: bool = False, fused: bool = True) -> PyTree:
        """Plan application — shared across rules, dispatched on plan.kind.

        With ``use_pallas`` the bulyan kind takes the fully fused kernel
        path (one HBM read per leaf, no (θ, d) intermediates); pass
        ``fused=False`` to benchmark the two-step Pallas path instead.

        An :class:`EncodedGrads` wire container is decoded first — the
        apply phase mixes values across workers, so it runs on the
        codec-decoded fp32 rows (callers that already hold the decoded
        stack should pass it directly to avoid a second decode).
        """
        enc = _as_encoded(grads)
        if enc is not None:
            from repro.comm import codecs as CC
            grads = CC.get_codec(enc.spec).decode(enc)
        if plan.kind == "mean":
            return jax.tree.map(lambda x: jnp.mean(x, axis=0), grads)
        if plan.kind == "weighted":
            return jax.tree.map(
                functools.partial(_weighted_mean_leaf, plan.weights), grads)
        if plan.kind == "bulyan":
            fn = functools.partial(_bulyan_leaf, plan.w_ext, plan.w_agr,
                                   plan.beta, coord_chunk=coord_chunk,
                                   use_pallas=use_pallas, fused=fused)
            return jax.tree.map(fn, grads)
        if plan.kind == "coordinate":
            return jax.tree.map(
                functools.partial(self._coordinate_leaf, plan), grads)
        raise ValueError(f"unknown plan kind {plan.kind!r}")

    def _coordinate_leaf(self, plan: AggPlan, leaf: Array) -> Array:
        raise NotImplementedError

    # --------------------------------------------------------- convenience
    def __call__(self, grads: PyTree, f: int, *,
                 dists: Optional[Array] = None, coord_chunk: int = 0,
                 use_pallas: bool = False) -> PyTree:
        stats = compute_stats(grads, f, needs_dists=self.needs_dists,
                              use_pallas=use_pallas, dists=dists)
        self.validate(stats.n, stats.f)
        return self.apply(self.plan(stats), grads, coord_chunk=coord_chunk,
                          use_pallas=use_pallas)


REGISTRY: Dict[str, Aggregator] = {}


def register_gar(cls):
    """Class decorator: instantiate and register a GAR by its ``name``."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    if inst.name in REGISTRY:
        # every consumer dispatches by name; silent replacement of e.g.
        # multi_bulyan would change results with no indication why
        raise ValueError(
            f"GAR {inst.name!r} is already registered "
            f"({type(REGISTRY[inst.name]).__name__}); pick a distinct name "
            f"or REGISTRY.pop() the old rule first")
    REGISTRY[inst.name] = inst
    return cls


def get_aggregator(name: str) -> Aggregator:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown GAR {name!r}; available: {sorted(REGISTRY)}") from None


def available_gars() -> Tuple[str, ...]:
    return tuple(sorted(REGISTRY))


# ==========================================================================
# the seven rules
# ==========================================================================
@register_gar
class Average(Aggregator):
    """Plain averaging — fastest, non-byzantine-resilient baseline."""

    name = "average"

    def plan(self, stats: AggStats) -> AggPlan:
        return AggPlan(kind="mean", n=stats.n, f=stats.f)


@register_gar
class CoordinateMedian(Aggregator):
    """Coordinate-wise median (the MEDIAN baseline of §V)."""

    name = "median"

    def plan(self, stats: AggStats) -> AggPlan:
        return AggPlan(kind="coordinate", n=stats.n, f=stats.f)

    def _coordinate_leaf(self, plan: AggPlan, leaf: Array) -> Array:
        return G._median_axis0(leaf.astype(jnp.float32)).astype(leaf.dtype)


@register_gar
class TrimmedMean(Aggregator):
    """Coordinate-wise trimmed mean: drop the f largest and f smallest."""

    name = "trimmed_mean"
    min_n_formula = "2f+1"

    @staticmethod
    def min_n(f: int) -> int:
        return 2 * f + 1

    def plan(self, stats: AggStats) -> AggPlan:
        if stats.n <= 2 * stats.f:
            raise ValueError(
                f"trimmed_mean needs n > 2f (n={stats.n}, f={stats.f})")
        return AggPlan(kind="coordinate", n=stats.n, f=stats.f)

    def _coordinate_leaf(self, plan: AggPlan, leaf: Array) -> Array:
        s = G._sort_by_value(leaf.astype(jnp.float32), axis=0)
        return jnp.mean(s[plan.f:plan.n - plan.f], axis=0).astype(leaf.dtype)


class _KrumFamily(Aggregator):
    needs_dists = True
    coordinate_local = False
    min_n_formula = "2f+3"
    _m_select: Optional[int] = None       # None -> the paper's m̃ = n-f-2

    @staticmethod
    def min_n(f: int) -> int:
        return 2 * f + 3

    def plan(self, stats: AggStats) -> AggPlan:
        n, f = stats.n, stats.f
        self.validate(n, f)
        m = self._m_select if self._m_select is not None else n - f - 2
        # selection is piecewise-constant in G: the aggregate's gradient
        # flows through the selected average only, never through the plan
        scores = jax.lax.stop_gradient(G.krum_scores(stats.dists, f))
        mask = G._select_smallest_mask(scores, m)
        w = mask.astype(jnp.float32)
        return AggPlan(kind="weighted", n=n, f=f, weights=w / jnp.sum(w))


@register_gar
class Krum(_KrumFamily):
    """Krum (Blanchard et al. 2017): the single best-scored gradient."""

    name = "krum"
    _m_select = 1


@register_gar
class MultiKrum(_KrumFamily):
    """MULTI-KRUM (§III): average of the m̃ = n-f-2 best-scored."""

    name = "multi_krum"


class _BulyanFamily(Aggregator):
    needs_dists = True
    coordinate_local = False
    min_n_formula = "4f+3"
    _multi = True

    @staticmethod
    def min_n(f: int) -> int:
        return 4 * f + 3

    def plan(self, stats: AggStats) -> AggPlan:
        n, f = stats.n, stats.f
        self.validate(n, f)
        theta = n - 2 * f - 2
        beta = theta - 2 * f
        w_ext, w_agr = G.extraction_plan(stats.dists, f, theta,
                                         multi=self._multi)
        return AggPlan(kind="bulyan", n=n, f=f, w_ext=w_ext, w_agr=w_agr,
                       beta=beta)


@register_gar
class Bulyan(_BulyanFamily):
    """Classic BULYAN: iterated Krum extraction + coordinate phase."""

    name = "bulyan"
    _multi = False


@register_gar
class MultiBulyan(_BulyanFamily):
    """MULTI-BULYAN (Algorithm 1): BULYAN over MULTI-KRUM aggregates."""

    name = "multi_bulyan"


# ==========================================================================
# high-level entry points (what the shims delegate to)
# ==========================================================================
def aggregate_tree(grads: PyTree, f: int, name: str = "multi_bulyan", *,
                   coord_chunk: int = 0, use_pallas: bool = False,
                   fused: bool = True,
                   dists: Optional[Array] = None) -> PyTree:
    """Aggregate a stacked gradient pytree with the named registered rule."""
    agg = get_aggregator(name)
    stats = compute_stats(grads, f, needs_dists=agg.needs_dists,
                          use_pallas=use_pallas, dists=dists)
    agg.validate(stats.n, stats.f)
    return agg.apply(agg.plan(stats), grads, coord_chunk=coord_chunk,
                     use_pallas=use_pallas, fused=fused)


def aggregate_matrix(Gm: Array, f: int, name: str = "multi_bulyan", *,
                     dists: Optional[Array] = None) -> Array:
    """(n, d) stack -> (d,) aggregate: the single-leaf pytree special case."""
    return aggregate_tree(Gm, f, name, dists=dists)


# ==========================================================================
# pre-aggregation transforms
# ==========================================================================
class Transform:
    """A composable stage rewriting the stacked gradients before the GAR.

    ``stateful`` transforms carry a per-worker state pytree across steps
    (see :func:`init_transform_states`); ``needs_dists`` ones receive an
    :class:`AggStats` with the distance matrix of the *current* stack.
    Signature: ``(grads, stats=None, state=None, key=None) -> (grads, state)``.
    """

    name: str = ""
    stateful: bool = False
    needs_dists: bool = False

    def init(self, grads: PyTree) -> PyTree:
        raise NotImplementedError(f"{self.name} is stateless")

    def __call__(self, grads: PyTree, *, stats: Optional[AggStats] = None,
                 state: Optional[PyTree] = None,
                 key: Optional[Array] = None) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ClipByNorm(Transform):
    """Per-worker l2 clipping: ||g_i|| <= max_norm (static-shape, jit-safe).

    A cheap prefilter against magnitude attacks — the GAR still provides
    the directional guarantee.
    """

    max_norm: float = 1.0
    name: str = "clip"

    def __call__(self, grads, *, stats=None, state=None, key=None):
        norms = jnp.sqrt(jnp.maximum(tree_sq_norms(grads), 1e-30))   # (n,)
        scale = jnp.minimum(1.0, self.max_norm / norms)              # (n,)

        def clip_leaf(x):
            s = scale.reshape((-1,) + (1,) * (x.ndim - 1))
            return (x.astype(jnp.float32) * s).astype(x.dtype)

        return jax.tree.map(clip_leaf, grads), state


@dataclasses.dataclass(frozen=True)
class WorkerMomentum(Transform):
    """Resilient averaging of momentums (Farhadkhani et al. 2022).

    Each worker's gradient is replaced by its exponential momentum
    m_i <- β·m_i + g_i before aggregation; the GAR then runs on momentums,
    which shrinks the honest-worker variance the no-free-lunch bound (§VI)
    is driven by.
    """

    beta: float = 0.9
    name: str = "worker_momentum"
    stateful: bool = True

    def init(self, grads: PyTree) -> PyTree:
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), grads)

    def __call__(self, grads, *, stats=None, state=None, key=None):
        if state is None:
            raise ValueError("worker_momentum needs a state pytree; "
                             "seed it with init_transform_states()")
        new = jax.tree.map(
            lambda m, g: self.beta * m + g.astype(jnp.float32), state, grads)
        out = jax.tree.map(lambda m, g: m.astype(g.dtype), new, grads)
        return out, new


@dataclasses.dataclass(frozen=True)
class NearestNeighborMix(Transform):
    """Replace g_i by the mean of its k nearest neighbours (self included).

    A pre-aggregation smoothing step (NNM, Allouah et al. 2023 style) that
    provably tightens the variance condition the paper's §VI bound depends
    on.  Plan-shaped: the (n, n) mixing matrix depends only on distances.
    """

    k: int = 3
    name: str = "nn_mix"
    needs_dists: bool = True

    def __call__(self, grads, *, stats=None, state=None, key=None):
        if stats is None or stats.dists is None:
            raise ValueError("nn_mix needs AggStats with the distance matrix")
        n = stats.n
        k = min(self.k, n)
        # rank each row's distances (self-distance 0 ranks first)
        order = jnp.argsort(stats.dists, axis=1)
        ranks = jnp.argsort(order, axis=1)
        W = (ranks < k).astype(jnp.float32) / float(k)        # (n, n)
        mix = functools.partial(_mix_leaf, W)
        return jax.tree.map(mix, grads), state


def _mix_leaf(W: Array, leaf: Array) -> Array:
    x = leaf.astype(jnp.float32)
    return jnp.tensordot(W, x, axes=(1, 0)).astype(leaf.dtype)


TRANSFORMS: Dict[str, Callable[..., Transform]] = {
    "clip": ClipByNorm,
    "worker_momentum": WorkerMomentum,
    "nn_mix": NearestNeighborMix,
}


def init_transform_states(transforms: Sequence[Transform],
                          grads_like: PyTree) -> Tuple[PyTree, ...]:
    """Initial state tuple (one entry per transform; None when stateless)."""
    return tuple(t.init(grads_like) if t.stateful else None
                 for t in transforms)


def apply_transforms(grads: PyTree, transforms: Sequence[Transform],
                     states: Optional[Sequence[PyTree]] = None, *,
                     key: Optional[Array] = None,
                     use_pallas: bool = False
                     ) -> Tuple[PyTree, Tuple[PyTree, ...]]:
    """Run the transform pipeline; returns (grads, new_states)."""
    if not transforms:
        return grads, ()
    if states is None:
        states = (None,) * len(transforms)
    new_states = []
    f0 = 0  # transforms are rule-agnostic; stats carry distances only
    for i, (t, st) in enumerate(zip(transforms, states)):
        stats = None
        if t.needs_dists:
            stats = compute_stats(grads, f0, needs_dists=True,
                                  use_pallas=use_pallas)
        k = jax.random.fold_in(key, i) if key is not None else None
        grads, st = t(grads, stats=stats, state=st, key=k)
        new_states.append(st)
    return grads, tuple(new_states)
