"""Plan/apply aggregation API — the public seam of the whole system.

The paper's O(d) claim for multi-Bulyan rests on a structural split that this
module promotes to the public API (DESIGN.md §3):

* ``plan(stats)``  — runs on the replicated ``(n, n)`` squared-distance
  matrix / per-worker norms only.  O(n²·θ·log n) scalar work, no touch of
  the d axis, returns *static-shape* weight matrices.
* ``apply(plan, grads)`` — sharding-preserving per-leaf einsums plus the
  purely coordinate-local phase over the d axis.  No communication on the
  model axis.

Every GAR is an :class:`Aggregator` registered via :func:`register_gar` with
capability flags (``needs_dists``, ``coordinate_local``, ``min_n``).  The
legacy entry points ``core.gar.aggregate`` and ``core.robust.tree_aggregate``
are thin shims over this registry (bitwise-identical outputs — tested in
``tests/test_agg_api.py``).

A composable pre-aggregation :class:`Transform` stage runs on the stacked
gradients *before* the GAR sees them — worker momentum (Farhadkhani et al.
2022), per-worker clipping, nearest-neighbour mixing.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import gar as G

Array = jax.Array
PyTree = Any


# ==========================================================================
# statistics (the plan's only input)
# ==========================================================================
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("dists", "sq_norms"),
    meta_fields=("n", "f"))
@dataclasses.dataclass(frozen=True)
class AggStats:
    """Replicated per-round statistics the selection plan is computed from.

    ``dists`` is the global (n, n) squared-distance matrix (fp32), present
    only when the rule's ``needs_dists`` flag is set; ``sq_norms`` the per
    worker squared l2 norms.  Both are O(n²) scalars — tiny next to d.
    """

    n: int
    f: int
    dists: Optional[Array] = None
    sq_norms: Optional[Array] = None


def _leaf_stats_contrib(leaf: Array) -> Tuple[Array, Array]:
    """One leaf's raw (dists, sq_norms) contribution — the XLA formula.

    Contraction over all parameter dims: sharded dims reduce locally + one
    psum under GSPMD.  HIGHEST: distances between near-identical honest
    gradients must not lose bits to bf16-pass matmuls on TPU — score order
    decides selection.  The single shared implementation keeps the
    streaming pass-1 path (leaf_sqdist_contrib) and the stacked path
    (tree_pairwise_stats) on the exact same float summation.
    """
    x = leaf.astype(jnp.float32)
    axes = _param_axes(x)
    sq = jnp.sum(x * x, axis=axes)
    gram = jax.lax.dot_general(
        x, x, ((axes, axes), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32) if x.ndim == 2 else \
        jnp.tensordot(x, x, axes=(axes, axes),
                      precision=jax.lax.Precision.HIGHEST)
    return sq[:, None] + sq[None, :] - 2.0 * gram, sq


def leaf_sqdist_contrib(leaf: Array, *, use_pallas: bool = False) -> Array:
    """One leaf's raw contribution to the global (n, n) distance matrix.

    Raw (unclamped, diagonal kept) so cross-leaf accumulation stays a plain
    sum; callers finalise with :func:`finalize_dists`.
    """
    if use_pallas:
        from repro.kernels import ops as kops
        # raw contribution, matching this function's contract — streaming
        # pass 1 accumulates the exact float sum the stacked path's
        # tree_pairwise_stats produces.  The kernel still writes its (1, n)
        # norm output (pallas_call is opaque to XLA DCE); that extra VMEM
        # write is noise next to the tile loads.
        return kops.pairwise_stats(_leaf2d(leaf))[0]
    return _leaf_stats_contrib(leaf)[0]


def finalize_dists(total: Array) -> Array:
    """Numerical floor + exact-zero diagonal on an accumulated (n, n) sum."""
    total = jnp.maximum(total, 0.0)
    n = total.shape[0]
    return total * (1.0 - jnp.eye(n, dtype=total.dtype))


def tree_pairwise_sqdist(grads: PyTree, *, use_pallas: bool = False) -> Array:
    """Sum of per-leaf pairwise squared distances -> global (n, n) matrix."""
    return tree_pairwise_stats(grads, use_pallas=use_pallas)[0]


def tree_pairwise_stats(grads: PyTree, *, use_pallas: bool = False
                        ) -> Tuple[Array, Array]:
    """Single pass over the stack: (global (n, n) sq-dists, (n,) sq-norms).

    On the Pallas path every leaf is read from HBM exactly once — the
    ``pairwise_stats`` kernel emits that leaf's raw distance contribution
    and its norm contribution from the same VMEM tile load; both are
    accumulated across leaves and the distances finalised once.  The XLA
    path shares the gram intermediate so the norms also cost no extra read.
    """
    total_d, total_s = raw_pairwise_stats(grads, use_pallas=use_pallas)
    return finalize_dists(total_d), total_s


def tree_sq_norms(grads: PyTree) -> Array:
    """Per-worker squared l2 norms across every leaf -> (n,) fp32."""
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    total = jnp.zeros((n,), dtype=jnp.float32)
    for leaf in leaves:
        x = leaf.astype(jnp.float32)
        total = total + jnp.sum(x * x, axis=_param_axes(x))
    return total


def _as_encoded(grads: PyTree):
    """The wire container, or None for a plain pytree.

    Cheap duck check first so the common path never imports ``repro.comm``
    (``core`` stays the bottom layer; the comm subsystem imports only
    ``core.attacks``, so the lazy import is cycle-free).
    """
    if type(grads).__name__ != "EncodedGrads":
        return None
    from repro.comm import codecs as CC
    return grads if CC.is_encoded(grads) else None


def compute_stats(grads: PyTree, f: int, *, needs_dists: bool = True,
                  needs_norms: bool = False, use_pallas: bool = False,
                  dists: Optional[Array] = None,
                  mesh_ctx: Optional["MeshContext"] = None) -> AggStats:
    """Build the :class:`AggStats` a rule's ``plan`` consumes.

    Only what the capability flags ask for is computed — ``average`` pays
    zero extra collectives, distance rules pay the one (n, n) all-reduce.
    When distances are needed the single-pass kernel also yields the norms
    as a free byproduct of the same HBM read, so ``sq_norms`` is populated
    whenever ``dists`` is computed here.

    ``grads`` may be a ``repro.comm`` :class:`EncodedGrads` wire container:
    statistics then run straight on the quantized payloads — through the
    fused dequantize→stats kernel under ``use_pallas`` (DESIGN.md §9) —
    without materialising the decoded stack here.

    With ``mesh_ctx`` the statistics run mesh-native (DESIGN.md §10): the
    worker axis is sharded over ``mesh_ctx.worker_axes`` inside a
    ``shard_map`` and every device computes only its row block of the
    (n, n) matrix — bitwise-identical to the replicated path.
    """
    enc = _as_encoded(grads)
    if enc is not None:
        def enc_stats():
            if mesh_ctx is not None:
                raw, sq = sharded_raw_stats(enc, mesh_ctx=mesh_ctx,
                                            use_pallas=use_pallas)
                return finalize_dists(raw), sq
            from repro.comm import codecs as CC
            return CC.encoded_pairwise_stats(enc, use_pallas=use_pallas)

        norms = None
        if needs_dists and dists is None:
            dists, norms = enc_stats()
        if needs_norms and norms is None:
            norms = enc_stats()[1]
        return AggStats(n=enc.n, f=f, dists=dists, sq_norms=norms)
    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    for leaf in leaves:
        if leaf.shape[0] != n:
            raise ValueError("all leaves must share the worker axis size")
    norms = None
    if needs_dists and dists is None:
        if mesh_ctx is not None:
            raw, norms = sharded_raw_stats(grads, mesh_ctx=mesh_ctx,
                                           use_pallas=use_pallas)
            dists = finalize_dists(raw)
        else:
            dists, norms = tree_pairwise_stats(grads, use_pallas=use_pallas)
    if needs_norms and norms is None:
        # norms alone are O(n·d) row sums — replicated compute is cheaper
        # than the sharded distance phase even on a mesh, and the values
        # are identical (same per-leaf accumulation order)
        norms = tree_sq_norms(grads)
    return AggStats(n=n, f=f, dists=dists, sq_norms=norms)


# ==========================================================================
# mesh-native (SPMD) execution — DESIGN.md §10
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Execution context for the mesh-native (shard_map) aggregation path.

    ``worker_axes`` name the mesh axes carrying the byzantine worker
    dimension (``("pod", "data")`` multi-pod, ``("data",)`` single-pod);
    ``model_axis`` the tensor-parallel axis the apply phase shards the
    d dimension over (``None`` disables d-sharding).  The context is pure
    metadata — hashable, jit-static — so step builders can close over it.
    """

    mesh: Any
    worker_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"

    @classmethod
    def for_mesh(cls, mesh, worker_axes: Optional[Sequence[str]] = None
                 ) -> "MeshContext":
        """Derive the canonical context from a mesh's axis names."""
        names = tuple(mesh.axis_names)
        if worker_axes is None:
            worker_axes = ("pod", "data") if "pod" in names else ("data",)
        missing = [a for a in worker_axes if a not in names]
        if missing:
            raise ValueError(
                f"worker axes {missing} not in mesh axes {names}")
        return cls(mesh=mesh, worker_axes=tuple(worker_axes),
                   model_axis="model" if "model" in names else None)

    @property
    def worker_size(self) -> int:
        sizes = dict(self.mesh.shape)
        out = 1
        for a in self.worker_axes:
            out *= sizes[a]
        return out

    @property
    def model_size(self) -> int:
        return dict(self.mesh.shape)[self.model_axis] \
            if self.model_axis is not None else 1

    @property
    def worker_entry(self):
        """The PartitionSpec entry for the worker axis (str or tuple)."""
        return self.worker_axes if len(self.worker_axes) > 1 \
            else self.worker_axes[0]


def _shard_map(fn, ctx: MeshContext, in_specs, out_specs):
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=ctx.mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _worker_index(ctx: MeshContext) -> Array:
    """Flat index of this device's worker-axis shard (inside shard_map)."""
    idx = jnp.zeros((), jnp.int32)
    sizes = dict(ctx.mesh.shape)
    for a in ctx.worker_axes:
        idx = idx * sizes[a] + jax.lax.axis_index(a)
    return idx


def _pad_rows(x: Array, n_pad: int) -> Array:
    return jnp.pad(x, ((0, n_pad - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def _block_stats_contrib(x_loc: Array, x_full: Array
                         ) -> Tuple[Array, Array]:
    """Row-block partial of :func:`_leaf_stats_contrib`.

    ``x_loc`` is this device's worker rows, ``x_full`` the gathered stack.
    Each output element is the same full-d reduction the replicated formula
    computes, so the block is bitwise-identical to the matching rows of
    ``_leaf_stats_contrib(x_full)`` (tests/test_spmd.py).
    """
    xl = x_loc.astype(jnp.float32)
    xf = x_full.astype(jnp.float32)
    axes = _param_axes(xf)
    sq_full = jnp.sum(xf * xf, axis=axes)
    sq_loc = jnp.sum(xl * xl, axis=axes)
    gram = jax.lax.dot_general(
        xl, xf, ((axes, axes), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32) if xf.ndim == 2 else \
        jnp.tensordot(xl, xf, axes=(axes, axes),
                      precision=jax.lax.Precision.HIGHEST)
    return sq_loc[:, None] + sq_full[None, :] - 2.0 * gram, sq_full


def sharded_raw_stats(grads: PyTree, *, mesh_ctx: MeshContext,
                      use_pallas: bool = False) -> Tuple[Array, Array]:
    """Mesh-native single pass: (raw (n, n) sq-dists, (n,) sq-norms).

    The worker axis of every leaf (gradient rows, or ``EncodedGrads``
    payload/sidecar rows) is sharded over ``mesh_ctx.worker_axes`` inside a
    ``shard_map``; each device all-gathers the rows of one leaf at a time,
    computes its *row block* of that leaf's contribution — the O(n²·d)
    distance phase decomposes across the worker shards, the paper's §IV
    parallelisation claim — and the blocks are reassembled by the out-spec.
    Raw contract matches :func:`leaf_sqdist_contrib` (no clamp, diagonal
    kept), and the float summation order matches the replicated path
    exactly, so results are bitwise-identical (tests/test_spmd.py).

    n not divisible by the worker-shard count is zero-row padded; padded
    rows decode/contract to exact zeros and are sliced away.  Under
    ``use_pallas`` each device runs the *rectangular* stats kernels
    (``pairwise_stats_rect`` / ``dequant_stats_rect``) — its own row block
    against the gathered stack, O(n_loc·n·d) instead of the square
    kernel's redundant O(n²·d) per device — bitwise-identical to the
    square kernels' matching rows at the shared autotuned ``d_tile``
    (kernels/pairwise_sqdist.py header), same wire cost.
    """
    enc = _as_encoded(grads)
    W = mesh_ctx.worker_size
    lead = mesh_ctx.worker_entry
    axes_names = mesh_ctx.worker_axes

    if enc is not None:
        from repro.comm import codecs as CC
        codec = CC.get_codec(enc.spec)
        n = enc.n
        n_pad = -(-n // W) * W
        n_loc = n_pad // W
        p_leaves = jax.tree.leaves(enc.payload)
        s_leaves = jax.tree.leaves(enc.sidecar) \
            if enc.sidecar is not None else [None] * len(p_leaves)
        shapes = [(n_pad,) + tuple(s[1:]) for s in enc.shapes]
        operands = [_pad_rows(x, n_pad) for x in p_leaves] + \
            [_pad_rows(s, n_pad) for s in s_leaves if s is not None]
        has_sidecar = [s is not None for s in s_leaves]
        in_specs = tuple(P(*((lead,) + (None,) * (x.ndim - 1)))
                         for x in operands)

        def local(*flat):
            ps = flat[: len(p_leaves)]
            ss_iter = iter(flat[len(p_leaves):])
            idx = _worker_index(mesh_ctx)
            total_d = jnp.zeros((n_loc, n_pad), jnp.float32)
            total_s = jnp.zeros((n_pad,), jnp.float32)
            for p_loc, has_s, shape in zip(ps, has_sidecar, shapes):
                s_loc = next(ss_iter) if has_s else None
                p_full = jax.lax.all_gather(p_loc, axes_names, axis=0,
                                            tiled=True)
                s_full = None if s_loc is None else \
                    jax.lax.all_gather(s_loc, axes_names, axis=0, tiled=True)
                if use_pallas:
                    dd, sq = CC.encoded_leaf_block_contrib(
                        codec, p_loc, s_loc, p_full, s_full, shape,
                        row_start=idx * n_loc, n_loc=n_loc)
                else:
                    g_full = codec.decode_leaf(
                        _leaf2d(p_full), s_full, shape).reshape(shape)
                    g_loc = jax.lax.dynamic_slice_in_dim(
                        g_full, idx * n_loc, n_loc, 0)
                    dd, sq = _block_stats_contrib(g_loc, g_full)
                total_d = total_d + dd
                total_s = total_s + sq
            return total_d, total_s

        fn = _shard_map(local, mesh_ctx, in_specs,
                        (P(lead, None), P(None)))
        dd, sq = fn(*operands)
        return dd[:n, :n], sq[:n]

    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    n_pad = -(-n // W) * W
    n_loc = n_pad // W
    padded = [_pad_rows(x, n_pad) for x in leaves]
    in_specs = tuple(P(*((lead,) + (None,) * (x.ndim - 1))) for x in padded)

    def local(*loc_leaves):
        total_d = jnp.zeros((n_loc, n_pad), jnp.float32)
        total_s = jnp.zeros((n_pad,), jnp.float32)
        for xl in loc_leaves:
            full = jax.lax.all_gather(xl, axes_names, axis=0, tiled=True)
            if use_pallas:
                from repro.kernels import ops as kops
                dd, sq = kops.pairwise_stats_rect(_leaf2d(xl),
                                                  _leaf2d(full))
            else:
                dd, sq = _block_stats_contrib(xl, full)
            total_d = total_d + dd
            total_s = total_s + sq
        return total_d, total_s

    fn = _shard_map(local, mesh_ctx, in_specs, (P(lead, None), P(None)))
    dd, sq = fn(*padded)
    return dd[:n, :n], sq[:n]


def sharded_raw_stats_model_axis(grads: PyTree, *, mesh_ctx: MeshContext,
                                 use_pallas: bool = False
                                 ) -> Tuple[Array, Array]:
    """Model-axis-sharded single pass: raw ((n, n) sq-dists, (n,) norms)
    from (n/W, d/M) leaf tiles — the §10 tensor-parallel stats seam.

    Where :func:`sharded_raw_stats` keeps every leaf's d axis replicated,
    this variant shards it over ``mesh_ctx.model_axis`` as well: each
    device all-gathers only its *column shard*'s worker rows, runs the
    rectangular stats kernel on the (n_loc, d/M) × (n, d/M) tile pair,
    and the per-shard partial contributions ``psum`` over the model axis.
    No replicated-leaf round-trip: a tensor-parallel trainer can feed its
    grads without first all-gathering d.

    Float caveat: the model-axis ``psum`` is a different summation order
    than the replicated full-d contraction, so parity with the replicated
    path is bitwise at M = 1 (plain CI) and ~1e-6 at M > 1 — unlike the
    worker-axis sharding, which is bitwise at any W.  Leaf columns pad to
    a multiple of M with exact zeros.
    """
    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    W = mesh_ctx.worker_size
    M = mesh_ctx.model_size
    lead = mesh_ctx.worker_entry
    axes_names = mesh_ctx.worker_axes
    n_pad = -(-n // W) * W
    n_loc = n_pad // W
    flat = []
    for x in leaves:
        x2 = _leaf2d(x)
        m_pad = (-x2.shape[1]) % M
        if m_pad:
            x2 = jnp.pad(x2, ((0, 0), (0, m_pad)))
        flat.append(_pad_rows(x2, n_pad))
    in_specs = tuple(P(lead, mesh_ctx.model_axis) for _ in flat)

    def local(*loc_leaves):
        total_d = jnp.zeros((n_loc, n_pad), jnp.float32)
        total_s = jnp.zeros((n_pad,), jnp.float32)
        for xl in loc_leaves:
            full = jax.lax.all_gather(xl, axes_names, axis=0, tiled=True)
            if use_pallas:
                from repro.kernels import ops as kops
                dd, sq = kops.pairwise_stats_rect(xl, full)
            else:
                dd, sq = _block_stats_contrib(xl, full)
            total_d = total_d + dd
            total_s = total_s + sq
        if mesh_ctx.model_axis is not None:
            total_d = jax.lax.psum(total_d, mesh_ctx.model_axis)
            total_s = jax.lax.psum(total_s, mesh_ctx.model_axis)
        return total_d, total_s

    fn = _shard_map(local, mesh_ctx, in_specs, (P(lead, None), P(None)))
    dd, sq = fn(*flat)
    return dd[:n, :n], sq[:n]


def raw_pairwise_stats(grads: PyTree, *, use_pallas: bool = False,
                       mesh_ctx: Optional[MeshContext] = None
                       ) -> Tuple[Array, Array]:
    """Raw accumulation unit shared by stacked and streaming trainers.

    (raw (n, n) sq-dists, (n,) sq-norms) of a stacked pytree *or* an
    ``EncodedGrads`` container — unclamped, diagonal kept; finalise once
    with :func:`finalize_dists`.  Bit-exact parity with the stacked
    single pass requires matching its flat per-leaf accumulation order:
    a cross-block accumulator must add one *leaf* at a time (as the
    streaming pass-1 does), not pre-summed per-block subtotals, or the
    float sums reassociate.  Routes through :func:`sharded_raw_stats`
    when a :class:`MeshContext` is given.
    """
    if mesh_ctx is not None:
        return sharded_raw_stats(grads, mesh_ctx=mesh_ctx,
                                 use_pallas=use_pallas)
    enc = _as_encoded(grads)
    if enc is not None:
        from repro.comm import codecs as CC
        return CC.encoded_raw_stats(enc, use_pallas=use_pallas)
    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    total_d = jnp.zeros((n, n), jnp.float32)
    total_s = jnp.zeros((n,), jnp.float32)
    for leaf in leaves:
        if use_pallas:
            from repro.kernels import ops as kops
            dd, sq = kops.pairwise_stats(_leaf2d(leaf))
        else:
            dd, sq = _leaf_stats_contrib(leaf)
        total_d = total_d + dd
        total_s = total_s + sq
    return total_d, total_s


# ==========================================================================
# plans
# ==========================================================================
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("weights", "w_ext", "w_agr"),
    meta_fields=("kind", "n", "f", "beta"))
@dataclasses.dataclass(frozen=True)
class AggPlan:
    """Static-shape output of a rule's selection phase.

    ``kind`` picks the apply path:
    * ``"mean"``       — plain per-leaf mean over the worker axis;
    * ``"weighted"``   — one (n,) convex weight vector, per-leaf tensordot;
    * ``"coordinate"`` — no weights; the rule is purely coordinate-local
      over the raw stack (median / trimmed mean);
    * ``"bulyan"``     — (θ, n) extraction + aggregate weight matrices and
      the β count for the coordinate phase.

    Every field is either a static python int/str or an array whose shape
    depends only on (n, f) — never on d — so plans jit cleanly and replicate
    for free.
    """

    kind: str
    n: int
    f: int
    weights: Optional[Array] = None       # (n,) for kind == "weighted"
    w_ext: Optional[Array] = None         # (theta, n) for kind == "bulyan"
    w_agr: Optional[Array] = None         # (theta, n) for kind == "bulyan"
    beta: int = 0

    # ------------------------------------------------------------ telemetry
    def selection_weights(self) -> Array:
        """Per-worker selection mass as one convex (n,) fp32 vector.

        * ``weighted`` — the plan's weight vector itself;
        * ``bulyan``   — the mean over extraction rounds of the (θ, n)
          aggregate-weight rows (each row convex, so the mean is too): the
          mass each worker contributes to the values entering the coordinate
          phase;
        * ``mean`` / ``coordinate`` — uniform 1/n (every worker's value
          participates; coordinate rules have no worker-level selection).
        """
        if self.kind == "weighted":
            return self.weights.astype(jnp.float32)
        if self.kind == "bulyan":
            return jnp.mean(self.w_agr.astype(jnp.float32), axis=0)
        return jnp.full((self.n,), 1.0 / self.n, jnp.float32)

    def diagnostics(self, stats: Optional[AggStats] = None) -> Dict[str, Array]:
        """Jit-safe per-round diagnostics of *why* the plan chose what it did.

        Returns a dict of fp32 arrays whose shapes depend only on (n, f):

        * ``selection``      — convex (n,) selection mass per worker;
        * ``byz_mass``       — scalar: mass on the first f rows (byzantine
          rows come first by the ``inject_byzantine`` convention, so under
          attack this is the adversary's captured share);
        * ``score_spectrum`` — (n,) ascending Krum scores (needs ``stats``
          with the distance matrix; -inf-free, +inf for dead entries);
        * ``score_gap``      — scalar: min score among zero-mass workers
          minus max score among selected ones — the margin by which the
          selection boundary held (0 when everyone is selected);
        * ``mean_dist``      — scalar: mean off-diagonal pairwise sq-dist.

        Score fields are omitted when ``stats``/``stats.dists`` is absent.
        The suspicion EMA built on these lives in ``repro.sim.telemetry``
        (it needs cross-step state a single plan does not have).
        """
        sel = self.selection_weights()
        byz = jnp.sum(sel[: self.f]) if self.f else jnp.zeros((), jnp.float32)
        out: Dict[str, Array] = {"selection": sel, "byz_mass": byz}
        if stats is not None and stats.dists is not None:
            scores = G.krum_scores(stats.dists, self.f)
            picked = sel > 0.0
            sel_max = jnp.max(jnp.where(picked, scores, -jnp.inf))
            rej_min = jnp.min(jnp.where(picked, jnp.inf, scores))
            gap = jnp.where(jnp.all(picked), 0.0, rej_min - sel_max)
            n = stats.dists.shape[0]
            off = jnp.sum(stats.dists) / (n * (n - 1)) if n > 1 else \
                jnp.zeros((), jnp.float32)
            out.update(score_spectrum=jnp.sort(scores),
                       score_gap=gap.astype(jnp.float32),
                       mean_dist=off.astype(jnp.float32))
        return out


# --------------------------------------------------------------- leaf math
def _leaf2d(x: Array) -> Array:
    """(n, ...) -> (n, numel) view — Pallas/coord-chunk paths only.

    Under pjit, reshaping a param-dim-sharded leaf is NOT sharding
    preserving (GSPMD replicates the flattened stack); the default paths
    operate on the unreshaped leaves via tensordot.
    """
    return x.reshape((x.shape[0], -1))


def _param_axes(leaf: Array):
    return tuple(range(1, leaf.ndim))


def _weighted_mean_leaf(w: Array, leaf: Array) -> Array:
    """(n,) weights (summing to 1) applied over the worker axis of a leaf."""
    x = leaf.astype(jnp.float32)
    return jnp.tensordot(w, x, axes=(0, 0)).astype(leaf.dtype)


def _bulyan_leaf(w_ext: Array, w_agr: Array, beta: int,
                 leaf: Array, coord_chunk: int = 0,
                 use_pallas: bool = False,
                 fused: "bool | str" = True) -> Array:
    """Apply an extraction plan + coordinate phase to one gradient leaf.

    Default path is sharding-preserving: (theta, n) @ (n, ...) tensordots
    keep the parameter-dim sharding, and the coordinate phase is purely
    elementwise/axis-0 over (theta, ...).

    With ``use_pallas`` and ``fused=True`` the apply phase runs in the
    ``fused_select`` kernel (extraction einsums + coordinate phase per
    d-tile in VMEM, no (θ, numel) HBM intermediates) — *unless* the leaf
    sits past the measured large-d crossover where the fused kernel loses
    to plain XLA (``kernels.dispatch.fused_wins``, read off
    BENCH_agg_time.json), in which case the XLA substrate is taken.
    ``fused="force"`` pins the kernel regardless (the substrate
    benchmarks); ``fused=False`` keeps the two-step Pallas path
    (materialised einsums + ``coord_select``) for benchmarking the fusion
    win.
    """
    if use_pallas and fused:
        numel = 1
        for s in leaf.shape[1:]:
            numel *= int(s)
        from repro.kernels import dispatch as kdispatch
        if fused == "force" or kdispatch.fused_wins(w_ext.shape[1], numel):
            from repro.kernels import ops as kops
            x = _leaf2d(leaf).astype(jnp.float32)  # (n, numel)
            out = kops.fused_select(x, w_ext, w_agr, beta)
            return out.reshape(leaf.shape[1:]).astype(leaf.dtype)
        # measured-crossover fallback: past the cliff the whole Pallas
        # stack loses (two-step loses too) — take the XLA substrate
        use_pallas = False

    if use_pallas or coord_chunk:
        x = _leaf2d(leaf).astype(jnp.float32)      # (n, numel)

        def phase(xc: Array) -> Array:             # (n, c) -> (c,)
            # HIGHEST: substrate parity — the fused kernel contracts at
            # HIGHEST, and g_ext feeds the selection-deciding median
            g_ext = jnp.matmul(w_ext, xc,
                               precision=jax.lax.Precision.HIGHEST)
            g_agr = jnp.matmul(w_agr, xc,
                               precision=jax.lax.Precision.HIGHEST)
            if use_pallas:
                from repro.kernels import ops as kops
                return kops.coord_select(g_ext, g_agr, beta)
            return G.bulyan_coordinate_phase(g_ext, g_agr, beta)

        numel = x.shape[1]
        if coord_chunk and numel > coord_chunk:
            pad = (-numel) % coord_chunk
            xp = jnp.pad(x, ((0, 0), (0, pad)))
            chunks = xp.reshape(x.shape[0], -1, coord_chunk).transpose(1, 0, 2)
            out = jax.lax.map(phase, chunks).reshape(-1)[:numel]
        else:
            out = phase(x)
        return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    x = leaf.astype(jnp.float32)
    g_ext = jnp.tensordot(w_ext, x, axes=(1, 0),   # (theta, ...)
                          precision=jax.lax.Precision.HIGHEST)
    g_agr = jnp.tensordot(w_agr, x, axes=(1, 0),
                          precision=jax.lax.Precision.HIGHEST)
    return G.bulyan_coordinate_phase(g_ext, g_agr, beta).astype(leaf.dtype)


def _sharded_apply_leaf(plan: "AggPlan", leaf: Array, ctx: MeshContext,
                        coordinate_fn=None, *, use_pallas: bool = False,
                        fused: "bool | str" = True,
                        row_mult: Optional[Array] = None) -> Array:
    """Mesh-native apply of one plan to one leaf (DESIGN.md §10).

    The leaf's flattened d axis is sharded over ``ctx.model_axis`` and the
    worker axis over ``ctx.worker_axes``; inside the shard_map each device
    all-gathers the worker rows of its d-shard — the one worker→model
    reshard the pipeline admits — and runs the coordinate phase purely
    locally, so no device ever holds more than (n, d/M) of the stack and
    the model axis pays zero collectives after the gather.

    With ``row_mult`` the leaf is a quantized wire *payload* (int8/bf16)
    and the (n,) per-row dequant multipliers are applied after the gather
    — the §9 decode invariant ``payload.astype(f32) * mult[row]`` runs
    per shard, so the fp32 stack never exists replicated; the result is
    fp32 (the decoded dtype), not the payload dtype.

    Coordinate-kind plans (median / trimmed mean) shard only d: zero-row
    worker padding would perturb order statistics, and their apply never
    mixes workers with weights that could mask padding.
    """
    n = leaf.shape[0]
    M = ctx.model_size
    lead = ctx.worker_entry
    kind = plan.kind
    out_dtype = jnp.float32 if row_mult is not None else leaf.dtype
    x = _leaf2d(leaf)                                  # (n, numel)
    if row_mult is None:
        x = x.astype(jnp.float32)
    numel = x.shape[1]
    d_pad = -(-numel // M) * M
    x = jnp.pad(x, ((0, 0), (0, d_pad - numel)))
    model = ctx.model_axis

    def dequant(rows, mult):
        if mult is None:
            return rows
        return rows.astype(jnp.float32) * mult[:, None]

    if kind == "coordinate":
        fn = _shard_map(
            lambda xl: coordinate_fn(plan, dequant(xl, row_mult)), ctx,
            (P(None, model),), P(model))
        out = fn(x)
        return out[:numel].reshape(leaf.shape[1:]).astype(out_dtype)

    if kind not in ("mean", "weighted", "bulyan"):
        raise ValueError(f"unknown plan kind {kind!r}")
    W = ctx.worker_size
    n_pad = -(-n // W) * W
    x = _pad_rows(x, n_pad)
    mult_pad = None if row_mult is None else \
        jnp.pad(row_mult.astype(jnp.float32), (0, n_pad - n))
    if kind == "weighted":
        w = jnp.pad(plan.weights.astype(jnp.float32), (0, n_pad - n))
    elif kind == "bulyan":
        w_ext = jnp.pad(plan.w_ext, ((0, 0), (0, n_pad - n)))
        w_agr = jnp.pad(plan.w_agr, ((0, 0), (0, n_pad - n)))

    # per-shard fused-vs-XLA dispatch on the static per-device leaf size
    # (the kernel a device actually runs is (n, d_pad/M)); past the
    # measured crossover the whole Pallas stack falls back to XLA, as in
    # _bulyan_leaf
    take_fused = bool(use_pallas and fused)
    take_pallas = use_pallas
    if take_fused and fused != "force":
        from repro.kernels import dispatch as kdispatch
        take_fused = kdispatch.fused_wins(n_pad, d_pad // M)
        take_pallas = take_fused

    def local(xl):                                     # (n_loc, d_loc)
        xfull = jax.lax.all_gather(xl, ctx.worker_axes, axis=0, tiled=True)
        xfull = dequant(xfull, mult_pad)
        if kind == "mean":
            return jnp.sum(xfull, axis=0) / n
        if kind == "weighted":
            return jnp.tensordot(w, xfull, axes=(0, 0))
        if take_fused:
            from repro.kernels import ops as kops
            return kops.fused_select(xfull, w_ext, w_agr, plan.beta)
        g_ext = jnp.matmul(w_ext, xfull,
                           precision=jax.lax.Precision.HIGHEST)
        g_agr = jnp.matmul(w_agr, xfull,
                           precision=jax.lax.Precision.HIGHEST)
        if take_pallas:
            from repro.kernels import ops as kops
            return kops.coord_select(g_ext, g_agr, plan.beta)
        return G.bulyan_coordinate_phase(g_ext, g_agr, plan.beta)

    fn = _shard_map(local, ctx, (P(lead, model),), P(model))
    out = fn(x)
    return out[:numel].reshape(leaf.shape[1:]).astype(out_dtype)


def _sharded_apply_encoded(plan: "AggPlan", enc, ctx: MeshContext,
                           coordinate_fn=None, *, use_pallas: bool = False,
                           fused: "bool | str" = True) -> PyTree:
    """Sharded apply straight off an ``EncodedGrads`` container.

    Leaves whose codec admits the dequant form (int8/bf16 payload × one
    fp32 multiplier per worker row — §9) shard the *payload* columns over
    the model axis and dequantize per shard inside the shard_map, so the
    replicated fp32 (n, d) stack never materializes.  Codecs without the
    form (identity — already fp32; top-k — the index scatter is not
    column-local) decode that leaf replicated first.
    """
    from repro.comm import codecs as CC
    codec = CC.get_codec(enc.spec)
    p_leaves, treedef = jax.tree.flatten(enc.payload)
    s_leaves = jax.tree.leaves(enc.sidecar) \
        if enc.sidecar is not None else [None] * len(p_leaves)
    out = []
    for p, s, shape in zip(p_leaves, s_leaves, enc.shapes):
        form = codec.dequant_form(p, s)
        if form is not None:
            payload2d, mult = form
            out.append(_sharded_apply_leaf(
                plan, payload2d.reshape(shape), ctx, coordinate_fn,
                use_pallas=use_pallas, fused=fused, row_mult=mult))
        else:
            g = codec.decode_leaf(_leaf2d(p), s, shape).reshape(shape)
            out.append(_sharded_apply_leaf(
                plan, g, ctx, coordinate_fn,
                use_pallas=use_pallas, fused=fused))
    return jax.tree.unflatten(treedef, out)


# ==========================================================================
# the Aggregator protocol + registry
# ==========================================================================
class Aggregator:
    """Two-phase GAR: ``plan`` on the (n, n) statistics, ``apply`` on d.

    Capability flags (class attributes):
    * ``needs_dists``       — plan consumes the pairwise-distance matrix;
    * ``coordinate_local``  — apply never mixes coordinates (shards freely);
    * ``min_n(f)``          — the paper's resilience precondition, with its
      human-readable ``min_n_formula`` for error messages.
    """

    name: str = ""
    needs_dists: bool = False
    coordinate_local: bool = True
    min_n_formula: str = "1"

    @staticmethod
    def min_n(f: int) -> int:
        return 1

    # ------------------------------------------------------------- phases
    def validate(self, n: int, f: int) -> None:
        # the one n-vs-f gate, shared with the hierarchical per-level
        # budget checks (theory.split_f_budget / repro.hier)
        from repro.core import theory
        theory.check_level(n, f, rule=self.name, need=self.min_n(f),
                           formula=self.min_n_formula)

    def plan(self, stats: AggStats) -> AggPlan:
        raise NotImplementedError

    def apply(self, plan: AggPlan, grads: PyTree, *, coord_chunk: int = 0,
              use_pallas: bool = False, fused: "bool | str" = True,
              mesh_ctx: Optional[MeshContext] = None) -> PyTree:
        """Plan application — shared across rules, dispatched on plan.kind.

        With ``use_pallas`` the bulyan kind takes the fully fused kernel
        path (one HBM read per leaf, no (θ, d) intermediates) below the
        measured large-d crossover and the XLA substrate above it
        (``kernels.dispatch``); pass ``fused="force"`` to pin the kernel,
        ``fused=False`` to benchmark the two-step Pallas path instead.

        An :class:`EncodedGrads` wire container is decoded first — the
        apply phase mixes values across workers, so it runs on the
        codec-decoded fp32 rows (callers that already hold the decoded
        stack should pass it directly to avoid a second decode).

        With ``mesh_ctx`` every leaf's apply runs mesh-native: the d axis
        shards over the model axis inside a shard_map — no device holds
        more than (n, d/M) of the stack (DESIGN.md §10); wire containers
        with a dequant-form codec shard the quantized payload and decode
        per shard instead of decoding replicated.
        """
        enc = _as_encoded(grads)
        if enc is not None:
            if mesh_ctx is not None:
                return _sharded_apply_encoded(
                    plan, enc, mesh_ctx, self._coordinate_leaf,
                    use_pallas=use_pallas, fused=fused)
            from repro.comm import codecs as CC
            grads = CC.get_codec(enc.spec).decode(enc)
        if mesh_ctx is not None:
            fn = functools.partial(
                _sharded_apply_leaf, plan, ctx=mesh_ctx,
                coordinate_fn=self._coordinate_leaf,
                use_pallas=use_pallas, fused=fused)
            return jax.tree.map(lambda x: fn(x), grads)
        if plan.kind == "mean":
            return jax.tree.map(lambda x: jnp.mean(x, axis=0), grads)
        if plan.kind == "weighted":
            return jax.tree.map(
                functools.partial(_weighted_mean_leaf, plan.weights), grads)
        if plan.kind == "bulyan":
            fn = functools.partial(_bulyan_leaf, plan.w_ext, plan.w_agr,
                                   plan.beta, coord_chunk=coord_chunk,
                                   use_pallas=use_pallas, fused=fused)
            return jax.tree.map(fn, grads)
        if plan.kind == "coordinate":
            return jax.tree.map(
                functools.partial(self._coordinate_leaf, plan), grads)
        raise ValueError(f"unknown plan kind {plan.kind!r}")

    def _coordinate_leaf(self, plan: AggPlan, leaf: Array) -> Array:
        raise NotImplementedError

    # --------------------------------------------------------- convenience
    def __call__(self, grads: PyTree, f: int, *,
                 dists: Optional[Array] = None, coord_chunk: int = 0,
                 use_pallas: bool = False,
                 mesh_ctx: Optional[MeshContext] = None) -> PyTree:
        stats = compute_stats(grads, f, needs_dists=self.needs_dists,
                              use_pallas=use_pallas, dists=dists,
                              mesh_ctx=mesh_ctx)
        self.validate(stats.n, stats.f)
        return self.apply(self.plan(stats), grads, coord_chunk=coord_chunk,
                          use_pallas=use_pallas, mesh_ctx=mesh_ctx)


# ==========================================================================
# the shared aggregation backend (plan service + apply service)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class AggregatorBackend:
    """One bound stats→validate→plan→apply pipeline, shared by every
    consumer (DESIGN.md §13).

    The trainers (``dist.trainer``), the robust serving ensemble
    (``dist.serving.make_robust_serve_step``) and the async bounded-
    staleness service (``repro.serve``) all aggregate through the same
    instance shape: ``plan_stats`` is the *plan service* (O(n²) on the
    replicated statistics, d-free), ``apply`` the *apply service*
    (sharding-preserving einsums + coordinate phase over d).  Splitting
    the two is what lets the async service reuse a previous round's plan
    while still applying it to the freshest buffered gradients.

    Frozen and hashable (``mesh_ctx`` is pure metadata), so step builders
    close over a backend and jit caches key on its configuration.
    """

    gar: str
    f: int
    use_pallas: bool = False
    coord_chunk: int = 0
    fused: "bool | str" = True
    needs_dists: bool = False          # force stats for distance-free rules
    mesh_ctx: Optional[MeshContext] = None
    # observability switchboard (repro.obs.ObsConfig, frozen+hashable):
    # every consumer of a backend — trainers, async service, hier tree —
    # reads the same config, so instrumentation can't half-apply.  None
    # (the default) keeps every step builder on the uninstrumented path.
    obs: Optional[Any] = None

    @classmethod
    def for_config(cls, rcfg, **overrides) -> "AggregatorBackend":
        """Build from a ``RobustConfig`` (gar / f / use_pallas)."""
        kw = dict(gar=rcfg.gar, f=rcfg.f, use_pallas=rcfg.use_pallas)
        kw.update(overrides)
        return cls(**kw)

    @property
    def aggregator(self) -> "Aggregator":
        return get_aggregator(self.gar)

    def stats(self, grads: PyTree, *,
              dists: Optional[Array] = None) -> AggStats:
        agg = self.aggregator
        return compute_stats(grads, self.f,
                             needs_dists=agg.needs_dists or self.needs_dists,
                             use_pallas=self.use_pallas, dists=dists,
                             mesh_ctx=self.mesh_ctx)

    def plan(self, stats: AggStats) -> AggPlan:
        """The plan service: validate + selection on the statistics only."""
        agg = self.aggregator
        agg.validate(stats.n, stats.f)
        return agg.plan(stats)

    def plan_stats(self, grads: PyTree, *, dists: Optional[Array] = None
                   ) -> Tuple[AggPlan, AggStats]:
        stats = self.stats(grads, dists=dists)
        return self.plan(stats), stats

    def apply(self, plan: AggPlan, grads: PyTree) -> PyTree:
        """The apply service: one plan over the d axis of a stack."""
        return self.aggregator.apply(plan, grads,
                                     coord_chunk=self.coord_chunk,
                                     use_pallas=self.use_pallas,
                                     fused=self.fused,
                                     mesh_ctx=self.mesh_ctx)

    def __call__(self, grads: PyTree) -> PyTree:
        plan, _ = self.plan_stats(grads)
        return self.apply(plan, grads)


def select_plan(pred: Array, on_true: AggPlan, on_false: AggPlan) -> AggPlan:
    """Jit-safe plan choice: ``pred ? on_true : on_false`` over the data
    arrays of two same-kind plans (meta fields — kind/n/f/beta — must
    match; they do whenever both came from the same backend).  This is how
    the async service degrades an inadmissible round to the previous
    round's plan without changing any traced shape."""
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b),
                        on_true, on_false)


REGISTRY: Dict[str, Aggregator] = {}


def register_gar(cls):
    """Class decorator: instantiate and register a GAR by its ``name``."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    if inst.name in REGISTRY:
        # every consumer dispatches by name; silent replacement of e.g.
        # multi_bulyan would change results with no indication why
        raise ValueError(
            f"GAR {inst.name!r} is already registered "
            f"({type(REGISTRY[inst.name]).__name__}); pick a distinct name "
            f"or REGISTRY.pop() the old rule first")
    REGISTRY[inst.name] = inst
    return cls


def get_aggregator(name: str) -> Aggregator:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown GAR {name!r}; available: {sorted(REGISTRY)}") from None


def available_gars() -> Tuple[str, ...]:
    return tuple(sorted(REGISTRY))


# ==========================================================================
# the seven rules
# ==========================================================================
@register_gar
class Average(Aggregator):
    """Plain averaging — fastest, non-byzantine-resilient baseline."""

    name = "average"

    def plan(self, stats: AggStats) -> AggPlan:
        return AggPlan(kind="mean", n=stats.n, f=stats.f)


@register_gar
class CoordinateMedian(Aggregator):
    """Coordinate-wise median (the MEDIAN baseline of §V)."""

    name = "median"

    def plan(self, stats: AggStats) -> AggPlan:
        return AggPlan(kind="coordinate", n=stats.n, f=stats.f)

    def _coordinate_leaf(self, plan: AggPlan, leaf: Array) -> Array:
        return G._median_axis0(leaf.astype(jnp.float32)).astype(leaf.dtype)


@register_gar
class TrimmedMean(Aggregator):
    """Coordinate-wise trimmed mean: drop the f largest and f smallest."""

    name = "trimmed_mean"
    min_n_formula = "2f+1"

    @staticmethod
    def min_n(f: int) -> int:
        return 2 * f + 1

    def plan(self, stats: AggStats) -> AggPlan:
        if stats.n <= 2 * stats.f:
            raise ValueError(
                f"trimmed_mean needs n > 2f (n={stats.n}, f={stats.f})")
        return AggPlan(kind="coordinate", n=stats.n, f=stats.f)

    def _coordinate_leaf(self, plan: AggPlan, leaf: Array) -> Array:
        s = G._sort_by_value(leaf.astype(jnp.float32), axis=0)
        return jnp.mean(s[plan.f:plan.n - plan.f], axis=0).astype(leaf.dtype)


class _KrumFamily(Aggregator):
    needs_dists = True
    coordinate_local = False
    min_n_formula = "2f+3"
    _m_select: Optional[int] = None       # None -> the paper's m̃ = n-f-2

    @staticmethod
    def min_n(f: int) -> int:
        return 2 * f + 3

    def plan(self, stats: AggStats) -> AggPlan:
        n, f = stats.n, stats.f
        self.validate(n, f)
        m = self._m_select if self._m_select is not None else n - f - 2
        # selection is piecewise-constant in G: the aggregate's gradient
        # flows through the selected average only, never through the plan
        scores = jax.lax.stop_gradient(G.krum_scores(stats.dists, f))
        mask = G._select_smallest_mask(scores, m)
        w = mask.astype(jnp.float32)
        return AggPlan(kind="weighted", n=n, f=f, weights=w / jnp.sum(w))


@register_gar
class Krum(_KrumFamily):
    """Krum (Blanchard et al. 2017): the single best-scored gradient."""

    name = "krum"
    _m_select = 1


@register_gar
class MultiKrum(_KrumFamily):
    """MULTI-KRUM (§III): average of the m̃ = n-f-2 best-scored."""

    name = "multi_krum"


class _BulyanFamily(Aggregator):
    needs_dists = True
    coordinate_local = False
    min_n_formula = "4f+3"
    _multi = True

    @staticmethod
    def min_n(f: int) -> int:
        return 4 * f + 3

    def plan(self, stats: AggStats) -> AggPlan:
        n, f = stats.n, stats.f
        self.validate(n, f)
        theta = n - 2 * f - 2
        beta = theta - 2 * f
        w_ext, w_agr = G.extraction_plan(stats.dists, f, theta,
                                         multi=self._multi)
        return AggPlan(kind="bulyan", n=n, f=f, w_ext=w_ext, w_agr=w_agr,
                       beta=beta)


@register_gar
class Bulyan(_BulyanFamily):
    """Classic BULYAN: iterated Krum extraction + coordinate phase."""

    name = "bulyan"
    _multi = False


@register_gar
class MultiBulyan(_BulyanFamily):
    """MULTI-BULYAN (Algorithm 1): BULYAN over MULTI-KRUM aggregates."""

    name = "multi_bulyan"


# ==========================================================================
# high-level entry points (what the shims delegate to)
# ==========================================================================
def aggregate_tree(grads: PyTree, f: int, name: str = "multi_bulyan", *,
                   coord_chunk: int = 0, use_pallas: bool = False,
                   fused: "bool | str" = True, dists: Optional[Array] = None,
                   mesh_ctx: Optional[MeshContext] = None) -> PyTree:
    """Aggregate a stacked gradient pytree with the named registered rule."""
    agg = get_aggregator(name)
    stats = compute_stats(grads, f, needs_dists=agg.needs_dists,
                          use_pallas=use_pallas, dists=dists,
                          mesh_ctx=mesh_ctx)
    agg.validate(stats.n, stats.f)
    return agg.apply(agg.plan(stats), grads, coord_chunk=coord_chunk,
                     use_pallas=use_pallas, fused=fused, mesh_ctx=mesh_ctx)


def aggregate_matrix(Gm: Array, f: int, name: str = "multi_bulyan", *,
                     dists: Optional[Array] = None) -> Array:
    """(n, d) stack -> (d,) aggregate: the single-leaf pytree special case."""
    return aggregate_tree(Gm, f, name, dists=dists)


# ==========================================================================
# pre-aggregation transforms
# ==========================================================================
class Transform:
    """A composable stage rewriting the stacked gradients before the GAR.

    ``stateful`` transforms carry a per-worker state pytree across steps
    (see :func:`init_transform_states`); ``needs_dists`` ones receive an
    :class:`AggStats` with the distance matrix of the *current* stack.
    Signature: ``(grads, stats=None, state=None, key=None) -> (grads, state)``.
    """

    name: str = ""
    stateful: bool = False
    needs_dists: bool = False

    def init(self, grads: PyTree) -> PyTree:
        raise NotImplementedError(f"{self.name} is stateless")

    def __call__(self, grads: PyTree, *, stats: Optional[AggStats] = None,
                 state: Optional[PyTree] = None,
                 key: Optional[Array] = None) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ClipByNorm(Transform):
    """Per-worker l2 clipping: ||g_i|| <= max_norm (static-shape, jit-safe).

    A cheap prefilter against magnitude attacks — the GAR still provides
    the directional guarantee.
    """

    max_norm: float = 1.0
    name: str = "clip"

    def __call__(self, grads, *, stats=None, state=None, key=None):
        norms = jnp.sqrt(jnp.maximum(tree_sq_norms(grads), 1e-30))   # (n,)
        scale = jnp.minimum(1.0, self.max_norm / norms)              # (n,)

        def clip_leaf(x):
            s = scale.reshape((-1,) + (1,) * (x.ndim - 1))
            return (x.astype(jnp.float32) * s).astype(x.dtype)

        return jax.tree.map(clip_leaf, grads), state


@dataclasses.dataclass(frozen=True)
class WorkerMomentum(Transform):
    """Resilient averaging of momentums (Farhadkhani et al. 2022).

    Each worker's gradient is replaced by its exponential momentum
    m_i <- β·m_i + g_i before aggregation; the GAR then runs on momentums,
    which shrinks the honest-worker variance the no-free-lunch bound (§VI)
    is driven by.
    """

    beta: float = 0.9
    name: str = "worker_momentum"
    stateful: bool = True

    def init(self, grads: PyTree) -> PyTree:
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), grads)

    def __call__(self, grads, *, stats=None, state=None, key=None):
        if state is None:
            raise ValueError("worker_momentum needs a state pytree; "
                             "seed it with init_transform_states()")
        new = jax.tree.map(
            lambda m, g: self.beta * m + g.astype(jnp.float32), state, grads)
        out = jax.tree.map(lambda m, g: m.astype(g.dtype), new, grads)
        return out, new


@dataclasses.dataclass(frozen=True)
class NearestNeighborMix(Transform):
    """Replace g_i by the mean of its k nearest neighbours (self included).

    A pre-aggregation smoothing step (NNM, Allouah et al. 2023 style) that
    provably tightens the variance condition the paper's §VI bound depends
    on.  Plan-shaped: the (n, n) mixing matrix depends only on distances.
    """

    k: int = 3
    name: str = "nn_mix"
    needs_dists: bool = True

    def __call__(self, grads, *, stats=None, state=None, key=None):
        if stats is None or stats.dists is None:
            raise ValueError("nn_mix needs AggStats with the distance matrix")
        n = stats.n
        k = min(self.k, n)
        # rank each row's distances (self-distance 0 ranks first)
        order = jnp.argsort(stats.dists, axis=1)
        ranks = jnp.argsort(order, axis=1)
        W = (ranks < k).astype(jnp.float32) / float(k)        # (n, n)
        mix = functools.partial(_mix_leaf, W)
        return jax.tree.map(mix, grads), state


def _mix_leaf(W: Array, leaf: Array) -> Array:
    x = leaf.astype(jnp.float32)
    return jnp.tensordot(W, x, axes=(1, 0)).astype(leaf.dtype)


TRANSFORMS: Dict[str, Callable[..., Transform]] = {
    "clip": ClipByNorm,
    "worker_momentum": WorkerMomentum,
    "nn_mix": NearestNeighborMix,
}


def init_transform_states(transforms: Sequence[Transform],
                          grads_like: PyTree) -> Tuple[PyTree, ...]:
    """Initial state tuple (one entry per transform; None when stateless)."""
    return tuple(t.init(grads_like) if t.stateful else None
                 for t in transforms)


def apply_transforms(grads: PyTree, transforms: Sequence[Transform],
                     states: Optional[Sequence[PyTree]] = None, *,
                     key: Optional[Array] = None,
                     use_pallas: bool = False
                     ) -> Tuple[PyTree, Tuple[PyTree, ...]]:
    """Run the transform pipeline; returns (grads, new_states)."""
    if not transforms:
        return grads, ()
    if states is None:
        states = (None,) * len(transforms)
    new_states = []
    f0 = 0  # transforms are rule-agnostic; stats carry distances only
    for i, (t, st) in enumerate(zip(transforms, states)):
        stats = None
        if t.needs_dists:
            stats = compute_stats(grads, f0, needs_dists=True,
                                  use_pallas=use_pallas)
        k = jax.random.fold_in(key, i) if key is not None else None
        grads, st = t(grads, stats=stats, state=st, key=k)
        new_states.append(st)
    return grads, tuple(new_states)
