"""Core: the paper's gradient aggregation rules and byzantine machinery.

The public aggregation surface is the plan/apply ``Aggregator`` registry in
:mod:`repro.core.api`; ``aggregate``/``tree_aggregate`` are legacy shims.
"""
from repro.core.api import (  # noqa: F401
    AggPlan,
    AggStats,
    Aggregator,
    ClipByNorm,
    NearestNeighborMix,
    REGISTRY,
    TRANSFORMS,
    Transform,
    WorkerMomentum,
    aggregate_matrix,
    aggregate_tree,
    apply_transforms,
    available_gars,
    compute_stats,
    get_aggregator,
    init_transform_states,
    register_gar,
)
from repro.core.gar import (  # noqa: F401
    GARS,
    aggregate,
    average,
    bulyan,
    coordinate_median,
    extraction_plan,
    get_gar,
    krum,
    multi_bulyan,
    multi_krum,
    pairwise_sqdist,
    trimmed_mean,
)
from repro.core.robust import (  # noqa: F401
    RobustAggregator,
    tree_aggregate,
    tree_pairwise_sqdist,
)
from repro.core.attacks import (  # noqa: F401
    ADAPTIVE,
    ATTACKS,
    apply_attack,
    get_adaptive,
    get_attack,
    is_adaptive,
    parse_spec,
)
from repro.core import theory  # noqa: F401
