"""Theoretical quantities from the paper (Lemmas 1-2, Theorems 1-2).

Used by benchmarks/resilience.py to check the empirical behaviour against the
proved bounds, and by the trainer to surface the variance condition
``η(n,f)·√d·σ < ||g||`` as a runtime diagnostic.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def eta(n: int, f: int, m: int | None = None) -> float:
    """η(n, f) from Lemma 1.

    η(n,f) = sqrt( 2 ( n - f + (f·m + f²·(m+1)) / (n - 2f - 2) ) ),
    with m = n - f - 2 (the MULTI-KRUM selection size) by default.
    """
    if m is None:
        m = n - f - 2
    if n - 2 * f - 2 <= 0:
        raise ValueError(f"need n > 2f+2 (n={n}, f={f})")
    return math.sqrt(2.0 * (n - f + (f * m + f * f * (m + 1)) / (n - 2 * f - 2)))


def sin_alpha(n: int, f: int, d: int, sigma: float, g_norm: float) -> float:
    """sin α = η(n,f)·√d·σ / ||g|| (Lemma 1).  Must be < 1 for resilience."""
    return eta(n, f) * math.sqrt(d) * sigma / g_norm


def variance_condition(n: int, f: int, d: int, sigma: float, g_norm: float) -> bool:
    """The paper's no-free-lunch requirement: η(n,f)·√d·σ < ||g||."""
    return sin_alpha(n, f, d, sigma, g_norm) < 1.0


def multi_krum_slowdown(n: int, f: int) -> float:
    """Theorem 1(ii): byzantine-free slowdown of MULTI-KRUM vs averaging."""
    return (n - f - 2) / n


def multi_bulyan_slowdown(n: int, f: int) -> float:
    """Theorem 2(iii): byzantine-free slowdown of MULTI-BULYAN vs averaging."""
    return (n - 2 * f - 2) / n


def strong_leeway_bound(d: int) -> float:
    """Definition 2: per-coordinate leeway O(1/√d) for strong resilience."""
    return 1.0 / math.sqrt(d)


def empirical_sigma(G) -> float:
    """Per-coordinate std σ of a stack of correct gradients (E||G-g||² = dσ²)."""
    g = jnp.mean(G, axis=0, keepdims=True)
    d = G.shape[1]
    return float(jnp.sqrt(jnp.mean(jnp.sum((G - g) ** 2, axis=1)) / d))


def cone_cosine(agg, g) -> float:
    """cos of the angle between the aggregate and the true gradient."""
    num = float(jnp.vdot(agg, g))
    den = float(jnp.linalg.norm(agg) * jnp.linalg.norm(g)) + 1e-30
    return num / den


def min_workers(gar: str, f: int) -> int:
    if gar in ("bulyan", "multi_bulyan"):
        return 4 * f + 3
    if gar in ("krum", "multi_krum"):
        return 2 * f + 3
    if gar == "trimmed_mean":
        return 2 * f + 1
    return 1
