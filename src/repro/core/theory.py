"""Theoretical quantities from the paper (Lemmas 1-2, Theorems 1-2).

Used by benchmarks/resilience.py to check the empirical behaviour against the
proved bounds, and by the trainer to surface the variance condition
``η(n,f)·√d·σ < ||g||`` as a runtime diagnostic.

This module also owns the resilience *precondition arithmetic* shared by
every layer that admits workers: :func:`check_level` is the single n-vs-f
gate (``core.api.Aggregator.validate`` — and through it
``RobustConfig.validate()`` — delegates here), and :func:`split_f_budget`
derives the per-level byzantine budgets of the hierarchical (grouped)
aggregation in ``repro.hier`` (DESIGN.md §11): with groups of at least
``g_min`` workers each defending ``f_inner`` traitors, an adversary holding
``f`` workers can fully capture at most ``floor(f / (f_inner+1))`` groups —
the round-based resilience argument of Chen et al. (arXiv 1705.05491) — so
the outer rule must tolerate that many byzantine *group aggregates*.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


def eta(n: int, f: int, m: int | None = None) -> float:
    """η(n, f) from Lemma 1.

    η(n,f) = sqrt( 2 ( n - f + (f·m + f²·(m+1)) / (n - 2f - 2) ) ),
    with m = n - f - 2 (the MULTI-KRUM selection size) by default.
    """
    if m is None:
        m = n - f - 2
    if n - 2 * f - 2 <= 0:
        raise ValueError(f"need n > 2f+2 (n={n}, f={f})")
    return math.sqrt(2.0 * (n - f + (f * m + f * f * (m + 1)) / (n - 2 * f - 2)))


def sin_alpha(n: int, f: int, d: int, sigma: float, g_norm: float) -> float:
    """sin α = η(n,f)·√d·σ / ||g|| (Lemma 1).  Must be < 1 for resilience."""
    return eta(n, f) * math.sqrt(d) * sigma / g_norm


def variance_condition(n: int, f: int, d: int, sigma: float, g_norm: float) -> bool:
    """The paper's no-free-lunch requirement: η(n,f)·√d·σ < ||g||."""
    return sin_alpha(n, f, d, sigma, g_norm) < 1.0


def multi_krum_slowdown(n: int, f: int) -> float:
    """Theorem 1(ii): byzantine-free slowdown of MULTI-KRUM vs averaging."""
    return (n - f - 2) / n


def multi_bulyan_slowdown(n: int, f: int) -> float:
    """Theorem 2(iii): byzantine-free slowdown of MULTI-BULYAN vs averaging."""
    return (n - 2 * f - 2) / n


def strong_leeway_bound(d: int) -> float:
    """Definition 2: per-coordinate leeway O(1/√d) for strong resilience."""
    return 1.0 / math.sqrt(d)


def empirical_sigma(G) -> float:
    """Per-coordinate std σ of a stack of correct gradients (E||G-g||² = dσ²)."""
    g = jnp.mean(G, axis=0, keepdims=True)
    d = G.shape[1]
    return float(jnp.sqrt(jnp.mean(jnp.sum((G - g) ** 2, axis=1)) / d))


def cone_cosine(agg, g) -> float:
    """cos of the angle between the aggregate and the true gradient."""
    num = float(jnp.vdot(agg, g))
    den = float(jnp.linalg.norm(agg) * jnp.linalg.norm(g)) + 1e-30
    return num / den


def min_workers(gar: str, f: int) -> int:
    if gar in ("bulyan", "multi_bulyan"):
        return 4 * f + 3
    if gar in ("krum", "multi_krum"):
        return 2 * f + 3
    if gar == "trimmed_mean":
        return 2 * f + 1
    return 1


MIN_N_FORMULA = {
    "bulyan": "4f+3", "multi_bulyan": "4f+3",
    "krum": "2f+3", "multi_krum": "2f+3",
    "trimmed_mean": "2f+1",
}


def max_f(gar: str, n: int) -> int:
    """The largest byzantine budget ``n`` workers admit under ``gar``
    (inverse of :func:`min_workers`; may be negative when even f=0 is
    infeasible)."""
    if gar in ("bulyan", "multi_bulyan"):
        return (n - 3) // 4
    if gar in ("krum", "multi_krum"):
        return (n - 3) // 2
    if gar == "trimmed_mean":
        return (n - 1) // 2
    return n


def check_level(n: int, f: int, *, rule: str, need: Optional[int] = None,
                formula: Optional[str] = None,
                level: Optional[str] = None) -> None:
    """The one n-vs-f resilience gate, applied at every aggregation level.

    Raises ``ValueError`` when ``n`` workers cannot defend ``f`` traitors
    under ``rule`` (n ≥ 2f+3 for the Krum family, 4f+3 for Bulyan, 2f+1
    for the trimmed mean).  ``need``/``formula`` let callers with their
    own ``min_n`` (custom registered GARs) reuse the shared message
    format; ``level`` names the hierarchy level in the error
    (``"inner"``/``"outer"`` for ``repro.hier``).
    """
    if f < 0:
        raise ValueError(f"f must be >= 0, got {f}")
    if need is None:
        need = min_workers(rule, f)
    if formula is None:
        formula = MIN_N_FORMULA.get(rule, str(need))
    if n < need:
        where = f" at hierarchy level {level!r}" if level else ""
        raise ValueError(
            f"{rule}{where} requires n >= {formula} "
            f"(n={n}, f={f}, need n >= {need})")


# ==========================================================================
# hierarchical (grouped) f-budget arithmetic — DESIGN.md §11
# ==========================================================================
def group_sizes(n: int, g: int) -> Tuple[int, ...]:
    """Deterministic balanced split of ``n`` workers into groups of at
    most ``g``: ``ceil(n/g)`` contiguous groups whose sizes differ by at
    most one (larger groups first)."""
    if g < 1:
        raise ValueError(f"group size must be >= 1, got g={g}")
    if n < 1:
        raise ValueError(f"need at least one worker, got n={n}")
    n_groups = -(-n // g)
    base, rem = divmod(n, n_groups)
    return tuple(base + 1 if i < rem else base for i in range(n_groups))


@dataclasses.dataclass(frozen=True)
class FBudget:
    """Per-level byzantine budgets of a two-level grouped aggregation.

    ``f_inner`` is what every group defends; ``f_outer`` what the outer
    rule over the ``n_groups`` group aggregates defends.  The budget
    *covers* the flat contract ``f`` when no placement of ``f`` traitors
    can capture more than ``f_outer`` groups: a group is captured only
    when it holds more than ``f_inner`` traitors, so at most
    ``floor(f / (f_inner+1))`` groups can fall.
    """

    n: int
    f: int
    g: int
    group_sizes: Tuple[int, ...]
    f_inner: int
    f_outer: int

    @property
    def n_groups(self) -> int:
        return len(self.group_sizes)

    def capturable_groups(self, f: Optional[int] = None) -> int:
        f = self.f if f is None else f
        if self.n_groups == 1:
            return 0 if f <= self.f_inner else 1
        return f // (self.f_inner + 1)

    def covers(self, f: Optional[int] = None) -> bool:
        """Whether any placement of ``f`` traitors stays defended."""
        return self.capturable_groups(f) <= self.f_outer

    def bounds(self) -> Tuple[Tuple[int, int], ...]:
        """Contiguous (start, stop) worker-row ranges per group."""
        out, start = [], 0
        for s in self.group_sizes:
            out.append((start, start + s))
            start += s
        return tuple(out)


# ==========================================================================
# bounded-staleness f-budget arithmetic — DESIGN.md §13
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class StalenessBudget:
    """How an async round's stale workers spend the byzantine budget.

    The bounded-asynchrony argument of Chen et al. (arXiv 1705.05491):
    a worker whose buffered gradient is older than the staleness bound
    ``tau`` is indistinguishable from an adversarial one — its update may
    point anywhere relative to the current iterate — so every *overstale*
    worker is charged against the same contract ``f`` the GAR defends.

    * ``f_defended(k)`` — byzantine defense remaining after ``k`` workers
      went overstale: ``max(f - k, 0)``.
    * ``admissible(k)`` — whether a round with ``k`` overstale workers is
      still covered by the contract (``k <= f``); past that the plan
      service must fall back to the previous round's plan rather than
      trust a majority-stale selection.

    Mirrors :class:`FBudget`: static python arithmetic for config-time
    checks; ``repro.serve.buffer`` computes the identical quantities in
    jnp inside the jitted round (parity tested in tests/test_serve.py).
    """

    n: int
    f: int
    tau: int

    def f_defended(self, n_overstale: int) -> int:
        return max(self.f - min(n_overstale, self.f), 0)

    def admissible(self, n_overstale: int) -> bool:
        return n_overstale <= self.f

    def covers(self, n_byz: int, n_overstale: int) -> bool:
        """Whether ``n_byz`` true traitors plus ``n_overstale`` stale rows
        stay within the contract — the staleness↔f budget law."""
        return n_byz + n_overstale <= self.f


def staleness_budget(n: int, f: int, tau: int, *,
                     rule: str = "multi_bulyan") -> StalenessBudget:
    """Derive (and check) the staleness budget for an async service.

    Gates through :func:`check_level` exactly like the hierarchical
    budgets: ``n`` must defend the contract ``f`` under ``rule`` before
    any of it can be spent on staleness.
    """
    if tau < 0:
        raise ValueError(f"staleness bound tau must be >= 0, got {tau}")
    check_level(n, f, rule=rule)
    return StalenessBudget(n=n, f=f, tau=tau)


def split_f_budget(n: int, f: int, g: int, *, rule: str = "multi_bulyan",
                   outer_rule: Optional[str] = None,
                   f_inner: Optional[int] = None,
                   f_outer: Optional[int] = None,
                   enforce: bool = True) -> FBudget:
    """Derive (and check) the per-level f budgets for groups of size ``g``.

    Default policy: ``f_inner`` is the largest budget the smallest group
    admits under ``rule`` (capped at ``f``); ``f_outer`` is the number of
    groups an ``f``-strong adversary can then capture,
    ``floor(f / (f_inner+1))``.  Every level is gated through
    :func:`check_level` (n ≥ 2f+3 / 4f+3 at level granularity) and —
    unless ``enforce=False`` — the derived budget must cover the contract
    ``f``.  ``enforce=False`` exists for the simulator's poisoned-subtree
    campaigns, which deliberately run under-provisioned trees to *show*
    the capture; explicit ``f_inner``/``f_outer`` overrides model them.

    A single group (g >= n) degenerates to the flat rule: ``f_inner = f``,
    no outer level (``f_outer = 0``).
    """
    if f < 0:
        raise ValueError(f"f must be >= 0, got {f}")
    sizes = group_sizes(n, g)
    n_groups, g_min = len(sizes), min(sizes)
    if n_groups == 1:
        fi = f if f_inner is None else f_inner
        check_level(g_min, fi, rule=rule, level="inner")
        budget = FBudget(n=n, f=f, g=g, group_sizes=sizes,
                         f_inner=fi, f_outer=0)
    else:
        fi = min(f, max(0, max_f(rule, g_min))) if f_inner is None \
            else f_inner
        check_level(g_min, fi, rule=rule, level="inner")
        fo = f // (fi + 1) if f_outer is None else f_outer
        if fo > 0 or outer_rule is not None:
            # a robust outer level must itself satisfy its precondition
            # over the n_groups aggregates (f_outer = 0 with an explicit
            # robust outer rule still needs e.g. n_groups >= 3 for bulyan)
            check_level(n_groups, fo, rule=outer_rule or rule,
                        level="outer")
        budget = FBudget(n=n, f=f, g=g, group_sizes=sizes,
                         f_inner=fi, f_outer=fo)
    if enforce and not budget.covers():
        raise ValueError(
            f"hierarchical f budget (f_inner={budget.f_inner}, "
            f"f_outer={budget.f_outer}, groups={budget.n_groups}) does not "
            f"cover contract f={f}: {budget.capturable_groups()} groups "
            f"capturable > f_outer; increase g, decrease f, or pass "
            f"enforce=False to deliberately run past the budget")
    return budget
