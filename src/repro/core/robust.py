"""Pytree-aware robust aggregation — deprecation shims over ``core.api``.

The trainer hands us a *stacked gradient pytree*: every leaf has a leading
worker axis ``n`` (sharded over the data/pod mesh axes) while the remaining
axes carry the parameter sharding (model axis).  We never concatenate the
gradient into a single (n, d) matrix — instead (DESIGN.md §3):

1. the (n, n) squared-distance matrix is accumulated *per leaf* via the gram
   decomposition and summed across leaves (a cross-leaf ``+`` — under GSPMD
   each model shard contributes its local partial, one tiny all-reduce);
2. the selection logic (Krum scores, Bulyan extraction plan) runs on that
   replicated (n, n) matrix — O(n²θ) scalar work (``Aggregator.plan``);
3. the plan is applied leaf-by-leaf as einsums + the coordinate phase, both
   purely coordinate-local → no communication on the model axis
   (``Aggregator.apply``).

This realises the paper's O(d) claim in the distributed dimension.

The implementation now lives in :mod:`repro.core.api` behind the registered
plan/apply :class:`~repro.core.api.Aggregator` protocol; ``tree_aggregate``
and :class:`RobustAggregator` are kept as thin, bitwise-identical shims for
existing call sites (equivalence is pinned by ``tests/test_agg_api.py``).
New code should use the registry directly::

    agg = api.get_aggregator("multi_bulyan")
    plan = agg.plan(api.compute_stats(grads, f))
    out = agg.apply(plan, grads)

``coord_chunk``: the Bulyan pipeline momentarily materialises (θ, d) per
leaf; for billion-parameter models we process coordinates in chunks via
``lax.map`` to bound the live buffer (a beyond-paper memory optimisation,
exercised in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import RobustConfig
from repro.core import api

# Re-exported so old ``from repro.core.robust import tree_pairwise_sqdist``
# call sites keep working; the implementation moved to core/api.py.
tree_pairwise_sqdist = api.tree_pairwise_sqdist

PyTree = Any


def tree_aggregate(grads: PyTree, f: int, name: str = "multi_bulyan",
                   *, coord_chunk: int = 0, use_pallas: bool = False,
                   dists: Optional[jax.Array] = None) -> PyTree:
    """Aggregate a stacked gradient pytree with the named GAR.

    .. deprecated:: use :func:`repro.core.api.aggregate_tree` (this shim
       delegates to it and is bitwise-identical).
    """
    return api.aggregate_tree(grads, f, name, coord_chunk=coord_chunk,
                              use_pallas=use_pallas, dists=dists)


class RobustAggregator:
    """Callable façade bound to a :class:`RobustConfig`.

    >>> agg = RobustAggregator(RobustConfig(n_workers=16, f=3))
    >>> g = agg(stacked_grads)          # pytree -> pytree

    ``transforms`` (pre-aggregation stages, see ``core.api``) run on the
    stack before the GAR; stateful ones need ``states=`` threaded by the
    caller (the trainer does this automatically).
    """

    def __init__(self, cfg: RobustConfig, coord_chunk: int = 0,
                 transforms: Sequence[api.Transform] = ()):
        cfg.validate()
        self.cfg = cfg
        self.coord_chunk = coord_chunk
        self.transforms = tuple(transforms)
        self.aggregator = api.get_aggregator(cfg.gar)

    def init_transform_states(self, grads_like: PyTree):
        return api.init_transform_states(self.transforms, grads_like)

    def __call__(self, grads: PyTree, *, states=None, key=None):
        grads, new_states = api.apply_transforms(
            grads, self.transforms, states, key=key,
            use_pallas=self.cfg.use_pallas)
        out = api.aggregate_tree(
            grads, self.cfg.f, self.cfg.gar,
            coord_chunk=self.coord_chunk, use_pallas=self.cfg.use_pallas,
        )
        return (out, new_states) if self.transforms else out

    def diagnostics(self, grads: PyTree) -> dict:
        """Variance-condition diagnostics (paper §VI no-free-lunch)."""
        dists = tree_pairwise_sqdist(grads)
        mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), grads)
        g_sq = sum(jnp.sum(m.astype(jnp.float32) ** 2) for m in jax.tree.leaves(mean))
        n = dists.shape[0]
        # E||G - g||^2 = (1/n) Σ_i ||G_i - ḡ||² ; compute from distances:
        # Σ_i ||G_i - ḡ||² = (1/2n) Σ_ij d²_ij
        dsig2 = jnp.sum(dists) / (2.0 * n * n)
        return {
            "grad_norm": jnp.sqrt(g_sq),
            "sqrt_d_sigma": jnp.sqrt(dsig2),
            "mean_pairwise_dist": jnp.sqrt(jnp.sum(dists) / (n * (n - 1))),
        }
