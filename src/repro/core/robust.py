"""Pytree-aware robust aggregation — the GAR applied to model gradients.

The trainer hands us a *stacked gradient pytree*: every leaf has a leading
worker axis ``n`` (sharded over the data/pod mesh axes) while the remaining
axes carry the parameter sharding (model axis).  We never concatenate the
gradient into a single (n, d) matrix — instead:

1. the (n, n) squared-distance matrix is accumulated *per leaf* via the gram
   decomposition and summed across leaves (a cross-leaf ``+`` — under GSPMD
   each model shard contributes its local partial, one tiny all-reduce);
2. the selection logic (Krum scores, Bulyan extraction plan) runs on that
   replicated (n, n) matrix — O(n²θ) scalar work;
3. the plan is applied leaf-by-leaf as einsums + the coordinate phase, both
   purely coordinate-local → no communication on the model axis.

This realises the paper's O(d) claim in the distributed dimension: robustness
costs one all-gather of the worker gradients plus O(n²) scalars, on top of
what plain data-parallel averaging already pays.

``coord_chunk``: the Bulyan pipeline momentarily materialises (θ, d) per
leaf; for billion-parameter models we process coordinates in chunks via
``lax.map`` to bound the live buffer (a beyond-paper memory optimisation,
exercised in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RobustConfig
from repro.core import gar as G

PyTree = Any


def _leaf2d(x: jax.Array) -> jax.Array:
    """(n, ...) -> (n, numel) view.

    Only used on the Pallas/coord-chunk paths.  Under pjit, reshaping a
    param-dim-sharded leaf to (n, numel) is NOT sharding-preserving (GSPMD
    replicates the flattened stack — measured at +214 GB/device on
    qwen2-1.5b, EXPERIMENTS.md §Perf iteration 1); the default paths below
    therefore operate on the *unreshaped* leaves via tensordot.
    """
    return x.reshape((x.shape[0], -1))


def _param_axes(leaf: jax.Array):
    return tuple(range(1, leaf.ndim))


def tree_pairwise_sqdist(grads: PyTree, *, use_pallas: bool = False) -> jax.Array:
    """Sum of per-leaf pairwise squared distances -> global (n, n) matrix.

    Per leaf: contraction over all parameter dims (sharded dims reduce
    locally + one psum under GSPMD); the cross-leaf sum completes the global
    squared distance.
    """
    leaves = jax.tree.leaves(grads)
    if not leaves:
        raise ValueError("empty gradient pytree")
    n = leaves[0].shape[0]
    total = jnp.zeros((n, n), dtype=jnp.float32)
    if use_pallas:
        from repro.kernels import ops as kops
        for leaf in leaves:
            total = total + kops.pairwise_sqdist(_leaf2d(leaf))
    else:
        for leaf in leaves:
            x = leaf.astype(jnp.float32)
            axes = _param_axes(x)
            sq = jnp.sum(x * x, axis=axes)
            gram = jax.lax.dot_general(
                x, x, (( axes, axes), ((), ())),
                preferred_element_type=jnp.float32) if x.ndim == 2 else \
                jnp.tensordot(x, x, axes=(axes, axes))
            total = total + (sq[:, None] + sq[None, :] - 2.0 * gram)
    total = jnp.maximum(total, 0.0)
    return total * (1.0 - jnp.eye(n, dtype=total.dtype))


def _weighted_mean_leaf(w: jax.Array, leaf: jax.Array) -> jax.Array:
    """(n,) weights (summing to 1) applied over the worker axis of a leaf."""
    x = leaf.astype(jnp.float32)
    return jnp.tensordot(w, x, axes=(0, 0)).astype(leaf.dtype)


def _bulyan_leaf(w_ext: jax.Array, w_agr: jax.Array, beta: int,
                 leaf: jax.Array, coord_chunk: int = 0,
                 use_pallas: bool = False) -> jax.Array:
    """Apply an extraction plan + coordinate phase to one gradient leaf.

    Default path is sharding-preserving: (theta, n) @ (n, ...) tensordots
    keep the parameter-dim sharding, and the coordinate phase is purely
    elementwise/axis-0 over (theta, ...).
    """
    if use_pallas or coord_chunk:
        x = _leaf2d(leaf).astype(jnp.float32)      # (n, numel)

        def phase(xc: jax.Array) -> jax.Array:     # (n, c) -> (c,)
            g_ext = w_ext @ xc                     # (theta, c)
            g_agr = w_agr @ xc
            if use_pallas:
                from repro.kernels import ops as kops
                return kops.coord_select(g_ext, g_agr, beta)
            return G.bulyan_coordinate_phase(g_ext, g_agr, beta)

        numel = x.shape[1]
        if coord_chunk and numel > coord_chunk:
            pad = (-numel) % coord_chunk
            xp = jnp.pad(x, ((0, 0), (0, pad)))
            chunks = xp.reshape(x.shape[0], -1, coord_chunk).transpose(1, 0, 2)
            out = jax.lax.map(phase, chunks).reshape(-1)[:numel]
        else:
            out = phase(x)
        return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    x = leaf.astype(jnp.float32)
    g_ext = jnp.tensordot(w_ext, x, axes=(1, 0))   # (theta, ...)
    g_agr = jnp.tensordot(w_agr, x, axes=(1, 0))
    return G.bulyan_coordinate_phase(g_ext, g_agr, beta).astype(leaf.dtype)


def tree_aggregate(grads: PyTree, f: int, name: str = "multi_bulyan",
                   *, coord_chunk: int = 0, use_pallas: bool = False,
                   dists: Optional[jax.Array] = None) -> PyTree:
    """Aggregate a stacked gradient pytree with the named GAR.

    Returns a pytree of the per-leaf shapes minus the worker axis.
    """
    leaves = jax.tree.leaves(grads)
    n = leaves[0].shape[0]
    for leaf in leaves:
        if leaf.shape[0] != n:
            raise ValueError("all leaves must share the worker axis size")

    if name == "average":
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), grads)
    if name == "median":
        return jax.tree.map(
            lambda x: G._median_axis0(x.astype(jnp.float32)).astype(x.dtype),
            grads)
    if name == "trimmed_mean":
        if n <= 2 * f:
            raise ValueError(f"trimmed_mean needs n > 2f (n={n}, f={f})")
        def tm(x):
            s = G._sort_by_value(x.astype(jnp.float32), axis=0)
            return jnp.mean(s[f:n - f], axis=0).astype(x.dtype)
        return jax.tree.map(tm, grads)

    if dists is None:
        dists = tree_pairwise_sqdist(grads, use_pallas=use_pallas)

    if name in ("krum", "multi_krum"):
        m = 1 if name == "krum" else n - f - 2
        if n < 2 * f + 3:
            raise ValueError(f"{name} needs n >= 2f+3 (n={n}, f={f})")
        scores = G.krum_scores(dists, f)
        mask = G._select_smallest_mask(scores, m)
        w = mask.astype(jnp.float32)
        w = w / jnp.sum(w)
        return jax.tree.map(functools.partial(_weighted_mean_leaf, w), grads)

    if name in ("bulyan", "multi_bulyan"):
        if n < 4 * f + 3:
            raise ValueError(f"{name} needs n >= 4f+3 (n={n}, f={f})")
        theta = n - 2 * f - 2
        beta = theta - 2 * f
        w_ext, w_agr = G.extraction_plan(dists, f, theta,
                                         multi=(name == "multi_bulyan"))
        fn = functools.partial(_bulyan_leaf, w_ext, w_agr, beta,
                               coord_chunk=coord_chunk, use_pallas=use_pallas)
        return jax.tree.map(fn, grads)

    raise KeyError(f"unknown GAR {name!r}")


class RobustAggregator:
    """Callable façade bound to a :class:`RobustConfig`.

    >>> agg = RobustAggregator(RobustConfig(n_workers=16, f=3))
    >>> g = agg(stacked_grads)          # pytree -> pytree
    """

    def __init__(self, cfg: RobustConfig, coord_chunk: int = 0):
        self.cfg = cfg
        self.coord_chunk = coord_chunk

    def __call__(self, grads: PyTree) -> PyTree:
        return tree_aggregate(
            grads, self.cfg.f, self.cfg.gar,
            coord_chunk=self.coord_chunk, use_pallas=self.cfg.use_pallas,
        )

    def diagnostics(self, grads: PyTree) -> dict:
        """Variance-condition diagnostics (paper §VI no-free-lunch)."""
        dists = tree_pairwise_sqdist(grads)
        mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), grads)
        g_sq = sum(jnp.sum(m.astype(jnp.float32) ** 2) for m in jax.tree.leaves(mean))
        n = dists.shape[0]
        # E||G - g||^2 = (1/n) Σ_i ||G_i - ḡ||² ; compute from distances:
        # Σ_i ||G_i - ḡ||² = (1/2n) Σ_ij d²_ij
        dsig2 = jnp.sum(dists) / (2.0 * n * n)
        return {
            "grad_norm": jnp.sqrt(g_sq),
            "sqrt_d_sigma": jnp.sqrt(dsig2),
            "mean_pairwise_dist": jnp.sqrt(jnp.sum(dists) / (n * (n - 1))),
        }
