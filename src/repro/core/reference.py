"""Literal numpy reference of Algorithm 1 (sequential pool removal).

This is the oracle against which the jit-safe masked implementations in
``gar.py`` are property-tested: the paper's pseudocode mutates a Python list
(``[G_1..G_n] \\ G_ext``); here we do exactly that, with no lax tricks, so
semantic drift in the fast path cannot hide.

Arithmetic is float32 on purpose: the coordinate phase has *exact* ties by
construction (with θ even, the two middle values are equidistant from their
midpoint-median), so tie resolution is precision-dependent; the oracle must
round like the implementation for index-order tie-breaking to be comparable.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def ref_pairwise_sqdist(G: np.ndarray) -> np.ndarray:
    n = G.shape[0]
    out = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        for j in range(n):
            if i != j:
                diff = G[i].astype(np.float32) - G[j].astype(np.float32)
                out[i, j] = np.float32(diff @ diff)
    return out


def ref_krum_scores(G: np.ndarray, f: int, n_neighbors: int | None = None) -> np.ndarray:
    """Score_i = sum of sq-dists to the (k - f - 2) nearest other gradients."""
    k = G.shape[0]
    if n_neighbors is None:
        n_neighbors = k - f - 2
    d2 = ref_pairwise_sqdist(G)
    scores = np.empty((k,), dtype=np.float32)
    for i in range(k):
        others = np.sort(np.delete(d2[i], i))
        scores[i] = others[:n_neighbors].sum()
    return scores


def ref_multi_krum(G: np.ndarray, f: int, m: int | None = None) -> Tuple[np.ndarray, np.ndarray]:
    """Return (winner, m-average) — Algorithm 1's MULTI-KRUM function.

    m defaults to k - f - 2.  Ties broken by smallest index (matches
    ``_select_smallest_mask``).
    """
    k = G.shape[0]
    if m is None:
        m = k - f - 2
    scores = ref_krum_scores(G, f, n_neighbors=k - f - 2)
    order = np.argsort(scores, kind="stable")
    winner = int(order[0])
    sel = order[:m]
    return G[winner].astype(np.float32), G[sel].astype(np.float32).mean(axis=0)


def ref_multi_bulyan(G: np.ndarray, f: int, multi: bool = True) -> np.ndarray:
    """Algorithm 1 with literal list removal."""
    n, d = G.shape
    if n < 4 * f + 3:
        raise ValueError("bulyan needs n >= 4f+3")
    theta = n - 2 * f - 2
    beta = theta - 2 * f
    pool: List[np.ndarray] = [G[i].astype(np.float32) for i in range(n)]
    g_ext = np.zeros((theta, d), np.float32)
    g_agr = np.zeros((theta, d), np.float32)
    for r in range(theta):
        P = np.stack(pool)
        m_r = P.shape[0] - f - 2
        scores = ref_krum_scores(P, f, n_neighbors=m_r)
        order = np.argsort(scores, kind="stable")
        winner = int(order[0])
        g_ext[r] = P[winner]
        g_agr[r] = P[order[:m_r]].mean(axis=0) if multi else P[winner]
        pool.pop(winner)
    med = np.median(g_ext, axis=0)
    out = np.zeros((d,), np.float32)
    for j in range(d):
        dist = np.abs(g_agr[:, j] - med[j])
        closest = np.argsort(dist, kind="stable")[:beta]
        out[j] = g_agr[closest, j].mean()
    return out


def ref_trimmed_mean(G: np.ndarray, f: int) -> np.ndarray:
    n = G.shape[0]
    s = np.sort(G.astype(np.float32), axis=0)
    return s[f:n - f].mean(axis=0)
