"""Property tests: the jit-safe masked GARs ≡ the literal Algorithm 1.

These are the core semantics guarantees: the lax.fori_loop/masked
re-expression of the paper's sequential pool removal must match the numpy
reference exactly, for every rule, including ties, duplicates and extreme
values.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:           # container has no hypothesis; use the shim
    from _mini_hypothesis import given, settings, strategies as st

from repro.core import gar
from repro.core import reference as ref
from repro.core.robust import tree_aggregate


def _nf(draw_n, draw_f, kind):
    """Valid (n, f) pairs per rule family."""
    if kind == "bulyan":
        return [(n, f) for n in draw_n for f in draw_f if n >= 4 * f + 3]
    return [(n, f) for n in draw_n for f in draw_f if n >= 2 * f + 3]


@st.composite
def gradient_stacks(draw, min_n=7, max_n=21, max_d=24):
    n = draw(st.integers(min_n, max_n))
    d = draw(st.integers(1, max_d))
    # values include duplicates and large magnitudes
    base = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(base)
    G = rng.normal(size=(n, d)).astype(np.float32)
    if draw(st.booleans()):
        G[draw(st.integers(0, n - 1))] = G[0]  # exact duplicate row
    if draw(st.booleans()):
        G[draw(st.integers(0, n - 1))] *= 1e4  # outlier row
    return G


@settings(max_examples=40, deadline=None)
@given(gradient_stacks())
def test_multi_bulyan_matches_reference(G):
    n = G.shape[0]
    f = (n - 3) // 4
    if f < 1:
        return
    got = np.asarray(gar.multi_bulyan(jnp.asarray(G), f))
    want = ref.ref_multi_bulyan(G, f, multi=True)
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-5 * scale)


@settings(max_examples=40, deadline=None)
@given(gradient_stacks())
def test_bulyan_matches_reference(G):
    n = G.shape[0]
    f = (n - 3) // 4
    if f < 1:
        return
    got = np.asarray(gar.bulyan(jnp.asarray(G), f))
    want = ref.ref_multi_bulyan(G, f, multi=False)
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-5 * scale)


@settings(max_examples=40, deadline=None)
@given(gradient_stacks())
def test_multi_krum_matches_reference(G):
    n = G.shape[0]
    f = (n - 3) // 2
    if f < 1:
        return
    got = np.asarray(gar.multi_krum(jnp.asarray(G), f))
    _, want = ref.ref_multi_krum(G, f)
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-5 * scale)


@settings(max_examples=40, deadline=None)
@given(gradient_stacks())
def test_krum_matches_reference(G):
    n = G.shape[0]
    f = (n - 3) // 2
    if f < 1:
        return
    got = np.asarray(gar.krum(jnp.asarray(G), f))
    want, _ = ref.ref_multi_krum(G, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(gradient_stacks(min_n=11, max_n=15), st.integers(0, 2 ** 31 - 1))
def test_permutation_invariance(G, seed):
    """GARs must not depend on worker ordering (up to fp summation noise)."""
    n = G.shape[0]
    f = (n - 3) // 4
    if f < 1:
        return
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    for name in ("average", "median", "trimmed_mean", "multi_krum",
                 "multi_bulyan"):
        a = np.asarray(gar.aggregate(jnp.asarray(G), f, name))
        b = np.asarray(gar.aggregate(jnp.asarray(G[perm]), f, name))
        scale = max(1.0, np.abs(a).max())
        np.testing.assert_allclose(a, b, rtol=0, atol=3e-5 * scale,
                                   err_msg=name)


@settings(max_examples=25, deadline=None)
@given(gradient_stacks(min_n=11, max_n=15))
def test_tree_aggregate_equals_flat(G):
    n, d = G.shape
    if d < 3:
        return
    f = (n - 3) // 4
    if f < 1:
        return
    split = d // 2
    tree = {"a": jnp.asarray(G[:, :split]).reshape(n, -1),
            "b": {"c": jnp.asarray(G[:, split:])}}
    for name in ("multi_krum", "multi_bulyan", "median"):
        out = tree_aggregate(tree, f, name)
        got = np.concatenate([np.asarray(out["a"]).ravel(),
                              np.asarray(out["b"]["c"]).ravel()])
        want = np.asarray(gar.aggregate(jnp.asarray(G), f, name))
        scale = max(1.0, np.abs(want).max())
        np.testing.assert_allclose(got, want, rtol=0, atol=3e-5 * scale,
                                   err_msg=name)


def test_trimmed_mean_matches_reference():
    rng = np.random.default_rng(0)
    G = rng.normal(size=(11, 17)).astype(np.float32)
    got = np.asarray(gar.trimmed_mean(jnp.asarray(G), 3))
    want = ref.ref_trimmed_mean(G, 3)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_constraint_validation():
    G = jnp.zeros((10, 4))
    with pytest.raises(ValueError):
        gar.multi_bulyan(G, 2)       # needs n >= 4f+3 = 11
    with pytest.raises(ValueError):
        gar.multi_krum(G, 4)         # needs n >= 2f+3 = 11
    with pytest.raises(ValueError):
        gar.trimmed_mean(G, 5)       # needs n > 2f


def test_f_zero_multi_krum_close_to_average():
    """With f=0, multi-krum averages n-2 of n i.i.d. gradients."""
    rng = np.random.default_rng(1)
    G = rng.normal(size=(9, 5)).astype(np.float32)
    mk = np.asarray(gar.multi_krum(jnp.asarray(G), 0))
    avg = G.mean(0)
    # not identical (drops 2), but close for i.i.d. gradients
    assert np.linalg.norm(mk - avg) < np.linalg.norm(G.std(0))


def test_gar_under_jit_and_grad():
    """GARs must be jit-able and the aggregate differentiable wrt inputs."""
    G = jnp.asarray(np.random.default_rng(2).normal(size=(11, 6)),
                    dtype=jnp.float32)
    out = jax.jit(lambda g: gar.multi_bulyan(g, 2))(G)
    assert out.shape == (6,)

    def loss(g):
        return jnp.sum(gar.multi_krum(g, 2) ** 2)

    g = jax.grad(loss)(G)
    assert g.shape == G.shape
    assert bool(jnp.all(jnp.isfinite(g)))
