"""repro.obs: jit-safe metrics registry, span ring, snapshots (DESIGN.md §14).

The two contracts money rides on:

* **disabled = uninstrumented, bitwise** — ``obs=None`` and
  ``ObsConfig(enabled=False)`` must produce the *identical jaxpr* of the
  step that never heard of observability, and the enabled path must not
  perturb the training computation (params bitwise equal);
* **the registry is exact** — histogram counts match numpy's
  ``searchsorted`` semantics under ``lax.scan``, the ring drains in seq
  order across wraparound, and the whole ``mstate`` survives a
  checkpoint round-trip.

The golden-summary regression pins the ``sim.campaign.v1`` digest
byte-for-byte across the telemetry→obs accumulator port.
"""
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import obs as OBS
from repro.checkpoint import restore, save
from repro.configs.base import ArchConfig, RobustConfig
from repro.data import lm_batches
from repro.dist import init_train_state, make_train_step, split_workers
from repro import models as MD
from repro.optim import constant, sgd

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = os.path.join(HERE, "fixtures_obs", "golden_summary.json")

KEY = jax.random.key(0)
ARCH = ArchConfig(name="obs-tiny", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)
N, F = 7, 1


def _setup(**rkw):
    rcfg = RobustConfig(n_workers=N, f=F, gar="multi_bulyan", **rkw)
    params = MD.init_model(KEY, ARCH)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params, n_workers=N)
    batch = split_workers(next(lm_batches(ARCH.vocab_size, N * 2, 16,
                                          seed=3)), N)
    return rcfg, params, opt, state, batch


def _step(rcfg, opt, **kw):
    return make_train_step(ARCH, rcfg, opt, constant(0.05), chunk_q=16,
                           **kw)


# ------------------------------------------------------- disabled = noop
def test_disabled_obs_is_bitwise_noop():
    rcfg, params, opt, state, batch = _setup()
    base = _step(rcfg, opt)
    off = _step(rcfg, opt, obs=OBS.ObsConfig(enabled=False))
    j0 = str(jax.make_jaxpr(base)(params, state, batch, KEY))
    j1 = str(jax.make_jaxpr(off)(params, state, batch, KEY))
    assert j0 == j1, "ObsConfig(enabled=False) changed the step jaxpr"


def test_disabled_obs_state_has_zero_leaves():
    assert OBS.init_train_obs(None, N) is None
    assert OBS.init_train_obs(OBS.ObsConfig(enabled=False), N) is None
    assert jax.tree.leaves(OBS.init_train_obs(
        OBS.ObsConfig(enabled=False), N)) == []


def test_enabled_obs_does_not_perturb_training():
    rcfg, params, opt, state, batch = _setup()
    base = jax.jit(_step(rcfg, opt))
    on = jax.jit(_step(rcfg, opt, obs=OBS.ObsConfig(enabled=True)))
    p0, s0, p1, s1 = params, state, params, state
    for i in range(2):
        k = jax.random.fold_in(KEY, i)
        p0, s0, m0 = base(p0, s0, batch, k)
        p1, s1, m1 = on(p1, s1, batch, k)
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(s1.mstate["m"].counters["rounds"]) == 2.0
    assert s0.mstate is None


def test_enabled_step_records_spans_in_pipeline_order():
    rcfg, params, opt, state, batch = _setup()
    on = jax.jit(_step(rcfg, opt, obs=OBS.ObsConfig(enabled=True)))
    p, s = params, state
    for i in range(2):
        p, s, _ = on(p, s, batch, jax.random.fold_in(KEY, i))
    recs = OBS.drain(s.mstate["t"])
    assert [(r["round"], r["phase"]) for r in recs] == [
        (0, "stats"), (0, "plan"), (0, "apply"),
        (1, "stats"), (1, "plan"), (1, "apply")]


# ------------------------------------------------------------- registry
def test_histogram_exact_vs_numpy_under_scan():
    edges = (0.5, 1.5, 2.5, 4.0)
    spec = OBS.MetricsSpec(counters=("n",), hists=(("v", edges),))
    rng = np.random.default_rng(7)
    vals = rng.uniform(-1.0, 6.0, size=64).astype(np.float32)

    def body(m, v):
        m = OBS.inc(m, "n")
        return OBS.observe(m, "v", v), ()

    m, _ = jax.lax.scan(body, OBS.init_metrics(spec), jnp.asarray(vals))
    want = np.bincount(
        np.searchsorted(np.asarray(edges), vals, side="right"),
        minlength=len(edges) + 1)
    np.testing.assert_array_equal(np.asarray(m.hists["v"]), want)
    assert float(m.counters["n"]) == len(vals)


def test_vector_observe_counts_every_element():
    spec = OBS.MetricsSpec(hists=(("age", (0.5, 1.5)),))
    m = OBS.observe(OBS.init_metrics(spec), "age",
                    jnp.asarray([0.0, 1.0, 1.0, 2.0]))
    np.testing.assert_array_equal(np.asarray(m.hists["age"]), [1, 2, 1])


def test_unknown_names_are_noops_and_none_passes_through():
    spec = OBS.MetricsSpec(counters=("a",))
    m = OBS.init_metrics(spec)
    assert OBS.inc(m, "nope") is m
    assert OBS.observe(m, "nope", 1.0) is m
    assert OBS.inc(None, "a") is None
    assert OBS.record(None, OBS.PH_STATS, 0) is None


def test_ring_wraparound_drains_in_seq_order():
    t = OBS.init_trace(4)
    for i in range(11):
        t = OBS.record(t, i % len(OBS.PHASES), i, payload=float(i))
    recs = OBS.drain(t)
    assert [r["seq"] for r in recs] == [7, 8, 9, 10]
    assert [r["payload"] for r in recs] == [7.0, 8.0, 9.0, 10.0]
    assert int(t.head) == 11


def test_mstate_checkpoint_round_trip(tmp_path):
    ms = OBS.init_train_obs(OBS.ObsConfig(enabled=True), N, telemetry=True)
    ms = {"m": OBS.observe(OBS.inc(ms["m"], "rounds", 3.0),
                           "agg_grad_norm", 2.5),
          "t": OBS.record(ms["t"], OBS.PH_PLAN, 1, 0.25)}
    save(str(tmp_path), 0, {"mstate": ms})
    like = {"mstate": OBS.init_train_obs(OBS.ObsConfig(enabled=True), N,
                                         telemetry=True)}
    back = restore(str(tmp_path), 0, like)["mstate"]
    assert float(back["m"].counters["rounds"]) == 3.0
    np.testing.assert_array_equal(np.asarray(back["m"].hists["agg_grad_norm"]),
                                  np.asarray(ms["m"].hists["agg_grad_norm"]))
    assert OBS.drain(back["t"]) == OBS.drain(ms["t"])


def test_spec_rejects_duplicates_and_bad_edges():
    with pytest.raises(ValueError, match="duplicate"):
        OBS.MetricsSpec(counters=("a", "a"))
    with pytest.raises(ValueError, match="sorted"):
        OBS.MetricsSpec(hists=(("h", (2.0, 1.0)),))
    with pytest.raises(ValueError, match="ring capacity"):
        OBS.ObsConfig(enabled=True, ring=0)


# ------------------------------------------------------------- snapshot
def test_snapshot_validates_and_catches_corruption():
    ms = OBS.init_train_obs(OBS.ObsConfig(enabled=True), N)
    snap = OBS.snapshot(metrics=ms["m"], trace_records=OBS.drain(ms["t"]))
    assert OBS.validate_snapshot(snap) == []
    bad = json.loads(json.dumps(snap))
    bad["metrics"]["hists"]["agg_grad_norm"]["counts"] = [0]
    bad["schema"] = "obs.v0"
    problems = OBS.validate_snapshot(bad)
    assert any("schema" in p for p in problems)
    assert any("edges+1" in p for p in problems)


# ------------------------------------------------- golden campaign summary
def test_campaign_summary_golden():
    """The telemetry→obs port must not move a single byte of the
    ``sim.campaign.v1`` summary (the digest now lives in
    ``obs.export.phase_summary``; ``telemetry.summarize`` delegates)."""
    from repro.sim.engine import run_campaign
    from repro.sim.scenario import AttackPhase, AttackSchedule, Scenario
    sc = Scenario(name="obs-golden", arch=ARCH, n_workers=N, f=F,
                  seed=0, per_worker_batch=2, seq=16, lr=0.05,
                  schedule=AttackSchedule(phases=(
                      AttackPhase(attack="none", steps=2),
                      AttackPhase(attack="sign_flip", steps=2))))
    got = json.dumps(run_campaign(sc).summary, sort_keys=True)
    with open(GOLDEN) as fh:
        assert got == fh.read().strip()
