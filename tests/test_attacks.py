"""Attack-library contract + permutation invariance over the Aggregator API.

``core/attacks.py`` promises: an attack maps the (n-f, d) stack of correct
gradients to (f, d) byzantine proposals, and GARs are permutation-invariant
(the docstring claims "property-tested" — this is that test, over the new
plan/apply registry).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import api, attacks

KEY = jax.random.key(0)
N, F, D = 15, 3, 40     # n >= 4f+3 so every registered rule is runnable
RNG = np.random.default_rng(3)


@pytest.mark.parametrize("name", sorted(attacks.ATTACKS))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attack_shape_and_dtype(name, dtype):
    correct = jnp.asarray(RNG.normal(size=(N - F, D)).astype(np.float32),
                          dtype=dtype)
    byz = attacks.get_attack(name)(correct, F, KEY)
    assert byz.shape == (F, D), name
    stack = attacks.apply_attack(correct, F, name, KEY)
    assert stack.shape == (N, D)
    assert stack.dtype == correct.dtype, (name, dtype)
    # correct rows ride through apply_attack untouched
    np.testing.assert_array_equal(
        np.asarray(stack[F:], np.float32), np.asarray(correct, np.float32))


def test_attack_f_zero_is_identity():
    correct = jnp.asarray(RNG.normal(size=(N, D)).astype(np.float32))
    out = attacks.apply_attack(correct, 0, "inf", KEY)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(correct))


def test_unknown_attack_raises():
    with pytest.raises(KeyError):
        attacks.get_attack("not_an_attack")


# ------------------------------------------------------------ spec strings
def test_parse_spec_grammar():
    assert attacks.parse_spec("mimic") == ("mimic", {})
    assert attacks.parse_spec("little_is_enough:z=2.5") == \
        ("little_is_enough", {"z": 2.5})
    assert attacks.parse_spec("gaussian:sigma=2,") == \
        ("gaussian", {"sigma": 2.0})
    with pytest.raises(ValueError, match="key=value"):
        attacks.parse_spec("sign_flip:scale")
    with pytest.raises(ValueError, match="non-numeric"):
        attacks.parse_spec("sign_flip:scale=big")


def test_get_attack_spec_binds_kwargs():
    correct = jnp.asarray(RNG.normal(size=(N - F, D)).astype(np.float32))
    # z=0 little_is_enough degenerates to broadcasting the mean (= no_attack)
    z0 = attacks.get_attack("little_is_enough:z=0.0")(correct, F, KEY)
    np.testing.assert_allclose(
        np.asarray(z0), np.asarray(attacks.no_attack(correct, F, KEY)),
        rtol=1e-6)
    s5 = attacks.get_attack("sign_flip:scale=5.0")(correct, F, KEY)
    np.testing.assert_allclose(
        np.asarray(s5),
        5.0 * np.asarray(attacks.sign_flip(correct, F, KEY)), rtol=1e-6)


def test_get_attack_spec_rejects_unknown_kwargs():
    with pytest.raises(ValueError, match="no parameter"):
        attacks.get_attack("little_is_enough:zz=2.0")
    with pytest.raises(ValueError, match="no parameter"):
        attacks.get_adaptive("adaptive_lie:warp=1.0")


def test_inject_byzantine_passes_spec_through():
    """dist.trainer._attack_leaf must honor parameterized specs."""
    from repro.dist import inject_byzantine

    grads = {"w": jnp.ones((N, 3, 4)), "b": jnp.ones((N, 5))}
    out = inject_byzantine(grads, F, "sign_flip:scale=4.0", KEY)
    np.testing.assert_allclose(np.asarray(out["w"][:F]), -4.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"][:F]), -4.0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out["w"][F:]),
                                  np.asarray(grads["w"][F:]))


@pytest.mark.parametrize("name", sorted(api.available_gars()))
def test_gar_permutation_invariance_over_registry(name):
    """Shuffling worker order must not change the aggregate (registry path).

    krum's argmin tie-breaking is by index, so exact invariance needs
    distinct scores — generic gaussian stacks provide that almost surely.
    """
    agg = api.get_aggregator(name)
    for trial in range(5):
        rng = np.random.default_rng(trial)
        G = rng.normal(size=(N, D)).astype(np.float32)
        G[0] *= 50.0                                  # one outlier row
        perm = rng.permutation(N)
        a = np.asarray(agg(jnp.asarray(G), F))
        b = np.asarray(agg(jnp.asarray(G[perm]), F))
        scale = max(1.0, np.abs(a).max())
        np.testing.assert_allclose(a, b, rtol=0, atol=3e-5 * scale,
                                   err_msg=f"{name} trial {trial}")


@pytest.mark.parametrize("name", sorted(api.available_gars()))
def test_gar_permutation_invariance_under_attack(name):
    """Same property with byzantine rows present (the setting that matters)."""
    agg = api.get_aggregator(name)
    rng = np.random.default_rng(7)
    correct = (np.ones(D) + 0.1 * rng.normal(size=(N - F, D))).astype(np.float32)
    stack = np.asarray(attacks.apply_attack(
        jnp.asarray(correct), F, "little_is_enough", KEY))
    perm = rng.permutation(N)
    a = np.asarray(agg(jnp.asarray(stack), F))
    b = np.asarray(agg(jnp.asarray(stack[perm]), F))
    scale = max(1.0, np.abs(a).max())
    np.testing.assert_allclose(a, b, rtol=0, atol=3e-5 * scale, err_msg=name)
