"""Minimal stand-in for the ``hypothesis`` API used by the property tests.

The container does not ship hypothesis and nothing may be pip-installed, so
this shim implements just the surface ``tests/test_gar_semantics.py`` needs
(``given``/``settings``/``strategies.{composite,integers,booleans}``) with
deterministic seeded example generation.  If the real hypothesis is
available it is used instead (see the import guard in the test module).
"""
from __future__ import annotations

import functools

import numpy as np

_DEFAULT_EXAMPLES = 20
# cap: this shim runs eager jnp per example; keep CI time bounded
_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def composite(fn):
        @functools.wraps(fn)
        def factory(*args, **kwargs):
            def draw_fn(rng):
                draw = lambda strat: strat.example(rng)  # noqa: E731
                return fn(draw, *args, **kwargs)
            return _Strategy(draw_fn)
        return factory


def given(*strats):
    def deco(fn):
        # deliberately NOT functools.wraps: pytest must see a zero-argument
        # signature, not the wrapped function's strategy parameters
        def wrapper():
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_EXAMPLES)
            for i in range(n):
                rng = np.random.default_rng(1000 + i)
                fn(*[s.example(rng) for s in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
