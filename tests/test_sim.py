"""repro.sim campaign simulator: acceptance, telemetry, schedules, engine.

The headline test is the ISSUE-3 acceptance criterion: a 40-step campaign
switching ``no_attack -> little_is_enough`` mid-run must show multi-Bulyan's
post-switch honest-mean deviation bounded with ≈ 0 byzantine selection
mass, while plain averaging is captured (full f/n selection share) and
dragged off the honest mean.  ``launch/simulate.py --smoke`` reproduces the
same assertion in CI.
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import api, attacks
from repro.dist import inject_byzantine
from repro.sim import (AttackPhase, AttackSchedule, DataConfig, Scenario,
                       run_campaign, switch_scenario)
from repro.sim.engine import _phase_batches

KEY = jax.random.key(0)

# small arch for the non-acceptance engine tests (TINY is the acceptance
# config — launch/simulate.py --smoke must see the same numbers)
SMALL = ArchConfig(name="sim-test", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128)


# ======================================================== acceptance (40 steps)
@pytest.fixture(scope="module")
def switch_results():
    out = {}
    for gar in ("multi_bulyan", "average"):
        out[gar] = run_campaign(switch_scenario(gar, pre=20, post=20))
    return out


def test_switch_campaign_robust_bounded_average_captured(switch_results):
    post = slice(20, 40)
    rb = switch_results["multi_bulyan"].trace
    av = switch_results["average"].trace
    # multi_bulyan: bounded post-switch deviation, byzantine rows deselected
    assert float(np.max(rb["honest_dev"][post])) < 2.0
    assert float(np.mean(rb["byz_mass"][post])) < 0.02
    # and it keeps learning through the switch
    assert rb["loss"][-1] < rb["loss"][19]
    # averaging: the adversary keeps its full f/n selection share and drags
    # the aggregate off the honest mean
    assert float(np.mean(av["byz_mass"][post])) > 0.15      # f/n = 0.1818
    assert float(np.mean(av["honest_dev"][post])) >= \
        2.0 * float(np.mean(rb["honest_dev"][post]))
    assert float(av["loss"][-1]) >= float(rb["loss"][-1]) + 0.2


def test_switch_campaign_suspicion_flags_byzantine(switch_results):
    susp = switch_results["multi_bulyan"].trace["suspicion"][-1]
    f = switch_results["multi_bulyan"].scenario.f
    assert np.mean(susp[:f]) > np.mean(susp[f:]) + 0.2


def test_campaign_trace_schema(switch_results):
    r = switch_results["multi_bulyan"]
    n = r.scenario.n_workers
    tr = r.trace
    for k in ("loss", "honest_dev", "byz_mass", "score_gap", "mean_dist",
              "lr", "agg_grad_norm", "phase"):
        assert tr[k].shape == (40,), k
    for k in ("selection", "suspicion", "score_spectrum", "loss_per_worker"):
        assert tr[k].shape == (40, n), k
    np.testing.assert_allclose(tr["selection"].sum(axis=1), 1.0, atol=1e-5)
    assert list(tr["phase"][:20]) == [0] * 20
    assert list(tr["phase"][20:]) == [1] * 20
    ph = r.summary["phases"]
    assert [p["attack"] for p in ph] == ["none", "little_is_enough:z=4.0"]


# ======================================================== plan diagnostics
def _attacked_stats(rule_f=2, n=11, d=50, attack="little_is_enough:z=4.0"):
    rng = np.random.default_rng(0)
    correct = (np.ones(d) + 0.1 * rng.normal(size=(n - rule_f, d))
               ).astype(np.float32)
    G = attacks.apply_attack(jnp.asarray(correct), rule_f, attack, KEY)
    return api.compute_stats(G, rule_f, needs_dists=True)


@pytest.mark.parametrize("rule", ["multi_krum", "multi_bulyan"])
def test_diagnostics_byzantine_rows_deselected(rule):
    stats = _attacked_stats()
    plan = api.get_aggregator(rule).plan(stats)
    diag = plan.diagnostics(stats)
    assert float(diag["byz_mass"]) < 1e-6
    np.testing.assert_allclose(float(jnp.sum(diag["selection"])), 1.0,
                               atol=1e-5)
    assert float(diag["score_gap"]) > 0.0          # clean selection boundary
    spectrum = np.asarray(diag["score_spectrum"])
    assert np.all(np.diff(spectrum) >= 0)          # ascending
    assert np.all(np.isfinite(spectrum))


def test_diagnostics_mean_kind_uniform():
    stats = _attacked_stats()
    plan = api.get_aggregator("average").plan(stats)
    diag = plan.diagnostics(stats)
    np.testing.assert_allclose(np.asarray(diag["selection"]), 1.0 / 11,
                               atol=1e-6)
    np.testing.assert_allclose(float(diag["byz_mass"]), 2.0 / 11, atol=1e-5)
    assert float(diag["score_gap"]) == 0.0         # everyone "selected"


def test_diagnostics_without_stats_has_no_score_fields():
    stats = _attacked_stats()
    plan = api.get_aggregator("multi_krum").plan(stats)
    diag = plan.diagnostics()
    assert set(diag) == {"selection", "byz_mass"}


# ==================================== schedule determinism across trainers
def test_inject_byzantine_block_determinism_under_schedule():
    """Per-block injection with leaf_offset must reproduce the full-tree
    injection for every phase of a multi-phase schedule (parameterized
    attack specs included) — the invariant that makes stacked and
    streaming campaigns comparable."""
    n, f = 11, 2
    rng = np.random.default_rng(1)
    tree = {
        "a": {"w": jnp.asarray(rng.normal(size=(n, 3, 4)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)},
        "c": {"w": jnp.asarray(rng.normal(size=(n, 2, 2)), jnp.float32)},
    }
    specs = ["little_is_enough:z=2.0", "sign_flip:scale=3.0",
             "gaussian:sigma=2.0"]
    for step, spec in enumerate(specs):            # one phase per spec
        key = jax.random.fold_in(KEY, step)
        full = inject_byzantine(tree, f, spec, key)
        offsets = {"a": 0, "c": len(jax.tree.leaves(tree["a"]))}
        blockwise = {
            k: inject_byzantine(tree[k], f, spec, key,
                                leaf_offset=offsets[k])
            for k in sorted(tree)
        }
        for x, y in zip(jax.tree.leaves(full), jax.tree.leaves(blockwise)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ======================================================== scenario validation
def test_scenario_rejects_bad_configs():
    ph = AttackPhase(steps=4)
    sched = AttackSchedule((ph,))
    with pytest.raises(ValueError, match="unknown trainer"):
        Scenario(name="x", schedule=sched, trainer="warp")
    with pytest.raises(ValueError, match="effective f"):
        Scenario(name="x", schedule=AttackSchedule(
            (AttackPhase(steps=2, f=3),)), f=2)
    with pytest.raises(ValueError, match="unknown attack"):
        Scenario(name="x", schedule=AttackSchedule(
            (AttackPhase(steps=2, attack="not_an_attack"),)))
    with pytest.raises(ValueError, match="stale_workers"):
        Scenario(name="x", schedule=AttackSchedule(
            (AttackPhase(steps=2, stale_workers=(99,)),)), n_workers=11)
    with pytest.raises(ValueError, match="trainer='stacked'"):
        Scenario(name="x", schedule=AttackSchedule(
            (AttackPhase(steps=2, attack="adaptive_lie"),)),
            trainer="stream_block")
    with pytest.raises(ValueError, match="steps must be positive"):
        AttackPhase(steps=0)
    with pytest.raises(ValueError, match="at least one phase"):
        AttackSchedule(())


def test_schedule_bounds_and_describe():
    sched = AttackSchedule((AttackPhase(steps=3), AttackPhase(steps=5,
                                                              attack="mimic")))
    assert sched.total_steps == 8
    assert sched.bounds() == ((0, 3), (3, 8))
    assert sched.describe() == "none@3 -> mimic@5"


def test_simulate_cli_phase_parsing():
    from repro.launch.simulate import parse_phase
    p = parse_phase("20=little_is_enough:z=4.0@f=1@stale=2+5")
    assert p.steps == 20 and p.attack == "little_is_enough:z=4.0"
    assert p.f == 1 and p.stale_workers == (2, 5)
    with pytest.raises(ValueError, match="STEPS=ATTACK_SPEC"):
        parse_phase("little_is_enough")
    with pytest.raises(ValueError, match="step count"):
        parse_phase("abc=none")


# ======================================================== data: non-IID + churn
def test_dirichlet_mixture_properties():
    from repro.data import dirichlet_mixture
    mix = dirichlet_mixture(KEY, 8, 4, alpha=0.1)
    assert mix.shape == (8, 4)
    np.testing.assert_allclose(np.asarray(mix).sum(axis=1), 1.0, atol=1e-5)
    # small alpha concentrates workers on few domains
    assert float(np.mean(np.max(np.asarray(mix), axis=1))) > 0.7
    with pytest.raises(ValueError, match="alpha"):
        dirichlet_mixture(KEY, 8, 4, alpha=0.0)


def test_noniid_batch_deterministic_and_worker_major():
    from repro.data import dirichlet_mixture, make_noniid_lm_batch
    mix = dirichlet_mixture(KEY, 6, 3, alpha=0.2)
    b1 = make_noniid_lm_batch(KEY, 128, 6, 2, 16, mix)
    b2 = make_noniid_lm_batch(KEY, 128, 6, 2, 16, mix)
    assert b1["tokens"].shape == (12, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
    with pytest.raises(ValueError, match="mixture rows"):
        make_noniid_lm_batch(KEY, 128, 5, 2, 16, mix)


def test_phase_batches_freeze_stale_workers():
    sc = Scenario(name="churn", schedule=AttackSchedule(
        (AttackPhase(steps=4, stale_workers=(1, 3)),)),
        n_workers=5, f=0, gar="average", arch=SMALL, seq=16)
    from repro.sim.engine import _make_batch_gen
    batches = _phase_batches(_make_batch_gen(sc, None),
                             sc.schedule.phases[0], 0)
    toks = np.asarray(batches["tokens"])           # (steps, n, pwb, seq)
    assert toks.shape[:2] == (4, 5)
    for w in (1, 3):                               # frozen to phase entry
        for t in range(1, 4):
            np.testing.assert_array_equal(toks[t, w], toks[0, w])
    assert not np.array_equal(toks[1, 0], toks[0, 0])  # fresh worker moves


# ======================================================== adaptive attacks
def test_adaptive_lie_feedback_tunes_z():
    atk = attacks.get_adaptive("adaptive_lie:z0=2.0")
    st = atk.init_state(11, 2)
    rejected = jnp.concatenate([jnp.zeros(2), jnp.full((9,), 1.0 / 9)])
    selected = jnp.full((11,), 1.0 / 11)
    st_r = atk.update(st, rejected)
    st_s = atk.update(st, selected)
    assert float(st_r["z"]) < 2.0 < float(st_s["z"])
    G = jnp.asarray(np.random.default_rng(0).normal(size=(9, 8)),
                    jnp.float32)
    byz = atk.propose(G, 2, KEY, st)
    np.testing.assert_allclose(
        np.asarray(byz[0]),
        np.asarray(jnp.mean(G, 0) - 2.0 * jnp.std(G, 0)), rtol=1e-5)


def test_adaptive_mimic_copies_most_trusted():
    atk = attacks.get_adaptive("adaptive_mimic")
    st = atk.init_state(6, 2)
    sel = jnp.asarray([0.0, 0.0, 0.1, 0.5, 0.2, 0.2])
    st = atk.update(st, sel)
    G = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                    jnp.float32)
    byz = atk.propose(G, 2, KEY, st)               # honest argmax = index 1
    np.testing.assert_array_equal(np.asarray(byz[0]), np.asarray(G[1]))
    np.testing.assert_array_equal(np.asarray(byz[1]), np.asarray(G[1]))


def test_effective_f_counts_only_attacked_rows():
    """A phase with f=1 under a contract f=2 reports captured mass over the
    single actually-byzantine row, not the rule's contract rows."""
    sc = Scenario(name="feff", schedule=AttackSchedule(
        (AttackPhase(steps=2, attack="inf", f=1),)),
        n_workers=11, f=2, gar="average", arch=SMALL, seq=16)
    r = run_campaign(sc)
    np.testing.assert_allclose(r.trace["byz_mass"], 1.0 / 11, atol=1e-5)


def test_adaptive_campaign_runs_on_stacked_trainer():
    sc = Scenario(name="adaptive", schedule=AttackSchedule(
        (AttackPhase(steps=2, attack="none"),
         AttackPhase(steps=3, attack="adaptive_lie:z0=4.0"))),
        n_workers=11, f=2, gar="multi_bulyan", arch=SMALL, seq=16)
    r = run_campaign(sc)
    assert len(r.trace["loss"]) == 5
    assert np.all(np.isfinite(r.trace["loss"]))
    assert float(np.mean(r.trace["byz_mass"][2:])) < 0.1


# ======================================================== streaming engine
@pytest.mark.parametrize("trainer", ["stream_global", "stream_block"])
def test_streaming_campaign_rejects_inf_attack(trainer):
    sc = Scenario(name=trainer, schedule=AttackSchedule(
        (AttackPhase(steps=2, attack="none"),
         AttackPhase(steps=2, attack="inf"))),
        n_workers=11, f=2, gar="multi_bulyan", trainer=trainer,
        arch=SMALL, seq=16)
    r = run_campaign(sc)
    assert np.all(np.isfinite(r.trace["loss"]))
    assert np.all(np.isfinite(r.trace["honest_dev"]))
    # inf-magnitude proposals can never be selected, in any block
    np.testing.assert_allclose(r.trace["byz_mass"][2:], 0.0, atol=1e-6)


# ======================================================== checkpoint / resume
def test_campaign_checkpoint_resume_replays_tail(tmp_path):
    sched = AttackSchedule((AttackPhase(steps=3, attack="none"),
                            AttackPhase(steps=3,
                                        attack="little_is_enough:z=2.0")))
    # non-IID data + a stateful transform: the resume must reproduce the
    # Dirichlet assignment and restore the per-worker momentum slots
    sc = Scenario(name="resume", schedule=sched, n_workers=11, f=2,
                  gar="multi_bulyan", arch=SMALL, seq=16,
                  data=DataConfig(noniid_alpha=0.3),
                  transforms=("worker_momentum:beta=0.9",))
    d = str(tmp_path / "ck")
    full = run_campaign(sc, ckpt_dir=d)
    assert sorted(os.listdir(d)) == ["ckpt_00000003.npz", "ckpt_00000006.npz"]
    os.remove(os.path.join(d, "ckpt_00000006.npz"))
    resumed = run_campaign(sc, ckpt_dir=d, resume=True)
    assert resumed.start_step == 3
    assert len(resumed.trace["loss"]) == 3
    for k in ("loss", "honest_dev", "byz_mass"):
        np.testing.assert_allclose(resumed.trace[k], full.trace[k][3:],
                                   rtol=0, atol=1e-6, err_msg=k)
    ph = resumed.summary["phases"]
    assert len(ph) == 1 and ph[0]["attack"] == "little_is_enough:z=2.0"


def test_identical_phase_configs_hit_trace_cache():
    """C204 regression for the engine: phases sharing one (attack, f)
    config reuse a single jitted scan runner, so a 3-phase campaign
    compiles no more than the 1-phase one (pre-fix it compiled the whole
    step once per phase)."""
    from repro.analysis.jaxpr_audit import CompileCounter

    def make(n_phases):
        return Scenario(
            name=f"cache{n_phases}",
            schedule=AttackSchedule(tuple(
                AttackPhase(steps=2, attack="sign_flip")
                for _ in range(n_phases))),
            n_workers=7, f=1, gar="multi_bulyan", arch=SMALL, seq=16)

    with CompileCounter() as one:
        run_campaign(make(1))
    with CompileCounter() as three:
        run_campaign(make(3))
    assert three.count > 0
    assert three.count <= one.count, (three.count, one.count)
