import os

# Tests must see the single real CPU device (the 512-device override is
# strictly dryrun.py-local) — unless the SPMD equivalence job opts in:
# CI's spmd-host-mesh job sets REPRO_FORCED_DEVICES=1 together with
# XLA_FLAGS=--xla_force_host_platform_device_count=8 so the sharded-vs-
# single-device tests (tests/test_spmd.py) exercise real worker/model
# sharding on CPU (DESIGN.md §10).
if os.environ.get("REPRO_FORCED_DEVICES") != "1":
    assert "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
