import os

# Tests must see the single real CPU device (the 512-device override is
# strictly dryrun.py-local).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
