"""R003 fixture: registry spec strings that do not resolve."""
from repro.core import attacks as ATK
from repro.comm import codecs as CC


def bad_attack():
    return ATK.get_attack("definitely_not_an_attack")   # R003


def bad_codec_kwarg(make_step):
    return make_step(codec="qsgd:bits=nope")            # R003: bad param
