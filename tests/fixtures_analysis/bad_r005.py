"""R005 fixture: jit'd config/flag params not declared static."""
import jax


@jax.jit
def step_bad(x, use_pallas=False):       # R005: bool flag traced
    return x


@jax.jit
def mode_bad(x, mode: str = "fast"):     # R005: str config traced
    return x
