"""R000 fixture: the file must not even parse."""
def broken(:
    pass
