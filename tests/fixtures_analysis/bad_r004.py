"""R004 fixture: TrainerState accessed by positional index."""


def momentum_of(state):
    return state[2]                      # R004: index, not field name


def opt_of(tstate):
    return tstate[0]                     # R004
