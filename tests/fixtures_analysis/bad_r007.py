"""R007 fixture: host debug I/O inside jitted step functions."""
import jax


@jax.jit
def jitted_step_bad(x):
    jax.debug.print("loss = {}", x)      # R007: host round-trip per step
    return x


def make_train_step():
    def step(params, state, batch):
        print("step!", params)           # R007: bare print in a step
        jax.debug.callback(lambda v: v, state)   # R007: host callback
        return params, state, batch

    return step
