"""R001 fixture: device/jnp work at module import time."""
import jax
import jax.numpy as jnp

SCALE = jnp.sqrt(jnp.asarray(2.0))       # R001: jnp call at import
N_DEV = jax.device_count()               # R001: backend query at import
NOISE = jax.random.normal(jax.random.key(0), (4,))   # R001


def fine():
    # inside a function is fine — only import-time work is flagged
    return jnp.zeros((2,))
