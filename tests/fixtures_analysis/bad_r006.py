"""R006 fixture: blocking collective inside an async service function."""
import jax


def async_plan_loop(stack, axis_name):   # R006: psum barriers the workers
    total = jax.lax.psum(stack, axis_name)
    return total
