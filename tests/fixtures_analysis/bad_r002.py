"""R002 fixture: Python branching on tracer-valued predicates."""
import jax.numpy as jnp


def clip_bad(x, lim):
    if jnp.linalg.norm(x) > lim:         # R002: tracer in `if`
        return x * 0.5
    return x


def loop_bad(x):
    while jnp.any(x > 0):                # R002: tracer in `while`
        x = x - 1
    return x


def ternary_bad(x):
    return 0.0 if jnp.sum(x) > 1 else x  # R002: tracer in IfExp


def fine(x):
    if jnp.issubdtype(x.dtype, jnp.floating):   # static predicate: allowed
        return x
    return x.astype(jnp.float32)
