"""MoE dispatch correctness vs a naive per-expert oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import moe as E

KEY = jax.random.key(0)


def naive_moe(p, x, cfg: MoEConfig, activation: str):
    """Loop-over-experts oracle with unlimited capacity."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d).astype(jnp.float32)
    logits = xf @ p["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    out = np.zeros((t, d), np.float64)
    for tok in range(t):
        for k in range(cfg.top_k):
            e = int(ids[tok, k])
            w_in = np.asarray(p["w_in"][e], np.float64)
            w_out = np.asarray(p["w_out"][e], np.float64)
            xv = np.asarray(xf[tok], np.float64)
            if activation == "swiglu":
                g = np.asarray(p["w_gate"][e], np.float64)
                sil = (xv @ g)
                sil = sil / (1 + np.exp(-sil))
                h = sil * (xv @ w_in)
            else:
                h = np.maximum(xv @ w_in, 0.0)
            out[tok] += float(gate[tok, k]) * (h @ w_out)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("activation", ["swiglu", "relu"])
def test_moe_matches_naive_oracle(activation):
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=16,
                    capacity_factor=8.0)  # capacity high: no drops
    b, s, d = 2, 6, 8
    p = E.moe_init(KEY, d, cfg, activation)
    x = jax.random.normal(jax.random.key(1), (b, s, d), jnp.float32)
    got, aux = E.moe_apply(p, x, cfg, activation)
    want = naive_moe(p, x, cfg, activation)
    np.testing.assert_allclose(np.asarray(got, np.float32), want.astype(np.float32),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) >= 0.0


def test_capacity_drops_are_zero_contribution():
    """Overflowing tokens must contribute 0 (residual passthrough), not junk."""
    cfg = MoEConfig(n_experts=2, top_k=1, d_expert=8, capacity_factor=0.01)
    b, s, d = 1, 16, 4
    p = E.moe_init(KEY, d, cfg, "relu")
    x = jax.random.normal(jax.random.key(2), (b, s, d), jnp.float32)
    y, _ = E.moe_apply(p, x, cfg, "relu")
    assert bool(jnp.all(jnp.isfinite(y)))
    # capacity 8-min => at most 8*2 slots over 16 tokens; some rows must be 0
    nonzero_rows = int(jnp.sum(jnp.any(y.reshape(-1, d) != 0, axis=1)))
    assert nonzero_rows <= 16


def test_aux_loss_balanced_router_is_minimal():
    """Uniform routing minimises the Switch aux loss at ~weight."""
    cfg = MoEConfig(n_experts=4, top_k=1, d_expert=8, aux_loss_weight=1.0)
    d = 4
    p = E.moe_init(KEY, d, cfg, "relu")
    # zero router weights -> uniform probs -> density uniform
    p = dict(p)
    p["router"] = {"w": jnp.zeros_like(p["router"]["w"])}
    x = jax.random.normal(jax.random.key(3), (2, 32, d), jnp.float32)
    _, aux = E.moe_apply(p, x, cfg, "relu")
    # aux = w * E * sum(density/k * mean_prob) = 1 * 4 * 4*(1/4 * 1/4) = 1.0
    assert float(aux) == pytest.approx(1.0, rel=0.05)


def test_moe_gradients_flow_to_experts_and_router():
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=4.0)
    d = 8
    p = E.moe_init(KEY, d, cfg, "swiglu")
    x = jax.random.normal(jax.random.key(4), (2, 8, d), jnp.float32)

    def loss(p):
        y, aux = E.moe_apply(p, x, cfg, "swiglu")
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_in"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_out"]))) > 0
