"""repro.serve: bounded-staleness buffer semantics + microbatched serving.

The contract under test (DESIGN.md §13): admission is deterministic (the
same delivery schedule yields bitwise-identical plans), a round with more
than f overstale workers degrades to the previous covered plan, and the
staleness haircut never defends more than the contract f.  The jnp
staleness arithmetic must agree with ``core.theory.StalenessBudget`` for
every overstale count, and the async service must aggregate through the
exact same backend as the synchronous registry path.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import models as MD
from repro.configs.base import RobustConfig
from repro.core import api, theory
from repro.dist.serving import make_robust_serve_step
from repro.serve import batching as SB
from repro.serve import buffer as BUF
from repro.serve import service as SV

from helpers import reduced_cfg

KEY = jax.random.key(0)
TOL = 5e-2
N, F, TAU = 11, 2, 1


def _service(tau=TAU, f=F, needs_dists=False):
    return SV.AsyncAggService(
        backend=api.AggregatorBackend(gar="multi_bulyan", f=f,
                                      needs_dists=needs_dists),
        tau=tau)


def _grads(r, d=64):
    g = jax.random.normal(jax.random.fold_in(KEY, r), (N, d))
    # byzantine convention: rows [0, f) are the traitors
    return {"w": g.at[:F].multiply(5.0)}


def _run(svc, schedule, d=64):
    """Replay a delivery schedule through the jitted round; collect all."""
    rnd = jax.jit(lambda s, g, fr: svc.round(s, g, fr))
    state = svc.init_state(_grads(0, d))
    out = []
    for r, fresh in enumerate(schedule):
        agg, state, info = rnd(state, _grads(r, d),
                               jnp.asarray(fresh, jnp.bool_))
        out.append((agg, state, info))
    return out


# ===================================================== admission semantics
def test_staleness_admission_determinism():
    """Same schedule ⇒ bitwise-identical aggregates AND plans."""
    rng = np.random.default_rng(7)
    schedule = [rng.random(N) < 0.7 for _ in range(6)]
    a = _run(_service(), schedule)
    b = _run(_service(), schedule)
    for (agg_a, st_a, _), (agg_b, st_b, _) in zip(a, b):
        assert np.array_equal(np.asarray(agg_a["w"]), np.asarray(agg_b["w"]))
        assert np.array_equal(np.asarray(st_a.plan.weights),
                              np.asarray(st_b.plan.weights))


def test_all_stale_round_degrades_to_previous_plan():
    """Past tau the round is inadmissible: the previous plan is reused.

    With tau=1 a single missed round (age 1) is still admissible — the
    whole point of bounded staleness — so degradation takes tau+1
    consecutive all-stale rounds.
    """
    fresh = [True] * N
    stale = [False] * N
    out = _run(_service(), [fresh, stale, stale])
    (_, _, i1), (agg2, st2, i2), (agg3, st3, i3) = out
    assert not bool(i1["plan_reused"])
    assert not bool(i2["plan_reused"])          # age 1 <= tau: admissible
    assert bool(i3["plan_reused"])              # age 2 > tau for all n > f
    assert int(i3["n_overstale"]) == N
    assert int(i3["f_defended"]) == 0
    # degraded plan IS the previous plan, and (buffer unchanged) so is agg
    assert np.array_equal(np.asarray(st3.plan.weights),
                          np.asarray(st2.plan.weights))
    assert np.array_equal(np.asarray(agg3["w"]), np.asarray(agg2["w"]))


def test_late_worker_enters_next_plan():
    """A straggler's slot keeps serving its old gradient until it delivers;
    its next delivery refreshes the slot (admitted into the *next* plan)."""
    svc = _service()
    miss = np.ones(N, bool)
    miss[-1] = False                           # worker n-1 misses round 1
    out = _run(svc, [np.ones(N, bool), miss, np.ones(N, bool)])
    _, st1, _ = out[0]
    _, st2, i2 = out[1]
    _, st3, i3 = out[2]
    # missed round: slot still holds the round-0 gradient, age ticks to 1
    assert np.array_equal(np.asarray(st2.grads["w"][-1]),
                          np.asarray(st1.grads["w"][-1]))
    assert int(st2.age[-1]) == 1 and int(i2["n_overstale"]) == 0
    # delivery: slot refreshed, age reset
    assert np.array_equal(np.asarray(st3.grads["w"][-1]),
                          np.asarray(_grads(2)["w"][-1]))
    assert int(st3.age[-1]) == 0 and int(i3["n_overstale"]) == 0


def test_effective_f_haircut_never_exceeds_contract():
    """jnp staleness arithmetic == theory.StalenessBudget for every k."""
    budget = theory.staleness_budget(N, F, TAU)
    for k in range(N + 1):
        age = jnp.full((N,), TAU + 1, jnp.int32).at[: N - k].set(0)
        info = BUF.staleness_info(age, tau=TAU, f=F)
        assert int(info["n_overstale"]) == k
        assert int(info["f_defended"]) == budget.f_defended(k)
        assert bool(info["admissible"]) == budget.admissible(k)
        assert 0 <= int(info["f_defended"]) <= F


def test_service_budget_gates_infeasible_pairs():
    svc = _service()
    assert svc.budget(N).f == F
    with pytest.raises(ValueError):
        svc.budget(F * 4 + 2)                  # multi_bulyan needs 4f+3
    with pytest.raises(ValueError):
        SV.AsyncAggService(backend=svc.backend, tau=-1)


def test_all_fresh_round_matches_registry_aggregate():
    """The async service on an all-fresh round IS the sync aggregator."""
    out = _run(_service(), [np.ones(N, bool)])
    agg, _, info = out[0]
    want = api.aggregate_tree(_grads(0), F, "multi_bulyan")
    assert np.array_equal(np.asarray(agg["w"]), np.asarray(want["w"]))
    assert int(info["f_defended"]) == F and not bool(info["plan_reused"])


# ==================================================== microbatched serving
def test_microbatch_fuses_per_lane_positions():
    """One plan/apply over the (n, B, V) stack == per-lane manual decode +
    the same backend; padded lanes contribute zeros."""
    cfg = reduced_cfg("qwen2-1.5b")
    rcfg = RobustConfig(n_workers=7, f=1)
    backend = api.AggregatorBackend.for_config(rcfg)
    n, B = rcfg.n_workers, 3
    lane_seq = [8, 12, 8]                       # per-request positions
    cache_len = 16

    params = [MD.init_model(jax.random.fold_in(KEY, i), cfg)
              for i in range(n)]
    stacked_params = jax.tree.map(lambda *xs: jnp.stack(xs), *params)

    lane_caches = []                            # [replica][lane] at B=1
    for i in range(n):
        row = []
        for b, seq in enumerate(lane_seq):
            batch = MD.make_batch(cfg, "prefill", 1, seq,
                                  key=jax.random.fold_in(KEY, 100 + b))
            _, c = MD.prefill_fn(params[i], cfg, batch, chunk_q=seq,
                                 cache_len=cache_len)
            row.append(c)
        lane_caches.append(row)
    # lanes concat on the cache batch axis (dim 1), replicas stack on dim 0
    per_replica = [jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                                *row) for row in lane_caches]
    stacked_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_replica)

    toks = [3, 5]                               # 2 live requests + 1 pad
    rb = SB.pack_requests(toks, lane_seq[:2], size=B)
    step = SB.make_microbatch_serve_step(cfg, rcfg, backend=backend)
    fused, new_caches = step(stacked_params, stacked_caches, rb)
    assert fused.shape == (B, cfg.vocab_size)

    # manual reference: per-replica per-lane B=1 decode, then one fuse
    manual = np.zeros((n, B, cfg.vocab_size), np.float32)
    for i in range(n):
        for b in range(B):
            lane = jax.tree.map(lambda x: x[i, :, b:b + 1],
                                stacked_caches)
            logits, _ = MD.decode_fn(params[i], cfg,
                                     jnp.asarray([int(rb.tokens[b])]),
                                     lane, rb.pos[b])
            manual[i, b] = np.asarray(logits[0], np.float32)
    manual *= np.asarray(rb.active, np.float32)[None, :, None]
    want = backend(jnp.asarray(manual))
    np.testing.assert_allclose(np.asarray(fused, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL, rtol=0)
    # padded lane's logits were zeroed before fusing
    assert not bool(rb.active[2])


def test_microbatch_agrees_with_robust_serve_step_at_uniform_pos():
    """At a uniform position the microbatch path and the batched robust
    serve step are the same computation through the same backend."""
    cfg = reduced_cfg("qwen2-1.5b")
    rcfg = RobustConfig(n_workers=7, f=1)
    backend = api.AggregatorBackend.for_config(rcfg)
    n, B, seq = rcfg.n_workers, 2, 8

    params = [MD.init_model(jax.random.fold_in(KEY, i), cfg)
              for i in range(n)]
    stacked_params = jax.tree.map(lambda *xs: jnp.stack(xs), *params)
    batch = MD.make_batch(cfg, "prefill", B, seq, key=KEY)
    caches = [MD.prefill_fn(p, cfg, batch, chunk_q=seq, cache_len=16)[1]
              for p in params]
    stacked_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    toks = [3, 5]
    robust = make_robust_serve_step(cfg, rcfg, backend=backend)
    want, _ = robust(stacked_params, stacked_caches,
                     jnp.asarray(toks, jnp.int32), jnp.int32(seq))

    rb = SB.pack_requests(toks, [seq] * B, size=B)
    micro = SB.make_microbatch_serve_step(cfg, rcfg, backend=backend)
    got, _ = micro(stacked_params, stacked_caches, rb)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL, rtol=0)


def test_pack_requests_validation():
    rb = SB.pack_requests([1, 2], [0, 3], size=4)
    assert rb.size == 4
    assert np.asarray(rb.active).tolist() == [True, True, False, False]
    with pytest.raises(ValueError):
        SB.pack_requests([1, 2, 3], [0, 1, 2], size=2)
    with pytest.raises(ValueError):
        SB.pack_requests([1, 2], [0], size=4)
