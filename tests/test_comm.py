"""repro.comm: codec laws, the fused dequantize→stats kernel, wire attacks,
trainer integration and the codec regression on the sim acceptance scenario.

Codec laws are property-style via tests/_mini_hypothesis.py (the container
has no hypothesis): round-trip identity for identity/bf16, unbiasedness of
QSGD stochastic rounding (mean over keys), top-k norm retention, and the
error-feedback residual telescoping identity.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.comm import codecs as CC
from repro.comm import transport as TP
from repro.core import api, attacks
from repro.kernels import ops as kops

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container path
    from _mini_hypothesis import given, settings, strategies as st

KEY = jax.random.key(0)


def _tree(n, seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(n, 6, 9)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(n, 77)), jnp.float32)}}


@st.composite
def _stack_shape(draw):
    return draw(st.integers(3, 12)), draw(st.integers(1, 90))


# ============================================================== codec laws
@settings(max_examples=10)
@given(_stack_shape())
def test_identity_and_bf16_round_trip(shape):
    """identity is exact on fp32; bf16 is exact on bf16-representable
    values (the encode→decode→encode fixed point)."""
    n, m = shape
    rng = np.random.default_rng(n * 100 + m)
    g = {"w": jnp.asarray(rng.normal(size=(n, m)), jnp.float32)}
    enc, _ = CC.get_codec("identity").encode(g)
    np.testing.assert_array_equal(
        np.asarray(CC.get_codec("identity").decode(enc)["w"]),
        np.asarray(g["w"]))
    gb = jax.tree.map(lambda x: x.astype(jnp.bfloat16).astype(jnp.float32), g)
    c = CC.get_codec("bf16")
    enc, _ = c.encode(gb)
    np.testing.assert_array_equal(np.asarray(c.decode(enc)["w"]),
                                  np.asarray(gb["w"]))


@settings(max_examples=5)
@given(st.integers(2, 8))
def test_qsgd_unbiased_over_keys(bits):
    """E[decode(encode(g))] = g: the stochastic rounding mean over many
    keys converges to the input coordinate-wise."""
    rng = np.random.default_rng(bits)
    g = {"w": jnp.asarray(rng.normal(size=(5, 40)), jnp.float32)}
    c = CC.get_codec(f"qsgd:bits={bits}")
    n_keys = 300
    acc = np.zeros((5, 40), np.float64)
    for i in range(n_keys):
        enc, _ = c.encode(g, key=jax.random.fold_in(KEY, i))
        acc += np.asarray(c.decode(enc)["w"], np.float64)
    # per-coordinate quantization step is scale/levels; the mean of n_keys
    # draws concentrates within ~3 standard errors of that step
    step = np.asarray(jnp.max(jnp.abs(g["w"]), axis=1))[:, None] / c.levels
    tol = np.broadcast_to(3.0 * step / np.sqrt(n_keys) + 1e-6, (5, 40))
    np.testing.assert_array_less(np.abs(acc / n_keys - np.asarray(g["w"])),
                                 tol)


@settings(max_examples=10)
@given(_stack_shape())
def test_topk_norm_retention(shape):
    """Top-k keeps exactly the k largest-magnitude coordinates per row, so
    the decoded row retains >= k/m of the squared-norm mass and matches
    the exact top-k energy."""
    n, m = shape
    rng = np.random.default_rng(n * 7 + m)
    x = rng.normal(size=(n, m)).astype(np.float32)
    c = CC.get_codec("topk:frac=0.25")
    k = c.row_k(m)
    enc, _ = c.encode({"w": jnp.asarray(x)})
    dec = np.asarray(c.decode(enc)["w"])
    want = np.sort(x ** 2, axis=1)[:, ::-1][:, :k].sum(axis=1)
    np.testing.assert_allclose((dec ** 2).sum(axis=1), want, rtol=1e-5)
    total = (x ** 2).sum(axis=1)
    assert np.all((dec ** 2).sum(axis=1) >= (k / m) * total - 1e-5)


@pytest.mark.parametrize("spec", ["signsgd:ef=1", "topk:frac=0.1,ef=1",
                                  "qsgd:bits=4,ef=1"])
def test_error_feedback_telescopes(spec):
    """sum_t decode_t + e_T = sum_t g_t: the residual chain telescopes, so
    compression error does not accumulate across steps."""
    c = CC.get_codec(spec)
    assert c.stateful
    rng = np.random.default_rng(3)
    gs = [{"w": jnp.asarray(rng.normal(size=(4, 30)), jnp.float32)}
          for _ in range(6)]
    res = c.init_residual(gs[0])
    sent = np.zeros((4, 30), np.float64)
    total = np.zeros((4, 30), np.float64)
    for t, g in enumerate(gs):
        enc, res = c.encode(g, key=jax.random.fold_in(KEY, t), residual=res)
        sent += np.asarray(c.decode(enc)["w"], np.float64)
        total += np.asarray(g["w"], np.float64)
    np.testing.assert_allclose(sent + np.asarray(res["w"], np.float64),
                               total, atol=1e-3)


def test_stateless_codec_rejects_missing_residual_only_when_ef():
    g = _tree(5)
    CC.get_codec("bf16").encode(g)                 # stateless: fine
    with pytest.raises(ValueError, match="residual"):
        CC.get_codec("bf16:ef=1").encode(g)


# ================================================== container + accounting
def test_wire_bytes_ordering_and_container():
    g = _tree(11)
    sizes = {}
    for spec in ("fp32", "bf16", "qsgd:bits=8", "signsgd"):
        enc, _ = CC.get_codec(spec).encode(g, key=KEY)
        assert enc.n == 11
        assert enc.wire_bytes == 11 * enc.bytes_per_worker
        sizes[spec] = enc.wire_bytes
    assert sizes["fp32"] > sizes["bf16"] > sizes["qsgd:bits=8"] \
        > sizes["signsgd"]


def test_transport_wire_stats_params_vs_encoded():
    """Shape-only accounting from a param tree == exact accounting off the
    encoded container, including the chunked-gather schedule."""
    params = {"w": jnp.zeros((40, 30)), "b": jnp.zeros((30,))}
    ws = TP.wire_stats("qsgd:bits=8", params, n=11, chunk_bytes=1024)
    g = jax.tree.map(lambda x: jnp.zeros((11,) + x.shape, jnp.float32),
                     params)
    enc, _ = CC.get_codec("qsgd:bits=8").encode(g, key=KEY)
    assert ws.bytes_per_worker == enc.bytes_per_worker
    assert ws.total_bytes == enc.wire_bytes
    assert ws.chunks_per_worker == -(-ws.bytes_per_worker // 1024)
    assert ws.compression > 3.5
    js = ws.to_json()
    assert js["codec"] == "qsgd" and js["n_workers"] == 11
    # the container-side entry point must agree with the shape-only one
    gs = TP.gather_stats(enc, chunk_bytes=1024)
    assert gs.bytes_per_worker == ws.bytes_per_worker
    assert gs.fp32_bytes_per_worker == ws.fp32_bytes_per_worker
    assert gs.to_json() == js


def test_codec_spec_errors():
    with pytest.raises(KeyError, match="unknown codec"):
        CC.get_codec("zstd")
    with pytest.raises(ValueError, match="no parameter"):
        CC.get_codec("bf16:bits=8")
    with pytest.raises(ValueError, match="bits"):
        CC.get_codec("qsgd:bits=9")
    with pytest.raises(ValueError, match="frac"):
        CC.get_codec("topk:frac=0")
    with pytest.raises(ValueError, match="PRNG key"):
        CC.get_codec("qsgd").encode(_tree(4))


# ============================== fused dequantize→stats kernel (acceptance)
# PR-2 edge-shape grid: n not a multiple of 8, d not a multiple of 128
# (and below the d_tile), plus d=1 and a multi-tile width.
EDGE_NS = (7, 11, 15)
EDGE_DS = (1, 100, 257)


@pytest.mark.parametrize("spec", ["bf16", "qsgd:bits=8", "signsgd"])
@pytest.mark.parametrize("n", EDGE_NS)
@pytest.mark.parametrize("d", EDGE_DS)
def test_dequant_stats_bitwise_vs_decode_reference(spec, n, d):
    """The fused kernel == decode-then-pairwise_stats, bit for bit, in
    interpret mode on the edge-shape grid."""
    rng = np.random.default_rng(n * 1000 + d)
    g = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = CC.get_codec(spec)
    enc, _ = c.encode(g, key=KEY)
    payload, mult = c.dequant_form(jax.tree.leaves(enc.payload)[0],
                                   jax.tree.leaves(enc.sidecar)[0]
                                   if enc.sidecar is not None else None)
    dd, sq = kops.dequant_stats(payload, mult)
    dec = c.decode(enc)
    dd_ref, sq_ref = kops.pairwise_stats(dec.reshape(n, d))
    np.testing.assert_array_equal(np.asarray(dd), np.asarray(dd_ref))
    np.testing.assert_array_equal(np.asarray(sq), np.asarray(sq_ref))


@pytest.mark.parametrize("spec", ["bf16", "qsgd:bits=8", "topk:frac=0.2"])
@pytest.mark.parametrize("n,d", [(7, 100), (11, 257)])
def test_encoded_compute_stats_matches_decoded(spec, n, d):
    """core.api.compute_stats on the wire container == on the decoded
    stack, on both substrates; aggregate_tree accepts the container."""
    rng = np.random.default_rng(n + d)
    tree = {"a": jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n, 5, 7)), jnp.float32)}
    c = CC.get_codec(spec)
    enc, _ = c.encode(tree, key=KEY)
    dec = c.decode(enc)
    f = 1
    for up in (False, True):
        se = api.compute_stats(enc, f, needs_dists=True, needs_norms=True,
                               use_pallas=up)
        sd = api.compute_stats(dec, f, needs_dists=True, needs_norms=True,
                               use_pallas=up)
        np.testing.assert_array_equal(np.asarray(se.dists),
                                      np.asarray(sd.dists))
        np.testing.assert_array_equal(np.asarray(se.sq_norms),
                                      np.asarray(sd.sq_norms))
    oe = api.aggregate_tree(enc, f, "multi_bulyan")
    od = api.aggregate_tree(dec, f, "multi_bulyan")
    for a, b in zip(jax.tree.leaves(oe), jax.tree.leaves(od)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_encoded_grads_is_a_jit_pytree():
    tree = _tree(7)
    c = CC.get_codec("qsgd:bits=8")
    enc, _ = c.encode(tree, key=KEY)
    out = jax.jit(lambda e: api.aggregate_tree(e, 1, "multi_bulyan"))(enc)
    ref = api.aggregate_tree(enc, 1, "multi_bulyan")
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ========================================================== wire attacks
def _honest_stack(n_honest, d=60, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((np.ones(d) + 0.05 * rng.normal(
        size=(n_honest, d))).astype(np.float32))


@pytest.mark.parametrize("wa", ["scale_poison:gain=100", "payload_flip"])
def test_wire_attacks_rejected_by_multi_bulyan(wa):
    """On a tight honest cluster the decoded wire-attack rows are far
    outliers: multi-Bulyan must give them zero selection mass."""
    from repro.dist.trainer import inject_wire
    n, f = 11, 2
    G = jnp.concatenate([_honest_stack(f), _honest_stack(n - f, seed=1)])
    c = CC.get_codec("qsgd:bits=8")
    enc, _ = c.encode(G, key=KEY)
    enc = inject_wire(enc, f, wa, KEY)
    stats = api.compute_stats(enc, f, needs_dists=True)
    plan = api.get_aggregator("multi_bulyan").plan(stats)
    diag = plan.diagnostics(stats)
    assert float(diag["byz_mass"]) < 1e-6
    # and averaging is captured by construction (uniform mass)
    avg_diag = api.get_aggregator("average").plan(stats).diagnostics(stats)
    np.testing.assert_allclose(float(avg_diag["byz_mass"]), f / n, atol=1e-5)


def test_scale_poison_decodes_to_outlier():
    """The poisoned sidecar multiplies through the decode: byz rows sit
    -gain× along an honest row, while their payloads look honest."""
    from repro.dist.trainer import inject_wire
    n, f, gain = 7, 2, 50.0
    G = jnp.concatenate([_honest_stack(f), _honest_stack(n - f, seed=1)])
    c = CC.get_codec("qsgd:bits=8")
    enc, _ = c.encode(G, key=KEY)
    poisoned = inject_wire(enc, f, f"scale_poison:gain={gain}", KEY)
    # payload rows are copied from the first honest worker (wire-legal)
    np.testing.assert_array_equal(np.asarray(poisoned.payload[0]),
                                  np.asarray(poisoned.payload[f]))
    dec = c.decode(poisoned)
    honest0 = np.asarray(c.decode(enc))[f]
    np.testing.assert_allclose(np.asarray(dec[0]), -gain * honest0,
                               rtol=1e-5)


def test_wire_attack_spec_validation():
    with pytest.raises(KeyError, match="unknown wire attack"):
        attacks.get_wire_attack("garbage")
    with pytest.raises(ValueError, match="no parameter"):
        attacks.get_wire_attack("payload_flip:gain=2")
    assert attacks.is_wire_attack("scale_poison:gain=3")
    assert not attacks.is_wire_attack("sign_flip")


def test_wire_attack_requires_codec():
    from repro.configs.base import ArchConfig, RobustConfig
    from repro.dist import make_train_step
    from repro.optim import sgd, constant
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128)
    rcfg = RobustConfig(n_workers=11, f=2, gar="multi_bulyan")
    with pytest.raises(ValueError, match="codec"):
        make_train_step(cfg, rcfg, sgd(), constant(0.1),
                        attack="scale_poison")


# ==================================================== trainer integration
SMALL_ARCH = None


def _small_arch():
    global SMALL_ARCH
    if SMALL_ARCH is None:
        from repro.configs.base import ArchConfig
        SMALL_ARCH = ArchConfig(name="comm-test", family="dense",
                                n_layers=2, d_model=64, n_heads=4,
                                n_kv_heads=2, d_ff=128, vocab_size=128)
    return SMALL_ARCH


@pytest.mark.parametrize("codec,attack",
                         [("bf16", "sign_flip"),
                          ("qsgd:bits=8", "scale_poison:gain=50")])
def test_stacked_vs_streaming_bit_parity_under_codec(codec, attack):
    """The leaf-offset encode-key convention: per-block encode + wire
    injection reproduces the stacked trainer bit for bit."""
    from repro.configs.base import RobustConfig
    from repro.data import make_lm_batch
    from repro.dist import make_train_step, split_workers
    from repro.dist.streaming import make_streaming_train_step
    from repro import models as MD
    from repro.optim import sgd, constant
    cfg = _small_arch()
    n = 11
    params = MD.init_model(KEY, cfg)
    opt = sgd(momentum=0.9)
    batch = split_workers(make_lm_batch(KEY, 128, n * 2, 16, seed=7), n)
    rcfg = RobustConfig(n_workers=n, f=2, gar="multi_bulyan")
    stacked = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.05),
                                      chunk_q=16, attack=attack,
                                      codec=codec, telemetry=True))
    stream = jax.jit(make_streaming_train_step(
        cfg, rcfg, opt, constant(0.05), scope="global", chunk_q=16,
        attack=attack, codec=codec, telemetry=True))
    from repro.dist import init_train_state
    state = init_train_state(opt, params)
    ps, _, ms = stacked(params, state, batch, KEY)
    pg, _, mg = stream(params, state, batch, KEY)
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pg)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(ms["telemetry"]["selection"]),
        np.asarray(mg["telemetry"]["selection"]))
    assert float(ms["telemetry"]["wire_bytes_per_worker"]) == \
        float(mg["telemetry"]["wire_bytes_per_worker"]) > 0


def test_error_feedback_state_threads_through_trainer():
    """An ef=1 codec fills the TrainerState ``cres`` slot; the residual
    becomes nonzero after one lossy step."""
    from repro.configs.base import RobustConfig
    from repro.data import make_lm_batch
    from repro.dist import init_train_state, make_train_step, split_workers
    from repro import models as MD
    from repro.optim import sgd, constant
    cfg = _small_arch()
    n = 11
    params = MD.init_model(KEY, cfg)
    opt = sgd(momentum=0.9)
    codec = "topk:frac=0.05,ef=1"
    state = init_train_state(opt, params, n_workers=n, codec=codec)
    assert state.cres is not None
    assert all(float(jnp.max(jnp.abs(x))) == 0.0
               for x in jax.tree.leaves(state.cres))
    rcfg = RobustConfig(n_workers=n, f=2, gar="multi_bulyan")
    step = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.05),
                                   chunk_q=16, codec=codec))
    batch = split_workers(make_lm_batch(KEY, 128, n * 2, 16, seed=7), n)
    _, state2, _ = step(params, state, batch, KEY)
    assert any(float(jnp.max(jnp.abs(x))) > 0.0
               for x in jax.tree.leaves(state2.cres))


def test_streaming_rejects_error_feedback_codec():
    from repro.configs.base import RobustConfig
    from repro.dist.streaming import make_streaming_train_step
    from repro.optim import sgd, constant
    rcfg = RobustConfig(n_workers=11, f=2, gar="multi_bulyan")
    with pytest.raises(NotImplementedError, match="error-feedback"):
        make_streaming_train_step(_small_arch(), rcfg, sgd(),
                                  constant(0.1), codec="signsgd:ef=1")


# ========================= regression: selection preserved under codecs
# (satellite: the PR-3 acceptance scenario must keep multi_bulyan's
# selection under bf16 and qsgd:bits=8 wires)
def _attacked_stats(codec=None, rule_f=2, n=11, d=50,
                    attack="little_is_enough:z=4.0"):
    rng = np.random.default_rng(0)
    correct = (np.ones(d) + 0.1 * rng.normal(size=(n - rule_f, d))
               ).astype(np.float32)
    G = attacks.apply_attack(jnp.asarray(correct), rule_f, attack, KEY)
    if codec is None:
        return api.compute_stats(G, rule_f, needs_dists=True)
    enc, _ = CC.get_codec(codec).encode(G, key=KEY)
    return api.compute_stats(enc, rule_f, needs_dists=True)


def test_plan_selection_preserved_under_codecs():
    """bf16 must reproduce the fp32 selection support exactly on the
    attacked reference stack; qsgd:bits=8 must keep byzantine mass at 0
    (quantization noise may permute near-tied honest rows)."""
    ref = api.get_aggregator("multi_bulyan").plan(_attacked_stats())
    ref_sel = np.asarray(ref.selection_weights()) > 0
    for codec in ("bf16", "qsgd:bits=8"):
        stats = _attacked_stats(codec)
        plan = api.get_aggregator("multi_bulyan").plan(stats)
        sel = np.asarray(plan.selection_weights())
        assert float(np.sum(sel[:2])) < 1e-6, codec
        if codec == "bf16":
            np.testing.assert_array_equal(sel > 0, ref_sel)


@pytest.mark.parametrize("codec", ["bf16", "qsgd:bits=8"])
def test_switch_campaign_bounded_under_codec(codec):
    """The PR-3 acceptance switch scenario over a compressed wire:
    multi-Bulyan's post-switch honest-mean deviation (measured against
    the *decoded* stack the rule consumed) stays within the acceptance
    bound max < 2.0 with < 2% byzantine selection — the documented
    tolerance: quantization must not widen the acceptance thresholds.
    Per-phase WireStats must land in the summary."""
    from repro.sim import run_campaign, switch_scenario
    sc = switch_scenario("multi_bulyan", pre=8, post=8, codec=codec)
    r = run_campaign(sc)
    post = slice(8, 16)
    assert float(np.max(r.trace["honest_dev"][post])) < 2.0
    assert float(np.mean(r.trace["byz_mass"][post])) < 0.02
    assert r.wire is not None and r.wire["bytes_per_worker"] > 0
    for ph in r.summary["phases"]:
        assert ph["wire"] == r.wire
    np.testing.assert_allclose(
        r.trace["wire_bytes_per_worker"],
        float(r.wire["bytes_per_worker"]), rtol=1e-6)


def test_scenario_codec_validation():
    from repro.sim import AttackPhase, AttackSchedule, Scenario
    sched = AttackSchedule((AttackPhase(steps=2),))
    with pytest.raises(KeyError, match="unknown codec"):
        Scenario(name="x", schedule=sched, codec="zstd")
    with pytest.raises(ValueError, match="needs a codec"):
        Scenario(name="x", schedule=AttackSchedule(
            (AttackPhase(steps=2, attack="scale_poison"),)))
    with pytest.raises(ValueError, match="trainer='stacked'"):
        Scenario(name="x", schedule=sched, codec="signsgd:ef=1",
                 trainer="stream_block")
    sc = Scenario(name="x", schedule=sched, codec="qsgd:bits=8")
    assert sc.to_json()["codec"] == "qsgd:bits=8"


# ===================================================== bench schema gate
def test_validate_bench_comm_schema(tmp_path):
    import json
    from benchmarks.validate_bench import check
    good = {
        "schema": "comm.v1",
        "results": {
            c: {k: {"wire_bytes": wb, "bytes_per_worker": wb // 11,
                    "us_per_call": 10.0, "ratio_vs_fp32": 4.0}
                for k, wb in (("n=11,d=100", base), ("n=11,d=200", 2 * base))}
            for c, base in (("fp32", 4400), ("bf16", 2200),
                            ("qsgd:bits=8", 1144))
        },
    }
    p = tmp_path / "BENCH_comm.json"
    p.write_text(json.dumps(good))
    assert check(str(p)) == []
    bad = json.loads(json.dumps(good))
    bad["results"]["bf16"]["n=11,d=100"]["wire_bytes"] = 9999
    p.write_text(json.dumps(bad))
    assert any("strictly ordered" in pr for pr in check(str(p)))
    del bad["results"]["fp32"]
    p.write_text(json.dumps(bad))
    assert any("missing required codec" in pr for pr in check(str(p)))


def test_validate_bench_accuracy_schema(tmp_path):
    import json
    from benchmarks.validate_bench import check
    good = {"schema": "accuracy.v1",
            "results": {r: {"b=5": {"acc_mean": 0.8, "acc_std": 0.01}}
                        for r in ("average", "multi_bulyan")}}
    p = tmp_path / "BENCH_accuracy.json"
    p.write_text(json.dumps(good))
    assert check(str(p)) == []
    good["results"]["average"]["b=5"]["acc_mean"] = 1.5
    p.write_text(json.dumps(good))
    assert any("outside [0, 1]" in pr for pr in check(str(p)))
