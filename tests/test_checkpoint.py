"""checkpoint/store round-trip contract: structure, dtypes, latest_step.

The sim engine's phase-boundary resume (tests/test_sim.py) is built on
these invariants — nested pytree structure is restored exactly and every
dtype (including the npz-unserialisable bfloat16 via bit-views) survives.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save


def _nested_tree():
    return {
        "params": {
            "embed": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "layers": [
                {"w": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
                 "b": jnp.zeros((2,), jnp.float32)},
                {"w": jnp.full((2, 2), -2.25, jnp.bfloat16),
                 "b": jnp.ones((2,), jnp.float32)},
            ],
        },
        "step": jnp.asarray(7, jnp.int32),
        "scales": (jnp.asarray([0.5, 0.25], jnp.float32),
                   jnp.asarray(3, jnp.int32)),
    }


def test_roundtrip_nested_pytree_preserves_values_and_dtypes(tmp_path):
    tree = _nested_tree()
    d = str(tmp_path)
    path = save(d, 5, tree)
    assert path.endswith("ckpt_00000005.npz")
    out = restore(d, 5, jax.tree.map(jnp.zeros_like, tree))
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bfloat16_bits_survive(tmp_path):
    # values that are NOT exactly representable in fp16/fp32 roundtrips:
    # exercise the uint16 bit-view path rather than a numeric cast
    vals = jnp.asarray([1.0 / 3.0, np.pi, -1e-20, 3e38], jnp.bfloat16)
    d = str(tmp_path)
    save(d, 1, {"x": vals})
    out = restore(d, 1, {"x": jnp.zeros_like(vals)})
    assert out["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["x"]).view(np.uint16),
        np.asarray(vals).view(np.uint16))


def test_latest_step_and_missing_dir(tmp_path):
    d = str(tmp_path / "ck")
    assert latest_step(d) is None
    save(d, 3, {"x": jnp.ones(2)})
    save(d, 12, {"x": jnp.ones(2)})
    save(d, 7, {"x": jnp.ones(2)})
    assert latest_step(d) == 12


def test_restore_validates_structure(tmp_path):
    d = str(tmp_path)
    save(d, 2, {"a": jnp.ones((2, 2)), "b": jnp.zeros(3)})
    with pytest.raises(KeyError, match="missing key"):
        restore(d, 2, {"a": jnp.ones((2, 2)), "c": jnp.zeros(3)})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(d, 2, {"a": jnp.ones((2, 3)), "b": jnp.zeros(3)})


def test_optimizer_state_roundtrip(tmp_path):
    """OptState NamedTuples (the engine's checkpoint payload) round-trip."""
    from repro.optim import sgd

    opt = sgd(momentum=0.9)
    params = {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))}
    st = opt.init(params)
    new_p, st = opt.update(jax.tree.map(jnp.ones_like, params), st, params,
                           0.1)
    d = str(tmp_path)
    save(d, 1, {"opt": st, "params": new_p})
    like = {"opt": opt.init(params), "params": params}
    out = restore(d, 1, like)
    assert int(out["opt"].step) == 1
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves({"opt": st,
                                                           "params": new_p})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
