"""Per-architecture smoke tests (deliverable f).

Every assigned architecture, as a REDUCED same-family variant (2 layers,
d_model<=256, <=4 experts), runs one forward and one robust train step on
CPU; output shapes and finiteness are asserted.  The FULL configs are
exercised by launch/dryrun.py (lowering only).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, RobustConfig, get_config
from repro import models as MD
from repro.dist import init_train_state, make_train_step, split_workers
from repro.data import lm_batches
from repro.optim import sgd, constant

from helpers import reduced_cfg

KEY = jax.random.key(0)
SEQ, BATCH = 32, 2


def _batch_for(cfg, kind, batch, seq):
    return MD.make_batch(cfg, kind, batch, seq, key=KEY)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_values(name):
    cfg = get_config(name)
    assert cfg.name == name
    assert cfg.param_count() > 0
    assert cfg.source


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = reduced_cfg(name)
    params = MD.init_model(KEY, cfg)
    b = _batch_for(cfg, "prefill", BATCH, SEQ)
    logits = MD.forward_fn(params, cfg, b, chunk_q=16, logits_tail=1)
    assert logits.shape[0] == BATCH and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_loss_and_grad_finite(name):
    cfg = reduced_cfg(name)
    params = MD.init_model(KEY, cfg)
    b = _batch_for(cfg, "train", BATCH, SEQ)
    loss, grads = jax.value_and_grad(
        lambda p: MD.loss_fn(p, cfg, b, chunk_q=16))(params)
    assert bool(jnp.isfinite(loss))
    assert loss > 0
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_robust_train_step(name):
    cfg = reduced_cfg(name)
    n, f = 11, 2
    rcfg = RobustConfig(n_workers=n, f=f, gar="multi_bulyan")
    params = MD.init_model(KEY, cfg)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)
    step = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.01), chunk_q=16))
    batch = _batch_for(cfg, "train", n * BATCH, SEQ)
    wb = split_workers(batch, n)
    new_params, new_state, metrics = step(params, state, wb, KEY)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert metrics["loss_per_worker"].shape == (n,)
    assert int(new_state.opt.step) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert moved


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_analytic_matches_actual(name):
    """ArchConfig.param_count() (used for roofline MODEL_FLOPS) must match
    the materialised reduced model exactly."""
    cfg = reduced_cfg(name)
    params = MD.init_model(KEY, cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert actual == cfg.param_count(), (actual, cfg.param_count())
