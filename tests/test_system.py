"""End-to-end system behaviour: the paper's full story on a real model.

Train a small LM with byzantine workers present under a strong attack and
assert the robust GAR defends while plain averaging fails — Definition 1
made executable — plus attacks/sharding/dryrun plumbing sanity.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RobustConfig
from repro.core import attacks
from repro.data import lm_batches
from repro.dist import init_train_state, make_train_step, split_workers
from repro.dist.sharding import param_specs, sanitize_spec
from repro import models as MD
from repro.optim import sgd, constant

KEY = jax.random.key(0)
CFG = ArchConfig(name="sys-t", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128)


def _train(gar, attack, steps=16, n=11, f=2):
    rcfg = RobustConfig(n_workers=n, f=f, gar=gar)
    params = MD.init_model(KEY, CFG)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)
    step = jax.jit(make_train_step(CFG, rcfg, opt, constant(0.05),
                                   chunk_q=16, attack=attack))
    it = lm_batches(CFG.vocab_size, n * 2, 16, seed=11)
    losses = []
    for i in range(steps):
        b = split_workers(next(it), n)
        params, state, m = step(params, state, b, jax.random.fold_in(KEY, i))
        losses.append(float(m["loss"]))
    return losses


def test_end_to_end_byzantine_defence():
    clean = _train("multi_bulyan", "none")
    attacked = _train("multi_bulyan", "inf")
    broken = _train("average", "inf")
    # robust training converges with or without the attack
    assert clean[-1] < clean[0]
    assert np.isfinite(attacked[-1]) and attacked[-1] < attacked[0] + 0.1
    # averaging under the same attack does not reach the robust loss
    assert (not np.isfinite(broken[-1])) or broken[-1] > attacked[-1] + 0.3


def test_all_attacks_produce_finite_training_with_robust_gar():
    for attack in attacks.ATTACKS:
        losses = _train("multi_bulyan", attack, steps=6)
        assert np.isfinite(losses[-1]), attack


def test_param_specs_cover_every_leaf():
    for name in ("qwen3-moe-30b-a3b", "jamba-1.5-large-398b", "whisper-tiny"):
        from repro.configs import get_config
        cfg = get_config(name).reduced()
        params = MD.init_model(KEY, cfg)
        specs = param_specs(params)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or x.__class__.__name__ == "PartitionSpec")
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(tuple(spec)) <= leaf.ndim, (spec, leaf.shape)


def test_sanitize_spec_drops_indivisible():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    s = sanitize_spec(P(None, "model"), (384, 51865), FakeMesh())
    assert tuple(s) == (None, None)
    s2 = sanitize_spec(P(None, "model"), (384, 51872), FakeMesh())
    assert tuple(s2) == (None, "model")


def test_dryrun_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
      %all-gather.1 = bf16[16,384,4096]{2,1,0} all-gather(%p0), replica_groups={}
      %ar = f32[128]{0} all-reduce(%x), to_apply=%add
      %ag-start = (f32[4], f32[8]) all-gather-start(%y)
      %nothing = f32[2] add(%a, %b)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 384 * 4096 * 2 + (4 + 8) * 4
    assert out["all-reduce"] == 128 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"]
