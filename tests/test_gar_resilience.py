"""Empirical validation of the paper's resilience claims (Defs 1-3, Lemma 1,
Theorems 1-2) on controlled gradient distributions."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import attacks, gar, theory

N, F, D = 15, 3, 64          # n >= 4f+3 = 15
RNG = np.random.default_rng(42)


def _correct_grads(n, d, g, sigma):
    return (g[None] + sigma * RNG.normal(size=(n, d))).astype(np.float32)


@pytest.mark.parametrize("attack", ["sign_flip", "gaussian", "inf",
                                    "mimic", "omniscient"])
@pytest.mark.parametrize("rule", ["krum", "multi_krum", "bulyan",
                                  "multi_bulyan"])
def test_cone_condition_under_attack(attack, rule):
    """(α,f)-resilience condition (i): <E[GAR], g> >= (1-sinα)||g||² > 0.

    Empirically: the aggregate stays positively aligned with the true
    gradient under every attack, provided the variance condition holds.
    """
    g = np.ones(D, dtype=np.float32)
    sigma = 0.05  # small: η(15,3)·√64·σ ≈ 0.4·||g|| < ||g||
    assert theory.variance_condition(N, F, D, sigma, float(np.linalg.norm(g)))
    cosines = []
    for trial in range(10):
        correct = _correct_grads(N - F, D, g, sigma)
        key = jax.random.key(trial)
        if attack == "sign_flip":
            byz = attacks.sign_flip(jnp.asarray(correct), F, key, scale=10.0)
        else:
            byz = attacks.get_attack(attack)(jnp.asarray(correct), F, key)
        stack = jnp.concatenate([jnp.asarray(byz, dtype=jnp.float32),
                                 jnp.asarray(correct)], axis=0)
        agg = np.asarray(gar.aggregate(stack, F, rule))
        assert np.all(np.isfinite(agg)), (attack, rule)
        cosines.append(theory.cone_cosine(jnp.asarray(agg), jnp.asarray(g)))
    # mean aggregate lives in the correct cone (positive alignment)
    assert np.mean(cosines) > 0.5, (attack, rule, np.mean(cosines))


@pytest.mark.parametrize("attack", ["sign_flip", "inf"])
def test_averaging_is_broken_but_multibulyan_is_not(attack):
    """The contrast the paper is built on (§I)."""
    g = np.ones(D, dtype=np.float32)
    correct = _correct_grads(N - F, D, g, 0.05)
    key = jax.random.key(0)
    if attack == "sign_flip":
        byz = attacks.sign_flip(jnp.asarray(correct), F, key, scale=20.0)
    else:
        byz = attacks.get_attack(attack)(jnp.asarray(correct), F, key)
    stack = jnp.concatenate([byz.astype(jnp.float32), jnp.asarray(correct)], 0)
    avg = np.asarray(gar.average(stack))
    mb = np.asarray(gar.multi_bulyan(stack, F))
    cos_avg = theory.cone_cosine(jnp.asarray(avg), jnp.asarray(g))
    cos_mb = theory.cone_cosine(jnp.asarray(mb), jnp.asarray(g))
    assert cos_mb > 0.9
    assert cos_avg < cos_mb  # averaging dragged off by the byzantine rows


def test_strong_resilience_leeway_shrinks_with_d():
    """Definition 2: per-coordinate gap E|GAR_i - G_i| = O(1/√d)·||G||.

    The l2 scale ||G|| of the gradients grows as √d here (unit coordinates),
    so the *expected per-coordinate* deviation of MULTI-BULYAN from the
    nearest correct gradient must stay ~flat in d — whereas a rule with an
    unchecked √d leeway would show per-coordinate gaps growing with d.
    """
    gaps = []
    for d in (16, 256, 1024):
        per_trial = []
        for t in range(5):
            g = np.ones(d, dtype=np.float32)
            correct = _correct_grads(N - F, d, g, 0.05)
            byz = attacks.omniscient_reverse(jnp.asarray(correct), F,
                                             jax.random.key(t))
            stack = jnp.concatenate([byz.astype(jnp.float32),
                                     jnp.asarray(correct)], 0)
            mb = np.asarray(gar.multi_bulyan(stack, F))
            per_trial.append(np.min(np.abs(mb[None, :] - correct),
                                    axis=0).mean())
        gaps.append(np.mean(per_trial))
    # E-per-coordinate gap flat in d (no √d growth): 1024-dim gap must stay
    # within 2x of the 16-dim gap while √(1024/16) = 8x would be unchecked
    assert gaps[-1] <= gaps[0] * 2.0, gaps


def test_multikrum_variance_reduction_ratio():
    """Theorem 1(ii): m̃-average has ~m̃× lower variance than a single Krum
    pick — the mechanism behind the m̃/n slowdown claim."""
    g = np.zeros(D, dtype=np.float32)
    m_tilde = N - F - 2
    var_krum, var_mk = [], []
    for t in range(200):
        stack = jnp.asarray(_correct_grads(N, D, g, 1.0))
        var_krum.append(np.asarray(gar.krum(stack, F)))
        var_mk.append(np.asarray(gar.multi_krum(stack, F)))
    v1 = np.var(np.stack(var_krum), axis=0).mean()
    vm = np.var(np.stack(var_mk), axis=0).mean()
    ratio = v1 / vm
    assert ratio > 0.5 * m_tilde, (ratio, m_tilde)


def test_mild_byzantine_noise_not_catastrophic():
    """§II: 'mild' byzantine behaviour (honest-mean resends) is harmless."""
    g = np.ones(D, dtype=np.float32)
    correct = _correct_grads(N - F, D, g, 0.05)
    stack = attacks.apply_attack(jnp.asarray(correct), F, "none",
                                 jax.random.key(0))
    mb = np.asarray(gar.multi_bulyan(stack, F))
    assert theory.cone_cosine(jnp.asarray(mb), jnp.asarray(g)) > 0.99
