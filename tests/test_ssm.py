"""Mamba selective-scan correctness vs a naive sequential recurrence."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import ssm as S
from repro.models import modules as M

KEY = jax.random.key(0)
CFG = ArchConfig(name="t-ssm", family="ssm", n_layers=1, d_model=16,
                 n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=64,
                 ssm=SSMConfig(d_state=4, d_conv=3, expand=2, dt_rank=4))


def naive_mamba(p, x, cfg):
    """Step-by-step fp64 recurrence oracle."""
    ssm = cfg.ssm
    b, s, d = x.shape
    di = ssm.expand * d
    xz = np.asarray(M.linear_apply(p["in_proj"], x), np.float64)
    xr, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv
    w = np.asarray(p["conv_w"], np.float64)
    bias = np.asarray(p["conv_b"], np.float64)
    k = w.shape[0]
    xp = np.concatenate([np.zeros((b, k - 1, di)), xr], axis=1)
    conv = np.stack([sum(xp[:, t + i] * w[i] for i in range(k)) + bias
                     for t in range(s)], axis=1)
    xc = conv / (1 + np.exp(-conv))  # silu
    proj = xc @ np.asarray(p["x_proj"]["w"], np.float64)
    dtr = ssm.resolved_dt_rank(d)
    dt_low, B, C = proj[..., :dtr], proj[..., dtr:dtr + ssm.d_state], \
        proj[..., dtr + ssm.d_state:]
    dt = np.logaddexp(0, dt_low @ np.asarray(p["dt_proj"]["w"], np.float64)
                      + np.asarray(p["dt_proj"]["b"], np.float64))
    A = -np.exp(np.asarray(p["A_log"], np.float64))
    h = np.zeros((b, di, ssm.d_state))
    ys = []
    for t in range(s):
        decay = np.exp(dt[:, t, :, None] * A[None])
        h = decay * h + dt[:, t, :, None] * B[:, t, None, :] * xc[:, t, :, None]
        y = (h * C[:, t, None, :]).sum(-1)
        ys.append(y)
    y = np.stack(ys, axis=1) + np.asarray(p["D"], np.float64) * xc
    y = y * (z / (1 + np.exp(-z)))
    return y @ np.asarray(p["out_proj"]["w"], np.float64)


@pytest.mark.parametrize("seq", [7, 16, 512])  # 512 exercises chunked scan
def test_mamba_matches_naive_recurrence(seq):
    p = S.mamba_init(KEY, CFG)
    x = jax.random.normal(jax.random.key(1), (2, seq, CFG.d_model),
                          jnp.float32) * 0.5
    got = np.asarray(S.mamba_apply(p, x, CFG, chunk=256), np.float64)
    want = naive_mamba(p, x, CFG)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)


def test_mamba_step_matches_full():
    """Streaming decode (mamba_step) == full-sequence apply at each step."""
    p = S.mamba_init(KEY, CFG)
    s = 10
    x = jax.random.normal(jax.random.key(2), (1, s, CFG.d_model),
                          jnp.float32) * 0.5
    full = np.asarray(S.mamba_apply(p, x, CFG))
    cache = S.init_mamba_cache(1, CFG)
    outs = []
    for t in range(s):
        y, cache = S.mamba_step(p, x[:, t:t + 1], cache, CFG)
        outs.append(np.asarray(y)[:, 0])
    step = np.stack(outs, axis=1)
    np.testing.assert_allclose(step, full, rtol=2e-2, atol=2e-3)


def test_mamba_causality():
    """Future inputs must not affect past outputs."""
    p = S.mamba_init(KEY, CFG)
    x = jax.random.normal(jax.random.key(3), (1, 12, CFG.d_model), jnp.float32)
    y1 = np.asarray(S.mamba_apply(p, x, CFG))
    x2 = x.at[:, 8:].set(9.9)
    y2 = np.asarray(S.mamba_apply(p, x2, CFG))
    np.testing.assert_allclose(y1[:, :8], y2[:, :8], rtol=1e-5, atol=1e-5)
