"""Mesh-native (shard_map) aggregation vs the single-device path.

The DESIGN.md §10 acceptance contract: on a host mesh (1×1 on plain CI;
2×4 when the spmd job forces 8 CPU devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` +
``REPRO_FORCED_DEVICES=1``), sharded ``compute_stats`` must be **bitwise**
identical to the replicated path — the (n, n) distances and (n,) norms —
and the sharded apply within 1e-6, for multi_krum and multi_bulyan on the
PR-2 edge grid (n∤8, d∤128), including qsgd/bf16 ``EncodedGrads`` inputs.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import api
from repro.launch.mesh import make_host_mesh

KEY = jax.random.key(0)
# the PR-2 edge grid: worker counts off the 8-sublane boundary, d off the
# 128-lane boundary (and off the host-mesh model-axis divisor)
EDGE_GRID = [(7, 1), (11, 2), (15, 3), (12, 2)]
D_EDGE = 257


def _ctx():
    return api.MeshContext.for_mesh(make_host_mesh())


def _stack(n, d, seed=0):
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(G)


def _tree(n, d, seed=0):
    G = _stack(n, d, seed)
    cut = d // 3 or 1
    return {"a": G[:, :cut], "b": G[:, cut:].reshape(n, -1)}


# ------------------------------------------------------------------ stats
@pytest.mark.parametrize("n,f", EDGE_GRID)
def test_sharded_stats_bitwise_xla(n, f):
    grads = _tree(n, D_EDGE, seed=n)
    ref = api.compute_stats(grads, f, needs_dists=True)
    out = api.compute_stats(grads, f, needs_dists=True, mesh_ctx=_ctx())
    np.testing.assert_array_equal(np.asarray(ref.dists),
                                  np.asarray(out.dists))
    np.testing.assert_array_equal(np.asarray(ref.sq_norms),
                                  np.asarray(out.sq_norms))


@pytest.mark.parametrize("n,f", [(11, 2), (12, 2)])
def test_sharded_stats_bitwise_pallas(n, f):
    grads = _tree(n, D_EDGE, seed=n)
    ref = api.compute_stats(grads, f, needs_dists=True, use_pallas=True)
    out = api.compute_stats(grads, f, needs_dists=True, use_pallas=True,
                            mesh_ctx=_ctx())
    np.testing.assert_array_equal(np.asarray(ref.dists),
                                  np.asarray(out.dists))
    np.testing.assert_array_equal(np.asarray(ref.sq_norms),
                                  np.asarray(out.sq_norms))


def test_sharded_raw_stats_matches_streaming_accumulation():
    """Per-block sharded raw contributions sum to the stacked total —
    the streaming pass-1 contract (raw: no clamp, diagonal kept)."""
    n = 11
    grads = _tree(n, D_EDGE)
    ctx = _ctx()
    total = jnp.zeros((n, n), jnp.float32)
    for leaf in jax.tree.leaves(grads):
        total = total + api.raw_pairwise_stats(leaf, mesh_ctx=ctx)[0]
    ref = api.tree_pairwise_stats(grads)[0]
    np.testing.assert_array_equal(np.asarray(api.finalize_dists(total)),
                                  np.asarray(ref))


@pytest.mark.parametrize("n,f", [(11, 2), (12, 2)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_model_axis_raw_stats_matches_replicated(n, f, use_pallas):
    """The §10 tensor-parallel stats seam: leaves sharded over the model
    axis too.  At M = 1 (plain CI host mesh) the psum is a no-op and
    parity with the replicated path is bitwise; at M > 1 the per-column-
    shard psum reassociates the d sum (~1e-6)."""
    grads = _tree(n, D_EDGE, seed=7 * n)
    ctx = _ctx()
    ref_d, ref_s = api.raw_pairwise_stats(grads, use_pallas=use_pallas)
    dd, sq = api.sharded_raw_stats_model_axis(grads, mesh_ctx=ctx,
                                              use_pallas=use_pallas)
    assert dd.shape == (n, n) and sq.shape == (n,)
    if ctx.model_size == 1:
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(ref_d))
        np.testing.assert_array_equal(np.asarray(sq), np.asarray(ref_s))
    else:
        scale = max(float(jnp.max(ref_d)), 1.0)
        np.testing.assert_allclose(np.asarray(dd), np.asarray(ref_d),
                                   rtol=0, atol=1e-5 * scale)
        np.testing.assert_allclose(np.asarray(sq), np.asarray(ref_s),
                                   rtol=0, atol=1e-5 * scale)


# ------------------------------------------------------------------ apply
@pytest.mark.parametrize("rule", ["multi_krum", "multi_bulyan"])
@pytest.mark.parametrize("n,f", EDGE_GRID)
def test_sharded_apply_matches_xla(rule, n, f):
    grads = _tree(n, D_EDGE, seed=3 * n)
    agg = api.get_aggregator(rule)
    stats = api.compute_stats(grads, f, needs_dists=True)
    plan = agg.plan(stats)
    ref = agg.apply(plan, grads)
    out = agg.apply(plan, grads, mesh_ctx=_ctx())
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


@pytest.mark.parametrize("n,f", [(11, 2), (15, 3)])
def test_sharded_fused_apply_matches(n, f):
    """Sharded fused bulyan select (the production fast path) vs the
    single-device fused kernel."""
    grads = {"w": _stack(n, D_EDGE, seed=5)}
    agg = api.get_aggregator("multi_bulyan")
    stats = api.compute_stats(grads, f, needs_dists=True, use_pallas=True)
    plan = agg.plan(stats)
    ref = agg.apply(plan, grads, use_pallas=True)
    out = agg.apply(plan, grads, use_pallas=True, mesh_ctx=_ctx())
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


@pytest.mark.parametrize("rule", ["average", "median", "trimmed_mean"])
def test_sharded_apply_distance_free_rules(rule):
    n, f = 11, 2
    grads = _tree(n, D_EDGE, seed=9)
    agg = api.get_aggregator(rule)
    stats = api.compute_stats(grads, f, needs_dists=False)
    plan = agg.plan(stats)
    ref = agg.apply(plan, grads)
    out = agg.apply(plan, grads, mesh_ctx=_ctx())
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


@pytest.mark.parametrize("rule", ["multi_krum", "multi_bulyan"])
def test_sharded_aggregate_tree_end_to_end(rule):
    n, f = 11, 2
    grads = _tree(n, D_EDGE, seed=13)
    ref = api.aggregate_tree(grads, f, rule)
    out = api.aggregate_tree(grads, f, rule, mesh_ctx=_ctx())
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


# ---------------------------------------------------------------- encoded
def _encode(grads, spec):
    from repro.comm import get_codec
    codec = get_codec(spec)
    enc, _ = codec.encode(grads, key=KEY)
    return enc


@pytest.mark.parametrize("spec", ["bf16", "qsgd:bits=8"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_sharded_encoded_stats_bitwise(spec, use_pallas):
    """EncodedGrads wire containers through the sharded stats path —
    payload/sidecar rows shard over the worker axes; bitwise parity with
    the replicated encoded path (fused dequant→stats under use_pallas)."""
    n, f = 11, 2
    enc = _encode(_tree(n, D_EDGE, seed=21), spec)
    ref = api.compute_stats(enc, f, needs_dists=True, use_pallas=use_pallas)
    out = api.compute_stats(enc, f, needs_dists=True, use_pallas=use_pallas,
                            mesh_ctx=_ctx())
    np.testing.assert_array_equal(np.asarray(ref.dists),
                                  np.asarray(out.dists))
    np.testing.assert_array_equal(np.asarray(ref.sq_norms),
                                  np.asarray(out.sq_norms))


@pytest.mark.parametrize("spec", ["bf16", "qsgd:bits=8"])
def test_sharded_encoded_plan_apply(spec):
    """Full plan/apply over a wire container under the mesh context."""
    n, f = 11, 2
    grads = _tree(n, D_EDGE, seed=22)
    enc = _encode(grads, spec)
    agg = api.get_aggregator("multi_bulyan")
    ref_stats = api.compute_stats(enc, f, needs_dists=True)
    out_stats = api.compute_stats(enc, f, needs_dists=True, mesh_ctx=_ctx())
    np.testing.assert_array_equal(np.asarray(ref_stats.dists),
                                  np.asarray(out_stats.dists))
    plan = agg.plan(ref_stats)
    ref = agg.apply(plan, enc)
    out = agg.apply(plan, enc, mesh_ctx=_ctx())
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


# ---------------------------------------------------------------- trainer
def test_sharded_train_step_matches_replicated():
    """The spmd trainer path (shard_map_mesh=host mesh) agrees with the
    replicated step.

    The aggregation pipeline itself is bitwise/1e-6 given identical
    gradients (the tests above); at the whole-step level the model's
    forward/backward is auto-partitioned differently on a multi-device
    mesh (bf16 activation reassociation, ~1e-3 relative on the grads —
    enough to swap near-tied *honest* workers in the selection), so the
    step-level assertions are: byzantine capture equally bounded on both
    paths — the robustness decision — and params within the backward
    noise.
    """
    from repro.configs.base import ArchConfig, RobustConfig
    from repro.data import lm_batches
    from repro.dist import (TrainerState, init_train_state, make_train_step,
                            split_workers)
    from repro import models as MD
    from repro.optim import sgd, constant

    cfg = ArchConfig(name="spmd-t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128)
    n = 11
    rcfg = RobustConfig(n_workers=n, f=2, gar="multi_bulyan")
    params = MD.init_model(KEY, cfg)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)
    b = split_workers(next(lm_batches(cfg.vocab_size, n * 2, 16, seed=4)), n)
    ref_step = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.05),
                                       chunk_q=16, attack="sign_flip",
                                       telemetry=True))
    spmd_step = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.05),
                                        chunk_q=16, attack="sign_flip",
                                        telemetry=True,
                                        shard_map_mesh=make_host_mesh()))
    p_ref, _, m_ref = ref_step(params, state, b, KEY)
    p_out, s_out, m_out = spmd_step(params, state, b, KEY)
    assert isinstance(s_out, TrainerState)
    if len(jax.devices()) == 1:
        np.testing.assert_array_equal(
            np.asarray(m_ref["telemetry"]["selection"]),
            np.asarray(m_out["telemetry"]["selection"]))
    # step-0 gradients are near-random, so sign_flip may capture a sliver
    # of extraction mass — what matters is that both paths agree on how
    # bounded the capture is (exactly, on one device)
    b_ref = float(m_ref["telemetry"]["byz_mass"])
    b_out = float(m_out["telemetry"]["byz_mass"])
    assert b_ref <= 0.2 and b_out <= 0.2, (b_ref, b_out)
    assert abs(b_ref - b_out) <= 0.1, (b_ref, b_out)
    atol = 1e-6 if len(jax.devices()) == 1 else 5e-2
    for a, c in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=0, atol=atol)


def test_mesh_context_derivation_and_validation():
    ctx = _ctx()
    mesh = ctx.mesh
    assert ctx.worker_axes == ("data",)
    assert ctx.model_axis == "model"
    assert ctx.worker_size == dict(mesh.shape)["data"]
    with pytest.raises(ValueError, match="worker axes"):
        api.MeshContext.for_mesh(mesh, worker_axes=("nonexistent",))
