"""Shared test fixtures: reduced configs for every family."""
from __future__ import annotations

from repro.configs import ARCH_NAMES, get_config

REDUCED = {name: get_config(name).reduced() for name in ARCH_NAMES}


def reduced_cfg(name: str):
    return REDUCED[name]
