"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import coord_select_ref, pairwise_sqdist_ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("n", [3, 8, 11, 16, 33])
@pytest.mark.parametrize("d", [1, 100, 257, 2048, 5000])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pairwise_sqdist_sweep(n, d, dtype):
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    got = ops.pairwise_sqdist(x)
    want = pairwise_sqdist_ref(x)
    assert got.shape == (n, n)
    assert got.dtype == jnp.float32
    scale = max(float(jnp.max(want)), 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5 * scale)
    assert np.all(np.diag(np.asarray(got)) == 0.0)


@pytest.mark.parametrize("d_tile", [128, 512, 2048])
def test_pairwise_sqdist_tile_invariance(d_tile):
    x = jnp.asarray(RNG.normal(size=(9, 3000)).astype(np.float32))
    got = ops.pairwise_sqdist(x, d_tile=d_tile)
    want = pairwise_sqdist_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("theta,beta", [(5, 1), (8, 2), (16, 4), (30, 10),
                                        (7, 7)])
@pytest.mark.parametrize("d", [1, 64, 1000, 2049])
def test_coord_select_sweep(theta, beta, d):
    ge = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    ga = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    got = ops.coord_select(ge, ga, beta)
    want = coord_select_ref(ge, ga, beta)
    assert got.shape == (d,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5)


def test_coord_select_ties():
    """Equal distances must break ties by row index (matches oracle)."""
    theta, d = 6, 10
    ge = jnp.zeros((theta, d), jnp.float32)
    ga = jnp.ones((theta, d), jnp.float32)      # all equidistant from median 0
    got = ops.coord_select(ge, ga, 3)
    want = coord_select_ref(ge, ga, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_coord_select_beta_equals_theta_is_mean():
    theta, d = 9, 33
    ge = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    ga = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    got = ops.coord_select(ge, ga, theta)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.mean(ga, axis=0)),
                               rtol=1e-5, atol=1e-6)
