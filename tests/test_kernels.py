"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import coord_select_ref, pairwise_sqdist_ref

RNG = np.random.default_rng(7)


def _bulyan_plan_weights(n, f):
    """A real extraction plan for an (n, f) pair (θ one-hots + averages)."""
    from repro.core import gar
    d = 64
    G = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    theta = n - 2 * f - 2
    beta = theta - 2 * f
    w_ext, w_agr = gar.extraction_plan(gar.pairwise_sqdist(G), f, theta)
    return G, w_ext, w_agr, beta


@pytest.mark.parametrize("n", [3, 8, 11, 16, 33])
@pytest.mark.parametrize("d", [1, 100, 257, 2048, 5000])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pairwise_sqdist_sweep(n, d, dtype):
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    got = ops.pairwise_sqdist(x)
    want = pairwise_sqdist_ref(x)
    assert got.shape == (n, n)
    assert got.dtype == jnp.float32
    scale = max(float(jnp.max(want)), 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5 * scale)
    assert np.all(np.diag(np.asarray(got)) == 0.0)


@pytest.mark.parametrize("d_tile", [128, 512, 2048])
def test_pairwise_sqdist_tile_invariance(d_tile):
    x = jnp.asarray(RNG.normal(size=(9, 3000)).astype(np.float32))
    got = ops.pairwise_sqdist(x, d_tile=d_tile)
    want = pairwise_sqdist_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("theta,beta", [(5, 1), (8, 2), (16, 4), (30, 10),
                                        (7, 7)])
@pytest.mark.parametrize("d", [1, 64, 1000, 2049])
def test_coord_select_sweep(theta, beta, d):
    ge = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    ga = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    got = ops.coord_select(ge, ga, beta)
    want = coord_select_ref(ge, ga, beta)
    assert got.shape == (d,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5)


def test_coord_select_ties():
    """Equal distances must break ties by row index (matches oracle)."""
    theta, d = 6, 10
    ge = jnp.zeros((theta, d), jnp.float32)
    ga = jnp.ones((theta, d), jnp.float32)      # all equidistant from median 0
    got = ops.coord_select(ge, ga, 3)
    want = coord_select_ref(ge, ga, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_coord_select_beta_equals_theta_is_mean():
    theta, d = 9, 33
    ge = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    ga = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    got = ops.coord_select(ge, ga, theta)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.mean(ga, axis=0)),
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------------- single-pass stats
@pytest.mark.parametrize("n", [3, 8, 11, 16])
@pytest.mark.parametrize("d", [1, 100, 257, 5000])
def test_pairwise_stats_single_pass(n, d):
    """One HBM read must reproduce both the distance and the norm kernels."""
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    dists, sq = ops.pairwise_stats(x)
    assert dists.shape == (n, n) and sq.shape == (n,)
    want_d = pairwise_sqdist_ref(x)
    # raw contribution: clamp + zero diagonal is the caller's finalisation
    got_d = np.maximum(np.asarray(dists), 0.0) * (1.0 - np.eye(n))
    scale = max(float(jnp.max(want_d)), 1.0)
    np.testing.assert_allclose(got_d, np.asarray(want_d),
                               rtol=0, atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(sq),
                               np.sum(np.asarray(x) ** 2, axis=1),
                               rtol=1e-5, atol=1e-5 * scale)


def test_pairwise_stats_matches_sqdist_kernel_bitwise():
    """Same tile schedule -> identical float accumulation for distances."""
    x = jnp.asarray(RNG.normal(size=(13, 3000)).astype(np.float32))
    dists, _ = ops.pairwise_stats(x, d_tile=512)
    fin = np.maximum(np.asarray(dists), 0.0) * (1.0 - np.eye(13))
    np.testing.assert_array_equal(
        fin.astype(np.float32),
        np.asarray(ops.pairwise_sqdist(x, d_tile=512)))


# ------------------------------------------------------------- fused select
@pytest.mark.parametrize("n,f", [(7, 1), (11, 2), (15, 3), (12, 2)])
@pytest.mark.parametrize("d", [1, 100, 2048, 2500])
def test_fused_select_matches_composed_reference(n, f, d):
    _, w_ext, w_agr, beta = _bulyan_plan_weights(n, f)
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    got = ops.fused_select(x, w_ext, w_agr, beta)
    ge = jnp.asarray(np.asarray(w_ext) @ np.asarray(x))
    ga = jnp.asarray(np.asarray(w_agr) @ np.asarray(x))
    want = coord_select_ref(ge, ga, beta)
    assert got.shape == (d,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5)


def test_fused_select_tile_invariance():
    n, f = 11, 2
    _, w_ext, w_agr, beta = _bulyan_plan_weights(n, f)
    x = jnp.asarray(RNG.normal(size=(n, 3000)).astype(np.float32))
    base = np.asarray(ops.fused_select(x, w_ext, w_agr, beta, d_tile=2048))
    for d_tile in (128, 512):
        np.testing.assert_allclose(
            np.asarray(ops.fused_select(x, w_ext, w_agr, beta,
                                        d_tile=d_tile)),
            base, rtol=0, atol=1e-5)


def test_fused_select_rejects_bad_shapes():
    x = jnp.zeros((8, 64), jnp.float32)
    w = jnp.zeros((3, 8), jnp.float32)
    with pytest.raises(ValueError, match="beta"):
        ops.fused_select(x, w, w, 0)
    with pytest.raises(ValueError, match="weights must be"):
        ops.fused_select(x, jnp.zeros((3, 7)), jnp.zeros((3, 7)), 1)
    with pytest.raises(ValueError, match="shapes differ"):
        ops.fused_select(x, w, jnp.zeros((4, 8)), 1)


# ---------------------------------------------------------------- autotuner
def test_autotune_d_tile_lane_aligned_and_budgeted():
    for rows in (8, 24, 64, 200):
        for d in (1, 100, 4096, 10_000_000):
            t = ops.autotune_d_tile(rows, d)
            assert t % 128 == 0 and t >= 128
            # padded-d cap: never wider than the lane-rounded operand
            assert t <= max(128, ((d - 1) // 128 + 1) * 128)
            if t > 128:  # above the floor the working set obeys the budget
                assert 2 * rows * t * 4 <= ops.VMEM_BUDGET_BYTES


def test_autotune_d_tile_monotone_in_rows():
    wide = ops.autotune_d_tile(8, 10_000_000)
    narrow = ops.autotune_d_tile(512, 10_000_000)
    assert narrow <= wide
    with pytest.raises(ValueError):
        ops.autotune_d_tile(0, 128)


def test_ops_interpret_resolved_outside_jit(monkeypatch):
    """Regression for the trace-time-baking bug: the backend/override must
    be resolved in the unjitted wrapper and reach the kernel as a static
    argument — not be re-evaluated (and cached) inside the trace."""
    seen = []
    real = ops.pairwise_sqdist_pallas

    def spy(x, *, d_tile, interpret):
        seen.append(interpret)
        return real(x, d_tile=d_tile, interpret=True)  # CPU can only interpret

    monkeypatch.setattr(ops, "pairwise_sqdist_pallas", spy)
    x = jnp.asarray(RNG.normal(size=(5, 133)).astype(np.float32))
    # unique d_tile values force fresh traces through the spy
    ops.pairwise_sqdist(x, d_tile=256, interpret=False)
    ops.pairwise_sqdist(x, d_tile=384)                # default: CPU backend
    assert seen == [False, True]
