"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import coord_select_ref, pairwise_sqdist_ref

RNG = np.random.default_rng(7)


def _bulyan_plan_weights(n, f):
    """A real extraction plan for an (n, f) pair (θ one-hots + averages)."""
    from repro.core import gar
    d = 64
    G = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    theta = n - 2 * f - 2
    beta = theta - 2 * f
    w_ext, w_agr = gar.extraction_plan(gar.pairwise_sqdist(G), f, theta)
    return G, w_ext, w_agr, beta


@pytest.mark.parametrize("n", [3, 8, 11, 16, 33])
@pytest.mark.parametrize("d", [1, 100, 257, 2048, 5000])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pairwise_sqdist_sweep(n, d, dtype):
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    got = ops.pairwise_sqdist(x)
    want = pairwise_sqdist_ref(x)
    assert got.shape == (n, n)
    assert got.dtype == jnp.float32
    scale = max(float(jnp.max(want)), 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5 * scale)
    assert np.all(np.diag(np.asarray(got)) == 0.0)


@pytest.mark.parametrize("d_tile", [128, 512, 2048])
def test_pairwise_sqdist_tile_invariance(d_tile):
    x = jnp.asarray(RNG.normal(size=(9, 3000)).astype(np.float32))
    got = ops.pairwise_sqdist(x, d_tile=d_tile)
    want = pairwise_sqdist_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("theta,beta", [(5, 1), (8, 2), (16, 4), (30, 10),
                                        (7, 7)])
@pytest.mark.parametrize("d", [1, 64, 1000, 2049])
def test_coord_select_sweep(theta, beta, d):
    ge = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    ga = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    got = ops.coord_select(ge, ga, beta)
    want = coord_select_ref(ge, ga, beta)
    assert got.shape == (d,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5)


def test_coord_select_ties():
    """Equal distances must break ties by row index (matches oracle)."""
    theta, d = 6, 10
    ge = jnp.zeros((theta, d), jnp.float32)
    ga = jnp.ones((theta, d), jnp.float32)      # all equidistant from median 0
    got = ops.coord_select(ge, ga, 3)
    want = coord_select_ref(ge, ga, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_coord_select_beta_equals_theta_is_mean():
    theta, d = 9, 33
    ge = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    ga = jnp.asarray(RNG.normal(size=(theta, d)).astype(np.float32))
    got = ops.coord_select(ge, ga, theta)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.mean(ga, axis=0)),
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------------- single-pass stats
@pytest.mark.parametrize("n", [3, 8, 11, 16])
@pytest.mark.parametrize("d", [1, 100, 257, 5000])
def test_pairwise_stats_single_pass(n, d):
    """One HBM read must reproduce both the distance and the norm kernels."""
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    dists, sq = ops.pairwise_stats(x)
    assert dists.shape == (n, n) and sq.shape == (n,)
    want_d = pairwise_sqdist_ref(x)
    # raw contribution: clamp + zero diagonal is the caller's finalisation
    got_d = np.maximum(np.asarray(dists), 0.0) * (1.0 - np.eye(n))
    scale = max(float(jnp.max(want_d)), 1.0)
    np.testing.assert_allclose(got_d, np.asarray(want_d),
                               rtol=0, atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(sq),
                               np.sum(np.asarray(x) ** 2, axis=1),
                               rtol=1e-5, atol=1e-5 * scale)


def test_pairwise_stats_matches_sqdist_kernel_bitwise():
    """Same tile schedule -> identical float accumulation for distances."""
    x = jnp.asarray(RNG.normal(size=(13, 3000)).astype(np.float32))
    dists, _ = ops.pairwise_stats(x, d_tile=512)
    fin = np.maximum(np.asarray(dists), 0.0) * (1.0 - np.eye(13))
    np.testing.assert_array_equal(
        fin.astype(np.float32),
        np.asarray(ops.pairwise_sqdist(x, d_tile=512)))


# ------------------------------------------------------------- fused select
@pytest.mark.parametrize("n,f", [(7, 1), (11, 2), (15, 3), (12, 2)])
@pytest.mark.parametrize("d", [1, 100, 2048, 2500])
def test_fused_select_matches_composed_reference(n, f, d):
    _, w_ext, w_agr, beta = _bulyan_plan_weights(n, f)
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    got = ops.fused_select(x, w_ext, w_agr, beta)
    ge = jnp.asarray(np.asarray(w_ext) @ np.asarray(x))
    ga = jnp.asarray(np.asarray(w_agr) @ np.asarray(x))
    want = coord_select_ref(ge, ga, beta)
    assert got.shape == (d,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5)


def test_fused_select_tile_invariance():
    n, f = 11, 2
    _, w_ext, w_agr, beta = _bulyan_plan_weights(n, f)
    x = jnp.asarray(RNG.normal(size=(n, 3000)).astype(np.float32))
    base = np.asarray(ops.fused_select(x, w_ext, w_agr, beta, d_tile=2048))
    for d_tile in (128, 512):
        np.testing.assert_allclose(
            np.asarray(ops.fused_select(x, w_ext, w_agr, beta,
                                        d_tile=d_tile)),
            base, rtol=0, atol=1e-5)


def test_fused_select_rejects_bad_shapes():
    x = jnp.zeros((8, 64), jnp.float32)
    w = jnp.zeros((3, 8), jnp.float32)
    with pytest.raises(ValueError, match="beta"):
        ops.fused_select(x, w, w, 0)
    with pytest.raises(ValueError, match="weights must be"):
        ops.fused_select(x, jnp.zeros((3, 7)), jnp.zeros((3, 7)), 1)
    with pytest.raises(ValueError, match="shapes differ"):
        ops.fused_select(x, w, jnp.zeros((4, 8)), 1)


# ------------------------------------------------------ two-level invariance
@pytest.mark.parametrize("n,f", [(7, 1), (11, 2), (15, 3), (12, 2)])
def test_fused_select_two_level_bitwise_vs_single_level(n, f):
    """The macro grid is pure launch geometry: any (d_tile, macro_tile)
    pair — including the policy default — must be bitwise-identical to
    the single-level launch (fused_select is column-independent)."""
    _, w_ext, w_agr, beta = _bulyan_plan_weights(n, f)
    x = jnp.asarray(RNG.normal(size=(n, 257)).astype(np.float32))
    single = np.asarray(ops.fused_select(x, w_ext, w_agr, beta,
                                         d_tile=128, macro_tile=128))
    for macro in (256, 384):
        two = np.asarray(ops.fused_select(x, w_ext, w_agr, beta,
                                          d_tile=128, macro_tile=macro))
        np.testing.assert_array_equal(two, single)
    np.testing.assert_array_equal(
        np.asarray(ops.fused_select(x, w_ext, w_agr, beta)), single)


def test_fused_select_two_level_bitwise_deep_grid():
    """>= 2 macro blocks, each sweeping many inner windows — the d=1e6
    launch shape in miniature, against the windows=1 launch."""
    n, f = 11, 2
    _, w_ext, w_agr, beta = _bulyan_plan_weights(n, f)
    d = 120_000
    dt, macro = ops.fused_select_tiles(16, d, w_ext.shape[0])
    assert macro > dt and -(-d // macro) >= 2   # the regime under test
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    two = np.asarray(ops.fused_select(x, w_ext, w_agr, beta))
    one = np.asarray(ops.fused_select(x, w_ext, w_agr, beta,
                                      d_tile=dt, macro_tile=dt))
    np.testing.assert_array_equal(two, one)


def test_pairwise_stats_two_level_bitwise():
    """Macro blocks must not change the accumulation order: the inner
    d_tile windows run in global order across macro steps (the first-
    window init + zero-pad tail windows add exact +0.0)."""
    x = jnp.asarray(RNG.normal(size=(13, 3000)).astype(np.float32))
    base_d, base_s = ops.pairwise_stats(x, d_tile=512, macro_tile=512)
    for macro in (1024, 2048):      # 2048 pads d: exercises tail windows
        dd, ss = ops.pairwise_stats(x, d_tile=512, macro_tile=macro)
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(base_d))
        np.testing.assert_array_equal(np.asarray(ss), np.asarray(base_s))


def test_pairwise_stats_two_level_bitwise_deep_grid():
    d = 131_072
    dt, macro = ops._stats_tiles(16, d)
    assert macro > dt and -(-d // macro) >= 2
    x = jnp.asarray(RNG.normal(size=(15, d)).astype(np.float32))
    two_d, two_s = ops.pairwise_stats(x)            # policy launch
    one_d, one_s = ops.pairwise_stats(x, d_tile=dt, macro_tile=dt)
    np.testing.assert_array_equal(np.asarray(two_d), np.asarray(one_d))
    np.testing.assert_array_equal(np.asarray(two_s), np.asarray(one_s))


def test_dequant_stats_two_level_bitwise():
    p = jnp.asarray(RNG.integers(-127, 127, size=(11, 3000)), jnp.int8)
    m = jnp.asarray(RNG.random(11).astype(np.float32))
    base_d, base_s = ops.dequant_stats(p, m, d_tile=512, macro_tile=512)
    dd, ss = ops.dequant_stats(p, m, d_tile=512, macro_tile=2048)
    np.testing.assert_array_equal(np.asarray(dd), np.asarray(base_d))
    np.testing.assert_array_equal(np.asarray(ss), np.asarray(base_s))


# ------------------------------------------------------- rectangular stats
def test_pairwise_stats_rect_matches_square_rows():
    """The §10 shard kernel: each row block of the rect kernel must be
    bitwise-identical to the matching rows of the square kernel (same
    inner tile policy + row-subset gemm determinism)."""
    x = jnp.asarray(RNG.normal(size=(13, 3000)).astype(np.float32))
    dd, sq = ops.pairwise_stats(x)
    for start, stop in ((0, 4), (4, 9), (9, 13)):
        rdd, rsq = ops.pairwise_stats_rect(x[start:stop], x)
        assert rdd.shape == (stop - start, 13) and rsq.shape == (13,)
        np.testing.assert_array_equal(np.asarray(rdd),
                                      np.asarray(dd)[start:stop])
        np.testing.assert_array_equal(np.asarray(rsq), np.asarray(sq))


def test_dequant_stats_rect_matches_square_rows():
    p = jnp.asarray(RNG.integers(-127, 127, size=(11, 2300)), jnp.int8)
    m = jnp.asarray(RNG.random(11).astype(np.float32))
    dd, sq = ops.dequant_stats(p, m)
    rdd, rsq = ops.dequant_stats_rect(p[3:8], m[3:8], p, m)
    np.testing.assert_array_equal(np.asarray(rdd), np.asarray(dd)[3:8])
    np.testing.assert_array_equal(np.asarray(rsq), np.asarray(sq))
    pb = jnp.asarray(RNG.normal(size=(11, 500)).astype(np.float32)
                     ).astype(jnp.bfloat16)
    mb = jnp.ones((11,), jnp.float32)
    dd2, sq2 = ops.dequant_stats(pb, mb)
    rdd2, rsq2 = ops.dequant_stats_rect(pb[:5], mb[:5], pb, mb)
    np.testing.assert_array_equal(np.asarray(rdd2), np.asarray(dd2)[:5])
    np.testing.assert_array_equal(np.asarray(rsq2), np.asarray(sq2))


def test_dequant_stats_rect_rejects_mixed_payloads():
    p8 = jnp.zeros((8, 256), jnp.int8)
    pb = jnp.zeros((8, 256), jnp.bfloat16)
    m = jnp.ones((8,), jnp.float32)
    with pytest.raises(ValueError):
        ops.dequant_stats_rect(p8[:4], m[:4], pb, m)


# ---------------------------------------------------------------- autotuner
def test_autotune_d_tile_lane_aligned_and_budgeted():
    for rows in (8, 24, 64, 200):
        for d in (1, 100, 4096, 10_000_000):
            t = ops.autotune_d_tile(rows, d)
            assert t % 128 == 0 and t >= 128
            # padded-d cap: never wider than the lane-rounded operand
            assert t <= max(128, ((d - 1) // 128 + 1) * 128)
            if t > 128:  # above the floor the working set obeys the budget
                assert 2 * rows * t * 4 <= ops.VMEM_BUDGET_BYTES


def test_autotune_d_tile_monotone_in_rows():
    wide = ops.autotune_d_tile(8, 10_000_000)
    narrow = ops.autotune_d_tile(512, 10_000_000)
    assert narrow <= wide
    with pytest.raises(ValueError):
        ops.autotune_d_tile(0, 128)


def test_two_level_tiles_aligned_budgeted_and_never_deeper():
    for rows, d in ((16, 257), (16, 100_000), (16, 1_000_000),
                    (64, 500_000)):
        dt, macro = ops.two_level_tiles(rows, d, out_rows=1,
                                        scratch_rows=100, fixed_bytes=4096)
        assert dt % 128 == 0 and macro % dt == 0
        if (dt, macro) != (128, 128):   # above the degenerate floor
            assert (2 * (rows + 1) * 4 * macro + (100 + rows) * 4 * dt
                    + 4096) <= ops.VMEM_BUDGET_BYTES
        # the whole point: never more outer steps than single-level
        assert -(-d // macro) <= -(-d // dt)
        # never wider than the padded operand
        assert macro <= ((d - 1) // dt + 1) * dt


def test_two_level_tiles_deep_launch_is_macro_resident():
    # the d=1e6 launch runs a multi-window macro block with a wide inner
    # window (the _MIN_D_TILE floor: tiny windows lose to loop overhead)
    dt, macro = ops.fused_select_tiles(16, 1_000_000, 7)
    assert dt >= ops._MIN_D_TILE
    assert macro >= 4 * dt
    # stats keep their PR-2 inner tile and only grow the macro block
    sdt, smacro = ops._stats_tiles(16, 1_000_000)
    assert sdt == ops.autotune_d_tile(16, 1_000_000,
                                      fixed_bytes=16 * 24 * 4)
    assert smacro > sdt and smacro % sdt == 0


def test_ops_interpret_resolved_outside_jit(monkeypatch):
    """Regression for the trace-time-baking bug: the backend/override must
    be resolved in the unjitted wrapper and reach the kernel as a static
    argument — not be re-evaluated (and cached) inside the trace."""
    seen = []
    real = ops.pairwise_sqdist_pallas

    def spy(x, *, d_tile, interpret):
        seen.append(interpret)
        return real(x, d_tile=d_tile, interpret=True)  # CPU can only interpret

    monkeypatch.setattr(ops, "pairwise_sqdist_pallas", spy)
    x = jnp.asarray(RNG.normal(size=(5, 133)).astype(np.float32))
    # unique d_tile values force fresh traces through the spy
    ops.pairwise_sqdist(x, d_tile=256, interpret=False)
    ops.pairwise_sqdist(x, d_tile=384)                # default: CPU backend
    assert seen == [False, True]
