"""Unit coverage for dist/sharding.py and launch/mesh.py heuristics —
previously the only untested ``dist`` module (PR-5 satellite)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (_worker_axes, batch_specs,
                                 grad_stack_specs, sanitize_spec)
from repro.launch.mesh import data_parallel_size, make_host_mesh


class FakeMesh:
    """Shape-only stand-in (sanitize_spec/_worker_axes read ``.shape``)."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


# --------------------------------------------------------- sanitize_spec
def test_sanitize_spec_drops_non_dividing_dims():
    mesh = FakeMesh(data=16, model=16)
    # 51865 % 16 != 0 -> the model entry drops to replicated
    assert tuple(sanitize_spec(P(None, "model"), (384, 51865), mesh)) == \
        (None, None)
    assert tuple(sanitize_spec(P(None, "model"), (384, 51872), mesh)) == \
        (None, "model")


def test_sanitize_spec_tuple_entries_use_axis_product():
    mesh = FakeMesh(pod=2, data=16, model=16)
    # ("pod", "data") needs divisibility by 32
    ok = sanitize_spec(P(("pod", "data"), None), (64, 7), mesh)
    assert tuple(ok) == (("pod", "data"), None)
    bad = sanitize_spec(P(("pod", "data"), None), (48, 7), mesh)
    assert tuple(bad) == (None, None)


def test_sanitize_spec_rank_overflow_drops():
    """A spec entry past the shape's rank cannot divide anything."""
    mesh = FakeMesh(data=2, model=2)
    s = sanitize_spec(P(None, "model"), (4,), mesh)
    assert tuple(s) == (None, None)


def test_sanitize_spec_preserves_none_entries():
    mesh = FakeMesh(data=4, model=4)
    s = sanitize_spec(P(None, None, "model"), (3, 5, 8), mesh)
    assert tuple(s) == (None, None, "model")


# ----------------------------------------------------------- worker axes
def test_worker_axes_pod_vs_single_pod():
    assert _worker_axes(FakeMesh(pod=2, data=16, model=16)) == \
        ("pod", "data")
    assert _worker_axes(FakeMesh(data=16, model=16)) == "data"
    assert _worker_axes(None) == "data"


def test_data_parallel_size_multiplies_pod():
    assert data_parallel_size(FakeMesh(data=16, model=16)) == 16
    assert data_parallel_size(FakeMesh(pod=2, data=16, model=16)) == 32


def test_make_host_mesh_factors_devices():
    mesh = make_host_mesh()
    sizes = dict(mesh.shape)
    assert set(mesh.axis_names) == {"data", "model"}
    assert sizes["data"] * sizes["model"] == len(jax.devices())
    assert sizes["data"] <= sizes["model"]


# ------------------------------------------------------------ spec trees
@pytest.fixture
def host_mesh():
    return make_host_mesh()


def test_batch_specs_lead_axis(host_mesh):
    import jax.numpy as jnp
    n = dict(host_mesh.shape)["data"]
    batch = {"tokens": jnp.zeros((4 * n, 16), jnp.int32)}
    specs = batch_specs(batch, host_mesh)
    spec = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert tuple(spec)[0] in ("data", ("data",), None)
    # a leading axis the mesh cannot divide stays replicated
    odd = {"tokens": jnp.zeros((3, 16), jnp.int32)}
    if n > 3:
        spec = jax.tree.leaves(batch_specs(odd, host_mesh),
                               is_leaf=lambda x: isinstance(x, P))[0]
        assert tuple(spec)[0] is None


def test_grad_stack_specs_shift_param_spec_right(host_mesh):
    import jax.numpy as jnp
    msize = dict(host_mesh.shape)["model"]
    params = {"w": jnp.zeros((8 * msize, 4 * msize), jnp.float32)}
    specs = grad_stack_specs(params, host_mesh)
    spec = tuple(jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))[0])
    # (n, *param): dim 0 is the worker axis, the tp entry moved right
    assert len(spec) == 3
    assert "model" not in (spec[0],)
