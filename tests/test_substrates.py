"""Substrate tests: optimizers, schedules, data, checkpointing, losses,
attention primitives, and the fused-vs-unfused aggregation substrates."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.data import classification_batches, lm_batches, make_lm_batch
from repro.models import attention as A
from repro.models import modules as M
from repro.models.losses import chunked_xent
from repro.optim import adamw, constant, sgd, warmup_cosine
from repro.configs.base import ArchConfig

KEY = jax.random.key(0)


# ------------------------------------------------------------- optimizers
def test_sgd_momentum_matches_manual():
    params = {"w": jnp.asarray([1.0, 2.0])}
    grads = {"w": jnp.asarray([0.5, -1.0])}
    opt = sgd(momentum=0.9)
    st = opt.init(params)
    p1, st = opt.update(grads, st, params, 0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), [1 - 0.05, 2 + 0.1])
    p2, st = opt.update(grads, st, p1, 0.1)
    # m2 = 0.9*0.5 + 0.5 = 0.95 ; p = 0.95 - 0.1*0.95
    np.testing.assert_allclose(np.asarray(p2["w"])[0], 0.95 - 0.095,
                               rtol=1e-6)


def test_adamw_first_step_is_lr_sized():
    params = {"w": jnp.asarray([0.0])}
    grads = {"w": jnp.asarray([123.0])}
    opt = adamw()
    st = opt.init(params)
    p1, _ = opt.update(grads, st, params, 1e-3)
    # bias-corrected first step ≈ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(p1["w"]), [-1e-3], rtol=1e-4)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(fn(jnp.int32(0))) == 0.0
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(jnp.int32(100))) < 0.2


# ------------------------------------------------------------------ data
def test_lm_batches_deterministic_and_learnable():
    a = next(lm_batches(64, 4, 16, seed=5))
    b = next(lm_batches(64, 4, 16, seed=5))
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                  np.asarray(a["labels"][:, :-1]))
    # bigram automaton: each token has <= branching successors => the
    # empirical conditional entropy is far below uniform
    batch = make_lm_batch(KEY, 64, 64, 128, seed=5)
    toks = np.asarray(batch["tokens"])
    pairs = set(zip(toks[:, :-1].ravel().tolist(), toks[:, 1:].ravel().tolist()))
    succ = {}
    for a_, b_ in pairs:
        succ.setdefault(a_, set()).add(b_)
    assert max(len(v) for v in succ.values()) <= 4


def test_classification_batches_separable():
    it = classification_batches(8, 3, 64, seed=1, noise=0.1)
    x, y = next(it)
    assert x.shape == (64, 8) and y.shape == (64,)
    # same-class points cluster: intra-class distance << inter-class
    x, y = np.asarray(x), np.asarray(y)
    mus = np.stack([x[y == c].mean(0) for c in range(3)])
    intra = np.mean([np.linalg.norm(x[y == c] - mus[c], axis=1).mean()
                     for c in range(3)])
    inter = np.linalg.norm(mus[0] - mus[1])
    assert inter > intra


# ------------------------------------------------------------ checkpoints
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)},
            "d": [jnp.ones((4,), jnp.bfloat16)]}
    d = str(tmp_path / "ck")
    save(d, 7, tree)
    assert latest_step(d) == 7
    back = restore(d, 7, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    save(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore(d, 1, {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------- losses
@pytest.mark.parametrize("chunk", [4, 16, 1 << 20])
def test_chunked_xent_matches_naive(chunk):
    b, s, d, v = 2, 9, 8, 32
    x = jax.random.normal(KEY, (b, s, d), jnp.float32)
    labels = jax.random.randint(jax.random.key(1), (b, s), 0, v)
    w = jax.random.normal(jax.random.key(2), (d, v), jnp.float32)
    got = chunked_xent(x, labels, {"lm_head": {"w": w}}, tied=False,
                       chunk=chunk)
    logits = x @ w
    lf = logits.astype(jnp.float32)
    want = jnp.mean(jax.nn.logsumexp(lf, -1) -
                    jnp.take_along_axis(lf, labels[..., None], -1)[..., 0])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_xent_mask():
    b, s, d, v = 1, 6, 4, 16
    x = jax.random.normal(KEY, (b, s, d), jnp.float32)
    labels = jax.random.randint(jax.random.key(1), (b, s), 0, v)
    w = jax.random.normal(jax.random.key(2), (d, v), jnp.float32)
    mask = jnp.asarray([[1, 1, 0, 0, 0, 0]], jnp.float32)
    got = chunked_xent(x, labels, {"lm_head": {"w": w}}, tied=False,
                       mask=mask, chunk=3)
    got_full = chunked_xent(x[:, :2], labels[:, :2],
                            {"lm_head": {"w": w}}, tied=False, chunk=3)
    np.testing.assert_allclose(float(got), float(got_full), rtol=1e-5)


# -------------------------------------------------------------- attention
def test_rope_preserves_norm_and_relativity():
    cfg = ArchConfig(name="t", family="dense", d_model=32, n_heads=2,
                     n_kv_heads=2, rope="full")
    x = jax.random.normal(KEY, (1, 6, 2, 16), jnp.float32)
    pos = jnp.arange(6)[None]
    y = A.apply_rope(x, pos, cfg)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relativity: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(3), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(4), (1, 1, 1, 16))
    def dot(i, j):
        qi = A.apply_rope(q, jnp.asarray([[i]]), cfg)
        kj = A.apply_rope(k, jnp.asarray([[j]]), cfg)
        return float(jnp.vdot(qi, kj))
    assert dot(3, 1) == pytest.approx(dot(7, 5), rel=1e-4)


def test_partial_rope_rotates_half():
    cfg = ArchConfig(name="t", family="dense", d_model=32, n_heads=2,
                     n_kv_heads=2, rope="partial", rope_fraction=0.5)
    x = jnp.ones((1, 2, 1, 16), jnp.float32)
    y = A.apply_rope(x, jnp.asarray([[0, 5]]), cfg)
    # second half of head_dim untouched
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(y[0, 1, 0, :8]),
                           np.asarray(x[0, 1, 0, :8]))


def test_attend_full_causality_and_window():
    b, s, h, hd = 1, 8, 2, 4
    q = jax.random.normal(KEY, (b, s, h, hd))
    k = jax.random.normal(jax.random.key(1), (b, s, h, hd))
    v = jax.random.normal(jax.random.key(2), (b, s, h, hd))
    full = A.attend_full(q, k, v, causal=True, chunk_q=4)
    # causality: changing the future does not change the past
    k2 = k.at[:, 6:].set(7.0)
    v2 = v.at[:, 6:].set(7.0)
    full2 = A.attend_full(q, k2, v2, causal=True, chunk_q=4)
    np.testing.assert_allclose(np.asarray(full[:, :6]),
                               np.asarray(full2[:, :6]), rtol=1e-5, atol=1e-5)
    # window=1: each position attends only to itself => out = v
    w1 = A.attend_full(q, k, v, causal=True, window=1, chunk_q=4)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(v), rtol=1e-4,
                               atol=1e-4)


def test_gqa_expand_kv_grouping():
    b, s, hkv, hd, h = 1, 3, 2, 4, 6
    k = jax.random.normal(KEY, (b, s, hkv, hd))
    ke = A._expand_kv(k, h)
    assert ke.shape == (b, s, h, hd)
    # heads 0..2 share kv head 0
    np.testing.assert_array_equal(np.asarray(ke[:, :, 0]),
                                  np.asarray(ke[:, :, 2]))
    np.testing.assert_array_equal(np.asarray(ke[:, :, 3]),
                                  np.asarray(ke[:, :, 5]))


# =================================================================
# fused apply substrate: kernel edge shapes + bitwise agreement with
# the unfused plan/apply path (interpret mode).  The grid covers:
# n not a multiple of 8 (7, 11, 15), d not a multiple of 128 and
# smaller than d_tile (1, 100, 257), the even-θ median branch
# (n=12, f=2 → θ=6), and β = θ (f=0 → β = θ = n-2).
# =================================================================
_RNG_SUB = np.random.default_rng(23)
EDGE_GRID = [(7, 1), (11, 2), (15, 3), (12, 2), (6, 0)]


def _edge_stack(n, d):
    G = _RNG_SUB.normal(size=(n, d)).astype(np.float32)
    G[: max(1, n // 5)] *= 20.0       # some rows far out, like an attack
    return jnp.asarray(G)


@pytest.mark.parametrize("rule", ["multi_krum", "multi_bulyan"])
@pytest.mark.parametrize("n,f", EDGE_GRID)
@pytest.mark.parametrize("d", [100, 257])
def test_fused_apply_bitwise_vs_unfused(rule, n, f, d):
    """Same plan, fused Pallas apply ≡ unfused XLA apply, bit for bit."""
    from repro.core import api
    agg = api.get_aggregator(rule)
    if n < agg.min_n(f):
        pytest.skip("below the rule's resilience precondition")
    G = _edge_stack(n, d)
    stats = api.compute_stats(G, f, needs_dists=agg.needs_dists)
    plan = agg.plan(stats)
    unfused = np.asarray(agg.apply(plan, G, use_pallas=False))
    fused = np.asarray(agg.apply(plan, G, use_pallas=True, fused=True))
    np.testing.assert_array_equal(unfused, fused)


@pytest.mark.parametrize("n,f", EDGE_GRID)
def test_fused_apply_degenerate_width(n, f):
    """d=1 (single coordinate): XLA lowers the unfused einsum to a gemv
    with a different k-reduction order, so agreement is to the last ulp
    rather than bitwise — the fused path itself is tile-invariant."""
    from repro.core import api
    G = _edge_stack(n, 1)
    stats = api.compute_stats(G, f, needs_dists=True)
    plan = api.get_aggregator("multi_bulyan").plan(stats)
    agg = api.get_aggregator("multi_bulyan")
    unfused = np.asarray(agg.apply(plan, G, use_pallas=False))
    fused = np.asarray(agg.apply(plan, G, use_pallas=True, fused=True))
    np.testing.assert_allclose(unfused, fused, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,f", [(12, 2), (6, 0)])
def test_fused_apply_theta_branches(n, f):
    """Even-θ median and β = θ hit the fused kernel's special branches."""
    from repro.core import api
    theta = n - 2 * f - 2
    beta = theta - 2 * f
    if f == 0:
        assert beta == theta          # β = θ: selection keeps every row
    else:
        assert theta % 2 == 0         # even-θ median: midpoint average
    G = _edge_stack(n, 257)
    plan = api.get_aggregator("multi_bulyan").plan(
        api.compute_stats(G, f, needs_dists=True))
    assert plan.beta == beta and plan.w_ext.shape == (theta, n)
    fused = np.asarray(api.get_aggregator("multi_bulyan").apply(
        plan, G, use_pallas=True, fused=True))
    unfused = np.asarray(api.get_aggregator("multi_bulyan").apply(
        plan, G, use_pallas=False))
    np.testing.assert_array_equal(unfused, fused)


@pytest.mark.parametrize("n,f", [(11, 2), (12, 2)])
def test_fused_full_pipeline_bitwise_on_trees(n, f):
    """End-to-end aggregate_tree: fused vs two-step Pallas on a pytree,
    sharing the Pallas statistics path (single-pass kernel)."""
    from repro.core import api
    d = 300
    G = _edge_stack(n, d)
    tree = {"a": G[:, :120].reshape(n, 8, 15), "b": {"c": G[:, 120:]}}
    fused = api.aggregate_tree(tree, f, "multi_bulyan", use_pallas=True,
                               fused=True)
    twostep = api.aggregate_tree(tree, f, "multi_bulyan", use_pallas=True,
                                 fused=False)
    for a, b in zip(jax.tree.leaves(fused), jax.tree.leaves(twostep)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_single_pass_stats_matches_two_pass():
    """compute_stats' fused statistics ≡ separate dists + norms passes."""
    from repro.core import api
    n, d = 11, 500
    G = _edge_stack(n, d)
    tree = {"a": G[:, :200], "b": G[:, 200:].reshape(n, 10, 30)}
    stats = api.compute_stats(tree, 2, needs_dists=True, needs_norms=True)
    np.testing.assert_allclose(
        np.asarray(stats.dists), np.asarray(api.tree_pairwise_sqdist(tree)),
        rtol=1e-6, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(stats.sq_norms), np.asarray(api.tree_sq_norms(tree)),
        rtol=1e-5, atol=1e-4)
    # pallas single-pass agrees with the XLA single-pass
    ds, sq = api.tree_pairwise_stats(tree, use_pallas=True)
    scale = max(float(jnp.max(stats.dists)), 1.0)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(stats.dists),
                               rtol=0, atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(stats.sq_norms),
                               rtol=1e-5, atol=1e-5 * scale)
